//! Profiling workload for the §Perf pass: the paper SoC fully saturated
//! (11 TGs, NoC@100MHz) for 30 ms of simulated time. Use with:
//!
//!   cargo build --release --example perfprobe
//!   perf record ./target/release/examples/perfprobe && perf report

fn main() {
    let cfg = vespa::config::presets::paper_soc(("dfadd", 1), ("dfadd", 1));
    let mut soc =
        vespa::sim::Soc::build(cfg, Box::new(vespa::runtime::RefCompute::new())).unwrap();
    soc.host_set_tg_active(11);
    let t0 = std::time::Instant::now();
    soc.run_for(30_000_000_000);
    let wall = t0.elapsed().as_secs_f64();
    let router_cycles = soc.islands[0].cycles * 48;
    println!(
        "edges {} flits {} | {:.2} M edges/s, {:.2} M router-cycles/s",
        soc.edges,
        soc.fabric.total_flits(),
        soc.edges as f64 / wall / 1e6,
        router_cycles as f64 / wall / 1e6
    );
}
