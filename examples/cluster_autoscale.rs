//! Cluster example: one workload, an elastic fleet. A diurnal ramp
//! (quiet base load with periodic bursts) plus a flash crowd drive a
//! 4-slot fleet of paper SoCs; the SLO-driven autoscaler grows the
//! fleet into each burst and drains it back through the troughs, and
//! the merged report prices the run in replica-seconds against the
//! fixed-maximum alternative.
//!
//!   cargo run --release --example cluster_autoscale

use vespa::cluster::{AutoscaleSpec, ClusterSpec};
use vespa::config::presets::paper_soc;
use vespa::report::{plot, Table};
use vespa::scenario::ms;
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};

fn main() -> vespa::Result<()> {
    let slo = ms(5);
    let cfg = || paper_soc(("dfmul", 2), ("dfmul", 2));

    let mut summary = Table::new(
        "elastic fleet vs fixed fleets — dfmul paper SoC, JSQ balancer",
        &["fleet", "phase", "achieved rps", "p95 ms", "SLO", "repl-s", "final active"],
    );
    let mut row = |name: &str, phase: &str, r: &vespa::cluster::ClusterReport| {
        summary.row(&[
            name.to_string(),
            phase.to_string(),
            format!("{:.0}", r.achieved_rps),
            format!("{:.3}", r.latency.p95_ms()),
            match r.slo_met {
                Some(true) => "met",
                Some(false) => "miss",
                None => "-",
            }
            .to_string(),
            format!("{:.4}", r.replica_seconds),
            r.final_active.to_string(),
        ]);
    };

    // Phase 1 — diurnal ramp: 600 rps base, 5000 rps bursts for 40% of
    // each 60 ms "day". One ~4250 req/s SoC overloads in every burst.
    let diurnal = ServeSpec::new(
        Arrival::Burst {
            base_rps: 600.0,
            burst_rps: 5000.0,
            period: ms(60),
            duty: 0.4,
        },
        ms(300),
    )
    .policy(DispatchPolicy::JoinShortestQueue)
    .slo(slo)
    .sample_interval(ms(2))
    .seed(0xD1A);

    let fixed_max = ClusterSpec::new(4, diurnal.clone()).run(cfg())?;
    row("fixed-4", "diurnal", &fixed_max);
    let elastic = ClusterSpec::new(4, diurnal)
        .autoscale(AutoscaleSpec::new(1))
        .run(cfg())?;
    row("auto 1..4", "diurnal", &elastic);
    println!("{}", elastic.render());
    println!("fleet size during the diurnal phase:");
    println!("{}", plot(&[&elastic.active_replicas], 70, 8));
    println!(
        "diurnal cost: autoscaled {:.4} replica-seconds vs fixed-max {:.4} ({:.0}% saved)\n",
        elastic.replica_seconds,
        fixed_max.replica_seconds,
        100.0 * (1.0 - elastic.replica_seconds / fixed_max.replica_seconds)
    );

    // Phase 2 — flash crowd: a quiet 400 rps stream that spikes to
    // 12000 rps for one 50 ms burst mid-run, then vanishes.
    let mut arrivals = Arrival::Poisson { rps: 400.0 }.times(0xF1A5, ms(250));
    arrivals.extend(Arrival::Poisson { rps: 12_000.0 }.times(0xC20, ms(50)).iter().map(|t| t + ms(100)));
    arrivals.sort_unstable();
    let flash = ServeSpec::new(Arrival::Trace(arrivals), ms(250))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(slo)
        .sample_interval(ms(2))
        .seed(0xF1A5);

    let crowd = ClusterSpec::new(4, flash)
        .autoscale(AutoscaleSpec::new(1))
        .run(cfg())?;
    row("auto 1..4", "flash crowd", &crowd);
    println!("fleet size through the flash crowd:");
    println!("{}", plot(&[&crowd.active_replicas], 70, 8));
    println!(
        "flash crowd: {} autoscale actions, spilled {} at the balancer",
        crowd.autoscale_actions.len(),
        crowd.spilled
    );

    println!("{}", summary.render());
    println!("cluster_autoscale OK");
    Ok(())
}
