//! Monitoring example: run the paper SoC under load and dump every
//! hardware counter both through the host path and through MMIO
//! addresses (the two access paths §II-C describes), plus the reactive
//! DFS policy acting on live RTT readings.
//!
//!   cargo run --release --example monitor_dump

use vespa::config::presets::{paper_soc, A1_POS, A2_POS};
use vespa::monitor::mmio::{counter_addr, CounterReg};
use vespa::policy::{run_with_policy, ReactiveDfs};
use vespa::report::Table;
use vespa::scenario::{ms, Session};

fn main() -> vespa::Result<()> {
    let mut cfg = paper_soc(("adpcm", 2), ("dfmul", 4));
    cfg.cpu_poll_interval = 200; // CPU softly polls over the config plane
    let mut session = Session::new(cfg)?;
    let a1 = session.tile_at(A1_POS.0, A1_POS.1);
    let a2 = session.tile_at(A2_POS.0, A2_POS.1);
    session
        .stage(a1, 1)?
        .stage(a2, 1)?
        .perf_only()
        .with_tg_load(8)
        .freq(0, 20)?; // stress the NoC island

    // Run with the reactive policy watching A2's round-trip times.
    let mut pol = ReactiveDfs::new(0, vec![a2], 3_000.0, 300.0);
    run_with_policy(session.soc_mut(), &mut pol, ms(20), ms(200))?;

    let soc = session.soc();
    let mut t = Table::new(
        "hardware counters (host/USB path)",
        &["tile", "kind", "exec_cycles", "inv", "pkts_in", "pkts_out", "rtt_ns", "rtt_cnt"],
    );
    for (i, tile) in soc.tiles.iter().enumerate() {
        let c = soc.mon.tile(i);
        if c.pkts_in + c.pkts_out == 0 {
            continue;
        }
        t.row(&[
            i.to_string(),
            tile.kind_name().to_string(),
            c.exec_cycles.to_string(),
            c.invocations.to_string(),
            c.pkts_in.to_string(),
            c.pkts_out.to_string(),
            format!("{:.0}", c.rtt_mean() / 1e3),
            c.rtt_count.to_string(),
        ]);
    }
    println!("{}", t.render());

    // The same values through the MMIO register map.
    println!("MMIO map spot-check for tile {a2}:");
    for reg in [CounterReg::ExecTime, CounterReg::PktsIn, CounterReg::RttCnt] {
        let addr = counter_addr(a2, reg);
        println!(
            "  [{addr:#010x}] {:?} = {}",
            reg,
            soc.host_read_counter(a2, reg)
        );
    }

    println!(
        "reactive DFS: {} frequency actions, final NoC = {} MHz",
        pol.actions.len(),
        soc.islands[0].freq(soc.now).as_mhz()
    );
    println!(
        "mem totals: {} pkts in, {} data beats",
        soc.mon.mem_pkts_in, soc.mon.mem_beats_in
    );
    assert!(soc.mon.mem_pkts_in > 0);
    println!("monitor_dump OK");
    Ok(())
}
