//! Chaos example: the same fleet, three postures toward failure. A
//! deterministic fault plan — a tile slowdown, a stuck DFS actuator,
//! then a full replica crash mid-run — hits a 2-slot fleet of paper
//! SoCs serving steady Poisson traffic:
//!
//! * **bare** — no resilience: the crash kills a replica for good and
//!   its in-flight requests with it;
//! * **retry** — per-request deadlines with exponential backoff
//!   re-admit interrupted requests, but the fleet stays down a slot;
//! * **retry+health** — health checks spot the dead slot and replace
//!   it from the warm-standby snapshot, so capacity (and the SLO)
//!   recover too.
//!
//! The fault ledger in each report shows the arithmetic: what was
//! injected, what was lost, what came back.
//!
//!   cargo run --release --example chaos_serving

use vespa::cluster::ClusterSpec;
use vespa::config::presets::paper_soc;
use vespa::fault::{FaultPlan, HealthSpec, RetrySpec};
use vespa::report::Table;
use vespa::scenario::{ms, Session};
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};

fn main() -> vespa::Result<()> {
    let cfg = || paper_soc(("dfmul", 2), ("dfmul", 2));

    // Aim the component faults at the first accelerator tile and its
    // DFS island — resolved from the config, not hard-coded.
    let session = Session::new(cfg())?;
    let tile = session.mra_tiles()[0];
    let soc_cfg = &session.soc().cfg;
    let island = soc_cfg
        .tiles
        .iter()
        .find(|t| soc_cfg.node_of(t.x, t.y) == tile)
        .map(|t| t.island)
        .expect("the MRA tile has a spec");
    drop(session);

    // The plan: replica 0's accelerator runs at quarter speed from
    // 20 ms, the island's DFS actuator wedges meanwhile, and at 60 ms
    // the whole replica crashes. Same seed + plan => same run, every
    // time, on every engine and thread count.
    let plan = FaultPlan::parse(&format!(
        "slow@t{tile}@r0:at=20ms,dur=30ms,factor=4;\
         stuck@i{island}@r0:at=20ms,dur=30ms;\
         crash@r0:at=60ms"
    ))?;

    let serve = ServeSpec::new(Arrival::Poisson { rps: 2500.0 }, ms(200))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0xC4A05)
        .faults(plan);
    let retry = RetrySpec::new(4, 500_000_000).deadline(ms(50)); // 500 us backoff

    let bare = ClusterSpec::new(2, serve.clone()).run(cfg())?;
    let retried = ClusterSpec::new(2, serve.clone().retry(retry.clone())).run(cfg())?;
    let healed = ClusterSpec::new(2, serve.retry(retry))
        .health(HealthSpec::new())
        .run(cfg())?;

    let mut summary = Table::new(
        "one crash, three postures — dfmul paper SoC, JSQ balancer",
        &["posture", "completed", "p95 ms", "SLO", "lost", "rescued", "failed over"],
    );
    for (name, r) in [("bare", &bare), ("retry", &retried), ("retry+health", &healed)] {
        summary.row(&[
            name.to_string(),
            r.completed.to_string(),
            format!("{:.3}", r.latency.p95_ms()),
            match r.slo_met {
                Some(true) => "met",
                Some(false) => "miss",
                None => "-",
            }
            .to_string(),
            r.faults.lost.to_string(),
            r.faults.rescued.to_string(),
            r.faults.failed_over.to_string(),
        ]);
    }
    println!("{}", summary.render());

    println!("full report, retry+health posture:\n");
    println!("{}", healed.render());
    println!(
        "rescued fraction {:.3} — the ledger's bottom line",
        healed.faults.rescued_fraction()
    );
    Ok(())
}
