//! Fig.-4-style DFS schedule example: drive island frequencies through a
//! timed program while sampling memory traffic, then print the time
//! series — the run-time optimization loop in miniature.
//!
//!   cargo run --release --example dfs_schedule

use vespa::config::presets::{paper_soc, ISL_A1, ISL_NOC, ISL_TG};
use vespa::policy::{run_with_policy, DfsPolicy, StaticSchedule};
use vespa::report::plot;
use vespa::scenario::{ms, Session};

fn main() -> vespa::Result<()> {
    let mut cfg = paper_soc(("dfmul", 4), ("dfmul", 4));
    cfg.islands[ISL_NOC].freq_mhz = 20;
    cfg.islands[ISL_TG].freq_mhz = 10;
    let mut session = Session::new(cfg)?;
    session
        .stage_all(1)?
        .perf_only()
        .with_tg_load(11)
        .sample_every(ms(1));

    // A three-act schedule: accel step (no traffic effect), TG boost,
    // NoC boost (big traffic effect).
    let mut sched = StaticSchedule::new(vec![
        (ms(10), ISL_A1, 50),
        (ms(30), ISL_TG, 50),
        (ms(50), ISL_NOC, 100),
    ]);
    run_with_policy(session.soc_mut(), &mut sched, ms(1), ms(80))?;
    println!("schedule: {} steps applied, {} rejected ({})", 3, sched.rejected, sched.name());

    let sampler = session.soc().sampler.as_ref().unwrap();
    let rate = sampler.series("mem_pkts_in").unwrap().to_rate();
    println!("{}", plot(&[&rate], 70, 14));

    let early = rate.mean_in(ms(5), ms(25));
    let late = rate.mean_in(ms(60), ms(80));
    println!(
        "mem traffic: {:.2} Mpkt/s before the TG/NoC boost, {:.2} Mpkt/s after",
        early / 1e6,
        late / 1e6
    );
    assert!(late > early * 1.5, "boost must raise memory pressure");
    println!("dfs_schedule OK");
    Ok(())
}
