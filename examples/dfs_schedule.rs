//! Fig.-4-style DFS schedule example: drive island frequencies through a
//! timed program while sampling memory traffic, then print the time
//! series — the run-time optimization loop in miniature.
//!
//!   cargo run --release --example dfs_schedule

use vespa::config::presets::{paper_soc, ISL_A1, ISL_NOC, ISL_TG};
use vespa::policy::{run_with_policy, DfsPolicy, StaticSchedule};
use vespa::report::plot;
use vespa::runtime::RefCompute;
use vespa::sim::{stage_inputs_for, Soc};

fn main() -> vespa::Result<()> {
    let mut cfg = paper_soc(("dfmul", 4), ("dfmul", 4));
    cfg.islands[ISL_NOC].freq_mhz = 20;
    cfg.islands[ISL_TG].freq_mhz = 10;
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new()))?;
    for t in soc.mra_tiles() {
        stage_inputs_for(&mut soc, t, 1);
        soc.mra_mut(t).functional_every_invocation = false;
    }
    soc.host_set_tg_active(11);
    soc.enable_sampler(1_000_000_000); // 1 ms samples

    // A three-act schedule: accel step (no traffic effect), TG boost,
    // NoC boost (big traffic effect).
    let ms = 1_000_000_000u64;
    let mut sched = StaticSchedule::new(vec![
        (10 * ms, ISL_A1, 50),
        (30 * ms, ISL_TG, 50),
        (50 * ms, ISL_NOC, 100),
    ]);
    run_with_policy(&mut soc, &mut sched, ms, 80 * ms);
    println!("schedule: {} steps applied, {} rejected ({})", 3, sched.rejected, sched.name());

    let sampler = soc.sampler.as_ref().unwrap();
    let rate = sampler.series("mem_pkts_in").unwrap().to_rate();
    println!("{}", plot(&[&rate], 70, 14));

    let early = rate.mean_in(5 * ms, 25 * ms);
    let late = rate.mean_in(60 * ms, 80 * ms);
    println!(
        "mem traffic: {:.2} Mpkt/s before the TG/NoC boost, {:.2} Mpkt/s after",
        early / 1e6,
        late / 1e6
    );
    assert!(late > early * 1.5, "boost must raise memory pressure");
    println!("dfs_schedule OK");
    Ok(())
}
