//! Quickstart: the Scenario/Session API end to end.
//!
//! Builds a 4x4 SoC with the fluent [`Scenario`] builder (dfmul 2x near
//! memory, gsm 1x far from it), loads the AOT-compiled PJRT artifacts if
//! available (native oracle otherwise), then drives two declarative
//! phases — NoC at 100 MHz, then a run-time DFS drop to 20 MHz — and
//! reads back typed [`PhaseReport`]s plus the functional outputs.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! [`Scenario`]: vespa::scenario::Scenario
//! [`PhaseReport`]: vespa::scenario::PhaseReport

use vespa::runtime::{AccelCompute, PjrtCompute, RefCompute};
use vespa::scenario::{ms, Scenario, Session};

fn main() -> vespa::Result<()> {
    // 1. Functional backend: PJRT artifacts when built, else native.
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend: Box<dyn AccelCompute> = if cfg!(feature = "pjrt")
        && artifacts.join("manifest.txt").exists()
    {
        println!("backend: PJRT (artifacts/)");
        Box::new(PjrtCompute::load(&artifacts)?)
    } else {
        println!("backend: native reference (`make artifacts` + --features pjrt for PJRT)");
        Box::new(RefCompute::new())
    };

    // 2. Compose the SoC: 4x4 grid, three frequency islands, dfmul 2x
    //    adjacent to MEM, gsm 1x in the far corner, TGs everywhere else.
    let cfg = Scenario::grid(4, 4)
        .island_dfs("noc-mem", 100, 10..=100, 5)
        .island_dfs("acc", 50, 10..=50, 5)
        .island("sys", 50)
        .mem_at(0, 0)
        .cpu_at_on(1, 0, "sys")
        .io_at_on(2, 0, "sys")
        .accel_at(0, 1, "dfmul", 2, "acc")
        .accel_at(3, 3, "gsm", 1, "acc")
        .fill_tg("sys")
        .build()?;

    // 3. Session: stage inputs, load the NoC with 4 TGs, warm up, and
    //    measure — then drop the NoC island to 20 MHz at run time and
    //    measure again.
    let mut session = Session::with_backend(cfg, backend)?;
    let a1 = session.tile_at(0, 1);
    let a2 = session.tile_at(3, 3);
    session.stage(a1, 1)?.stage(a2, 1)?.with_tg_load(4).warmup(ms(2));
    let fast = session.measure(a1, ms(5))?;
    session.freq(0, 20)?.warmup(100_000_000); // actuator swap + settle
    let slow = session.measure(a1, ms(5))?;

    println!(
        "A1 dfmul 2x: {:.2} MB/s @ NoC 100 MHz ({} invocations, RTT {:.0} ns), \
         {:.2} MB/s @ NoC 20 MHz (RTT {:.0} ns)",
        fast.throughput_mbs, fast.invocations, fast.rtt_ns, slow.throughput_mbs, slow.rtt_ns
    );

    // 4. Validate the functional datapath end to end: dfmul == a * b.
    let staged = session.staged(a1)[0].clone();
    let soc = session.soc();
    let a = soc.blocks.get(staged[0]).as_f32().unwrap().to_vec();
    let b = soc.blocks.get(staged[1]).as_f32().unwrap().to_vec();
    let out = soc.mra(a1).last_outputs[0].as_f32().unwrap();
    let max_err = a
        .iter()
        .zip(&b)
        .zip(out)
        .map(|((x, y), o)| (o - x * y).abs())
        .fold(0f32, f32::max);
    println!(
        "functional check: dfmul output vs a*b, max |err| = {max_err:.2e} over {} elements",
        a.len()
    );
    assert!(max_err < 1e-5);
    assert!(fast.throughput_mbs > 0.0 && slow.throughput_mbs > 0.0);
    assert!(fast.pkts_in > 0 && fast.pkts_out > 0);
    println!("quickstart OK");
    Ok(())
}
