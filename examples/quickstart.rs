//! Quickstart: the end-to-end driver proving all layers compose.
//!
//! Builds the paper's 4x4 SoC, loads the AOT-compiled PJRT artifacts if
//! available (falls back to the native oracle otherwise), runs a real
//! workload — two MRA tiles computing through the PJRT datapath while
//! traffic generators load the NoC — exercises a run-time DFS change
//! through the frequency registers, reads every monitor counter the way
//! the paper's host tooling does, and validates the accelerator's
//! functional output against the independent native implementation.
//!
//!   make artifacts && cargo run --release --example quickstart

use vespa::config::presets::{paper_soc, A1_POS, A2_POS, ISL_NOC};
use vespa::monitor::CounterReg;
use vespa::report::Table;
use vespa::runtime::{AccelCompute, PjrtCompute, RefCompute};
use vespa::sim::{stage_inputs_for, Soc, ThroughputProbe};

fn main() -> vespa::Result<()> {
    // 1. Functional backend: PJRT artifacts when built, else native.
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend: Box<dyn AccelCompute> = if artifacts.join("manifest.txt").exists() {
        println!("backend: PJRT (artifacts/)");
        Box::new(PjrtCompute::load(&artifacts)?)
    } else {
        println!("backend: native reference (run `make artifacts` for PJRT)");
        Box::new(RefCompute::new())
    };

    // 2. The paper's SoC: dfmul 2x near memory, gsm 1x far from it.
    let cfg = paper_soc(("dfmul", 2), ("gsm", 1));
    let mut soc = Soc::build(cfg, backend)?;
    let a1 = soc.cfg.node_of(A1_POS.0, A1_POS.1);
    let a2 = soc.cfg.node_of(A2_POS.0, A2_POS.1);
    let in_a1 = stage_inputs_for(&mut soc, a1, 1);
    stage_inputs_for(&mut soc, a2, 1);

    // 3. Phase 1 — NoC at 100 MHz, 4 TGs active.
    soc.host_set_tg_active(4);
    soc.run_for(2_000_000_000); // 2 ms warmup
    let probe = ThroughputProbe::begin(&soc, a1);
    soc.run_for(5_000_000_000); // 5 ms measured
    let thr_fast = probe.mbs(&soc);

    // 4. Phase 2 — DFS: drop the NoC island to 20 MHz at run time.
    soc.host_write_freq(ISL_NOC, 20)?;
    soc.run_for(100_000_000); // actuator reprogram + swap (~11 us) + settle
    let probe = ThroughputProbe::begin(&soc, a1);
    soc.run_for(5_000_000_000);
    let thr_slow = probe.mbs(&soc);

    // 5. Monitoring readout (host path, as over USB-serial).
    let mut t = Table::new(
        "monitor counters after the run",
        &["tile", "kind", "inv", "pkts_in", "pkts_out", "rtt_ns"],
    );
    for (i, tile) in soc.tiles.iter().enumerate() {
        let c = soc.mon.tile(i);
        if c.invocations == 0 && c.pkts_out == 0 {
            continue;
        }
        t.row(&[
            i.to_string(),
            tile.kind_name().to_string(),
            soc.host_read_counter(i, CounterReg::Invocations).to_string(),
            soc.host_read_counter(i, CounterReg::PktsIn).to_string(),
            soc.host_read_counter(i, CounterReg::PktsOut).to_string(),
            format!("{:.0}", c.rtt_mean() / 1e3),
        ]);
    }
    println!("{}", t.render());
    println!("A1 dfmul 2x throughput: {thr_fast:.2} MB/s @ NoC 100 MHz, {thr_slow:.2} MB/s @ NoC 20 MHz");

    // 6. Validate the functional datapath end to end.
    let a = soc.blocks.get(in_a1[0][0]).as_f32().unwrap().to_vec();
    let b = soc.blocks.get(in_a1[0][1]).as_f32().unwrap().to_vec();
    let out = soc.mra(a1).last_outputs[0].as_f32().unwrap();
    let max_err = a
        .iter()
        .zip(&b)
        .zip(out)
        .map(|((x, y), o)| (o - x * y).abs())
        .fold(0f32, f32::max);
    println!(
        "functional check: dfmul output vs a*b, max |err| = {max_err:.2e} over {} elements",
        a.len()
    );
    assert!(max_err < 1e-5);
    assert!(thr_fast > 0.0 && thr_slow > 0.0);
    println!("quickstart OK");
    Ok(())
}
