//! Serving example: drive the paper's 4x4 SoC with ramping Poisson
//! traffic — dfmul replicated in A1 *and* A2, JSQ dispatch across the
//! two tiles, and the queue-driven DFS governor holding a p95 SLO on
//! the A1 island while the load triples.
//!
//!   cargo run --release --example serve_traffic

use vespa::config::presets::{paper_soc, A1_POS, A2_POS, ISL_A1};
use vespa::report::{plot, Table};
use vespa::scenario::{ms, Session};
use vespa::serve::{Arrival, DispatchPolicy, GovernorSpec, ServeSpec};

fn main() -> vespa::Result<()> {
    let slo = ms(8); // p95 target per phase
    let mut session = Session::new(paper_soc(("dfmul", 2), ("dfmul", 2)))?;
    let a1 = session.tile_at(A1_POS.0, A1_POS.1);
    let a2 = session.tile_at(A2_POS.0, A2_POS.1);

    // Start the governed island low: the governor must *earn* its
    // frequency as the ramp arrives.
    session.freq(ISL_A1, 10)?;

    let mut summary = Table::new(
        "ramping Poisson load — JSQ across A1+A2, governor on A1",
        &["phase", "offered rps", "achieved rps", "p95 ms", "p99 ms", "dropped", "A1 MHz"],
    );
    let mut last_depths = None;
    for (phase, rps) in [(1u32, 500.0), (2, 1500.0), (3, 3000.0)] {
        let spec = ServeSpec::new(Arrival::Poisson { rps }, ms(120))
            .tiles(vec![a1, a2])
            .policy(DispatchPolicy::JoinShortestQueue)
            .slo(slo)
            .sample_interval(ms(2))
            .seed(0xE5B + phase as u64)
            .governor(GovernorSpec {
                depth_high: 2.0,
                ..GovernorSpec::new(ISL_A1, slo)
            });
        let report = session.serve(&spec)?;
        summary.row(&[
            phase.to_string(),
            format!("{:.0}", report.offered_rps),
            format!("{:.0}", report.achieved_rps),
            format!("{:.3}", report.latency.p95_ms()),
            format!("{:.3}", report.latency.p99_ms()),
            report.dropped.to_string(),
            report.final_freq_mhz[ISL_A1].to_string(),
        ]);
        println!("{}", report.render());
        last_depths = Some(report.queue_depth);
    }
    println!("{}", summary.render());

    if let Some(depths) = last_depths {
        let refs: Vec<&vespa::monitor::TimeSeries> = depths.iter().collect();
        println!("queue depth during the final phase:");
        println!("{}", plot(&refs, 70, 12));
    }

    println!("serve_traffic OK");
    Ok(())
}
