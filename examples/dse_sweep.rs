//! DSE example: sweep replication factors and frequencies for one
//! accelerator, check device fit, and print the area-throughput Pareto
//! frontier — the §I workflow ("exploring a multitude of solutions that
//! differ in the replication of accelerators, the clock frequencies of
//! the frequency islands, and the tiles' placement").
//!
//! The sweep's design points are independent scenarios; they evaluate
//! across every core via `ScenarioSet::run_parallel`, with results
//! bit-identical to (and ordered like) the serial path.
//!
//!   cargo run --release --example dse_sweep [accel]

use vespa::dse::{pareto_front, sweep_replication, SweepParams};
use vespa::report::Table;
use vespa::resources::XC7V2000T;

fn main() -> vespa::Result<()> {
    let accel = std::env::args().nth(1).unwrap_or_else(|| "gsm".into());
    let mut p = SweepParams::quick(&accel);
    p.accel_mhz = vec![25, 50];
    p.placements = vec![true, false];
    p.window = 8_000_000_000;
    p.warmup = 1_000_000_000;

    println!(
        "sweeping {accel}: K in {:?}, f in {:?} MHz, A1/A2 placement, {} scenarios in parallel ...",
        p.replications,
        p.accel_mhz,
        p.specs().len()
    );
    let t0 = std::time::Instant::now();
    let pts = sweep_replication(&p)?;
    println!("{} points in {:.2}s wall clock", pts.len(), t0.elapsed().as_secs_f64());

    let costs: Vec<(f64, f64)> = pts
        .iter()
        .map(|pt| (pt.area.lut as f64, pt.throughput_mbs))
        .collect();
    let front = pareto_front(&costs);

    let mut t = Table::new(
        format!("DSE: {accel} area vs throughput"),
        &["K", "MHz", "place", "LUT", "DSP", "% of 2000T", "MB/s", "pareto"],
    );
    for (i, pt) in pts.iter().enumerate() {
        let pct = pt.area.percent_of(&XC7V2000T)[0];
        t.row(&[
            pt.replicas.to_string(),
            pt.accel_mhz.to_string(),
            if pt.near_mem { "A1" } else { "A2" }.into(),
            pt.area.lut.to_string(),
            pt.area.dsp.to_string(),
            format!("{pct:.2}%"),
            format!("{:.2}", pt.throughput_mbs),
            if front.contains(&i) { "*" } else { "" }.into(),
        ]);
    }
    println!("{}", t.render());
    println!("{} points, {} on the Pareto frontier", pts.len(), front.len());
    assert!(!front.is_empty());
    Ok(())
}
