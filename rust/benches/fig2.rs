//! Bench: regenerate Fig. 2 (the SoC floorplan) and time the
//! floorplanner + resource model.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::experiments::fig2;
use vespa::resources::XC7V2000T;

fn main() {
    let args = BenchArgs::from_env();
    let bench = Bench::new(3, args.iters.unwrap_or(20));
    let r = bench.run("fig2/floorplan", |_| fig2::run().expect("fig2"));
    let (rendered, fp) = fig2::run().unwrap();
    println!("{rendered}");
    println!("{}", r.report());

    let mut report = BenchReport::new("fig2");
    report.push(r);
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    assert!(fp.fits, "the paper instance must fit the Virtex-7 2000T");
    let p = fp.total.percent_of(&XC7V2000T);
    // The full 16-tile SoC uses a modest fraction of the 2000T.
    assert!(p[0] < 40.0, "LUT {:.1}%", p[0]);
    println!("fig2 bench OK");
}
