//! Bench: regenerate Table I (area + throughput of 1x/2x/4x MRA tiles).
//!
//!   cargo bench --bench table1            full table (15 simulations)
//!   cargo bench --bench table1 -- --quick smaller measurement windows

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::experiments::table1;

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let inv = if quick { 3 } else { 8 };

    let bench = Bench::new(0, 1);
    let mut table = None;
    let r = bench.run("table1/full-reproduction", |_| {
        let (t, rows) = table1::run(inv).expect("table1");
        table = Some((t, rows));
    });
    let (t, rows) = table.unwrap();
    println!("{}", t.render());
    let (r2, r4) = table1::average_increments(&rows);
    println!("Average throughput increment: 2x = {r2:.2}x, 4x = {r4:.2}x (paper: 1.92x / 3.58x)");
    println!("{}", r.report());

    let mut report = BenchReport::new("table1");
    report.metric("avg_increment_2x", r2);
    report.metric("avg_increment_4x", r4);
    report.push(r);
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    // Shape assertions (who wins, by what factor).
    assert!((1.6..=2.2).contains(&r2), "2x increment {r2:.2}");
    assert!((3.0..=4.0).contains(&r4), "4x increment {r4:.2}");
    for chunk in rows.chunks(3) {
        let base = &chunk[0];
        assert!(
            (base.thr_mbs - base.paper_thr_mbs).abs() / base.paper_thr_mbs < 0.15,
            "{} baseline off: {:.2} vs {:.2}",
            base.accel,
            base.thr_mbs,
            base.paper_thr_mbs
        );
    }
    println!("table1 bench OK");
}
