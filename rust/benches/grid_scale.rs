//! Grid-scaling bench: how engine cost grows with mesh size when the
//! *activity* does not. 8x8 and 16x16 SoCs carry the same sparse bursty
//! workload (8 active TGs, one burst every ~1500 TG cycles); everything
//! else on the grid is idle silicon. The idle-aware engine still scans
//! every tile deadline and ticks every router on every delivered edge,
//! so its per-edge cost grows with the grid; the event-driven engine
//! pops only due components off the per-island heaps, so its cost
//! tracks the 8 bursting TGs regardless of mesh size.
//!
//! Writes `BENCH_grid_scale.json` (override with `--json <path>`); the
//! `sparse_event_speedup_vs_idle` metric (16x16) is CI-gated — the heap
//! scheduler must beat deadline scanning where it matters.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::config::SocConfig;
use vespa::runtime::RefCompute;
use vespa::scenario::Scenario;
use vespa::sim::{EngineMode, Soc};
use vespa::tiles::Tile;

/// Sparse scenario at `side` x `side`: one MEM corner, one IO tile, the
/// rest TGs — mirrors `noc_microbench`'s sparse preset, scaled up.
fn sparse_cfg(side: u16) -> SocConfig {
    Scenario::grid(side, side)
        .name(format!("grid-scale-{side}x{side}"))
        .seed(0x51AB)
        .island_dfs("noc-mem", 100, 10..=100, 5)
        .island_dfs("tg", 50, 10..=50, 5)
        .noc_island("noc-mem")
        .mem_at(0, 0)
        .io_at_on(2, 0, "tg")
        .fill_tg("tg")
        .build()
        .expect("grid-scale preset is structurally valid")
}

fn build_sparse(side: u16, engine: EngineMode) -> Soc {
    let mut soc = Soc::build(sparse_cfg(side), Box::new(RefCompute::new())).unwrap();
    soc.set_engine(engine);
    for t in &mut soc.tiles {
        if let Tile::Tg(tg) = t {
            tg.gap_cycles = 1500;
        }
    }
    soc.host_set_tg_active(8);
    soc
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let sim_ms = if quick { 3 } else { 10 };
    let sim_ps = sim_ms * 1_000_000_000;

    let bench = Bench::new(1, args.iters.unwrap_or(if quick { 3 } else { 5 }));
    let mut report = BenchReport::new("grid_scale");
    let mut speedups = Vec::new();

    for side in [8u16, 16] {
        let r_idle = bench.run(&format!("grid/{side}x{side}-sparse-idle"), |_| {
            let mut soc = build_sparse(side, EngineMode::IdleAware);
            soc.run_for(sim_ps);
            soc.edges
        });
        println!("{}", r_idle.report());
        let r_event = bench.run(&format!("grid/{side}x{side}-sparse-event"), |_| {
            let mut soc = build_sparse(side, EngineMode::EventDriven);
            soc.run_for(sim_ps);
            soc.edges
        });
        println!("{}", r_event.report());

        let speedup = r_idle.mean.as_secs_f64() / r_event.mean.as_secs_f64();
        println!("{side}x{side}: event vs idle-aware {speedup:.2}x");
        report.metric(&format!("event_speedup_vs_idle_{side}x{side}"), speedup);
        speedups.push(speedup);
        report.push(r_idle);
        report.push(r_event);
    }

    // Equivalence spot-check at 8x8 (16x16 behaves identically by
    // construction; the full proof lives in engine_equivalence.rs).
    let mut a = build_sparse(8, EngineMode::IdleAware);
    let mut b = build_sparse(8, EngineMode::EventDriven);
    a.run_for(sim_ps);
    b.run_for(sim_ps);
    assert_eq!(a.edges, b.edges, "engines disagree on delivered edges");
    assert_eq!(
        a.mon.mem_pkts_in, b.mon.mem_pkts_in,
        "engines disagree on memory traffic"
    );
    assert_eq!(
        a.fabric.total_flits(),
        b.fabric.total_flits(),
        "engines disagree on flits"
    );
    println!(
        "8x8 sparse: {} edges, {} coalesced, {} tile ticks under event",
        b.edges, b.engine_stats.coalesced_edges, b.engine_stats.tile_ticks
    );

    // Engine self-profiling counters from the 8x8 runs: how much work
    // each engine actually did (ticks executed/skipped, quiescent spans
    // coalesced, event-heap traffic). Deterministic, so they double as
    // a drift tripwire in the bench JSON (schema: docs/PERF.md).
    report.metric("idle8_tile_ticks", a.engine_stats.tile_ticks as f64);
    report.metric(
        "idle8_skipped_tile_ticks",
        a.engine_stats.skipped_tile_ticks as f64,
    );
    report.metric("event8_tile_ticks", b.engine_stats.tile_ticks as f64);
    report.metric("event8_router_ticks", b.engine_stats.router_ticks as f64);
    report.metric(
        "event8_coalesced_spans",
        b.engine_stats.coalesced_spans as f64,
    );
    report.metric(
        "event8_coalesced_edges",
        b.engine_stats.coalesced_edges as f64,
    );
    report.metric("event8_heap_ops", b.heap_ops() as f64);

    // Headline: the 16x16 ratio, where dead silicon dominates the grid.
    let headline = speedups[1];
    report.metric("sparse_event_speedup_vs_idle", headline);

    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    assert!(
        headline >= 1.5,
        "event engine must beat idle-aware deadline scanning on a 16x16 \
         sparse grid, got {headline:.2}x"
    );
    println!("grid_scale OK");
}
