//! Bench: regenerate Fig. 4 — memory incoming traffic while stepping
//! island frequencies at run time.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::experiments::fig4;
use vespa::report::plot;

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let phase = if quick { 10_000_000_000 } else { 30_000_000_000 };

    let bench = Bench::new(0, 1);
    let mut result = None;
    let r = bench.run("fig4/schedule-run", |_| {
        result = Some(fig4::run(phase, 1_000_000_000).expect("fig4"));
    });
    let res = result.unwrap();
    println!("{}", fig4::render_table(&res).render());
    println!("{}", plot(&[&res.pkts_rate], 70, 14));
    println!("{}", r.report());

    let mut report = BenchReport::new("fig4");
    for (i, &mpkts) in res.phase_mpkts.iter().enumerate() {
        report.metric(&format!("phase{i}_mpkts"), mpkts);
    }
    report.push(r);
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    // Shape: accel steps negligible, TG/NoC steps dominant.
    let accel_delta = (res.phase_mpkts[2] - res.phase_mpkts[0]).abs();
    let tg_delta = res.phase_mpkts[4] - res.phase_mpkts[2];
    assert!(
        tg_delta > 3.0 * accel_delta.max(1e-3),
        "TG/NoC must dominate: accel delta {accel_delta:.3}, tg delta {tg_delta:.3}"
    );
    println!("fig4 bench OK");
}
