//! Microbench: PJRT invocation latency per accelerator (the §Perf L1/L2
//! metric) vs. the native reference backend.
//!
//! Requires `make artifacts`; exits cleanly with a notice otherwise.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::mem::Block;
use vespa::report::Table;
use vespa::runtime::{AccelCompute, DType, Manifest, PjrtCompute, RefCompute};
use vespa::util::SplitMix64;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("runtime_microbench: artifacts/ missing — run `make artifacts` first (skipped)");
        return;
    }
    let args = BenchArgs::from_env();
    let iters = args.iters.unwrap_or(if args.quick { 20 } else { 100 });

    let manifest = Manifest::load(&dir).unwrap();
    let mut pjrt = PjrtCompute::from_manifest(manifest.clone()).unwrap();
    let mut refc = RefCompute::new();
    let mut rng = SplitMix64::new(99);

    let mut t = Table::new(
        "PJRT invocation latency per accelerator block",
        &["accel", "bytes in", "pjrt us", "native us", "pjrt MB/s"],
    );
    let bench = Bench::new(3, iters);
    let mut report = BenchReport::new("runtime_microbench");
    for (name, spec) in &manifest.modules {
        let inputs: Vec<Block> = spec
            .inputs
            .iter()
            .map(|ts| match ts.dtype {
                DType::F32 => {
                    Block::F32((0..ts.elems()).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                }
                DType::S32 => Block::I32(
                    (0..ts.elems())
                        .map(|_| rng.range_i64(-32768, 32767) as i32)
                        .collect(),
                ),
            })
            .collect();
        let refs: Vec<&Block> = inputs.iter().collect();

        let rp = bench.run(&format!("pjrt/{name}"), |_| {
            pjrt.invoke(name, &refs).unwrap()
        });
        let rn = bench.run(&format!("native/{name}"), |_| {
            refc.invoke(name, &refs).unwrap()
        });
        let mbs = spec.bytes_in() as f64 / rp.mean.as_secs_f64() / 1e6;
        t.row(&[
            name.clone(),
            spec.bytes_in().to_string(),
            format!("{:.1}", rp.mean.as_secs_f64() * 1e6),
            format!("{:.1}", rn.mean.as_secs_f64() * 1e6),
            format!("{mbs:.0}"),
        ]);
        report.push(rp.with_ops(1.0));
        report.push(rn.with_ops(1.0));
    }
    println!("{}", t.render());
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());
    println!("runtime_microbench OK ({} PJRT invocations)", pjrt.invocations);
}
