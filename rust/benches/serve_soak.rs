//! Macro-bench: the serve subsystem under sustained open-loop load
//! ("soak"), with the tail-latency claims the ISSUE gates on:
//!
//! * JSQ p99 <= round-robin p99 at equal offered load on a
//!   replicated-accelerator SoC (heterogeneous tile frequencies);
//! * the `QueueGovernor` meets a p95 SLO that a static low frequency
//!   misses, and ends below the always-max frequency.
//!
//! Every serve run is inherently single-threaded (`threads = 1`
//! semantics): one host loop drives one SoC, so the timings measure
//! simulation work, not core count. Writes `BENCH_serve_soak.json`;
//! `rr_over_jsq_p99` and `achieved_rps` are CI-gated.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::scenario::{ms, Scenario, Session};
use vespa::serve::{Arrival, DispatchPolicy, GovernorSpec, ServeReport, ServeSpec};
use vespa::telemetry::TraceSpec;

/// Two single-replica dfmul tiles at 50 / 15 MHz (replica-aware
/// dispatch across tiles; heterogeneity makes policy quality visible).
fn two_tile_session() -> Session {
    let cfg = Scenario::grid(2, 2)
        .name("serve-soak-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("fast", 50, 10..=50, 5)
        .island_dfs("slow", 15, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 1, "fast")
        .accel_at(0, 1, "dfmul", 1, "slow")
        .io_at_on(1, 1, "noc")
        .build()
        .unwrap();
    Session::new(cfg).unwrap()
}

/// One 2-replica dfmul tile on a 10..=50 MHz island (index 1).
fn governed_session(start_mhz: u64) -> Session {
    let cfg = Scenario::grid(2, 2)
        .name("serve-soak-governed")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", start_mhz, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .fill_tg("noc")
        .build()
        .unwrap();
    Session::new(cfg).unwrap()
}

fn soak_spec(policy: DispatchPolicy, duration_ms: u64) -> ServeSpec {
    ServeSpec::new(Arrival::Poisson { rps: 2000.0 }, ms(duration_ms))
        .policy(policy)
        .seed(0xFEED)
}

fn run_policy(policy: DispatchPolicy, duration_ms: u64) -> ServeReport {
    two_tile_session().serve(&soak_spec(policy, duration_ms)).expect("serve run")
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let duration_ms: u64 = if quick { 100 } else { 200 };
    let slo = ms(10);

    println!(
        "serve_soak: 2000 rps Poisson for {duration_ms} ms per run ({} mode, threads=1)",
        if quick { "quick" } else { "full" }
    );

    let bench = Bench::new(1, args.iters.unwrap_or(if quick { 2 } else { 3 }));
    let mut report = BenchReport::new("serve_soak");

    // Timed sections: one full serve run per policy.
    let r_rr = bench.run("serve/rr-soak", |_| {
        run_policy(DispatchPolicy::RoundRobin, duration_ms)
    });
    println!("{}", r_rr.report());
    let r_jsq = bench.run("serve/jsq-soak", |_| {
        run_policy(DispatchPolicy::JoinShortestQueue, duration_ms)
    });
    println!("{}", r_jsq.report());

    // Tracing overhead: the same JSQ soak with the flight recorder on
    // (1-in-8 sampling, a production-style setting). Spans piggyback on
    // gate logs the engine already keeps, so the soak must not slow
    // down measurably; `trace_overhead` uses min-over-min to shed
    // shared-runner noise and is CI-gated at <= 1.02.
    let traced_spec =
        soak_spec(DispatchPolicy::JoinShortestQueue, duration_ms).trace(TraceSpec::new().sample(8));
    let r_traced = bench.run("serve/jsq-soak-traced", |_| {
        two_tile_session().serve(&traced_spec).expect("traced serve run")
    });
    println!("{}", r_traced.report());
    let trace_overhead = r_traced.min.as_secs_f64() / r_jsq.min.as_secs_f64();
    let traced = two_tile_session().serve(&traced_spec).expect("traced serve run");
    let trace = traced.trace.as_ref().expect("tracing was enabled");
    println!(
        "tracing: {trace_overhead:.4}x overhead (min/min), {} of {} requests recorded",
        trace.recorded, trace.total_requests
    );
    assert!(trace.recorded > 0, "the traced soak must record spans");

    // Untimed runs for the gated tail-latency claims.
    let rr = run_policy(DispatchPolicy::RoundRobin, duration_ms);
    let jsq = run_policy(DispatchPolicy::JoinShortestQueue, duration_ms);
    assert_eq!(rr.offered, jsq.offered, "equal offered load");
    println!(
        "p99: rr {:.3} ms, jsq {:.3} ms | achieved: rr {:.0}, jsq {:.0} rps",
        rr.latency.p99_ms(),
        jsq.latency.p99_ms(),
        rr.achieved_rps,
        jsq.achieved_rps
    );
    assert!(
        jsq.latency.p99_ps <= rr.latency.p99_ps,
        "JSQ p99 {:.3} ms must not exceed RR p99 {:.3} ms",
        jsq.latency.p99_ms(),
        rr.latency.p99_ms()
    );

    // Governor: static 10 MHz misses the SLO; governed from 10 MHz
    // meets it and ends below the 50 MHz ceiling.
    let gov_spec = |governed: bool| {
        let s = ServeSpec::new(Arrival::Poisson { rps: 1200.0 }, ms(2 * duration_ms))
            .policy(DispatchPolicy::JoinShortestQueue)
            .slo(slo)
            .sample_interval(ms(2))
            .seed(0x50C);
        if governed {
            s.governor(GovernorSpec {
                depth_high: 2.0,
                ..GovernorSpec::new(1, slo)
            })
        } else {
            s
        }
    };
    let r_low = governed_session(10).serve(&gov_spec(false)).expect("static low");
    let r_gov = governed_session(10).serve(&gov_spec(true)).expect("governed");
    println!(
        "governor: static-low p95 {:.3} ms, governed p95 {:.3} ms, final {} MHz ({} actions)",
        r_low.latency.p95_ms(),
        r_gov.latency.p95_ms(),
        r_gov.final_freq_mhz[1],
        r_gov.governor_actions.len()
    );
    assert_eq!(r_low.slo_met, Some(false), "static low must miss the SLO");
    assert_eq!(r_gov.slo_met, Some(true), "governor must meet the SLO");
    assert!(
        r_gov.final_freq_mhz[1] < 50,
        "governor must settle below always-max, got {} MHz",
        r_gov.final_freq_mhz[1]
    );

    let rr_over_jsq = rr.latency.p99_ps / jsq.latency.p99_ps;
    report.metric("rr_over_jsq_p99", rr_over_jsq);
    report.metric("jsq_p99_ms", jsq.latency.p99_ms());
    report.metric("rr_p99_ms", rr.latency.p99_ms());
    report.metric("achieved_rps", jsq.achieved_rps);
    report.metric("governor_p95_ms", r_gov.latency.p95_ms());
    report.metric("static_low_p95_ms", r_low.latency.p95_ms());
    report.metric("governor_final_mhz", r_gov.final_freq_mhz[1] as f64);
    report.metric("dropped_jsq", jsq.dropped as f64);
    report.metric("trace_overhead", trace_overhead);
    report.metric("trace_recorded", trace.recorded as f64);
    report.push(r_rr);
    report.push(r_jsq);
    report.push(r_traced);

    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());
    println!("serve_soak OK");
}
