//! Macro-bench: fleet serving under the `cluster` subsystem, with the
//! three claims CI gates on:
//!
//! * a 4-replica fleet sustains >= 3x the achieved rps of a single SoC
//!   at the same offered load (`cluster4_rps_over_single`, min-gated);
//! * against a diurnal on/off load, the SLO-driven autoscaler finishes
//!   with well under a fixed maximum fleet's replica-seconds
//!   (`autoscale_replica_seconds_vs_fixed_max`, max-gated at 0.8);
//! * stepping an 8-replica fleet on a worker pool
//!   (`ClusterSpec::threads`) beats the serial reference wall-clock
//!   (`parallel_speedup_vs_serial`, min-gated at 2.0 on CI's
//!   multi-core runners) while producing a bit-identical report.
//!
//! The scaling and autoscale sections run serial (`threads = 1`) so
//! their timings track simulation work, not core count; the parallel
//! section times the same work on `--threads N` workers (default 0 =
//! all cores). Writes `BENCH_cluster_scale.json` for the CI bench gate.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::cluster::{serve_cluster_with_profile, AutoscaleSpec, ClusterSpec};
use vespa::config::SocConfig;
use vespa::scenario::{ms, Scenario};
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};
use vespa::telemetry::HostProfile;

/// One 2-replica dfmul tile at 50 MHz — ~4250 req/s per replica SoC,
/// so fleet size is the only capacity knob under test.
fn fleet_cfg() -> SocConfig {
    Scenario::grid(2, 2)
        .name("cluster-scale-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", 50, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .build()
        .unwrap()
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let duration_ms: u64 = if quick { 100 } else { 200 };
    let par_threads = args.threads.unwrap_or(0);

    println!(
        "cluster_scale: {duration_ms} ms horizons ({} mode, parallel section --threads {par_threads})",
        if quick { "quick" } else { "full" }
    );

    let bench = Bench::new(1, args.iters.unwrap_or(if quick { 2 } else { 3 }));
    let mut report = BenchReport::new("cluster_scale");

    // ---- Scaling claim: 16000 rps vs one ~4250 rps SoC. ----
    let scale_spec = ServeSpec::new(Arrival::Poisson { rps: 16_000.0 }, ms(duration_ms))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(20))
        .seed(0xF1EE);
    let r_single = bench.run("cluster/single-soc", |_| {
        ClusterSpec::new(1, scale_spec.clone())
            .run(fleet_cfg())
            .expect("single-SoC run")
    });
    println!("{}", r_single.report());
    let r_fleet = bench.run("cluster/fleet-4", |_| {
        ClusterSpec::new(4, scale_spec.clone())
            .run(fleet_cfg())
            .expect("fleet run")
    });
    println!("{}", r_fleet.report());

    let single = ClusterSpec::new(1, scale_spec.clone())
        .run(fleet_cfg())
        .expect("single-SoC run");
    let fleet4 = ClusterSpec::new(4, scale_spec)
        .run(fleet_cfg())
        .expect("fleet run");
    assert_eq!(single.offered, fleet4.offered, "equal offered load");
    let rps_ratio = fleet4.achieved_rps / single.achieved_rps;
    println!(
        "scaling: single {:.0} rps, fleet-4 {:.0} rps ({rps_ratio:.2}x), attainment {:.3} vs {:.3}",
        single.achieved_rps, fleet4.achieved_rps, fleet4.slo_attainment, single.slo_attainment
    );
    assert!(
        fleet4.slo_attainment >= single.slo_attainment,
        "scaling out must not trade tail quality for throughput"
    );

    // ---- Autoscaler cost claim: diurnal on/off load. ----
    // Bursts to 6000 rps (past one SoC) for 40% of each 50 ms period,
    // idling at 800 rps between — elasticity pays exactly when the
    // fleet can shrink through the troughs.
    let diurnal = ServeSpec::new(
        Arrival::Burst {
            base_rps: 800.0,
            burst_rps: 6000.0,
            period: ms(50),
            duty: 0.4,
        },
        ms(2 * duration_ms),
    )
    .policy(DispatchPolicy::JoinShortestQueue)
    .slo(ms(5))
    .sample_interval(ms(2))
    .seed(0x50C);
    let r_auto_t = bench.run("cluster/autoscale-diurnal", |_| {
        ClusterSpec::new(4, diurnal.clone())
            .autoscale(AutoscaleSpec::new(1))
            .run(fleet_cfg())
            .expect("autoscaled run")
    });
    println!("{}", r_auto_t.report());

    let r_max = ClusterSpec::new(4, diurnal.clone())
        .run(fleet_cfg())
        .expect("fixed-max run");
    let r_auto = ClusterSpec::new(4, diurnal)
        .autoscale(AutoscaleSpec::new(1))
        .run(fleet_cfg())
        .expect("autoscaled run");
    let cost_ratio = r_auto.replica_seconds / r_max.replica_seconds;
    println!(
        "autoscale: {:.4} replica-seconds vs fixed-max {:.4} ({cost_ratio:.2}x), p95 {:.3} ms, {} actions",
        r_auto.replica_seconds,
        r_max.replica_seconds,
        r_auto.latency.p95_ms(),
        r_auto.autoscale_actions.len()
    );
    assert!(
        !r_auto.autoscale_actions.is_empty(),
        "the autoscaler must act under a diurnal load"
    );

    // ---- Parallel fleet execution: 8 replicas, serial vs workers. ----
    // Round-robin balancer at ~94% utilization: between sample barriers
    // the wide-span fast path pre-bins arrivals per slot, so every
    // replica's window of simulation runs on its own worker.
    let par_spec = ServeSpec::new(Arrival::Poisson { rps: 32_000.0 }, ms(duration_ms))
        .slo(ms(20))
        .sample_interval(ms(2))
        .seed(0x8F1E);
    let fleet8_serial = ClusterSpec::new(8, par_spec)
        .balancer(DispatchPolicy::RoundRobin)
        .threads(1);
    let fleet8_parallel = fleet8_serial.clone().threads(par_threads);
    let r_f8s = bench.run("cluster/fleet-8-serial", |_| {
        fleet8_serial.run(fleet_cfg()).expect("fleet-8 serial run")
    });
    println!("{}", r_f8s.report());
    let r_f8p = bench.run("cluster/fleet-8-parallel", |_| {
        fleet8_parallel.run(fleet_cfg()).expect("fleet-8 parallel run")
    });
    println!("{}", r_f8p.report());

    let serial = fleet8_serial.run(fleet_cfg()).expect("fleet-8 serial run");
    let parallel = fleet8_parallel
        .run(fleet_cfg())
        .expect("fleet-8 parallel run");
    assert_eq!(
        serial, parallel,
        "parallel report must be bit-identical to the serial reference"
    );
    let speedup = r_f8s.mean.as_secs_f64() / r_f8p.mean.as_secs_f64();
    println!(
        "parallel: serial {:?} vs parallel {:?} ({speedup:.2}x), reports bit-identical ({} completed)",
        r_f8s.mean, r_f8p.mean, serial.completed
    );

    // ---- Host self-profiling: barrier rounds and worker busy/wait. ----
    // The profile is host wall-clock (non-deterministic by design), so
    // it feeds the bench JSON only — the report itself must stay
    // bit-identical to the unprofiled run.
    let profile = HostProfile::new();
    let profiled = serve_cluster_with_profile(fleet_cfg(), &fleet8_parallel, Some(&profile))
        .expect("profiled fleet-8 run");
    assert_eq!(profiled, parallel, "profiling must not perturb the run");
    let workers = match par_threads {
        0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
        n => n,
    }
    .min(8);
    println!(
        "profile: {} rounds ({:.1} us mean), {} tasks, busy {:.1} ms, est wait {:.1} ms on {workers} workers",
        profile.rounds(),
        profile.mean_round_ns() / 1e3,
        profile.tasks(),
        profile.task_busy_ns() as f64 / 1e6,
        profile.est_wait_ns(workers) / 1e6,
    );
    assert!(profile.rounds() > 0, "the profiled run must count rounds");

    report.metric("cluster4_rps_over_single", rps_ratio);
    report.metric("single_achieved_rps", single.achieved_rps);
    report.metric("fleet4_achieved_rps", fleet4.achieved_rps);
    report.metric("fleet4_slo_attainment", fleet4.slo_attainment);
    report.metric("autoscale_replica_seconds_vs_fixed_max", cost_ratio);
    report.metric("autoscale_replica_seconds", r_auto.replica_seconds);
    report.metric("fixed_max_replica_seconds", r_max.replica_seconds);
    report.metric("autoscale_p95_ms", r_auto.latency.p95_ms());
    report.metric("autoscale_actions", r_auto.autoscale_actions.len() as f64);
    report.metric("parallel_speedup_vs_serial", speedup);
    report.metric("fleet8_completed", serial.completed as f64);
    report.metric("profile_rounds", profile.rounds() as f64);
    report.metric("profile_mean_round_us", profile.mean_round_ns() / 1e3);
    report.metric("profile_tasks", profile.tasks() as f64);
    report.metric("profile_task_busy_ms", profile.task_busy_ns() as f64 / 1e6);
    report.metric("profile_est_wait_ms", profile.est_wait_ns(workers) / 1e6);
    report.push(r_single);
    report.push(r_fleet);
    report.push(r_auto_t);
    report.push(r_f8s);
    report.push(r_f8p);

    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());
    println!("cluster_scale OK");
}
