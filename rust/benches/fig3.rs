//! Bench: regenerate Fig. 3 — throughput of 4x adpcm (compute-bound) and
//! 4x dfmul (memory-bound) in A2 vs. active TG cores, NoC at 10 MHz.
//!
//!   cargo bench --bench fig3            full 12-point sweeps
//!   cargo bench --bench fig3 -- --quick 4 points per curve

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::experiments::fig3;
use vespa::report::Table;

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    // adpcm 4x completes one invocation per ~5.9 ms in steady state: its
    // window must stay long even in --quick or the measurement quantizes
    // to a handful of invocations.
    let (warm, win, adpcm_warm, adpcm_win) = if quick {
        (2_000_000_000u64, 10_000_000_000u64, 40_000_000_000u64, 60_000_000_000u64)
    } else {
        (2_000_000_000, 30_000_000_000, 40_000_000_000, 60_000_000_000)
    };
    let tg_points: Vec<usize> = if quick {
        vec![0, 4, 7, 11]
    } else {
        (0..=11).collect()
    };

    let bench = Bench::new(0, 1);
    let mut rows = Vec::new();
    let r = bench.run("fig3/sweep", |_| {
        rows.clear();
        for &tg in &tg_points {
            let a = fig3::measure_point("adpcm", 4, tg, adpcm_warm, adpcm_win).unwrap();
            let d = fig3::measure_point("dfmul", 4, tg, warm, win).unwrap();
            rows.push((tg, a.thr_mbs, d.thr_mbs));
        }
    });

    let mut t = Table::new(
        "Fig. 3 — A2 throughput vs active TGs (NoC@10MHz)",
        &["TGs", "adpcm 4x MB/s", "dfmul 4x MB/s"],
    );
    for &(tg, a, d) in &rows {
        t.row(&[tg.to_string(), format!("{a:.2}"), format!("{d:.2}")]);
    }
    println!("{}", t.render());
    println!("{}", r.report());

    let mut report = BenchReport::new("fig3");
    for &(tg, a, d) in &rows {
        report.metric(&format!("adpcm4x_mbs_tg{tg}"), a);
        report.metric(&format!("dfmul4x_mbs_tg{tg}"), d);
    }
    report.push(r);
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    // Shape assertions.
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(
        last.2 < first.2 * 0.5,
        "dfmul must collapse under TG pressure: {:.2} -> {:.2}",
        first.2,
        last.2
    );
    let mid = rows.iter().find(|r| r.0 == 4).unwrap();
    assert!(
        mid.1 > first.1 * 0.75,
        "adpcm must hold through moderate TG pressure: {:.2} -> {:.2}",
        first.1,
        mid.1
    );
    println!("fig3 bench OK");
}
