//! Macro-bench: DSE sweep turnaround — the warm-start snapshot/fork
//! planner against the cold per-point reference on a *frequency-major*
//! sweep (one structure, many island-frequency pairs), the axis the
//! paper's fine-grained DFS turns into a pure run-time knob.
//!
//! Cold pays (build + warmup + window) per point; WarmFork pays
//! (build + warmup) once per structure and (fork + retune + settle +
//! window) per point, so the speedup is the warmup amortization. Both
//! timed sweeps run with `threads = 1` so the ratio measures simulation
//! work, not the host's core count (the warm base is inherently serial
//! while cold points all parallelize — auto threading would make the
//! metric machine-dependent).
//!
//! Writes `BENCH_dse_sweep.json`; `warm_fork_speedup_vs_cold` is the
//! CI-gated proof (>= 2x required). A final untimed pass cross-checks
//! warm against cold results; the strict tolerance gates (20% per
//! point, 10% mean, wide windows) live in `rust/tests/snapshot_fork.rs`
//! — here the windows are deliberately short for timing, so the sanity
//! bound is loose (fixed windows quantize by whole invocation bursts).

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::dse::{clear_memo, memo_len, sweep_replication, SweepMode, SweepParams};

fn sweep_params(quick: bool) -> SweepParams {
    let mut p = SweepParams::quick("dfmul");
    p.replications = vec![2];
    if quick {
        p.accel_mhz = vec![30, 40, 50];
        p.noc_mhz = vec![50, 100];
        p.warmup = 12_000_000_000; // 12 ms
        p.window = 3_000_000_000; // 3 ms
    } else {
        p.accel_mhz = vec![25, 30, 35, 40, 45, 50];
        p.noc_mhz = vec![50, 100];
        p.warmup = 16_000_000_000; // 16 ms
        p.window = 4_000_000_000; // 4 ms
    }
    p
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;

    let points = sweep_params(quick).specs().len();
    println!(
        "dse_sweep: frequency-major sweep, {points} points ({} mode, threads=1)",
        if quick { "quick" } else { "full" }
    );

    let bench = Bench::new(1, args.iters.unwrap_or(if quick { 2 } else { 3 }));
    let mut report = BenchReport::new("dse_sweep");

    // Cold reference: every point cold-builds and re-warms its own Soc.
    // The memo cache is cleared inside the closure so every iteration
    // really simulates.
    let r_cold = bench.run("dse/cold-freq-sweep", |_| {
        clear_memo();
        let mut p = sweep_params(quick);
        p.mode = SweepMode::Cold;
        p.threads = 1;
        sweep_replication(&p).expect("cold sweep")
    });
    println!("{}", r_cold.report());

    // Warm-fork: one warmed base, forked + DFS-retuned per point.
    let r_warm = bench.run("dse/warm-fork-freq-sweep", |_| {
        clear_memo();
        let mut p = sweep_params(quick);
        p.mode = SweepMode::WarmFork;
        p.threads = 1;
        sweep_replication(&p).expect("warm-fork sweep")
    });
    println!("{}", r_warm.report());

    // Untimed sanity cross-check (auto threads). Short timing windows
    // quantize by whole invocation bursts (up to 2 replicas' worth each
    // way), so the bound here is loose; snapshot_fork.rs holds the
    // strict 20%/10% gates on statistically wide windows.
    clear_memo();
    let mut p = sweep_params(quick);
    p.mode = SweepMode::Cold;
    let cold = sweep_replication(&p).expect("cold sweep");
    p.mode = SweepMode::WarmFork;
    let warm = sweep_replication(&p).expect("warm-fork sweep");
    let mut max_rel: f64 = 0.0;
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!((c.accel_mhz, c.noc_mhz), (w.accel_mhz, w.noc_mhz));
        assert!(c.throughput_mbs > 0.0 && w.throughput_mbs > 0.0);
        let rel = (c.throughput_mbs - w.throughput_mbs).abs() / c.throughput_mbs;
        max_rel = max_rel.max(rel);
    }
    println!("warm-vs-cold max throughput deviation: {:.1}%", max_rel * 100.0);
    assert!(
        max_rel <= 0.5,
        "warm-fork drifted {:.1}% from cold — beyond burst quantization",
        max_rel * 100.0
    );

    // Memo: the sweeps just ran, so a re-run must be pure cache hits.
    assert!(memo_len() >= 2 * points, "memo holds both modes");
    let t0 = std::time::Instant::now();
    let warm_again = sweep_replication(&p).expect("memoized re-run");
    let memo_rerun = t0.elapsed();
    assert_eq!(warm, warm_again, "memoized re-run must be identical");
    println!("memoized re-run of {points} points: {memo_rerun:?}");

    let speedup = r_cold.mean.as_secs_f64() / r_warm.mean.as_secs_f64();
    println!("warm-fork speedup on frequency-major sweep: {speedup:.2}x");
    report.metric("warm_fork_speedup_vs_cold", speedup);
    report.metric("sweep_points", points as f64);
    report.metric("warm_vs_cold_max_rel_dev", max_rel);
    report.metric("memo_rerun_ns", memo_rerun.as_nanos() as f64);
    report.push(r_cold);
    report.push(r_warm);

    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    assert!(
        speedup >= 2.0,
        "warm-fork sweep must be >= 2x vs cold on a frequency-major sweep, got {speedup:.2}x"
    );
    println!("dse_sweep OK");
}
