//! Ablation: Vespa's dual-MMCM DFS actuator vs. the naive single-MMCM
//! design §II-B warns about.
//!
//! A storm of frequency requests hits both actuators; we count the dead
//! (gated) clock time and the island cycles actually delivered. The
//! dual-MMCM design must deliver every cycle; the naive one loses the
//! whole reconfiguration window each switch.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::clock::{DfsActuator, DualMmcmActuator, SingleMmcmActuator};
use vespa::report::Table;
use vespa::util::time::Freq;

/// Run `switches` alternating 20<->80 MHz requests spaced `gap_ps` apart;
/// return (dead_time_ps, delivered_cycles_estimate).
fn storm(actuator: &mut dyn DfsActuator, switches: u32, gap_ps: u64) -> (u64, u64) {
    let mut now = 0u64;
    let mut delivered = 0u64;
    for i in 0..switches {
        let target = if i % 2 == 0 { 80 } else { 20 };
        actuator.request(Freq::mhz(target), now);
        // Walk the gap in 1 us steps, counting delivered cycles.
        let end = now + gap_ps;
        while now < end {
            actuator.tick(now);
            if let Some(f) = actuator.output(now) {
                delivered += f.as_mhz(); // cycles per us at this freq
            }
            now += 1_000_000; // 1 us
        }
    }
    actuator.tick(now);
    (actuator.dead_time(), delivered)
}

fn main() {
    let args = BenchArgs::from_env();
    let bench = Bench::new(1, args.iters.unwrap_or(10));
    const SWITCHES: u32 = 50;
    const GAP: u64 = 40_000_000; // 40 us between requests

    let mut results = Vec::new();
    let r = bench.run("dfs_ablation/storm-50-switches", |_| {
        let mut dual = DualMmcmActuator::new(Freq::mhz(50));
        let mut single = SingleMmcmActuator::new(Freq::mhz(50));
        let d = storm(&mut dual, SWITCHES, GAP);
        let s = storm(&mut single, SWITCHES, GAP);
        results = vec![("dual-MMCM (Vespa)", d), ("single-MMCM (naive)", s)];
    });

    let mut t = Table::new(
        "DFS actuator ablation — 50 switches, 40us apart",
        &["design", "dead clock (us)", "delivered cycles"],
    );
    for (name, (dead, cycles)) in &results {
        t.row(&[
            name.to_string(),
            format!("{:.1}", *dead as f64 / 1e6),
            cycles.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("{}", r.report());

    let mut report = BenchReport::new("dfs_ablation");
    report.metric("dual_dead_us", results[0].1 .0 as f64 / 1e6);
    report.metric("single_dead_us", results[1].1 .0 as f64 / 1e6);
    report.metric("dual_cycles", results[0].1 .1 as f64);
    report.metric("single_cycles", results[1].1 .1 as f64);
    report.push(r);
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    let dual = results[0].1;
    let single = results[1].1;
    assert_eq!(dual.0, 0, "dual-MMCM never gates the clock");
    assert!(single.0 > 0, "naive design pays dead time");
    assert!(
        dual.1 > single.1,
        "dual delivers more cycles: {} vs {}",
        dual.1,
        single.1
    );
    println!("dfs_ablation bench OK");
}
