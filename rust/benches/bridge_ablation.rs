//! Ablation: MRA scaling efficiency vs. the AXI-bridge/DMA serialization
//! cost (the design choice DESIGN.md calls out).
//!
//! Sweeps the per-burst grant-switch overhead and reports the 4x
//! replication efficiency of the memory-bound dfmul: at zero cost
//! replication is ~linear; at the calibrated cost it lands on the
//! paper's ~3.0x; beyond it the shared path dominates.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::config::presets::{paper_soc, A1_POS};
use vespa::report::Table;
use vespa::scenario::Session;

fn measure(accel: &str, k: usize, switch_cycles: u64, inv: u64) -> f64 {
    let mut cfg = paper_soc((accel, k), ("dfadd", 1));
    cfg.bridge.switch_cycles = switch_cycles;
    let mut session = Session::new(cfg).unwrap();
    let tile = session.tile_at(A1_POS.0, A1_POS.1);
    session.stage(tile, 1).unwrap().perf_only();
    session
        .warmup_invocations(tile, k as u64, 400_000_000_000)
        .unwrap();
    session
        .measure_invocations(tile, inv, 2_000_000_000_000)
        .unwrap()
        .throughput_mbs
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let inv = if quick { 4 } else { 12 };
    let costs: &[u64] = if quick { &[0, 60, 120] } else { &[0, 20, 40, 60, 90, 120] };

    let bench = Bench::new(0, 1);
    let mut rows = Vec::new();
    let r = bench.run("bridge_ablation/dfmul-sweep", |_| {
        rows.clear();
        for &c in costs {
            let t1 = measure("dfmul", 1, c, inv);
            let t4 = measure("dfmul", 4, c, inv * 4);
            rows.push((c, t1, t4, t4 / t1));
        }
    });

    let mut t = Table::new(
        "AXI bridge ablation — dfmul 4x efficiency vs DMA serialization",
        &["switch cycles", "1x MB/s", "4x MB/s", "4x scaling"],
    );
    for &(c, t1, t4, eff) in &rows {
        t.row(&[
            c.to_string(),
            format!("{t1:.2}"),
            format!("{t4:.2}"),
            format!("{eff:.2}x"),
        ]);
    }
    println!("{}", t.render());
    println!("{}", r.report());

    let mut report = BenchReport::new("bridge_ablation");
    for &(c, t1, t4, eff) in &rows {
        report.metric(&format!("mbs_1x_switch{c}"), t1);
        report.metric(&format!("mbs_4x_switch{c}"), t4);
        report.metric(&format!("eff_4x_switch{c}"), eff);
    }
    report.push(r);
    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    // Shape: scaling decreases monotonically (within noise) with cost,
    // near-linear at zero.
    assert!(rows.first().unwrap().3 > 3.6, "zero-cost ~linear");
    assert!(
        rows.last().unwrap().3 < rows.first().unwrap().3 - 0.4,
        "serialization cost must bite at 4x"
    );
    println!("bridge_ablation bench OK");
}
