//! Chaos bench: a mid-run replica crash under the fault subsystem,
//! with the claim CI gates on:
//!
//! * retry + health-check replacement rescue >= 90% of the requests
//!   the crash interrupts (`rescued_fraction`, min-gated at 0.9) while
//!   the fleet still meets its p95 SLO;
//! * the same crash with no resilience loses requests for good and
//!   misses the SLO (`chaos/bare`, reported for contrast).
//!
//! The horizon is fixed at 120 ms in both quick and full modes — the
//! crash-then-recover arc needs the whole window, so `--quick` only
//! trims iterations. Writes `BENCH_chaos_recovery.json` for the CI
//! bench gate.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::cluster::ClusterSpec;
use vespa::config::SocConfig;
use vespa::fault::{FaultPlan, HealthSpec, RetrySpec};
use vespa::scenario::{ms, Scenario, Session};
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};

/// One 2-replica dfmul tile at 50 MHz — ~4250 req/s per replica SoC,
/// same box as the cluster benches.
fn fleet_cfg() -> SocConfig {
    Scenario::grid(2, 2)
        .name("chaos-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", 50, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .build()
        .unwrap()
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    println!(
        "chaos_recovery: fixed 120 ms horizon ({} mode)",
        if quick { "quick" } else { "full" }
    );

    let bench = Bench::new(1, args.iters.unwrap_or(if quick { 2 } else { 3 }));
    let mut report = BenchReport::new("chaos_recovery");

    // Slot 0's tile wedges at 36 ms so its queue is provably loaded,
    // then the replica crashes at 40 ms. 6000 rps is comfortable for
    // two ~4250 req/s replicas and hopeless for the lone survivor.
    let tile = Session::new(fleet_cfg()).expect("base session").mra_tiles()[0];
    let plan = FaultPlan::parse(&format!(
        "hang@t{tile}@r0:at=36ms,dur=4ms;crash@r0:at=40ms"
    ))
    .expect("chaos plan");
    let serve = ServeSpec::new(Arrival::Poisson { rps: 6000.0 }, ms(120))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0x5AFE)
        .faults(plan);

    let resilient_spec = ClusterSpec::new(2, serve.clone().retry(RetrySpec::new(4, 500_000_000)))
        .balancer(DispatchPolicy::RoundRobin)
        .health(HealthSpec::new());
    let bare_spec = ClusterSpec::new(2, serve).balancer(DispatchPolicy::RoundRobin);

    let r_recover = bench.run("chaos/recovery", |_| {
        resilient_spec.run(fleet_cfg()).expect("resilient run")
    });
    println!("{}", r_recover.report());
    let r_bare = bench.run("chaos/bare", |_| {
        bare_spec.run(fleet_cfg()).expect("bare run")
    });
    println!("{}", r_bare.report());

    let resilient = resilient_spec.run(fleet_cfg()).expect("resilient run");
    let bare = bare_spec.run(fleet_cfg()).expect("bare run");
    let rescued_fraction = resilient.faults.rescued_fraction();
    println!(
        "recovery: rescued {}/{} ({rescued_fraction:.3}), retried {}, failed-over {}, p95 {:.3} ms, SLO {}",
        resilient.faults.rescued,
        resilient.faults.rescued + resilient.faults.lost,
        resilient.faults.retried,
        resilient.faults.failed_over,
        resilient.latency.p95_ms(),
        match resilient.slo_met {
            Some(true) => "MET",
            Some(false) => "MISSED",
            None => "n/a",
        }
    );
    println!(
        "bare: lost {}, p95 {:.3} ms, completed {} vs {} resilient",
        bare.faults.lost,
        bare.latency.p95_ms(),
        bare.completed,
        resilient.completed
    );
    assert_eq!(
        resilient.slo_met,
        Some(true),
        "resilience must keep the SLO through the crash"
    );
    assert_eq!(bare.slo_met, Some(false), "the bare fleet must feel it");
    assert!(bare.faults.lost > 0, "the crash must lose work without retry");

    report.metric("rescued_fraction", rescued_fraction);
    report.metric("rescued", resilient.faults.rescued as f64);
    report.metric("retried", resilient.faults.retried as f64);
    report.metric("failed_over", resilient.faults.failed_over as f64);
    report.metric("resilient_p95_ms", resilient.latency.p95_ms());
    report.metric("resilient_completed", resilient.completed as f64);
    report.metric("bare_p95_ms", bare.latency.p95_ms());
    report.metric("bare_lost", bare.faults.lost as f64);
    report.push(r_recover);
    report.push(r_bare);

    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());
    println!("chaos_recovery OK");
}
