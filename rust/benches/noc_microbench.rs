//! Microbench: raw simulator performance on the NoC hot path —
//! router-cycles per second under TG saturation (the §Perf L3 metric).

use vespa::bench_harness::{bench_args, Bench};
use vespa::config::presets::paper_soc;
use vespa::runtime::RefCompute;
use vespa::sim::Soc;

fn main() {
    let (quick, _) = bench_args();
    let sim_ms = if quick { 5 } else { 20 };

    let bench = Bench::new(1, if quick { 3 } else { 5 });

    // Saturated: all TGs on, NoC at 100 MHz.
    let r = bench.run("noc/saturated-11tg", |_| {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        soc.host_set_tg_active(11);
        soc.run_for(sim_ms * 1_000_000_000);
        (soc.edges, soc.fabric.total_flits())
    });
    println!("{}", r.report());

    // Compute the engine metrics from one instrumented run.
    let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
    soc.host_set_tg_active(11);
    let t0 = std::time::Instant::now();
    soc.run_for(sim_ms * 1_000_000_000);
    let wall = t0.elapsed().as_secs_f64();
    // Router-cycles: NoC island cycles x routers (48 = 16 nodes x 3 planes).
    let router_cycles = soc.islands[0].cycles * 48;
    println!(
        "engine: {:.2} M edges/s, {:.2} M router-cycles/s, {:.2} M flits/s (sim {} ms in {:.2} s wall)",
        soc.edges as f64 / wall / 1e6,
        router_cycles as f64 / wall / 1e6,
        soc.fabric.total_flits() as f64 / wall / 1e6,
        sim_ms,
        wall
    );

    // Idle SoC (engine overhead floor).
    let r2 = bench.run("noc/idle", |_| {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        soc.run_for(sim_ms * 1_000_000_000);
        soc.edges
    });
    println!("{}", r2.report());
    println!("noc_microbench OK");
}
