//! Microbench: raw simulator performance on the NoC hot path —
//! router-cycles per second under TG saturation (the §Perf L3 metric) —
//! plus the idle-aware and event-driven engines' wins on
//! low-utilization traffic, measured against the `reference`
//! tick-everything engine.
//!
//! Writes `BENCH_noc_microbench.json` (override with `--json <path>`);
//! the `sparse_speedup_vs_reference` (>= 3x) and
//! `sparse_event_speedup_vs_reference` (>= 10x) metrics are the
//! CI-gated proof that deadline coalescing and heap scheduling pay off.

use vespa::bench_harness::{Bench, BenchArgs, BenchReport};
use vespa::config::presets::paper_soc;
use vespa::config::SocConfig;
use vespa::runtime::RefCompute;
use vespa::scenario::Scenario;
use vespa::sim::{EngineMode, Soc};
use vespa::tiles::Tile;

/// A 4x4 SoC with sparse, bursty TG traffic and no accelerators: every
/// TG issues one burst every ~1500 TG cycles, so the NoC drains and the
/// whole SoC goes quiescent between bursts — the DS3-style
/// low-utilization case event-driven simulation exists for.
fn sparse_cfg() -> SocConfig {
    Scenario::grid(4, 4)
        .name("noc-microbench-sparse")
        .seed(0x51AB)
        .island_dfs("noc-mem", 100, 10..=100, 5)
        .island_dfs("tg", 50, 10..=50, 5)
        .noc_island("noc-mem")
        .mem_at(0, 0)
        .io_at_on(2, 0, "tg")
        .fill_tg("tg")
        .build()
        .expect("sparse preset is structurally valid")
}

fn build_sparse(engine: EngineMode, active_tgs: usize) -> Soc {
    let mut soc = Soc::build(sparse_cfg(), Box::new(RefCompute::new())).unwrap();
    soc.engine = engine;
    for t in &mut soc.tiles {
        if let Tile::Tg(tg) = t {
            tg.gap_cycles = 1500;
        }
    }
    soc.host_set_tg_active(active_tgs);
    soc
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick;
    let sim_ms = if quick { 5 } else { 20 };
    let sim_ps = sim_ms * 1_000_000_000;

    let bench = Bench::new(1, args.iters.unwrap_or(if quick { 3 } else { 5 }));
    let mut report = BenchReport::new("noc_microbench");

    // Saturated: all TGs on, NoC at 100 MHz — the default engine's
    // (event-driven) worst case: nothing idle, every deadline fires.
    let r = bench.run("noc/saturated-11tg", |_| {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        soc.host_set_tg_active(11);
        soc.run_for(sim_ps);
        (soc.edges, soc.fabric.total_flits())
    });
    println!("{}", r.report());
    report.push(r);

    // Compute the engine metrics from one instrumented run.
    let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
    soc.host_set_tg_active(11);
    let t0 = std::time::Instant::now();
    soc.run_for(sim_ps);
    let wall = t0.elapsed().as_secs_f64();
    // Router-cycles: NoC island cycles x routers (48 = 16 nodes x 3 planes).
    let router_cycles = soc.islands[0].cycles * 48;
    println!(
        "engine: {:.2} M edges/s, {:.2} M router-cycles/s, {:.2} M flits/s (sim {} ms in {:.2} s wall)",
        soc.edges as f64 / wall / 1e6,
        router_cycles as f64 / wall / 1e6,
        soc.fabric.total_flits() as f64 / wall / 1e6,
        sim_ms,
        wall
    );
    report.metric("saturated_edges_per_s", soc.edges as f64 / wall);
    report.metric("saturated_flits_per_s", soc.fabric.total_flits() as f64 / wall);

    // Low utilization: sparse bursty TGs, idle-aware vs reference. Both
    // runs must agree bit-exactly; the wall-clock ratio is the payoff.
    let r_idle = bench.run("noc/low-util-sparse", |_| {
        let mut soc = build_sparse(EngineMode::IdleAware, 11);
        soc.run_for(sim_ps);
        soc.edges
    });
    println!("{}", r_idle.report());
    let r_event = bench.run("noc/low-util-sparse-event", |_| {
        let mut soc = build_sparse(EngineMode::EventDriven, 11);
        soc.run_for(sim_ps);
        soc.edges
    });
    println!("{}", r_event.report());
    let r_ref = bench.run("noc/low-util-sparse-reference", |_| {
        let mut soc = build_sparse(EngineMode::Reference, 11);
        soc.run_for(sim_ps);
        soc.edges
    });
    println!("{}", r_ref.report());

    // Equivalence spot-check on the bench scenario itself.
    let mut a = build_sparse(EngineMode::IdleAware, 11);
    let mut b = build_sparse(EngineMode::Reference, 11);
    let mut c = build_sparse(EngineMode::EventDriven, 11);
    a.run_for(sim_ps);
    b.run_for(sim_ps);
    c.run_for(sim_ps);
    assert_eq!(a.edges, b.edges, "engines disagree on delivered edges");
    assert_eq!(c.edges, b.edges, "event engine disagrees on edges");
    assert_eq!(
        a.mon.mem_pkts_in, b.mon.mem_pkts_in,
        "engines disagree on memory traffic"
    );
    assert_eq!(
        c.mon.mem_pkts_in, b.mon.mem_pkts_in,
        "event engine disagrees on memory traffic"
    );
    assert_eq!(
        a.fabric.total_flits(),
        b.fabric.total_flits(),
        "engines disagree on flits"
    );
    assert_eq!(
        c.fabric.total_flits(),
        b.fabric.total_flits(),
        "event engine disagrees on flits"
    );
    println!(
        "sparse scenario: {} edges, {} coalesced over {} spans, {} tile ticks ({} skipped)",
        a.edges,
        a.engine_stats.coalesced_edges,
        a.engine_stats.coalesced_spans,
        a.engine_stats.tile_ticks,
        a.engine_stats.skipped_tile_ticks,
    );
    assert!(
        a.engine_stats.coalesced_edges > a.edges / 2,
        "sparse workload should be dominated by coalesced spans"
    );

    let speedup = r_ref.mean.as_secs_f64() / r_idle.mean.as_secs_f64();
    println!("idle-aware speedup on low-utilization traffic: {speedup:.1}x");
    let event_speedup = r_ref.mean.as_secs_f64() / r_event.mean.as_secs_f64();
    println!("event-driven speedup on low-utilization traffic: {event_speedup:.1}x");
    report.metric("sparse_speedup_vs_reference", speedup);
    report.metric("sparse_event_speedup_vs_reference", event_speedup);
    report.metric("sparse_coalesced_edges", a.engine_stats.coalesced_edges as f64);
    report.push(r_idle);
    report.push(r_event);
    report.push(r_ref);

    // Idle SoC (engine overhead floor, MRA tiles self-driving).
    let r2 = bench.run("noc/idle", |_| {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        soc.run_for(sim_ps);
        soc.edges
    });
    println!("{}", r2.report());
    report.push(r2);

    let path = report.write(args.json_path()).expect("write bench report");
    println!("wrote {}", path.display());

    assert!(
        speedup >= 3.0,
        "idle-aware engine must be >= 3x on low-utilization traffic, got {speedup:.2}x"
    );
    assert!(
        event_speedup >= 10.0,
        "event engine must be >= 10x on low-utilization traffic, got {event_speedup:.2}x"
    );
    println!("noc_microbench OK");
}
