//! AXI4-Stream channel: a bounded valid/ready FIFO of beats.
//!
//! A beat is one transfer on the stream — for the control streams a DMA
//! descriptor, for the data streams one 32-bit word. The FIFO capacity
//! models the skid/packing buffers of the tile; `try_push`/`pop` are the
//! valid/ready handshake.

use std::collections::VecDeque;

/// One stream transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamBeat {
    /// Replica that produced/owns the beat (demux key on rdData).
    pub replica: u8,
    /// Descriptor or data word identifier; semantics are per-stream and
    /// owned by the MRA tile logic (e.g. burst tag for ctrl beats).
    pub payload: u64,
    /// TLAST marker (end of burst/descriptor).
    pub last: bool,
}

/// A bounded AXI4-Stream FIFO.
#[derive(Debug, Clone)]
pub struct AxiStream {
    cap: usize,
    q: VecDeque<StreamBeat>,
    /// Total beats accepted (TVALID & TREADY count).
    pub beats: u64,
    /// Cycles a producer presented a beat but the FIFO was full
    /// (TVALID & !TREADY) — recorded by callers via `note_stall`.
    pub stall_beats: u64,
}

impl AxiStream {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            q: VecDeque::with_capacity(cap),
            beats: 0,
            stall_beats: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.cap
    }

    /// TVALID/TREADY handshake: accepts the beat iff space is available.
    pub fn try_push(&mut self, beat: StreamBeat) -> bool {
        if self.is_full() {
            self.stall_beats += 1;
            false
        } else {
            self.q.push_back(beat);
            self.beats += 1;
            true
        }
    }

    pub fn peek(&self) -> Option<&StreamBeat> {
        self.q.front()
    }

    pub fn pop(&mut self) -> Option<StreamBeat> {
        self.q.pop_front()
    }

    pub fn note_stall(&mut self) {
        self.stall_beats += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn beat(replica: u8, payload: u64) -> StreamBeat {
        StreamBeat {
            replica,
            payload,
            last: false,
        }
    }

    #[test]
    fn handshake_accepts_until_full() {
        let mut s = AxiStream::new(2);
        assert!(s.try_push(beat(0, 1)));
        assert!(s.try_push(beat(0, 2)));
        assert!(!s.try_push(beat(0, 3)));
        assert_eq!(s.beats, 2);
        assert_eq!(s.stall_beats, 1);
    }

    #[test]
    fn fifo_order() {
        let mut s = AxiStream::new(4);
        for i in 0..4 {
            s.try_push(beat(0, i));
        }
        for i in 0..4 {
            assert_eq!(s.pop().unwrap().payload, i);
        }
        assert!(s.pop().is_none());
    }

    #[test]
    fn last_marker_carried() {
        let mut s = AxiStream::new(2);
        s.try_push(StreamBeat {
            replica: 3,
            payload: 9,
            last: true,
        });
        let b = s.pop().unwrap();
        assert!(b.last);
        assert_eq!(b.replica, 3);
    }
}
