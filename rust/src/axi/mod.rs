//! AXI4-Stream channel models and the multi-replica accelerator bridge.
//!
//! An ESP accelerator exposes four AXI4-Stream interfaces — read control
//! (*rdCtrl*), write control (*wrCtrl*), read data (*rdData*), write data
//! (*wrData*). Vespa's MRA tile (paper contribution 1) instantiates `K`
//! replicas and multiplexes their streams into the tile's four
//! NoC-facing streams through the [`bridge::AxiBridge`], which is the
//! architectural point where replication contention (and hence Table I's
//! sub-linear throughput scaling) arises.

pub mod bridge;
pub mod stream;

pub use bridge::{AxiBridge, BridgeParams, BridgeStats};
pub use stream::{AxiStream, StreamBeat};
