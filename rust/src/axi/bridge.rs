//! The MRA tile's *AXI bridge*: K replicas' four AXI4-Stream interfaces
//! multiplexed into the tile's four NoC-facing streams (Fig. 1).
//!
//! Three upstream streams are K-to-1 muxes (rdCtrl, wrCtrl, wrData) and
//! one downstream stream is a 1-to-K demux (rdData, keyed by the replica
//! tag assigned when the read burst was issued).
//!
//! Arbitration is round-robin at *burst* granularity: once a replica is
//! granted a stream it keeps it until a TLAST beat, and re-granting the
//! stream to a different replica costs [`BridgeParams::switch_cycles`]
//! (descriptor framing + mux retiming). That per-burst overhead is the
//! architectural source of the sub-linear memory-bound scaling Table I
//! reports (dfadd/dfmul: ~1.8x at K=2, ~2.8-3.0x at K=4), while
//! compute-bound accelerators (adpcm, dfsin) hide it entirely.

use super::stream::{AxiStream, StreamBeat};

/// Upstream mux streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum UpStream {
    RdCtrl = 0,
    WrCtrl = 1,
    WrData = 2,
}

pub const NUM_UP: usize = 3;

/// Bridge configuration.
#[derive(Debug, Clone)]
pub struct BridgeParams {
    /// Replication factor K.
    pub replicas: usize,
    /// Depth of each per-replica FIFO (per stream).
    pub replica_fifo_depth: usize,
    /// Depth of each tile-side FIFO (per stream).
    pub tile_fifo_depth: usize,
    /// Cycles lost when a stream's grant moves to a different replica.
    pub switch_cycles: u64,
}

impl Default for BridgeParams {
    fn default() -> Self {
        Self {
            replicas: 1,
            replica_fifo_depth: 8,
            tile_fifo_depth: 16,
            switch_cycles: 60,
        }
    }
}

/// Aggregate statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct BridgeStats {
    /// Beats muxed upstream per stream.
    pub up_beats: [u64; NUM_UP],
    /// Beats demuxed downstream (rdData).
    pub down_beats: u64,
    /// Grant changes per upstream stream.
    pub switches: [u64; NUM_UP],
    /// Cycles spent in switch penalty.
    pub switch_stall_cycles: u64,
}

/// Mux state of one upstream stream.
#[derive(Debug, Clone)]
struct MuxState {
    /// Currently granted replica (held until TLAST).
    grant: Option<usize>,
    /// Round-robin pointer.
    rr: usize,
    /// Remaining switch-penalty cycles.
    penalty: u64,
}

/// The bridge.
#[derive(Debug, Clone)]
pub struct AxiBridge {
    params: BridgeParams,
    /// Per-replica upstream FIFOs: `up[stream][replica]`.
    up: [Vec<AxiStream>; NUM_UP],
    /// Tile-side upstream FIFOs (towards the NoC NI).
    pub tile_up: [AxiStream; NUM_UP],
    /// Tile-side downstream FIFO (from the NoC NI).
    pub tile_rd_data: AxiStream,
    /// Per-replica downstream FIFOs.
    rd_data: Vec<AxiStream>,
    mux: [MuxState; NUM_UP],
    pub stats: BridgeStats,
}

impl AxiBridge {
    pub fn new(params: BridgeParams) -> Self {
        assert!(params.replicas >= 1);
        let mk_up = |depth: usize, n: usize| -> Vec<AxiStream> {
            (0..n).map(|_| AxiStream::new(depth)).collect()
        };
        let mux = MuxState {
            grant: None,
            rr: 0,
            penalty: 0,
        };
        Self {
            up: [
                mk_up(params.replica_fifo_depth, params.replicas),
                mk_up(params.replica_fifo_depth, params.replicas),
                mk_up(params.replica_fifo_depth, params.replicas),
            ],
            tile_up: [
                AxiStream::new(params.tile_fifo_depth),
                AxiStream::new(params.tile_fifo_depth),
                AxiStream::new(params.tile_fifo_depth),
            ],
            tile_rd_data: AxiStream::new(params.tile_fifo_depth),
            rd_data: mk_up(params.replica_fifo_depth, params.replicas),
            mux: [mux.clone(), mux.clone(), mux],
            stats: BridgeStats::default(),
            params,
        }
    }

    pub fn replicas(&self) -> usize {
        self.params.replicas
    }

    /// Replica-side push onto an upstream stream (accelerator -> bridge).
    pub fn push_up(&mut self, stream: UpStream, replica: usize, beat: StreamBeat) -> bool {
        self.up[stream as usize][replica].try_push(beat)
    }

    /// Replica-side upstream space check.
    pub fn can_push_up(&self, stream: UpStream, replica: usize) -> bool {
        !self.up[stream as usize][replica].is_full()
    }

    /// Replica-side pop from the rdData demux (bridge -> accelerator).
    pub fn pop_rd_data(&mut self, replica: usize) -> Option<StreamBeat> {
        self.rd_data[replica].pop()
    }

    pub fn rd_data_len(&self, replica: usize) -> usize {
        self.rd_data[replica].len()
    }

    /// Whether a tick would be a provable no-op: every FIFO (replica- and
    /// tile-side, both directions) empty and no mux switch penalty
    /// pending. Penalty cycles mutate stats each tick, so they count as
    /// work. Held grants with empty FIFOs do nothing and don't count.
    pub fn is_quiet(&self) -> bool {
        self.mux.iter().all(|m| m.penalty == 0)
            && self.tile_rd_data.is_empty()
            && self.tile_up.iter().all(AxiStream::is_empty)
            && self.rd_data.iter().all(AxiStream::is_empty)
            && self.up.iter().all(|s| s.iter().all(|f| f.is_empty()))
    }

    /// One bridge cycle (at the accelerator island clock): advance each
    /// upstream mux by at most one beat and the rdData demux by one beat.
    pub fn tick(&mut self) {
        for s in 0..NUM_UP {
            self.tick_mux(s);
        }
        self.tick_demux();
    }

    fn tick_mux(&mut self, s: usize) {
        if self.mux[s].penalty > 0 {
            self.mux[s].penalty -= 1;
            self.stats.switch_stall_cycles += 1;
            return;
        }
        if self.tile_up[s].is_full() {
            self.tile_up[s].note_stall();
            return;
        }
        let k = self.params.replicas;

        // Hold the grant until TLAST; otherwise arbitrate round-robin.
        let grantee = match self.mux[s].grant {
            Some(g) if !self.up[s][g].is_empty() => Some(g),
            Some(_) => None, // granted replica has nothing to send yet
            None => {
                let mut found = None;
                for i in 0..k {
                    let r = (self.mux[s].rr + i) % k;
                    if !self.up[s][r].is_empty() {
                        found = Some(r);
                        break;
                    }
                }
                if let Some(r) = found {
                    self.mux[s].rr = (r + 1) % k;
                    // Switching the mux to a new replica costs cycles —
                    // but only if it actually changes source.
                    let changed = self.mux[s].grant != Some(r);
                    self.mux[s].grant = Some(r);
                    if changed {
                        self.stats.switches[s] += 1;
                        if self.params.switch_cycles > 0 && k > 1 {
                            self.mux[s].penalty = self.params.switch_cycles;
                            self.stats.switch_stall_cycles += 1;
                            return; // penalty starts this cycle
                        }
                    }
                    Some(r)
                } else {
                    None
                }
            }
        };

        if let Some(g) = grantee {
            if let Some(beat) = self.up[s][g].pop() {
                let ok = self.tile_up[s].try_push(beat);
                debug_assert!(ok, "tile FIFO space checked above");
                self.stats.up_beats[s] += 1;
                if beat.last {
                    self.mux[s].grant = None;
                }
            }
        }
    }

    fn tick_demux(&mut self) {
        let Some(beat) = self.tile_rd_data.peek().copied() else {
            return;
        };
        let r = beat.replica as usize;
        assert!(r < self.params.replicas, "rdData beat for unknown replica");
        if self.rd_data[r].is_full() {
            self.rd_data[r].note_stall();
            return;
        }
        self.tile_rd_data.pop();
        self.rd_data[r].try_push(beat);
        self.stats.down_beats += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn beat(replica: u8, payload: u64, last: bool) -> StreamBeat {
        StreamBeat {
            replica,
            payload,
            last,
        }
    }

    fn bridge(k: usize, switch: u64) -> AxiBridge {
        AxiBridge::new(BridgeParams {
            replicas: k,
            replica_fifo_depth: 8,
            tile_fifo_depth: 16,
            switch_cycles: switch,
        })
    }

    #[test]
    fn quiescence_probe_tracks_beats_and_penalties() {
        let mut b = bridge(1, 12);
        assert!(b.is_quiet());
        b.push_up(UpStream::RdCtrl, 0, beat(0, 1, true));
        assert!(!b.is_quiet(), "replica-side beat pending");
        b.tick();
        assert!(!b.is_quiet(), "beat muxed to the tile side");
        b.tile_up[0].pop();
        assert!(b.is_quiet());

        // K=2 with a switch cost: the penalty cycles count as work.
        let mut b = bridge(2, 4);
        b.push_up(UpStream::RdCtrl, 0, beat(0, 1, true));
        b.tick(); // grant switch starts the penalty
        assert!(!b.is_quiet(), "switch penalty pending");
    }

    #[test]
    fn single_replica_passthrough_no_penalty() {
        let mut b = bridge(1, 12);
        b.push_up(UpStream::RdCtrl, 0, beat(0, 1, true));
        b.tick();
        assert_eq!(b.tile_up[0].pop().unwrap().payload, 1);
        assert_eq!(b.stats.switch_stall_cycles, 0, "K=1 never pays switches");
    }

    #[test]
    fn burst_granularity_no_interleave() {
        let mut b = bridge(2, 0);
        // Replica 0: 3-beat burst; replica 1: 1-beat burst.
        for i in 0..3 {
            b.push_up(UpStream::WrData, 0, beat(0, i, i == 2));
        }
        b.push_up(UpStream::WrData, 1, beat(1, 100, true));
        for _ in 0..6 {
            b.tick();
        }
        let order: Vec<u8> = std::iter::from_fn(|| b.tile_up[2].pop())
            .map(|x| x.replica)
            .collect();
        assert_eq!(order, vec![0, 0, 0, 1], "burst must not interleave");
    }

    #[test]
    fn switch_penalty_costs_cycles() {
        let mut b0 = bridge(2, 0);
        let mut b4 = bridge(2, 4);
        for b in [&mut b0, &mut b4] {
            b.push_up(UpStream::RdCtrl, 0, beat(0, 1, true));
            b.push_up(UpStream::RdCtrl, 1, beat(1, 2, true));
        }
        let drained = |b: &mut AxiBridge, cycles: usize| -> usize {
            for _ in 0..cycles {
                b.tick();
            }
            let mut n = 0;
            while b.tile_up[0].pop().is_some() {
                n += 1;
            }
            n
        };
        assert_eq!(drained(&mut b0, 2), 2, "no-penalty drains in 2");
        assert!(drained(&mut b4, 2) < 2, "penalty delays the mux");
    }

    #[test]
    fn rr_is_fair_across_replicas() {
        let mut b = bridge(4, 0);
        for r in 0..4u8 {
            for i in 0..2 {
                b.push_up(UpStream::RdCtrl, r as usize, beat(r, i, true));
            }
        }
        for _ in 0..8 {
            b.tick();
        }
        let order: Vec<u8> = std::iter::from_fn(|| b.tile_up[0].pop())
            .map(|x| x.replica)
            .collect();
        assert_eq!(order.len(), 8);
        // First four grants hit each replica exactly once.
        let mut first: Vec<u8> = order[..4].to_vec();
        first.sort();
        assert_eq!(first, vec![0, 1, 2, 3]);
    }

    #[test]
    fn demux_routes_by_replica_tag() {
        let mut b = bridge(2, 0);
        b.tile_rd_data.try_push(beat(1, 11, false));
        b.tile_rd_data.try_push(beat(0, 22, false));
        b.tick();
        b.tick();
        assert_eq!(b.pop_rd_data(1).unwrap().payload, 11);
        assert_eq!(b.pop_rd_data(0).unwrap().payload, 22);
        assert_eq!(b.stats.down_beats, 2);
    }

    #[test]
    fn demux_backpressure_per_replica() {
        let mut b = AxiBridge::new(BridgeParams {
            replicas: 2,
            replica_fifo_depth: 1,
            tile_fifo_depth: 8,
            switch_cycles: 0,
        });
        b.tile_rd_data.try_push(beat(0, 1, false));
        b.tile_rd_data.try_push(beat(0, 2, false));
        b.tick();
        b.tick(); // replica-0 FIFO full: second beat blocked
        assert_eq!(b.rd_data_len(0), 1);
        assert_eq!(b.tile_rd_data.len(), 1);
        b.pop_rd_data(0);
        b.tick();
        assert_eq!(b.rd_data_len(0), 1);
    }

    #[test]
    fn stream_isolation() {
        // Beats on wrCtrl never appear on rdCtrl.
        let mut b = bridge(2, 0);
        b.push_up(UpStream::WrCtrl, 0, beat(0, 7, true));
        b.tick();
        assert!(b.tile_up[0].is_empty());
        assert_eq!(b.tile_up[1].pop().unwrap().payload, 7);
    }
}
