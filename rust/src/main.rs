//! `vespa` — the framework launcher.
//!
//! Subcommands:
//!   run <config.toml>   simulate a SoC described by a config file
//!   table1              reproduce Table I (area + throughput, 1x/2x/4x)
//!   fig2 | floorplan    reproduce Fig. 2 (floorplan)
//!   fig3                reproduce Fig. 3 (throughput vs TG pressure)
//!   fig4                reproduce Fig. 4 (memory traffic vs DFS)
//!   dse                 replication/frequency design-space sweep
//!   validate <config>   parse + validate a config file
//!   accels              list the accelerator DB
//!   artifacts-check     load artifacts and cross-check PJRT vs native
//!
//! Global options: --artifacts <dir> to use the PJRT backend where
//! applicable; experiments default to the native reference backend.

use vespa::cli::Args;
use vespa::config::SocConfig;
use vespa::dse::{
    pareto_front, sweep_replication, sweep_replication_serial, SweepMode, SweepParams,
};
use vespa::experiments::{fig2, fig3, fig4, table1};
use vespa::mem::Block;
use vespa::report::{plot, Table};
use vespa::resources::AccelArea;
use vespa::runtime::{AccelCompute, Manifest, PjrtCompute, RefCompute};
use vespa::scenario::Session;
use vespa::tiles::AccelTiming;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: vespa <run|table1|fig2|fig3|fig4|dse|validate|accels|artifacts-check> [options]\n\
         options:\n\
           --invocations N     Table I measurement window (default 6)\n\
           --window-ms N       Fig. 3 window per point (default 10)\n\
           --phase-ms N        Fig. 4 phase length (default 30)\n\
           --accel NAME        DSE target accelerator (default dfmul)\n\
           --serial            DSE: disable the parallel scenario runner\n\
           --warm              DSE: warm-fork sweep (snapshot + DFS retune per point)\n\
           --artifacts DIR     use the PJRT backend from DIR\n\
           --duration-ms N     `run` duration (default 10)\n\
           --tg N              `run`: active TG count (default 0)"
    );
}

fn backend(args: &Args) -> vespa::Result<Box<dyn AccelCompute>> {
    match args.opt("artifacts") {
        Some(dir) => Ok(Box::new(PjrtCompute::load(dir)?)),
        None => Ok(Box::new(RefCompute::new())),
    }
}

fn dispatch(args: &Args) -> vespa::Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("table1") => {
            let inv = args.opt_u64("invocations", 6)?;
            let (t, rows) = table1::run(inv)?;
            println!("{}", t.render());
            let (r2, r4) = table1::average_increments(&rows);
            println!("Average throughput increment: 2x = {r2:.2}x, 4x = {r4:.2}x");
            println!("(paper: 2x = 1.92x, 4x = 3.58x)");
            Ok(())
        }
        Some("fig2") | Some("floorplan") => {
            let (s, _) = fig2::run()?;
            println!("{s}");
            Ok(())
        }
        Some("fig3") => {
            let win = args.opt_u64("window-ms", 60)? * 1_000_000_000;
            // Warmup covers the slowest pipeline fill (adpcm 4x: ~23 ms
            // per replica invocation at 50 MHz).
            let warm = args.opt_u64("warmup-ms", 30)? * 1_000_000_000;
            let (t, adpcm, dfmul) = fig3::run(warm, win)?;
            println!("{}", t.render());
            let mut sa = vespa::monitor::TimeSeries::new("adpcm4x");
            let mut sd = vespa::monitor::TimeSeries::new("dfmul4x");
            for p in &adpcm {
                sa.push(p.tg_active as u64 * 1_000_000, p.thr_mbs);
            }
            for p in &dfmul {
                sd.push(p.tg_active as u64 * 1_000_000, p.thr_mbs);
            }
            println!("{}", plot(&[&sa, &sd], 60, 16));
            Ok(())
        }
        Some("fig4") => {
            let phase = args.opt_u64("phase-ms", 30)? * 1_000_000_000;
            let r = fig4::run(phase, 1_000_000_000)?;
            println!("{}", fig4::render_table(&r).render());
            println!("{}", plot(&[&r.pkts_rate], 70, 14));
            Ok(())
        }
        Some("dse") => cmd_dse(args),
        Some("validate") => {
            let path = args
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("validate: missing config path"))?;
            let cfg = SocConfig::load(path)?;
            println!(
                "OK: {} — {}x{} grid, {} tiles, {} islands",
                cfg.name,
                cfg.width,
                cfg.height,
                cfg.tiles.len(),
                cfg.islands.len()
            );
            Ok(())
        }
        Some("accels") => {
            let mut t = Table::new(
                "Accelerator DB (CHStone via HLS)",
                &["name", "LUT", "FF", "BRAM", "DSP", "MB/s @50MHz", "class"],
            );
            for a in AccelArea::db() {
                let timing = AccelTiming::lookup(a.name)?;
                t.row(&[
                    a.name.to_string(),
                    a.baseline_tile.lut.to_string(),
                    a.baseline_tile.ff.to_string(),
                    a.baseline_tile.bram.to_string(),
                    a.baseline_tile.dsp.to_string(),
                    format!("{:.2}", timing.ideal_throughput_mbs(50)),
                    if timing.memory_bound {
                        "memory-bound".into()
                    } else {
                        "compute-bound".into()
                    },
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Some("artifacts-check") => cmd_artifacts_check(args),
        _ => {
            usage();
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> vespa::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("run: missing config path"))?;
    let cfg = SocConfig::load(path)?;
    let mut session = Session::with_backend(cfg, backend(args)?)?;
    let dur = args.opt_u64("duration-ms", 10)? * 1_000_000_000;
    session
        .stage_all(1)?
        .with_tg_load(args.opt_usize("tg", 0)?)
        .warmup(dur);
    let soc = session.soc();

    let mut t = Table::new(
        format!("run {} for {} ms", soc.cfg.name, dur / 1_000_000_000),
        &["tile", "kind", "inv", "pkts_in", "pkts_out", "rtt_ns", "exec_cycles"],
    );
    for (i, tile) in soc.tiles.iter().enumerate() {
        let c = soc.mon.tile(i);
        if c.pkts_in + c.pkts_out + c.invocations == 0 {
            continue;
        }
        t.row(&[
            i.to_string(),
            tile.kind_name().to_string(),
            c.invocations.to_string(),
            c.pkts_in.to_string(),
            c.pkts_out.to_string(),
            format!("{:.0}", c.rtt_mean() / 1e3),
            c.exec_cycles.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "mem: {} pkts in, {} data beats; NoC flits {}; backend {}",
        soc.mon.mem_pkts_in,
        soc.mon.mem_beats_in,
        soc.fabric.total_flits(),
        soc.compute.backend(),
    );
    Ok(())
}

fn cmd_dse(args: &Args) -> vespa::Result<()> {
    let accel = args.opt_str("accel", "dfmul");
    let mut p = SweepParams::quick(&accel);
    if args.flag("wide") {
        p.accel_mhz = vec![25, 50];
        p.noc_mhz = vec![50, 100];
        p.placements = vec![true, false];
    }
    if args.flag("quick") {
        p.window = 4_000_000_000;
        p.warmup = 500_000_000;
    }
    if args.flag("warm") {
        // Warm-fork: one warmed base SoC per structure, frequency points
        // fork its snapshot and retune through the DFS actuators.
        p.mode = SweepMode::WarmFork;
        // --serial selects the always-cold unmemoized reference path,
        // which would silently drop --warm; a deterministic warm sweep
        // is `--warm` alone with `threads = 1` semantics instead.
        anyhow::ensure!(
            !args.flag("serial"),
            "--warm and --serial are mutually exclusive (--serial is the cold reference path)"
        );
    }
    // Parallel across cores by default; --serial for the reference path
    // (results are bit-identical either way).
    let pts = if args.flag("serial") {
        sweep_replication_serial(&p)?
    } else {
        sweep_replication(&p)?
    };
    let mut t = Table::new(
        format!("DSE — {accel}"),
        &["K", "accel MHz", "NoC MHz", "near", "LUT", "DSP", "MB/s", "pareto"],
    );
    let costs: Vec<(f64, f64)> = pts
        .iter()
        .map(|pt| (pt.area.lut as f64, pt.throughput_mbs))
        .collect();
    let front = pareto_front(&costs);
    for (i, pt) in pts.iter().enumerate() {
        t.row(&[
            pt.replicas.to_string(),
            pt.accel_mhz.to_string(),
            pt.noc_mhz.to_string(),
            if pt.near_mem { "A1" } else { "A2" }.to_string(),
            pt.area.lut.to_string(),
            pt.area.dsp.to_string(),
            format!("{:.2}", pt.throughput_mbs),
            if front.contains(&i) { "*" } else { "" }.to_string(),
        ]);
    }
    println!("{}", t.render());
    // The evaluator floors warmup/window to the accelerator's invocation
    // time; report what was actually simulated (spread over the sweep's
    // frequency range when points disagree).
    let lo = pts.iter().map(|pt| pt.eff_window_ps).min().unwrap_or(0);
    let hi = pts.iter().map(|pt| pt.eff_window_ps).max().unwrap_or(0);
    let wlo = pts.iter().map(|pt| pt.eff_warmup_ps).min().unwrap_or(0);
    let whi = pts.iter().map(|pt| pt.eff_warmup_ps).max().unwrap_or(0);
    println!(
        "effective phases: warmup {:.1}..{:.1} ms, window {:.1}..{:.1} ms per point",
        wlo as f64 / 1e9,
        whi as f64 / 1e9,
        lo as f64 / 1e9,
        hi as f64 / 1e9
    );
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> vespa::Result<()> {
    let dir = args.opt_str("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    println!("manifest: {} modules from {dir}", manifest.modules.len());
    let mut pjrt = PjrtCompute::from_manifest(manifest.clone())?;
    let mut refc = RefCompute::new();
    let mut rng = vespa::util::SplitMix64::new(7);

    for (name, spec) in &manifest.modules {
        let inputs: Vec<Block> = spec
            .inputs
            .iter()
            .map(|ts| match ts.dtype {
                vespa::runtime::DType::F32 => {
                    Block::F32((0..ts.elems()).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                }
                vespa::runtime::DType::S32 => Block::I32(
                    (0..ts.elems())
                        .map(|_| rng.range_i64(-32768, 32767) as i32)
                        .collect(),
                ),
            })
            .collect();
        let refs: Vec<&Block> = inputs.iter().collect();
        let a = pjrt.invoke(name, &refs)?;
        let b = refc.invoke(name, &refs)?;
        let mut max_err = 0f64;
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (Block::F32(u), Block::F32(v)) => {
                    for (p, q) in u.iter().zip(v) {
                        max_err = max_err.max((p - q).abs() as f64);
                    }
                }
                (Block::I32(u), Block::I32(v)) => {
                    anyhow::ensure!(u == v, "{name}: integer outputs differ");
                }
                _ => anyhow::bail!("{name}: output dtype mismatch"),
            }
        }
        println!("  {name}: PJRT vs native max |err| = {max_err:.2e}  OK");
    }
    println!("artifacts-check OK ({} PJRT invocations)", pjrt.invocations);
    Ok(())
}
