//! `vespa` — the framework launcher.
//!
//! Subcommands come from the [`vespa::cli::SUBCOMMANDS`] registry (one
//! name + one-line description each); `vespa` with no subcommand or an
//! unknown one prints the full list. Highlights: `run` a config file,
//! `serve` open-loop traffic on one SoC, `cluster` a fleet of replica
//! SoCs behind a front-end balancer with an optional autoscaler, `dse`
//! replication/frequency/fleet sweeps, and the paper's `table1` /
//! `fig2`..`fig4` reproductions.
//!
//! Global options: --artifacts <dir> to use the PJRT backend where
//! applicable; experiments default to the native reference backend.

use vespa::cli::Args;
use vespa::cluster::{AutoscaleSpec, ClusterSpec};
use vespa::fault::{FaultPlan, HealthSpec, RetrySpec};
use vespa::config::presets::{A1_POS, A2_POS};
use vespa::config::SocConfig;
use vespa::dse::{
    pareto_front, rank_by_p99_under_slo, rank_by_replica_seconds_under_slo, sweep_replication,
    sweep_replication_serial, Objective, SweepMode, SweepParams,
};
use vespa::experiments::{fig2, fig3, fig4, table1};
use vespa::mem::Block;
use vespa::report::{plot, Table};
use vespa::resources::AccelArea;
use vespa::runtime::{AccelCompute, Manifest, PjrtCompute, RefCompute};
use vespa::scenario::Session;
use vespa::serve::{Arrival, DispatchPolicy, GovernorSpec, ServeSpec};
use vespa::tiles::AccelTiming;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "{header}\n\
         subcommands:\n\
         {subs}\n\
         options:\n\
           --invocations N     Table I measurement window (default 6)\n\
           --window-ms N       Fig. 3 window per point (default 10)\n\
           --phase-ms N        Fig. 4 phase length (default 30)\n\
           --accel NAME        DSE/serve/cluster target accelerator (default dfmul)\n\
           --serial            DSE: disable the parallel scenario runner\n\
           --warm              DSE: warm-fork sweep (snapshot + DFS retune per point)\n\
           --serve-rps N       DSE: rank points by p99-under-SLO at N req/s\n\
           --serve-ms N        DSE: serving horizon per point in ms (default 100)\n\
           --fleets A,B,..     DSE: evaluate fleet sizes, rank by replica-seconds\n\
           --artifacts DIR     use the PJRT backend from DIR\n\
           --duration-ms N     `run`/`serve`/`cluster` duration (default 10/200/100)\n\
           --tg N              `run`: active TG count (default 0)\n\
           --engine E          `run`/`serve`/`cluster` engine: reference | idle | event\n\
         serve/cluster options:\n\
           --rps N             offered Poisson load in req/s (default 1000 / 4000)\n\
           --policy P          per-SoC dispatch: rr | jsq | least (default jsq)\n\
           --queue N           per-tile admission queue bound (default 32)\n\
           --slo-ms N          p95 latency SLO in ms\n\
           --governor          queue-driven DFS governor on the A1 island\n\
           --seed N            arrival seed (default 0xE5B)\n\
           --json PATH         also write the report as JSON to PATH\n\
           --faults SPEC       deterministic fault plan, e.g.\n\
                               'hang@t5:at=10ms,dur=5ms;crash@r0:at=20ms'\n\
           --retry N           admission retries: N total attempts\n\
           --retry-backoff-us N  base retry backoff (default 500, doubles)\n\
           --deadline-ms N     per-request retry deadline from arrival\n\
           --trace PATH        write a Perfetto trace of sampled requests\n\
                               (+ ASCII span waterfall on stdout)\n\
           --trace-sample N    trace every Nth request (default 1 = all)\n\
           --metrics PATH      metrics snapshot: Prometheus text, or JSON\n\
                               when PATH ends in .json\n\
         serve options:\n\
           --replicas K        replicas per accelerator tile (default 2)\n\
           --tile T            serve one tile only: a1 | a2 (default both)\n\
         cluster options:\n\
           --replicas N        fleet size / autoscale ceiling (default 4)\n\
           --tile-replicas K   replicas per accelerator tile (default 2)\n\
           --balancer P        front-end: rr | jsq | least (default jsq)\n\
           --autoscale         SLO-driven autoscaler (defaults --slo-ms to 5)\n\
           --min-replicas N    autoscale floor (default 1)\n\
           --threads N         worker threads for replica stepping:\n\
                               0 = all cores, 1 = serial (default; same report)\n\
           --health            evict wedged replicas + replace from warm standby\n\
           --evict-after N     wedged sample windows before eviction (default 3)\n\
           --drain-deadline-ms N  force-retire a draining replica after N ms",
        header = vespa::cli::usage_header(),
        subs = vespa::cli::subcommand_lines()
    );
}

fn backend(args: &Args) -> vespa::Result<Box<dyn AccelCompute>> {
    match args.opt("artifacts") {
        Some(dir) => Ok(Box::new(PjrtCompute::load(dir)?)),
        None => Ok(Box::new(RefCompute::new())),
    }
}

/// `--engine reference|idle|event` — simulation engine for `run`,
/// `serve`, and `cluster` (default: event-driven).
fn engine_arg(args: &Args) -> vespa::Result<vespa::sim::EngineMode> {
    match args.opt("engine") {
        Some(s) => vespa::sim::EngineMode::parse(s),
        None => Ok(vespa::sim::EngineMode::default()),
    }
}

/// `--faults <spec>` — deterministic fault plan for `serve`/`cluster`
/// (see [`FaultPlan::parse`] for the grammar). Empty without the flag.
fn faults_arg(args: &Args) -> vespa::Result<FaultPlan> {
    match args.opt("faults") {
        Some(s) => FaultPlan::parse(s),
        None => Ok(FaultPlan::new()),
    }
}

/// `--trace PATH` (+ `--trace-sample N`) — deterministic request
/// tracing for `serve`/`cluster`. Returns the spec to set; the caller
/// writes the Perfetto export to PATH after the run.
fn trace_arg(args: &Args) -> vespa::Result<Option<vespa::telemetry::TraceSpec>> {
    if args.opt("trace").is_none() {
        anyhow::ensure!(
            args.opt("trace-sample").is_none(),
            "--trace-sample needs --trace PATH"
        );
        return Ok(None);
    }
    let sample = args.opt_u64("trace-sample", 1)?;
    anyhow::ensure!(sample >= 1, "--trace-sample must be at least 1");
    Ok(Some(vespa::telemetry::TraceSpec::new().sample(sample)))
}

/// Write the traced spans (Perfetto JSON to `--trace PATH`) and print
/// the span waterfall.
fn write_trace(args: &Args, trace: Option<&vespa::telemetry::Trace>) -> vespa::Result<()> {
    let Some(path) = args.opt("trace") else {
        return Ok(());
    };
    let trace = trace.expect("report carries a trace when --trace is set");
    std::fs::write(path, vespa::telemetry::to_perfetto(trace))
        .map_err(|e| anyhow::anyhow!("--trace {path}: {e}"))?;
    println!("wrote {path} (open in ui.perfetto.dev)");
    print!("{}", vespa::report::waterfall(trace, 70, 0));
    Ok(())
}

/// Write a metrics snapshot to `--metrics PATH`: JSON when the path
/// ends in `.json`, Prometheus text exposition otherwise.
fn write_metrics(args: &Args, reg: &vespa::telemetry::MetricsRegistry) -> vespa::Result<()> {
    let Some(path) = args.opt("metrics") else {
        return Ok(());
    };
    let body = if path.ends_with(".json") {
        reg.to_json()
    } else {
        reg.to_prometheus()
    };
    std::fs::write(path, body).map_err(|e| anyhow::anyhow!("--metrics {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}

/// `--retry N` (+ `--retry-backoff-us`, `--deadline-ms`) — admission
/// retry policy for `serve`/`cluster`: N total attempts with
/// exponential backoff, optionally bounded by a per-request deadline.
fn retry_arg(args: &Args) -> vespa::Result<Option<RetrySpec>> {
    let attempts = args.opt_u64("retry", 0)? as u32;
    let deadline_ms = args.opt_u64("deadline-ms", 0)?;
    if attempts == 0 {
        anyhow::ensure!(
            args.opt("retry-backoff-us").is_none() && deadline_ms == 0,
            "--retry-backoff-us/--deadline-ms need --retry N"
        );
        return Ok(None);
    }
    let backoff = args.opt_u64("retry-backoff-us", 500)? * 1_000_000;
    anyhow::ensure!(backoff > 0, "--retry-backoff-us must be positive");
    let mut rs = RetrySpec::new(attempts, backoff);
    if deadline_ms > 0 {
        rs = rs.deadline(deadline_ms * 1_000_000_000);
    }
    Ok(Some(rs))
}

fn dispatch(args: &Args) -> vespa::Result<()> {
    vespa::cli::validate_known(args)?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("serve") => cmd_serve(args),
        Some("cluster") => cmd_cluster(args),
        Some("table1") => {
            let inv = args.opt_u64("invocations", 6)?;
            let (t, rows) = table1::run(inv)?;
            println!("{}", t.render());
            let (r2, r4) = table1::average_increments(&rows);
            println!("Average throughput increment: 2x = {r2:.2}x, 4x = {r4:.2}x");
            println!("(paper: 2x = 1.92x, 4x = 3.58x)");
            Ok(())
        }
        Some("fig2") | Some("floorplan") => {
            let (s, _) = fig2::run()?;
            println!("{s}");
            Ok(())
        }
        Some("fig3") => {
            let win = args.opt_u64("window-ms", 60)? * 1_000_000_000;
            // Warmup covers the slowest pipeline fill (adpcm 4x: ~23 ms
            // per replica invocation at 50 MHz).
            let warm = args.opt_u64("warmup-ms", 30)? * 1_000_000_000;
            let (t, adpcm, dfmul) = fig3::run(warm, win)?;
            println!("{}", t.render());
            let mut sa = vespa::monitor::TimeSeries::new("adpcm4x");
            let mut sd = vespa::monitor::TimeSeries::new("dfmul4x");
            for p in &adpcm {
                sa.push(p.tg_active as u64 * 1_000_000, p.thr_mbs);
            }
            for p in &dfmul {
                sd.push(p.tg_active as u64 * 1_000_000, p.thr_mbs);
            }
            println!("{}", plot(&[&sa, &sd], 60, 16));
            Ok(())
        }
        Some("fig4") => {
            let phase = args.opt_u64("phase-ms", 30)? * 1_000_000_000;
            let r = fig4::run(phase, 1_000_000_000)?;
            println!("{}", fig4::render_table(&r).render());
            println!("{}", plot(&[&r.pkts_rate], 70, 14));
            Ok(())
        }
        Some("dse") => cmd_dse(args),
        Some("validate") => {
            let path = args
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("validate: missing config path"))?;
            let cfg = SocConfig::load(path)?;
            println!(
                "OK: {} — {}x{} grid, {} tiles, {} islands",
                cfg.name,
                cfg.width,
                cfg.height,
                cfg.tiles.len(),
                cfg.islands.len()
            );
            Ok(())
        }
        Some("accels") => {
            let mut t = Table::new(
                "Accelerator DB (CHStone via HLS)",
                &["name", "LUT", "FF", "BRAM", "DSP", "MB/s @50MHz", "class"],
            );
            for a in AccelArea::db() {
                let timing = AccelTiming::lookup(a.name)?;
                t.row(&[
                    a.name.to_string(),
                    a.baseline_tile.lut.to_string(),
                    a.baseline_tile.ff.to_string(),
                    a.baseline_tile.bram.to_string(),
                    a.baseline_tile.dsp.to_string(),
                    format!("{:.2}", timing.ideal_throughput_mbs(50)),
                    if timing.memory_bound {
                        "memory-bound".into()
                    } else {
                        "compute-bound".into()
                    },
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Some("artifacts-check") => cmd_artifacts_check(args),
        _ => {
            usage();
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> vespa::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("run: missing config path"))?;
    let cfg = SocConfig::load(path)?;
    let mut session = Session::with_backend(cfg, backend(args)?)?;
    let dur = args.opt_u64("duration-ms", 10)? * 1_000_000_000;
    session
        .engine(engine_arg(args)?)
        .stage_all(1)?
        .with_tg_load(args.opt_usize("tg", 0)?)
        .warmup(dur);
    let soc = session.soc();

    let mut t = Table::new(
        format!("run {} for {} ms", soc.cfg.name, dur / 1_000_000_000),
        &["tile", "kind", "inv", "pkts_in", "pkts_out", "rtt_ns", "exec_cycles"],
    );
    for (i, tile) in soc.tiles.iter().enumerate() {
        let c = soc.mon.tile(i);
        if c.pkts_in + c.pkts_out + c.invocations == 0 {
            continue;
        }
        t.row(&[
            i.to_string(),
            tile.kind_name().to_string(),
            c.invocations.to_string(),
            c.pkts_in.to_string(),
            c.pkts_out.to_string(),
            format!("{:.0}", c.rtt_mean() / 1e3),
            c.exec_cycles.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "mem: {} pkts in, {} data beats; NoC flits {}; backend {}",
        soc.mon.mem_pkts_in,
        soc.mon.mem_beats_in,
        soc.fabric.total_flits(),
        soc.compute.backend(),
    );
    Ok(())
}

/// Serve open-loop Poisson traffic on the paper SoC: the same
/// accelerator in A1 and A2 (replica-aware dispatch across tiles, each
/// tile spreading credited invocations across its own replicas), with
/// optional SLO judging and the queue-driven DFS governor.
fn cmd_serve(args: &Args) -> vespa::Result<()> {
    use vespa::config::presets::{paper_soc, ISL_A1, ISL_A2};

    let accel = args.opt_str("accel", "dfmul");
    AccelTiming::lookup(&accel)?; // clean error before the preset panics
    let replicas = args.opt_usize("replicas", 2)?;
    anyhow::ensure!(
        (1..=16).contains(&replicas),
        "--replicas {replicas} out of [1, 16]"
    );
    let rps = args.opt_u64("rps", 1000)? as f64;
    let duration = args.opt_u64("duration-ms", 200)? * 1_000_000_000;
    let policy = DispatchPolicy::parse(&args.opt_str("policy", "jsq"))?;
    let queue = args.opt_usize("queue", 32)?;
    let seed = args.opt_u64("seed", 0xE5B)?;
    let slo_ms = args.opt_u64("slo-ms", 0)?;

    let cfg = paper_soc((accel.as_str(), replicas), (accel.as_str(), replicas));
    let mut session = Session::with_backend(cfg, backend(args)?)?;
    session.engine(engine_arg(args)?);
    let a1 = session.tile_at(A1_POS.0, A1_POS.1);
    let a2 = session.tile_at(A2_POS.0, A2_POS.1);
    let (tiles, gov_island) = match args.opt("tile") {
        None => (vec![a1, a2], ISL_A1),
        Some("a1") => (vec![a1], ISL_A1),
        Some("a2") => (vec![a2], ISL_A2),
        Some(other) => anyhow::bail!("--tile must be a1 or a2, got {other:?}"),
    };

    let mut spec = ServeSpec::new(Arrival::Poisson { rps }, duration)
        .tiles(tiles)
        .policy(policy)
        .queue_capacity(queue)
        .seed(seed)
        .faults(faults_arg(args)?);
    if let Some(rs) = retry_arg(args)? {
        spec = spec.retry(rs);
    }
    if slo_ms > 0 {
        spec = spec.slo(slo_ms * 1_000_000_000);
    }
    if args.flag("governor") {
        // The governor needs a latency target; default the SLO to 5 ms.
        let slo_eff_ms = if slo_ms > 0 { slo_ms } else { 5 };
        let slo = slo_eff_ms * 1_000_000_000;
        if slo_ms == 0 {
            spec = spec.slo(slo);
        }
        spec = spec.governor(GovernorSpec::new(gov_island, slo));
    }
    if let Some(ts) = trace_arg(args)? {
        spec = spec.trace(ts);
    }

    let report = session.serve(&spec)?;
    println!("{}", report.render());
    let depth_refs: Vec<&vespa::monitor::TimeSeries> = report.queue_depth.iter().collect();
    if depth_refs.iter().any(|s| s.samples.len() > 1) {
        println!("queue depth over time:");
        println!("{}", plot(&depth_refs, 70, 12));
    }
    if let Some(path) = args.opt("json") {
        std::fs::write(path, report.to_json())
            .map_err(|e| anyhow::anyhow!("--json {path}: {e}"))?;
        println!("wrote {path}");
    }
    write_trace(args, report.trace.as_ref())?;
    if args.opt("metrics").is_some() {
        let mut reg = vespa::telemetry::MetricsRegistry::from_serve(&report);
        reg.add_soc(session.soc());
        write_metrics(args, &reg)?;
    }
    Ok(())
}

/// Serve one open-loop workload across a fleet of identical paper SoCs:
/// a front-end balancer picks the replica (`--balancer`), each replica
/// keeps its own dispatch + optional DFS governor, and an optional
/// SLO-driven autoscaler (`--autoscale`) grows/retires the fleet
/// between `--min-replicas` and `--replicas`.
fn cmd_cluster(args: &Args) -> vespa::Result<()> {
    use vespa::config::presets::{paper_soc, ISL_A1};

    let accel = args.opt_str("accel", "dfmul");
    AccelTiming::lookup(&accel)?; // clean error before the preset panics
    let tile_replicas = args.opt_usize("tile-replicas", 2)?;
    anyhow::ensure!(
        (1..=16).contains(&tile_replicas),
        "--tile-replicas {tile_replicas} out of [1, 16]"
    );
    let fleet = args.opt_usize("replicas", 4)?;
    let rps = args.opt_u64("rps", 4000)? as f64;
    let duration = args.opt_u64("duration-ms", 100)? * 1_000_000_000;
    let balancer = DispatchPolicy::parse(&args.opt_str("balancer", "jsq"))?;
    let policy = DispatchPolicy::parse(&args.opt_str("policy", "jsq"))?;
    let queue = args.opt_usize("queue", 32)?;
    let seed = args.opt_u64("seed", 0xE5B)?;
    let slo_ms = args.opt_u64("slo-ms", 0)?;
    let autoscale = args.flag("autoscale");

    let mut spec = ServeSpec::new(Arrival::Poisson { rps }, duration)
        .policy(policy)
        .queue_capacity(queue)
        .seed(seed)
        .faults(faults_arg(args)?);
    if let Some(rs) = retry_arg(args)? {
        spec = spec.retry(rs);
    }
    // The autoscaler and the governor both need a latency target;
    // default the SLO to 5 ms when either is on without --slo-ms.
    let slo_eff = if slo_ms > 0 { slo_ms } else { 5 } * 1_000_000_000;
    if slo_ms > 0 || autoscale || args.flag("governor") {
        spec = spec.slo(slo_eff);
    }
    if args.flag("governor") {
        spec = spec.governor(GovernorSpec::new(ISL_A1, slo_eff));
    }

    let mut cspec = ClusterSpec::new(fleet, spec)
        .balancer(balancer)
        .engine(engine_arg(args)?)
        .threads(args.opt_usize("threads", 1)?);
    if autoscale {
        cspec = cspec.autoscale(AutoscaleSpec::new(args.opt_usize("min-replicas", 1)?));
    }
    if args.flag("health") || args.opt("evict-after").is_some() {
        cspec = cspec
            .health(HealthSpec::new().evict_after(args.opt_u64("evict-after", 3)? as u32));
    }
    let drain_deadline_ms = args.opt_u64("drain-deadline-ms", 0)?;
    if drain_deadline_ms > 0 {
        cspec = cspec.drain_deadline(drain_deadline_ms * 1_000_000_000);
    }
    if let Some(ts) = trace_arg(args)? {
        cspec = cspec.trace(ts);
    }

    let cfg = paper_soc((accel.as_str(), tile_replicas), (accel.as_str(), tile_replicas));
    let report = cspec.run(cfg)?;
    println!("{}", report.render());
    if report.active_replicas.samples.len() > 1 && !report.autoscale_actions.is_empty() {
        println!("active replicas over time:");
        println!("{}", plot(&[&report.active_replicas], 70, 8));
    }
    if let Some(path) = args.opt("json") {
        std::fs::write(path, report.to_json())
            .map_err(|e| anyhow::anyhow!("--json {path}: {e}"))?;
        println!("wrote {path}");
    }
    write_trace(args, report.trace.as_ref())?;
    if args.opt("metrics").is_some() {
        write_metrics(args, &vespa::telemetry::MetricsRegistry::from_cluster(&report))?;
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> vespa::Result<()> {
    let accel = args.opt_str("accel", "dfmul");
    let mut p = SweepParams::quick(&accel);
    if args.flag("wide") {
        p.accel_mhz = vec![25, 50];
        p.noc_mhz = vec![50, 100];
        p.placements = vec![true, false];
    }
    if args.flag("quick") {
        p.window = 4_000_000_000;
        p.warmup = 500_000_000;
    }
    if args.flag("warm") {
        // Warm-fork: one warmed base SoC per structure, frequency points
        // fork its snapshot and retune through the DFS actuators.
        p.mode = SweepMode::WarmFork;
        // --serial selects the always-cold unmemoized reference path,
        // which would silently drop --warm; a deterministic warm sweep
        // is `--warm` alone with `threads = 1` semantics instead.
        anyhow::ensure!(
            !args.flag("serial"),
            "--warm and --serial are mutually exclusive (--serial is the cold reference path)"
        );
    }
    let serve_rps = args.opt_u64("serve-rps", 0)?;
    let fleets: Vec<usize> = match args.opt("fleets") {
        None => Vec::new(),
        Some(raw) => {
            let sizes: Vec<usize> = raw
                .split(',')
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        anyhow::anyhow!(
                            "--fleets must be a comma-separated list of fleet sizes, got {raw:?}"
                        )
                    })
                })
                .collect::<Result<_, _>>()?;
            anyhow::ensure!(!sizes.is_empty(), "--fleets: empty list");
            sizes
        }
    };
    if serve_rps > 0 {
        // Rank by p99-under-SLO (or, with --fleets, by
        // replica-seconds-under-SLO across fleet sizes): serve traffic
        // at every point instead of measuring a steady-state window.
        anyhow::ensure!(
            !args.flag("warm"),
            "--serve-rps and --warm are mutually exclusive (serving sweeps evaluate cold)"
        );
        let slo = args.opt_u64("slo-ms", 10)? * 1_000_000_000;
        let dur = args.opt_u64("serve-ms", 100)? * 1_000_000_000;
        let spec = ServeSpec::new(
            Arrival::Poisson {
                rps: serve_rps as f64,
            },
            dur,
        )
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(slo);
        let faults = faults_arg(args)?;
        p.objective = if !faults.is_empty() {
            // Robust: serve through the fault plan with the resilience
            // stack on, rank by p99-under-SLO at one fleet size.
            anyhow::ensure!(
                fleets.len() <= 1,
                "--faults evaluates one fleet size (pass at most one --fleets entry)"
            );
            let mut serve = spec.faults(faults);
            if let Some(rs) = retry_arg(args)? {
                serve = serve.retry(rs);
            }
            Objective::Robust {
                serve,
                balancer: DispatchPolicy::JoinShortestQueue,
                health: HealthSpec::default(),
                fleet: fleets.first().copied().unwrap_or(2),
                threads: args.opt_usize("threads", 1)?,
            }
        } else if fleets.is_empty() {
            Objective::TailLatency { spec }
        } else {
            Objective::Cluster {
                serve: spec,
                balancer: DispatchPolicy::JoinShortestQueue,
                autoscale: args.flag("autoscale").then(|| AutoscaleSpec::new(1)),
                fleets,
                threads: args.opt_usize("threads", 1)?,
            }
        };
    } else {
        anyhow::ensure!(
            fleets.is_empty(),
            "--fleets requires --serve-rps N (cluster sweeps serve traffic)"
        );
        anyhow::ensure!(
            args.opt("faults").is_none(),
            "--faults requires --serve-rps N (robust sweeps serve traffic)"
        );
    }
    // Parallel across cores by default; --serial for the reference path
    // (results are bit-identical either way).
    let pts = if args.flag("serial") {
        sweep_replication_serial(&p)?
    } else {
        sweep_replication(&p)?
    };
    let mut t = Table::new(
        format!("DSE — {accel}"),
        &["K", "accel MHz", "NoC MHz", "near", "LUT", "DSP", "MB/s", "pareto"],
    );
    let costs: Vec<(f64, f64)> = pts
        .iter()
        .map(|pt| (pt.area.lut as f64, pt.throughput_mbs))
        .collect();
    let front = pareto_front(&costs);
    for (i, pt) in pts.iter().enumerate() {
        t.row(&[
            pt.replicas.to_string(),
            pt.accel_mhz.to_string(),
            pt.noc_mhz.to_string(),
            if pt.near_mem { "A1" } else { "A2" }.to_string(),
            pt.area.lut.to_string(),
            pt.area.dsp.to_string(),
            format!("{:.2}", pt.throughput_mbs),
            if front.contains(&i) { "*" } else { "" }.to_string(),
        ]);
    }
    println!("{}", t.render());
    if matches!(
        p.objective,
        Objective::TailLatency { .. } | Objective::Robust { .. }
    ) {
        let order = rank_by_p99_under_slo(&pts);
        let mut t2 = Table::new(
            "serving rank — p99 under SLO",
            &["rank", "K", "accel MHz", "NoC MHz", "p99 ms", "rps", "SLO"],
        );
        for (rank, &i) in order.iter().enumerate() {
            let pt = &pts[i];
            t2.row(&[
                (rank + 1).to_string(),
                pt.replicas.to_string(),
                pt.accel_mhz.to_string(),
                pt.noc_mhz.to_string(),
                pt.p99_latency_ps
                    .map(|v| format!("{:.3}", v / 1e9))
                    .unwrap_or_else(|| "-".to_string()),
                pt.achieved_rps
                    .map(|v| format!("{v:.0}"))
                    .unwrap_or_else(|| "-".to_string()),
                match pt.slo_met {
                    Some(true) => "met",
                    Some(false) => "miss",
                    None => "-",
                }
                .to_string(),
            ]);
        }
        println!("{}", t2.render());
    }
    if matches!(p.objective, Objective::Cluster { .. }) {
        let order = rank_by_replica_seconds_under_slo(&pts);
        let mut t2 = Table::new(
            "cluster rank — replica-seconds under SLO",
            &["rank", "K", "accel MHz", "fleet", "rps", "p99 ms", "repl-s", "SLO"],
        );
        for (rank, &i) in order.iter().enumerate() {
            let pt = &pts[i];
            t2.row(&[
                (rank + 1).to_string(),
                pt.replicas.to_string(),
                pt.accel_mhz.to_string(),
                pt.fleet
                    .map(|f| f.to_string())
                    .unwrap_or_else(|| "-".to_string()),
                pt.achieved_rps
                    .map(|v| format!("{v:.0}"))
                    .unwrap_or_else(|| "-".to_string()),
                pt.p99_latency_ps
                    .map(|v| format!("{:.3}", v / 1e9))
                    .unwrap_or_else(|| "-".to_string()),
                pt.replica_seconds
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".to_string()),
                match pt.slo_met {
                    Some(true) => "met",
                    Some(false) => "miss",
                    None => "-",
                }
                .to_string(),
            ]);
        }
        println!("{}", t2.render());
    }
    // The evaluator floors warmup/window to the accelerator's invocation
    // time; report what was actually simulated (spread over the sweep's
    // frequency range when points disagree).
    let lo = pts.iter().map(|pt| pt.eff_window_ps).min().unwrap_or(0);
    let hi = pts.iter().map(|pt| pt.eff_window_ps).max().unwrap_or(0);
    let wlo = pts.iter().map(|pt| pt.eff_warmup_ps).min().unwrap_or(0);
    let whi = pts.iter().map(|pt| pt.eff_warmup_ps).max().unwrap_or(0);
    println!(
        "effective phases: warmup {:.1}..{:.1} ms, window {:.1}..{:.1} ms per point",
        wlo as f64 / 1e9,
        whi as f64 / 1e9,
        lo as f64 / 1e9,
        hi as f64 / 1e9
    );
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> vespa::Result<()> {
    let dir = args.opt_str("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    println!("manifest: {} modules from {dir}", manifest.modules.len());
    let mut pjrt = PjrtCompute::from_manifest(manifest.clone())?;
    let mut refc = RefCompute::new();
    let mut rng = vespa::util::SplitMix64::new(7);

    for (name, spec) in &manifest.modules {
        let inputs: Vec<Block> = spec
            .inputs
            .iter()
            .map(|ts| match ts.dtype {
                vespa::runtime::DType::F32 => {
                    Block::F32((0..ts.elems()).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                }
                vespa::runtime::DType::S32 => Block::I32(
                    (0..ts.elems())
                        .map(|_| rng.range_i64(-32768, 32767) as i32)
                        .collect(),
                ),
            })
            .collect();
        let refs: Vec<&Block> = inputs.iter().collect();
        let a = pjrt.invoke(name, &refs)?;
        let b = refc.invoke(name, &refs)?;
        let mut max_err = 0f64;
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (Block::F32(u), Block::F32(v)) => {
                    for (p, q) in u.iter().zip(v) {
                        max_err = max_err.max((p - q).abs() as f64);
                    }
                }
                (Block::I32(u), Block::I32(v)) => {
                    anyhow::ensure!(u == v, "{name}: integer outputs differ");
                }
                _ => anyhow::bail!("{name}: output dtype mismatch"),
            }
        }
        println!("  {name}: PJRT vs native max |err| = {max_err:.2e}  OK");
    }
    println!("artifacts-check OK ({} PJRT invocations)", pjrt.invocations);
    Ok(())
}
