//! The serve engine: drives open- or closed-loop traffic through a
//! running [`Session`], dispatching each request onto a gated MRA tile
//! and attributing tile completion tags back to requests.
//!
//! The loop advances the SoC between *host events* — the next arrival,
//! the next sample deadline, or the drain deadline — so queue decisions
//! observe exact simulator state while latencies come from the tiles'
//! per-invocation completion logs (exact timestamps, not event-loop
//! granularity). Everything is deterministic in `(ServeSpec, SoC seed)`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::fault::{FaultLedger, FaultPlan, RetrySpec};
use crate::monitor::TimeSeries;
use crate::policy::DfsPolicy;
use crate::scenario::Session;
use crate::telemetry::{TraceSpec, Tracer};
use crate::util::Ps;

use super::arrival::Arrival;
use super::dispatch::{DispatchPolicy, Dispatcher, TileQueue};
use super::governor::{GovernorSpec, QueueGovernor};
use super::report::{LatencyStats, ServeReport, TileServeReport};

/// Declarative description of one serving phase.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Arrival process over the offered-load horizon.
    pub arrival: Arrival,
    /// Offered-load horizon (ps): arrivals are generated in `[0, duration)`.
    pub duration: Ps,
    /// Extra simulated time after the horizon to let queued work finish
    /// before unfinished requests are counted.
    pub drain: Ps,
    /// Target tiles (empty = every MRA tile in the SoC).
    pub tiles: Vec<usize>,
    pub policy: DispatchPolicy,
    /// Bounded admission queue per tile: at most this many
    /// granted-but-uncompleted requests; beyond it, requests drop.
    pub queue_capacity: usize,
    /// p95 latency SLO (ps) the report and governor judge against.
    pub slo: Option<Ps>,
    /// Seed for the arrival process.
    pub seed: u64,
    /// Queue-depth / governor sampling cadence (0 = `duration / 100`,
    /// at least 1 us).
    pub sample_interval: Ps,
    /// Optional queue-driven DFS governor.
    pub governor: Option<GovernorSpec>,
    /// Run the functional datapath on every invocation (default off:
    /// serving measures timing, like Table I's perf mode).
    pub functional: bool,
    /// Deterministic fault plan injected before the first request
    /// (empty = bit-identical to a run without the fault subsystem).
    pub faults: FaultPlan,
    /// Per-request deadline + retry/backoff at the admission gate
    /// (`None` = legacy drop-on-full semantics, bit-identical).
    pub retry: Option<RetrySpec>,
    /// Deterministic request tracing into a bounded flight recorder
    /// (`None` = no tracing, zero overhead on the hot path).
    pub trace: Option<TraceSpec>,
}

impl ServeSpec {
    pub fn new(arrival: Arrival, duration: Ps) -> Self {
        Self {
            arrival,
            duration,
            drain: duration,
            tiles: Vec::new(),
            policy: DispatchPolicy::default(),
            queue_capacity: 32,
            slo: None,
            seed: 0xE5B,
            sample_interval: 0,
            governor: None,
            functional: false,
            faults: FaultPlan::new(),
            retry: None,
            trace: None,
        }
    }

    pub fn tiles(mut self, tiles: Vec<usize>) -> Self {
        self.tiles = tiles;
        self
    }

    pub fn policy(mut self, policy: DispatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap;
        self
    }

    pub fn slo(mut self, slo: Ps) -> Self {
        self.slo = Some(slo);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn drain(mut self, drain: Ps) -> Self {
        self.drain = drain;
        self
    }

    pub fn sample_interval(mut self, interval: Ps) -> Self {
        self.sample_interval = interval;
        self
    }

    pub fn governor(mut self, g: GovernorSpec) -> Self {
        self.governor = Some(g);
        self
    }

    pub fn functional(mut self, on: bool) -> Self {
        self.functional = on;
        self
    }

    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    pub fn retry(mut self, retry: RetrySpec) -> Self {
        self.retry = Some(retry);
        self
    }

    pub fn trace(mut self, trace: TraceSpec) -> Self {
        self.trace = Some(trace);
        self
    }
}

impl Session {
    /// Serve `spec`'s traffic and return the [`ServeReport`] — the
    /// serving counterpart of [`Session::measure`]. See the
    /// [module docs](crate::serve) for the model.
    pub fn serve(&mut self, spec: &ServeSpec) -> crate::Result<ServeReport> {
        serve_session(self, spec)
    }
}

/// Resolve and validate `spec`'s target tiles against `session` (empty
/// = every MRA tile). Shared with the cluster engine, which resolves
/// once on the warm base session.
pub(crate) fn resolve_tiles(session: &Session, spec: &ServeSpec) -> crate::Result<Vec<usize>> {
    let tiles = if spec.tiles.is_empty() {
        session.mra_tiles()
    } else {
        spec.tiles.clone()
    };
    anyhow::ensure!(!tiles.is_empty(), "serve: the SoC has no MRA tiles");
    for &t in &tiles {
        session.soc().try_mra(t)?;
    }
    Ok(tiles)
}

/// Prepare `tiles` for serving: staged inputs (functional datapath),
/// the per-invocation functional flag, the admission gate, and a settle
/// pass so the completion ledgers start empty. The cluster engine runs
/// this once on its warm base session before snapshotting, so replica
/// (re)activations fork an already-prepared SoC.
pub(crate) fn prepare_serve_tiles(
    session: &mut Session,
    spec: &ServeSpec,
    tiles: &[usize],
) -> crate::Result<()> {
    for &t in tiles {
        if session.staged(t).is_empty() {
            session.stage(t, 1)?;
        }
        let m = session.soc_mut().try_mra_mut(t)?;
        m.functional_every_invocation = spec.functional;
        m.serve_begin();
    }
    settle_gated_tiles(session, tiles)?;
    // After the settle pass (whose trailing `serve_begin` resets the
    // gates): with tracing on, log invocation starts so spans get their
    // exec-start stamps. The flag rides the gate into any snapshot the
    // cluster engine takes of this prepared session.
    if spec.trace.is_some() {
        for &t in tiles {
            session.soc_mut().try_mra_mut(t)?.serve_record_starts(true);
        }
    }
    Ok(())
}

/// Dispatcher state for `tiles`: one bounded queue per tile, seeded
/// with the tile's island, invocation cycles, and replica count.
/// Errors on a node with no tile spec (a malformed config that slipped
/// past resolution) rather than panicking mid-serve.
pub(crate) fn tile_queues(session: &Session, tiles: &[usize]) -> crate::Result<Vec<TileQueue>> {
    tiles
        .iter()
        .map(|&tile| {
            let soc = session.soc();
            let island = soc
                .cfg
                .tiles
                .iter()
                .find(|t| soc.cfg.node_of(t.x, t.y) == tile)
                .map(|t| t.island)
                .ok_or_else(|| {
                    anyhow::anyhow!("serve: node {tile} has no tile spec in the config")
                })?;
            let m = soc.mra(tile);
            Ok(TileQueue {
                tile,
                island,
                compute_cycles: m.timing.compute_cycles,
                replicas: m.replica_count(),
                in_flight: std::collections::VecDeque::new(),
                admitted: 0,
                completed: 0,
                max_depth: 0,
            })
        })
        .collect()
}

fn serve_session(session: &mut Session, spec: &ServeSpec) -> crate::Result<ServeReport> {
    anyhow::ensure!(spec.duration > 0, "serve: duration must be positive");
    anyhow::ensure!(
        spec.queue_capacity > 0,
        "serve: queue capacity must be at least 1"
    );

    let tiles = resolve_tiles(session, spec)?;
    prepare_serve_tiles(session, spec, &tiles)?;
    let mut disp =
        Dispatcher::new(spec.policy, spec.queue_capacity, tile_queues(session, &tiles)?);

    let mut governor = spec
        .governor
        .as_ref()
        .map(|g| QueueGovernor::new(g, tiles.clone()));

    // One trace track per serving tile, indexed by dispatch slot.
    let mut tracer = spec.trace.map(Tracer::new);
    if let Some(tr) = &mut tracer {
        for q in &disp.tiles {
            let island = &session.soc().islands[q.island].name;
            tr.add_track(format!("tile {} ({island})", q.tile), 0, q.tile);
        }
    }

    // Arrival schedule (absolute times). Closed-loop respawns are pushed
    // as completions drain.
    let t0 = session.soc().now;
    let horizon = t0 + spec.duration;
    let deadline = horizon + spec.drain;

    // Compile and pre-install the fault plan: windows become part of the
    // simulated hardware before the first request, so injection timing
    // is engine- and thread-invariant (see [`crate::fault`]). An empty
    // plan installs nothing and the run is bit-identical to one without
    // the fault subsystem.
    let resolved = spec.faults.compile(spec.duration, 1)?;
    anyhow::ensure!(
        resolved.crashes.is_empty(),
        "serve: replica-crash faults need the cluster layer (`vespa cluster --faults`)"
    );
    for f in resolved.for_replica(0) {
        session.soc_mut().install_fault(f, t0)?;
    }
    let mut ledger = FaultLedger { injected: resolved.injected, ..FaultLedger::default() };

    // Heap entries are `(due time, original arrival, attempt)`: first
    // attempts are due at their arrival, retries keep the original
    // arrival so deadlines and latency span the whole request.
    let mut arrivals: BinaryHeap<Reverse<(Ps, Ps, u32)>> = spec
        .arrival
        .times(spec.seed, spec.duration)
        .into_iter()
        .map(|rel| Reverse((t0 + rel, t0 + rel, 0)))
        .collect();
    let think = spec.arrival.think_time();
    let mut offered = arrivals.len() as u64;

    let sample_interval = if spec.sample_interval > 0 {
        spec.sample_interval
    } else {
        (spec.duration / 100).max(1_000_000)
    };
    let mut next_sample = t0;
    let mut queue_series: Vec<TimeSeries> = disp
        .tiles
        .iter()
        .map(|q| TimeSeries::new(format!("queue_t{}", q.tile)))
        .collect();
    let mut freq_series: Vec<TimeSeries> = session
        .soc()
        .islands
        .iter()
        .map(|d| TimeSeries::new(format!("freq_{}", d.name)))
        .collect();

    // Admitted-request count (each queue entry carries its own arrival
    // time, so no shared request table is needed).
    let mut admitted: u64 = 0;
    let mut latencies: Vec<f64> = Vec::new();
    // Reused completion-log buffer — drained tiles fill it in place
    // instead of collecting a fresh Vec every barrier.
    let mut log: Vec<Ps> = Vec::new();
    // Reused invocation-start buffer (tracing only).
    let mut starts: Vec<(Ps, u8)> = Vec::new();

    loop {
        let now = session.soc().now;
        let next_arrival = arrivals.peek().map(|Reverse((t, _, _))| *t);
        if now >= deadline || (now >= horizon && next_arrival.is_none() && disp.backlog == 0) {
            break;
        }
        let mut target = next_sample.min(deadline);
        if let Some(a) = next_arrival {
            target = target.min(a);
        }
        session.soc_mut().run_until(target.max(now));
        let now = session.soc().now;

        // 1) Attribute completions (exact tile-log timestamps). Peek
        // immutably first: mutable tile access resets the engine's wake
        // point, which would defeat a gated tile's idle sleep on every
        // empty poll.
        for slot in 0..disp.tiles.len() {
            let tile = disp.tiles[slot].tile;
            let has_completions = session
                .soc()
                .mra(tile)
                .serve
                .as_ref()
                .is_some_and(|g| !g.completions.is_empty());
            if !has_completions {
                continue;
            }
            log.clear();
            starts.clear();
            {
                let m = session.soc_mut().try_mra_mut(tile)?;
                if let Some(g) = &mut m.serve {
                    starts.extend(g.starts.drain(..));
                    log.extend(g.completions.drain(..).map(|(t, _replica)| t));
                }
            }
            // Exec starts precede their completions in sim time, so
            // record them first to keep span events time-ordered.
            if let Some(tr) = &mut tracer {
                for &(t_s, r) in &starts {
                    tr.exec_start(slot as u16, t_s, r);
                }
            }
            for &t_c in &log {
                let Some(req) = disp.complete_req(slot) else {
                    debug_assert!(false, "completion without an outstanding request");
                    continue;
                };
                // `extra` folds earlier attempts' wait back in, so the
                // latency spans the original arrival (zero fault-free).
                let lat = t_c - req.t_arr + req.extra;
                latencies.push(lat as f64);
                if let Some(tr) = &mut tracer {
                    tr.complete(slot as u16, t_c, lat);
                }
                if req.attempt > 0 {
                    ledger.rescued += 1;
                }
                if let Some(g) = &mut governor {
                    g.observe_latency(lat);
                }
                if let Some(think) = think {
                    let next = t_c + think;
                    if next < horizon {
                        arrivals.push(Reverse((next, next, 0)));
                        offered += 1;
                    }
                }
            }
        }

        // 2) Admit due arrivals: bind to a tile and grant one credit.
        while arrivals.peek().is_some_and(|Reverse((t, _, _))| *t <= now) {
            let Reverse((t_due, t_orig, attempt)) = arrivals.pop().expect("peeked");
            // Resolve the span handle for *every* pop (sampled or not)
            // so tracer ordinals and parked retries stay aligned with
            // the heap: attempt 0 is a fresh arrival, anything else
            // recovers the span parked under the heap tuple's identity.
            let span = match &mut tracer {
                Some(tr) if attempt == 0 => tr.arrive(t_orig),
                Some(tr) => tr.retry_pop(t_orig, attempt, false),
                None => None,
            };
            if let Some(rs) = &spec.retry {
                if rs.expired(now, t_orig) {
                    // The per-request deadline passed while waiting for
                    // a retry slot: the request is lost, not served
                    // stale. Counted as a drop to keep
                    // `offered == admitted + dropped` exact.
                    disp.drop_one();
                    ledger.detected += 1;
                    ledger.lost += 1;
                    if let Some(tr) = &mut tracer {
                        tr.expired(span, now);
                    }
                    continue;
                }
            }
            if let Some(slot) = disp.pick(session.soc(), now) {
                admitted += 1;
                disp.bind_attempt(slot, t_due, t_due - t_orig, attempt);
                let tile = disp.tiles[slot].tile;
                session.soc_mut().try_mra_mut(tile)?.serve_grant(1);
                if let Some(tr) = &mut tracer {
                    tr.admit(span, now, slot as u16, attempt);
                }
            } else if let Some(rs) = &spec.retry {
                // Queue-full with a retry policy: exponential backoff
                // instead of a final drop, while the deadline allows.
                match rs.next_retry(now, t_orig, attempt) {
                    Some(at) => {
                        disp.undrop(); // retrying, not dropping
                        ledger.retried += 1;
                        arrivals.push(Reverse((at, t_orig, attempt + 1)));
                        if let Some(tr) = &mut tracer {
                            tr.retry(span, now, t_orig, at, attempt + 1, false);
                        }
                    }
                    None => {
                        ledger.lost += 1; // pick counted the drop
                        if let Some(tr) = &mut tracer {
                            tr.dropped(span, now);
                        }
                    }
                }
            } else {
                if let Some(think) = think {
                    // A full system drops the request (the dispatcher
                    // counted it) — but a closed-loop *client* lives on:
                    // it thinks and retries, otherwise every drop would
                    // silently shrink the client population for the rest
                    // of the run.
                    let retry = now + think;
                    if retry < horizon {
                        arrivals.push(Reverse((retry, retry, 0)));
                        offered += 1;
                    }
                }
                // The drop itself is final either way (the respawned
                // closed-loop client is a *new* request).
                if let Some(tr) = &mut tracer {
                    tr.dropped(span, now);
                }
            }
        }

        // 3) Sample queue depths and frequencies; let the governor act.
        if now >= next_sample {
            for (i, q) in disp.tiles.iter().enumerate() {
                queue_series[i].push(now, q.in_flight.len() as f64);
            }
            for (i, d) in session.soc().islands.iter().enumerate() {
                freq_series[i].push(now, d.freq(now).as_mhz() as f64);
            }
            if let Some(g) = &mut governor {
                g.on_sample(session.soc_mut(), now);
            }
            while next_sample <= now {
                next_sample += sample_interval;
            }
        }
    }

    // A retry still pending when serving stopped is a lost request:
    // count it as a drop so `offered == admitted + dropped` stays exact.
    // (Without a retry policy the heap is empty here; the gate keeps the
    // legacy closed-loop accounting untouched.)
    if spec.retry.is_some() {
        let t_end = session.soc().now;
        while let Some(Reverse((_, t_orig, attempt))) = arrivals.pop() {
            disp.drop_one();
            ledger.lost += 1;
            if let Some(tr) = &mut tracer {
                let span = if attempt == 0 {
                    tr.arrive(t_orig)
                } else {
                    tr.retry_pop(t_orig, attempt, false)
                };
                tr.expired(span, t_end);
            }
        }
    }

    // Drain invocation starts still queued on the gates, so unfinished
    // spans keep their exec-start stamps, then restore free-running mode
    // for any later phases on this session.
    for (slot, q) in disp.tiles.iter().enumerate() {
        if let Some(tr) = &mut tracer {
            let m = session.soc_mut().try_mra_mut(q.tile)?;
            if let Some(g) = &mut m.serve {
                starts.clear();
                starts.extend(g.starts.drain(..));
                for &(t_s, r) in &starts {
                    tr.exec_start(slot as u16, t_s, r);
                }
            }
        }
        session.soc_mut().try_mra_mut(q.tile)?.serve_end();
    }

    // Assemble the report.
    let elapsed = session.soc().now - t0;
    let dur_s = spec.duration as f64 / 1e12;
    let completed = latencies.len() as u64;
    let latency = LatencyStats::from_latencies(&latencies)?;
    let slo_met = match (spec.slo, completed) {
        (Some(slo), c) if c > 0 => Some(latency.p95_ps <= slo as f64),
        _ => None,
    };
    let slo_attainment = match (spec.slo, completed) {
        (Some(slo), c) if c > 0 => {
            latencies.iter().filter(|&&l| l <= slo as f64).count() as f64 / c as f64
        }
        // An SLO with zero completions is total failure, not perfection.
        (Some(_), _) => 0.0,
        (None, _) => 1.0,
    };
    let per_tile = disp
        .tiles
        .iter()
        .map(|q| TileServeReport {
            tile: q.tile,
            replicas: q.replicas,
            admitted: q.admitted,
            completed: q.completed,
            max_depth: q.max_depth,
            unfinished: q.in_flight.len() as u64,
        })
        .collect();
    let soc = session.soc();
    let report = ServeReport {
        policy: spec.policy,
        offered,
        admitted,
        dropped: disp.dropped,
        completed,
        unfinished: admitted - completed,
        duration: spec.duration,
        elapsed,
        offered_rps: offered as f64 / dur_s,
        achieved_rps: completed as f64 / dur_s,
        latency,
        slo: spec.slo,
        slo_met,
        slo_attainment,
        per_tile,
        queue_depth: queue_series,
        freq_mhz: freq_series,
        governor_actions: governor.map(|g| g.actions).unwrap_or_default(),
        final_freq_mhz: soc
            .islands
            .iter()
            .map(|d| d.freq(soc.now).as_mhz())
            .collect(),
        faults: ledger,
        trace: tracer.map(Tracer::finish),
    };
    debug_assert!(
        report.verify_accounting().is_ok(),
        "serve accounting diverged: {:?}",
        report.verify_accounting()
    );
    Ok(report)
}

/// Run the SoC forward until every gated tile's pipeline is empty, so
/// the completion ledger holds only credited work. A tile that was
/// never run is idle already (zero cost); a warmed tile finishes its
/// in-flight invocations (the gate blocks new ones) within a few
/// invocation times.
pub(crate) fn settle_gated_tiles(session: &mut Session, tiles: &[usize]) -> crate::Result<()> {
    let all_idle =
        |s: &Session| tiles.iter().all(|&t| s.soc().mra(t).pipeline_idle());
    if all_idle(session) {
        return Ok(());
    }
    // Worst case in flight per replica: buffered + computing + draining
    // invocations, each as slow as the island's minimum frequency.
    let max_inv_ps: Ps = tiles
        .iter()
        .map(|&t| {
            let soc = session.soc();
            let cycles = soc.mra(t).timing.compute_cycles;
            let min_mhz = soc
                .cfg
                .tiles
                .iter()
                .find(|spec| soc.cfg.node_of(spec.x, spec.y) == t)
                .map(|spec| soc.islands[spec.island].min.as_mhz().max(1))
                .unwrap_or(1);
            cycles * 1_000_000 / min_mhz
        })
        .max()
        .unwrap_or(1_000_000);
    let cap = session.soc().now + 8 * max_inv_ps + 1_000_000_000;
    let slice = (max_inv_ps / 8).max(10_000_000);
    while !all_idle(session) && session.soc().now < cap {
        let next = (session.soc().now + slice).min(cap);
        session.soc_mut().run_until(next);
    }
    anyhow::ensure!(
        all_idle(session),
        "serve: a gated tile failed to quiesce within {} ps",
        cap
    );
    // Reset the gates: drop completions from pre-serve invocations.
    for &t in tiles {
        session.soc_mut().try_mra_mut(t)?.serve_begin();
    }
    Ok(())
}
