//! Arrival processes: when requests hit the SoC.
//!
//! All randomness comes from [`SplitMix64`] seeded by the
//! [`ServeSpec`](super::ServeSpec), so the same seed and spec always
//! produce the same arrival instants — the foundation of the serve
//! engine's deterministic-replay guarantee.

use crate::util::{Ps, SplitMix64};

/// How request arrivals are generated over the offered-load horizon.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Open-loop Poisson process at `rps` requests per second
    /// (exponential inter-arrival times).
    Poisson { rps: f64 },
    /// Open-loop on/off Poisson: within each `period`, the first
    /// `duty` fraction runs at `burst_rps`, the rest at `base_rps`.
    /// (The rate used for an inter-arrival draw is the rate in effect
    /// at the draw instant — a standard piecewise approximation.)
    Burst {
        base_rps: f64,
        burst_rps: f64,
        period: Ps,
        duty: f64,
    },
    /// Explicit arrival instants relative to serve start (unsorted and
    /// out-of-horizon entries are handled: the generator sorts and
    /// truncates). Seed-independent.
    Trace(Vec<Ps>),
    /// Closed loop: `clients` concurrent clients, each issuing its next
    /// request `think` after its previous one completes. The generator
    /// yields only the initial batch (one request per client at t=0);
    /// the serve engine schedules every follow-up from the observed
    /// completion times.
    ClosedLoop { clients: usize, think: Ps },
}

impl Arrival {
    /// Arrival instants in `[0, horizon)`, relative to serve start,
    /// sorted ascending. Deterministic in `(self, seed)`.
    pub fn times(&self, seed: u64, horizon: Ps) -> Vec<Ps> {
        let mut rng = SplitMix64::new(seed ^ 0xA221_7A15_0F5E_11ED);
        match self {
            Arrival::Poisson { rps } => {
                let mut out = Vec::new();
                let mut t: Ps = 0;
                loop {
                    let Some(dt) = exp_interval_ps(&mut rng, *rps) else {
                        break;
                    };
                    t = t.saturating_add(dt);
                    if t >= horizon {
                        break;
                    }
                    out.push(t);
                }
                out
            }
            Arrival::Burst {
                base_rps,
                burst_rps,
                period,
                duty,
            } => {
                let period = (*period).max(1);
                let on_span = (duty.clamp(0.0, 1.0) * period as f64) as Ps;
                let mut out = Vec::new();
                let mut t: Ps = 0;
                loop {
                    let rate = if t % period < on_span {
                        *burst_rps
                    } else {
                        *base_rps
                    };
                    let Some(dt) = exp_interval_ps(&mut rng, rate) else {
                        // Zero-rate phase: jump to the next phase edge.
                        let next_edge = (t / period) * period
                            + if t % period < on_span { on_span } else { period };
                        if next_edge <= t || next_edge >= horizon {
                            break;
                        }
                        t = next_edge;
                        continue;
                    };
                    t = t.saturating_add(dt);
                    if t >= horizon {
                        break;
                    }
                    out.push(t);
                }
                out
            }
            Arrival::Trace(times) => {
                let mut out: Vec<Ps> = times.iter().copied().filter(|&t| t < horizon).collect();
                out.sort_unstable();
                out
            }
            Arrival::ClosedLoop { clients, .. } => vec![0; *clients],
        }
    }

    /// Think time for closed-loop respawns (`None` for open loop).
    /// (The serve report's `offered_rps` comes from the *actual*
    /// generated arrival count, never from a nominal-rate formula.)
    pub fn think_time(&self) -> Option<Ps> {
        match self {
            Arrival::ClosedLoop { think, .. } => Some(*think),
            _ => None,
        }
    }
}

/// One exponential inter-arrival draw at `rate` requests/second, in ps.
/// `None` when the rate is not positive (no arrivals in this regime).
fn exp_interval_ps(rng: &mut SplitMix64, rate: f64) -> Option<Ps> {
    if rate <= 0.0 {
        return None;
    }
    // u in [0, 1) => 1-u in (0, 1]; -ln(1-u)/rate is a proper
    // exponential sample with no ln(0) hazard.
    let u = rng.next_f64();
    let dt_s = -(1.0 - u).ln() / rate;
    Some((dt_s * 1e12) as Ps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ms;

    #[test]
    fn poisson_is_deterministic_and_near_rate() {
        let a = Arrival::Poisson { rps: 1000.0 };
        let x = a.times(42, ms(1000)); // 1 s
        let y = a.times(42, ms(1000));
        assert_eq!(x, y, "same seed, same arrivals");
        // ~1000 arrivals +- 15%.
        assert!((850..=1150).contains(&x.len()), "{}", x.len());
        assert!(x.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let z = a.times(43, ms(1000));
        assert_ne!(x, z, "different seed, different stream");
    }

    #[test]
    fn burst_means_more_than_base() {
        let quiet = Arrival::Poisson { rps: 100.0 };
        let bursty = Arrival::Burst {
            base_rps: 100.0,
            burst_rps: 2000.0,
            period: ms(10),
            duty: 0.3,
        };
        let nq = quiet.times(7, ms(500)).len();
        let nb = bursty.times(7, ms(500)).len();
        assert!(nb > 2 * nq, "burst {nb} vs base {nq}");
    }

    #[test]
    fn burst_with_zero_base_rate_terminates() {
        let a = Arrival::Burst {
            base_rps: 0.0,
            burst_rps: 1000.0,
            period: ms(10),
            duty: 0.5,
        };
        let times = a.times(1, ms(100));
        assert!(!times.is_empty());
        // Arrivals concentrate in the on-phases (a draw from late in an
        // on-phase may overshoot into the off-phase, but no draws
        // *originate* there).
        let on = times.iter().filter(|t| *t % ms(10) < ms(5)).count();
        let off = times.len() - on;
        assert!(on > 3 * off, "on {on} vs off {off}");
    }

    #[test]
    fn trace_sorts_and_truncates() {
        let a = Arrival::Trace(vec![ms(5), ms(1), ms(99), ms(3)]);
        assert_eq!(a.times(0, ms(10)), vec![ms(1), ms(3), ms(5)]);
        assert_eq!(a.times(77, ms(10)), a.times(0, ms(10)), "seed-free");
    }

    #[test]
    fn closed_loop_initial_batch() {
        let a = Arrival::ClosedLoop {
            clients: 4,
            think: ms(1),
        };
        assert_eq!(a.times(9, ms(100)), vec![0, 0, 0, 0]);
        assert_eq!(a.think_time(), Some(ms(1)));
        assert_eq!(Arrival::Poisson { rps: 1.0 }.think_time(), None);
    }
}
