//! Traffic serving: requests arriving over time, replica-aware
//! dispatch, tail-latency SLOs, and a queue-driven DFS governor.
//!
//! The rest of the crate measures *steady-state throughput* — fixed
//! warmup/measure windows, as Table I and Fig. 3 do. Real workloads
//! arrive as *requests over time*: they queue, they have deadlines, and
//! the paper's headline features (accelerator replication, per-island
//! fine-grained DFS, run-time monitoring) exist to serve them well.
//! This module closes that gap:
//!
//! * [`Arrival`] — open-loop Poisson/bursty/trace arrivals and a
//!   closed-loop client model, all deterministic in the spec's seed;
//! * [`DispatchPolicy`] — binds each request to one MRA tile
//!   (round-robin, join-shortest-queue, or frequency-aware least-loaded)
//!   with bounded admission queues and drop accounting; the tile's AXI
//!   bridge then spreads credited invocations across its replicas,
//!   exactly as the hardware arbitrates;
//! * [`ServeReport`] — offered vs. achieved rps, per-tile queue-depth
//!   timelines, and *exact* p50/p95/p99/max end-to-end latency
//!   ([`crate::util::Percentiles`]);
//! * [`QueueGovernor`] — a [`DfsPolicy`](crate::policy::DfsPolicy) that
//!   boosts an island when queue depth or windowed p95 breaches the SLO
//!   and relaxes it when the island runs faster than the traffic needs
//!   — DFS paying off in tail latency, not just throughput.
//!
//! # Quickstart
//!
//! ```text
//! let mut session = Session::new(paper_soc(("dfmul", 2), ("dfmul", 2)))?;
//! let spec = ServeSpec::new(Arrival::Poisson { rps: 1200.0 }, ms(200))
//!     .policy(DispatchPolicy::JoinShortestQueue)
//!     .slo(ms(5))
//!     .governor(GovernorSpec::new(ISL_A1, ms(5)));
//! let report = session.serve(&spec)?;
//! println!("{}", report.render());
//! assert_eq!(report.slo_met, Some(true));
//! ```
//!
//! # Mechanics
//!
//! Serving gates the target tiles ([`crate::tiles::ServeGate`]): a
//! replica may start a new invocation only against a credit granted
//! when a request is admitted, and every credited invocation that
//! finishes draining is tagged `(time, replica)` in the tile's
//! completion log. The engine attributes completions to requests FIFO
//! per tile, so latencies are exact simulator timestamps — arrival to
//! final DMA writeback — independent of the host loop's event
//! granularity. Same seed + same spec ⇒ identical [`ServeReport`],
//! which `rust/tests/serve.rs` asserts.

pub mod arrival;
pub mod dispatch;
pub mod engine;
pub mod governor;
pub mod report;

pub use arrival::Arrival;
pub use dispatch::DispatchPolicy;
pub use engine::ServeSpec;
pub use governor::{GovernorSpec, QueueGovernor};
pub use report::{LatencyStats, ServeReport, TileServeReport};
