//! [`QueueGovernor`]: the serving-aware DFS policy that closes the
//! paper's monitoring loop around tail latency instead of throughput.
//!
//! Control law (hysteresis bang-bang, like [`crate::policy::ReactiveDfs`]
//! but driven by serving signals): at every sample,
//!
//! * **boost** the governed island one step when the window's p95
//!   latency breaches the SLO *or* the mean tile backlog exceeds
//!   `depth_high` (queues growing — latency is about to breach);
//! * **relax** one step when the window's p95 sits below
//!   `relax_margin * SLO` *and* the backlog is at most `depth_low`
//!   (the island is faster than the traffic needs — spend less power).
//!
//! Backlog comes straight from the SoC
//! ([`MraTile::serve_backlog`](crate::tiles::MraTile::serve_backlog)),
//! so the governor works as a plain [`DfsPolicy`] too; latency samples
//! are fed by the serve engine between samples via
//! [`QueueGovernor::observe_latency`].

use crate::policy::DfsPolicy;
use crate::sim::Soc;
use crate::util::{Percentiles, Ps};

/// Declarative governor configuration carried by a
/// [`ServeSpec`](super::ServeSpec).
#[derive(Debug, Clone, PartialEq)]
pub struct GovernorSpec {
    /// Frequency island to actuate.
    pub island: usize,
    /// p95 latency target (ps).
    pub slo: Ps,
    /// Boost when the mean backlog across served tiles exceeds this.
    pub depth_high: f64,
    /// Relax only when the mean backlog is at most this.
    pub depth_low: f64,
    /// MHz per actuation step.
    pub step_mhz: u64,
}

impl GovernorSpec {
    /// A governor on `island` targeting p95 `slo`, with defaults sized
    /// for a handful of replicas (boost above 4 queued, relax below 1).
    pub fn new(island: usize, slo: Ps) -> Self {
        Self {
            island,
            slo,
            depth_high: 4.0,
            depth_low: 1.0,
            step_mhz: 5,
        }
    }
}

/// The governor. Construct directly or from a [`GovernorSpec`] plus the
/// tiles being served.
#[derive(Debug, Clone)]
pub struct QueueGovernor {
    pub island: usize,
    /// Tiles whose backlog is watched.
    pub tiles: Vec<usize>,
    pub slo: Ps,
    pub depth_high: f64,
    pub depth_low: f64,
    pub step_mhz: u64,
    /// Relax only while window p95 < `relax_margin * slo` (hysteresis:
    /// keeps boost/relax from oscillating around the SLO edge).
    pub relax_margin: f64,
    /// Latencies (ps) observed since the last decision.
    window: Vec<f64>,
    /// Decisions taken: (time, new MHz).
    pub actions: Vec<(Ps, u64)>,
}

impl QueueGovernor {
    pub fn new(spec: &GovernorSpec, tiles: Vec<usize>) -> Self {
        Self {
            island: spec.island,
            tiles,
            slo: spec.slo,
            depth_high: spec.depth_high,
            depth_low: spec.depth_low,
            step_mhz: spec.step_mhz,
            relax_margin: 0.5,
            window: Vec::new(),
            actions: Vec::new(),
        }
    }

    /// Feed one completed request's end-to-end latency (ps). Called by
    /// the serve engine as completions drain.
    pub fn observe_latency(&mut self, latency: Ps) {
        self.window.push(latency as f64);
    }

    /// Mean granted-but-uncompleted backlog across the watched tiles.
    fn mean_backlog(&self, soc: &Soc) -> f64 {
        if self.tiles.is_empty() {
            return 0.0;
        }
        let sum: u64 = self.tiles.iter().map(|&t| soc.mra(t).serve_backlog()).sum();
        sum as f64 / self.tiles.len() as f64
    }
}

impl DfsPolicy for QueueGovernor {
    fn on_sample(&mut self, soc: &mut Soc, now: Ps) {
        let depth = self.mean_backlog(soc);
        // p95 of the completions inside this window; None when nothing
        // completed (deep overload counts as a breach via the backlog).
        let p95 = Percentiles::from_samples(&self.window)
            .ok()
            .filter(|p| !p.is_empty())
            .map(|p| p.p95());
        self.window.clear();

        let slo = self.slo as f64;
        let breach = p95.is_some_and(|p| p > slo) || depth > self.depth_high;
        let relaxed = p95.is_none_or(|p| p < self.relax_margin * slo) && depth <= self.depth_low;

        let cur = soc.islands[self.island].freq(now).as_mhz();
        let (min, max) = (
            soc.islands[self.island].min.as_mhz(),
            soc.islands[self.island].max.as_mhz(),
        );
        let target = if breach && cur < max {
            (cur + self.step_mhz).min(max)
        } else if relaxed && cur > min {
            cur.saturating_sub(self.step_mhz).max(min)
        } else {
            return;
        };
        if target != cur && soc.host_write_freq(self.island, target).is_ok() {
            self.actions.push((now, target));
        }
    }

    fn name(&self) -> &'static str {
        "queue-governor"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RefCompute;
    use crate::scenario::Scenario;

    fn soc_with_gated_tile(start_mhz: u64) -> (Soc, usize) {
        let cfg = Scenario::grid(2, 2)
            .island("noc", 100)
            .island_dfs("acc", start_mhz, 10..=50, 5)
            .noc_island("noc")
            .mem_at(0, 0)
            .accel_at(1, 0, "dfmul", 1, "acc")
            .io_at_on(0, 1, "noc")
            .fill_tg("noc")
            .build()
            .unwrap();
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let tile = soc.mra_tiles()[0];
        soc.mra_mut(tile).serve_begin();
        (soc, tile)
    }

    #[test]
    fn boosts_on_slo_breach_and_on_backlog() {
        let spec = GovernorSpec::new(1, 1_000_000_000); // p95 SLO 1 ms
        let (mut soc, tile) = soc_with_gated_tile(20);
        let mut g = QueueGovernor::new(&spec, vec![tile]);
        // Latency breach: p95 over the SLO.
        g.observe_latency(2_000_000_000);
        g.on_sample(&mut soc, 0);
        assert_eq!(g.actions.last(), Some(&(0, 25)));
        // Backlog breach with no completions at all. (Run past the
        // actuator swap first — the governor reads the *current* island
        // frequency, which stays 20 MHz until the dual-MMCM swaps.)
        soc.run_until(20_000_000);
        let now = soc.now;
        soc.mra_mut(tile).serve_grant(10); // backlog 10 > depth_high
        g.on_sample(&mut soc, now);
        assert_eq!(g.actions.last(), Some(&(now, 30)));
    }

    #[test]
    fn relaxes_when_idle_and_fast() {
        let spec = GovernorSpec::new(1, 1_000_000_000);
        let (mut soc, tile) = soc_with_gated_tile(50);
        let mut g = QueueGovernor::new(&spec, vec![tile]);
        // Fast completions, empty queue: step down.
        g.observe_latency(100_000_000); // 0.1 ms << 0.5 * SLO
        g.on_sample(&mut soc, 0);
        assert_eq!(g.actions.last(), Some(&(0, 45)));
        // No completions and no backlog (idle): also steps down, once
        // the first retune has actually swapped in.
        soc.run_until(20_000_000);
        let now = soc.now;
        g.on_sample(&mut soc, now);
        assert_eq!(g.actions.last(), Some(&(now, 40)));
    }

    #[test]
    fn holds_inside_the_hysteresis_band() {
        let spec = GovernorSpec::new(1, 1_000_000_000);
        let (mut soc, _tile) = soc_with_gated_tile(30);
        let mut g = QueueGovernor::new(&spec, vec![]);
        // p95 between relax margin and SLO: no action either way.
        g.observe_latency(700_000_000);
        g.on_sample(&mut soc, 0);
        assert!(g.actions.is_empty());
    }

    #[test]
    fn clamps_at_island_bounds() {
        let spec = GovernorSpec::new(1, 1_000_000_000);
        let (mut soc, tile) = soc_with_gated_tile(50);
        let mut g = QueueGovernor::new(&spec, vec![tile]);
        g.observe_latency(5_000_000_000);
        g.on_sample(&mut soc, 0); // breach at max: nothing to boost to
        assert!(g.actions.is_empty());
        let (mut soc, tile) = soc_with_gated_tile(10);
        let mut g = QueueGovernor::new(&spec, vec![tile]);
        g.observe_latency(1_000_000); // far under SLO at min
        g.on_sample(&mut soc, 0);
        assert!(g.actions.is_empty(), "nothing to relax to at min");
    }
}
