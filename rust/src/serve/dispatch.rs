//! Replica-aware dispatch: which accelerator tile serves each request.
//!
//! Replication exists on two levels in the paper's architecture — `K`
//! replicas behind one NoC node (the MRA bridge arbitrates those) and
//! replicated MRA *tiles* across the grid. The dispatcher balances the
//! second level: each admitted request is bound to one tile and granted
//! one invocation credit there; the tile's bridge then spreads credited
//! invocations across its replicas exactly as the hardware would.
//!
//! Admission queues are bounded: a tile holds at most `queue_capacity`
//! granted-but-uncompleted requests, and a request that finds every
//! candidate tile full is dropped (counted, never silently lost).

use std::collections::VecDeque;

use crate::sim::Soc;
use crate::util::Ps;

/// Tile-selection policy for admitted requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchPolicy {
    /// Cycle through the tiles in index order, skipping full ones.
    #[default]
    RoundRobin,
    /// Bind to the tile with the fewest outstanding requests
    /// (ties break on the lower tile index).
    JoinShortestQueue,
    /// Bind to the tile with the least *estimated drain time*:
    /// outstanding work weighted by the tile's invocation cycles at its
    /// island's current DFS frequency — replica- and frequency-aware
    /// where [`DispatchPolicy::JoinShortestQueue`] only counts heads.
    LeastLoadedTile,
}

impl DispatchPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::JoinShortestQueue => "join-shortest-queue",
            DispatchPolicy::LeastLoadedTile => "least-loaded-tile",
        }
    }

    /// Parse a CLI spelling (`rr` / `jsq` / `least`, or the full names).
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "rr" | "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Ok(DispatchPolicy::JoinShortestQueue),
            "least" | "least-loaded" | "least-loaded-tile" => Ok(DispatchPolicy::LeastLoadedTile),
            other => anyhow::bail!(
                "unknown dispatch policy {other:?} (expected rr, jsq, or least)"
            ),
        }
    }
}

/// One granted-but-uncompleted request in a tile queue.
///
/// `extra` carries latency already accrued by earlier attempts of the
/// same request (retry backoff, queueing before a replica crash), so
/// end-to-end latency always spans the *original* arrival:
/// `t_complete - t_arr + extra`. Both are zero on the fault-free path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Req {
    /// Arrival (or retry-due) time of this attempt.
    pub t_arr: Ps,
    /// Latency accrued before this attempt started.
    pub extra: Ps,
    /// 0-based attempt index (0 = first try).
    pub attempt: u32,
}

/// Per-tile dispatch state.
#[derive(Debug, Clone)]
pub(crate) struct TileQueue {
    /// Tile (node) index in the SoC.
    pub tile: usize,
    /// Frequency island the tile clocks on (for load estimation).
    pub island: usize,
    /// Compute cycles of one invocation on this tile's accelerator.
    pub compute_cycles: u64,
    /// Replicas behind the tile's bridge.
    pub replicas: usize,
    /// Arrival times of requests granted to this tile and not yet
    /// completed, in dispatch order (the tile completes credited
    /// invocations FIFO up to replica overlap; attribution pops the
    /// front). Carrying the arrival time directly — instead of an index
    /// into a shared request table — keeps latency attribution local to
    /// the dispatcher, so cluster replicas can drain completions on
    /// worker threads without sharing state.
    pub in_flight: VecDeque<Req>,
    pub admitted: u64,
    pub completed: u64,
    /// Peak queue depth observed.
    pub max_depth: usize,
}

/// The dispatcher: policy + bounded per-tile queues + drop accounting.
#[derive(Debug, Clone)]
pub(crate) struct Dispatcher {
    pub policy: DispatchPolicy,
    pub capacity: usize,
    pub tiles: Vec<TileQueue>,
    pub dropped: u64,
    /// Outstanding requests across every tile queue, maintained by
    /// [`Dispatcher::bind`] / [`Dispatcher::complete`] so hot paths
    /// (cluster barriers, balancer eligibility) never re-sum per-tile
    /// queue lengths.
    pub backlog: usize,
    rr_cursor: usize,
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy, capacity: usize, tiles: Vec<TileQueue>) -> Self {
        Self {
            policy,
            capacity,
            tiles,
            dropped: 0,
            backlog: 0,
            rr_cursor: 0,
        }
    }

    /// Whether any tile queue still has admission space. `backlog`
    /// equals `capacity * tiles` exactly when every queue is full, so
    /// this is O(1).
    pub fn has_space(&self) -> bool {
        self.backlog < self.capacity * self.tiles.len()
    }

    /// Pick the queue slot for a new request, or `None` (drop) when
    /// every candidate tile is at capacity. `now` feeds the
    /// frequency-aware load estimate.
    pub fn pick(&mut self, soc: &Soc, now: Ps) -> Option<usize> {
        let n = self.tiles.len();
        let capacity = self.capacity;
        let has_space = move |q: &TileQueue| q.in_flight.len() < capacity;
        let choice = match self.policy {
            DispatchPolicy::RoundRobin => {
                let mut choice = None;
                for off in 0..n {
                    let i = (self.rr_cursor + off) % n;
                    if has_space(&self.tiles[i]) {
                        choice = Some(i);
                        self.rr_cursor = (i + 1) % n;
                        break;
                    }
                }
                choice
            }
            DispatchPolicy::JoinShortestQueue => self
                .tiles
                .iter()
                .enumerate()
                .filter(|(_, q)| has_space(q))
                .min_by_key(|(i, q)| (q.in_flight.len(), *i))
                .map(|(i, _)| i),
            DispatchPolicy::LeastLoadedTile => self
                .tiles
                .iter()
                .enumerate()
                .filter(|(_, q)| has_space(q))
                .map(|(i, q)| {
                    let mhz = soc.islands[q.island].freq(now).as_mhz().max(1);
                    // Estimated time to drain this queue plus the new
                    // request, spread across the tile's replicas.
                    let backlog = (q.in_flight.len() + 1) as f64;
                    let est = backlog * q.compute_cycles as f64
                        / (mhz as f64 * q.replicas as f64);
                    (i, est)
                })
                .min_by(|(ia, a), (ib, b)| a.total_cmp(b).then(ia.cmp(ib)))
                .map(|(i, _)| i),
        };
        if choice.is_none() {
            self.dropped += 1;
        }
        choice
    }

    /// Record that a request that arrived at `t_arr` was granted to
    /// queue slot `slot`.
    pub fn bind(&mut self, slot: usize, t_arr: Ps) {
        self.bind_attempt(slot, t_arr, 0, 0);
    }

    /// [`Dispatcher::bind`] for a retried request: carries the latency
    /// already accrued by earlier attempts and the attempt index.
    pub fn bind_attempt(&mut self, slot: usize, t_arr: Ps, extra: Ps, attempt: u32) {
        let q = &mut self.tiles[slot];
        q.in_flight.push_back(Req { t_arr, extra, attempt });
        q.admitted += 1;
        q.max_depth = q.max_depth.max(q.in_flight.len());
        self.backlog += 1;
    }

    /// Attribute one completion on queue slot `slot` to the oldest
    /// outstanding request there (FIFO); returns its arrival time.
    pub fn complete(&mut self, slot: usize) -> Option<Ps> {
        self.complete_req(slot).map(|r| r.t_arr)
    }

    /// [`Dispatcher::complete`], returning the full request record
    /// (arrival, accrued latency, attempt index).
    pub fn complete_req(&mut self, slot: usize) -> Option<Req> {
        let q = &mut self.tiles[slot];
        let req = q.in_flight.pop_front();
        if req.is_some() {
            q.completed += 1;
            self.backlog -= 1;
        }
        req
    }

    /// Undo the drop [`Dispatcher::pick`] just counted: the caller is
    /// scheduling a retry instead of losing the request.
    pub fn undrop(&mut self) {
        debug_assert!(self.dropped > 0, "undrop without a preceding drop");
        self.dropped = self.dropped.saturating_sub(1);
    }

    /// Count one drop outside [`Dispatcher::pick`] — a deadline-expired
    /// request or a retry still pending when serving stopped — so
    /// `offered == admitted + dropped` stays exact under faults.
    pub fn drop_one(&mut self) {
        self.dropped += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RefCompute;
    use crate::scenario::Scenario;

    fn mini_soc() -> Soc {
        let cfg = Scenario::grid(2, 2)
            .island("noc", 100)
            .island_dfs("fast", 50, 10..=50, 5)
            .island_dfs("slow", 20, 10..=50, 5)
            .noc_island("noc")
            .mem_at(0, 0)
            .accel_at(1, 0, "dfmul", 1, "fast")
            .accel_at(0, 1, "dfmul", 1, "slow")
            .io_at_on(1, 1, "noc")
            .build()
            .unwrap();
        Soc::build(cfg, Box::new(RefCompute::new())).unwrap()
    }

    fn queues(soc: &Soc) -> Vec<TileQueue> {
        soc.mra_tiles()
            .into_iter()
            .map(|tile| {
                let island = soc
                    .cfg
                    .tiles
                    .iter()
                    .find(|t| soc.cfg.node_of(t.x, t.y) == tile)
                    .map(|t| t.island)
                    .unwrap();
                TileQueue {
                    tile,
                    island,
                    compute_cycles: soc.mra(tile).timing.compute_cycles,
                    replicas: soc.mra(tile).replica_count(),
                    in_flight: VecDeque::new(),
                    admitted: 0,
                    completed: 0,
                    max_depth: 0,
                }
            })
            .collect()
    }

    #[test]
    fn round_robin_alternates_and_skips_full() {
        let soc = mini_soc();
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin, 2, queues(&soc));
        let a = d.pick(&soc, 0).unwrap();
        d.bind(a, 0);
        let b = d.pick(&soc, 0).unwrap();
        d.bind(b, 1);
        assert_ne!(a, b, "round robin alternates");
        // Fill slot a to capacity; RR must skip it.
        d.bind(a, 2);
        let c = d.pick(&soc, 0).unwrap();
        assert_eq!(c, b, "full tile skipped");
    }

    #[test]
    fn jsq_prefers_shorter_queue_and_drops_when_full() {
        let soc = mini_soc();
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue, 1, queues(&soc));
        let a = d.pick(&soc, 0).unwrap();
        assert_eq!(a, 0, "tie breaks on the lower index");
        d.bind(a, 0);
        let b = d.pick(&soc, 0).unwrap();
        assert_eq!(b, 1, "shorter queue wins");
        d.bind(b, 1);
        assert_eq!(d.pick(&soc, 0), None, "everything full: drop");
        assert_eq!(d.dropped, 1);
        assert_eq!(d.backlog, 2);
        assert!(!d.has_space());
        // A completion frees the slot again.
        assert_eq!(d.complete(0), Some(0));
        assert_eq!(d.backlog, 1);
        assert!(d.has_space());
        assert_eq!(d.pick(&soc, 0), Some(0));
    }

    #[test]
    fn least_loaded_is_frequency_aware() {
        let soc = mini_soc();
        let mut d = Dispatcher::new(DispatchPolicy::LeastLoadedTile, 8, queues(&soc));
        // Identical depths: the 50 MHz tile drains 2.5x faster than the
        // 20 MHz one, so it absorbs the first several requests.
        for i in 0..2 {
            let s = d.pick(&soc, 0).unwrap();
            assert_eq!(s, 0, "fast tile absorbs request {i}");
            d.bind(s, i);
        }
        // Once the fast tile's estimated drain time exceeds the empty
        // slow tile's, the slow tile gets its first request: 3 ahead on
        // fast = 3/50 cycles-per-MHz > 1/20.
        let s = d.pick(&soc, 0).unwrap();
        assert_eq!(s, 1, "load estimate eventually routes to slow tile");
    }

    #[test]
    fn completion_attribution_is_fifo() {
        let soc = mini_soc();
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin, 8, queues(&soc));
        d.bind(0, 10);
        d.bind(0, 11);
        assert_eq!(d.backlog, 2, "bind maintains the backlog counter");
        assert_eq!(d.complete(0), Some(10), "FIFO returns the oldest arrival");
        assert_eq!(d.complete(0), Some(11));
        assert_eq!(d.complete(0), None);
        assert_eq!(d.backlog, 0, "complete maintains the backlog counter");
        assert_eq!(d.tiles[0].max_depth, 2);
    }

    #[test]
    fn retry_attempt_metadata_rides_the_queue() {
        let soc = mini_soc();
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin, 8, queues(&soc));
        d.bind(0, 10);
        d.bind_attempt(0, 500, 490, 2);
        assert_eq!(
            d.complete_req(0),
            Some(Req { t_arr: 10, extra: 0, attempt: 0 }),
            "bind is bind_attempt with zero extra/attempt"
        );
        assert_eq!(d.complete_req(0), Some(Req { t_arr: 500, extra: 490, attempt: 2 }));
        assert_eq!(d.complete_req(0), None);
        // undrop/drop_one adjust the drop counter symmetrically.
        d.drop_one();
        d.drop_one();
        assert_eq!(d.dropped, 2);
        d.undrop();
        assert_eq!(d.dropped, 1);
    }

    #[test]
    fn zero_capacity_queues_drop_everything_exactly() {
        // A zero-capacity dispatcher admits nothing: every pick is a
        // drop, under every policy, and the accounting is exact.
        let soc = mini_soc();
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::LeastLoadedTile,
        ] {
            let mut d = Dispatcher::new(policy, 0, queues(&soc));
            for _ in 0..17 {
                assert_eq!(d.pick(&soc, 0), None, "{policy:?} must drop at cap 0");
            }
            assert_eq!(d.dropped, 17, "{policy:?} counts every drop");
            assert!(d.tiles.iter().all(|q| q.admitted == 0 && q.in_flight.is_empty()));
            assert!(d.tiles.iter().all(|q| q.max_depth == 0));
        }
    }

    #[test]
    fn saturated_tiles_drop_then_recover_per_policy() {
        // Fill every tile to capacity: each policy must drop (not stall,
        // not overfill); a single completion re-opens exactly one slot.
        let soc = mini_soc();
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::LeastLoadedTile,
        ] {
            let cap = 2;
            let mut d = Dispatcher::new(policy, cap, queues(&soc));
            let mut req: usize = 0;
            while let Some(slot) = d.pick(&soc, 0) {
                d.bind(slot, req as Ps);
                req += 1;
                assert!(req <= cap * d.tiles.len(), "{policy:?} overfilled a queue");
            }
            assert_eq!(req, cap * d.tiles.len(), "{policy:?} filled every slot");
            assert_eq!(d.backlog, req, "{policy:?} backlog counts every bind");
            assert_eq!(d.dropped, 1, "{policy:?}: the failed pick was counted");
            assert!(d.tiles.iter().all(|q| q.in_flight.len() == cap));
            // One completion frees exactly one slot; the next pick must
            // land there and the one after must drop again.
            assert!(d.complete(1).is_some());
            let slot = d.pick(&soc, 0).expect("freed capacity is usable");
            assert_eq!(slot, 1, "{policy:?} routes to the only open tile");
            d.bind(slot, req as Ps);
            assert_eq!(d.pick(&soc, 0), None);
            assert_eq!(d.dropped, 2);
        }
    }

    #[test]
    fn policy_parse_spellings() {
        assert_eq!(
            DispatchPolicy::parse("rr").unwrap(),
            DispatchPolicy::RoundRobin
        );
        assert_eq!(
            DispatchPolicy::parse("jsq").unwrap(),
            DispatchPolicy::JoinShortestQueue
        );
        assert_eq!(
            DispatchPolicy::parse("least-loaded-tile").unwrap(),
            DispatchPolicy::LeastLoadedTile
        );
        assert!(DispatchPolicy::parse("zeal").is_err());
    }

    #[test]
    fn policy_parse_rejects_unknowns_actionably() {
        // The error must name the bad input AND list the valid
        // spellings, so a CLI user can fix their invocation from the
        // message alone.
        for bad in ["zeal", "", "JSQ", "round robin"] {
            let err = DispatchPolicy::parse(bad).unwrap_err().to_string();
            assert!(err.contains(&format!("{bad:?}")), "{err}");
            for spelling in ["rr", "jsq", "least"] {
                assert!(err.contains(spelling), "{err} must suggest {spelling}");
            }
        }
    }
}
