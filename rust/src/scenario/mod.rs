//! The Scenario/Session API: fluent SoC construction, declarative
//! workload phases, and parallel scenario evaluation.
//!
//! This is the crate's front door for design-space exploration — the
//! paper's §I workflow of "exploring a multitude of solutions that differ
//! in the replication of accelerators, the clock frequencies of the
//! frequency islands, and the tiles' placement" — packaged as three
//! layers:
//!
//! 1. [`Scenario`] — a validated fluent builder over
//!    [`crate::config::SocConfig`]: arbitrary `WxH` grids, named
//!    frequency islands, any tile kind at any coordinate.
//! 2. [`Session`] — wraps a running [`crate::sim::Soc`] with declarative
//!    workload phases (`stage` → `warmup` → `measure`) that return typed
//!    [`PhaseReport`]s instead of hand-rolled counter choreography.
//! 3. [`ScenarioSet`] — evaluates independent scenarios across OS
//!    threads (one `Soc` per worker) with results in deterministic
//!    scenario-index order; [`ScenarioSpec`] names one paper-grid design
//!    point for `dse::sweep` and friends.
//!
//! ```text
//! let cfg = Scenario::grid(4, 4)
//!     .island_dfs("noc", 100, 10..=100, 5)
//!     .island_dfs("acc", 50, 10..=50, 5)
//!     .mem_at(0, 0)
//!     .cpu_at(3, 0)
//!     .accel_at(0, 1, "dfmul", 2, "acc")
//!     .fill_tg("acc")
//!     .build()?;
//! let mut session = Session::new(cfg)?;
//! let tile = session.soc().cfg.node_of(0, 1);
//! session.stage(tile, 1)?.with_tg_load(4).warmup(ms(2));
//! let report = session.measure(tile, ms(5))?;
//! println!("{:.2} MB/s, RTT {:.0} ns", report.throughput_mbs, report.rtt_ns);
//! ```

pub mod builder;
pub mod session;
pub mod set;

pub use builder::{IslandRef, Scenario};
pub use session::{run_until_invocations, PhaseReport, Session, SocSnapshot};
pub use set::{ScenarioSet, ScenarioSpec};

use crate::util::Ps;

/// `n` milliseconds of simulated time, in [`Ps`].
pub const fn ms(n: u64) -> Ps {
    n * 1_000_000_000
}

/// `n` microseconds of simulated time, in [`Ps`].
pub const fn us(n: u64) -> Ps {
    n * 1_000_000
}
