//! [`Session`]: declarative workload phases over a running [`Soc`].
//!
//! A session owns the simulated SoC and exposes the staging → warmup →
//! measure choreography that the paper's host tooling performs, as
//! chainable phases that return typed [`PhaseReport`]s. It replaces the
//! hand-rolled `stage_inputs_for` + `ThroughputProbe` + `run_for`
//! sequences that every experiment and example used to copy.

use std::collections::BTreeMap;

use crate::config::SocConfig;
use crate::mem::BlockId;
use crate::monitor::CounterReg;
use crate::runtime::{AccelCompute, RefCompute};
use crate::sim::{driver, Soc};
use crate::util::Ps;

/// Typed result of one measurement phase on one MRA tile.
///
/// Counter fields are *deltas over the measurement window* (the session
/// snapshots the hardware counters when the phase begins), so a report
/// is meaningful even after earlier phases ran on the same tile — the
/// one exception is [`PhaseReport::last_exec_cycles`], which mirrors the
/// auto-resetting hardware exec-time counter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseReport {
    /// Tile the phase measured.
    pub tile: usize,
    /// Simulation time when the window opened (ps).
    pub start: Ps,
    /// Window length (ps).
    pub elapsed: Ps,
    /// Completed accelerator invocations in the window.
    pub invocations: u64,
    /// Throughput in MB/s credited per the accelerator's stream bytes —
    /// the quantity Table I and Fig. 3 report.
    pub throughput_mbs: f64,
    /// Mean DMA round-trip time inside the window (ns); 0 if no
    /// round-trips completed.
    pub rtt_ns: f64,
    /// NoC packets into the tile during the window.
    pub pkts_in: u64,
    /// NoC packets out of the tile during the window.
    pub pkts_out: u64,
    /// Exec-time counter at window close (island-clock cycles). The
    /// hardware counter auto-resets when a computation starts, so this
    /// is the most recent computation's cycle count — not a window
    /// total.
    pub last_exec_cycles: u64,
}

/// Snapshot of one tile's counters at the start of a window.
#[derive(Debug, Clone, Copy)]
struct CounterSnapshot {
    start: Ps,
    invocations: u64,
    pkts_in: u64,
    pkts_out: u64,
    rtt_sum: u64,
    rtt_count: u64,
}

impl CounterSnapshot {
    fn take(soc: &Soc, tile: usize) -> Self {
        let c = soc.mon.tile(tile);
        Self {
            start: soc.now,
            invocations: c.invocations,
            pkts_in: c.pkts_in,
            pkts_out: c.pkts_out,
            rtt_sum: c.rtt_sum,
            rtt_count: c.rtt_count,
        }
    }

    fn report(&self, soc: &Soc, tile: usize) -> PhaseReport {
        let c = soc.mon.tile(tile);
        let elapsed = soc.now - self.start;
        let invocations = c.invocations - self.invocations;
        let dt_s = elapsed as f64 / 1e12;
        let credit = soc.mra(tile).timing.credit_bytes as f64;
        let throughput_mbs = if dt_s > 0.0 {
            invocations as f64 * credit / 1e6 / dt_s
        } else {
            0.0
        };
        let rtt_n = c.rtt_count - self.rtt_count;
        let rtt_ns = if rtt_n > 0 {
            (c.rtt_sum - self.rtt_sum) as f64 / rtt_n as f64 / 1e3
        } else {
            0.0
        };
        PhaseReport {
            tile,
            start: self.start,
            elapsed,
            invocations,
            throughput_mbs,
            rtt_ns,
            pkts_in: c.pkts_in - self.pkts_in,
            pkts_out: c.pkts_out - self.pkts_out,
            last_exec_cycles: c.exec_cycles,
        }
    }
}

/// Run `soc` until `tile` has completed `n` more invocations (or `cap`
/// time elapses). Returns elapsed ps. Time advances in 20 us slices —
/// fine enough that measurement windows align with invocation completion
/// (sub-5% quantization even for the fastest accelerators), coarse
/// enough to amortize loop overhead.
pub fn run_until_invocations(soc: &mut Soc, tile: usize, n: u64, cap: Ps) -> Ps {
    let start = soc.now;
    let target = soc.host_read_counter(tile, CounterReg::Invocations) + n;
    let cap_t = start + cap;
    while soc.host_read_counter(tile, CounterReg::Invocations) < target && soc.now < cap_t {
        let next = (soc.now + 20_000_000).min(cap_t);
        soc.run_until(next);
    }
    soc.now - start
}

/// A deep-frozen simulation instant: the complete [`Soc`] state (tiles,
/// NoC links and routers, packet arena, block store, clock domains with
/// in-flight DFS retimings, monitor counters, sampler traces, RNGs)
/// plus the session's staged-block bookkeeping.
///
/// Created by [`Session::snapshot`]; any number of independent sessions
/// can be forked from the same snapshot with [`Session::resume`] — the
/// warm-start primitive `dse::sweep`'s `WarmFork` planner builds on
/// (warm up one base SoC, fork it per frequency point, retune each fork
/// through the DFS actuators).
pub struct SocSnapshot {
    soc: Soc,
    staged: BTreeMap<usize, Vec<Vec<BlockId>>>,
}

impl SocSnapshot {
    /// Simulation time the snapshot was taken at (ps).
    pub fn now(&self) -> Ps {
        self.soc.now
    }

    /// Read-only view of the frozen SoC.
    pub fn soc(&self) -> &Soc {
        &self.soc
    }
}

/// A running simulation with declarative workload phases. See the
/// [module docs](crate::scenario) for the quickstart.
pub struct Session {
    soc: Soc,
    /// Block ids staged per tile (for functional output validation).
    staged: BTreeMap<usize, Vec<Vec<BlockId>>>,
}

impl Session {
    /// Build a session over `cfg` with the native reference backend.
    pub fn new(cfg: SocConfig) -> crate::Result<Self> {
        Self::with_backend(cfg, Box::new(RefCompute::new()))
    }

    /// Build a session over `cfg` with an explicit functional backend
    /// (e.g. PJRT).
    pub fn with_backend(cfg: SocConfig, backend: Box<dyn AccelCompute>) -> crate::Result<Self> {
        Ok(Self::from_soc(Soc::build(cfg, backend)?))
    }

    /// Wrap an already-built SoC.
    pub fn from_soc(soc: Soc) -> Self {
        Self {
            soc,
            staged: BTreeMap::new(),
        }
    }

    /// The underlying SoC (counters, sampler, tiles, ...).
    pub fn soc(&self) -> &Soc {
        &self.soc
    }

    /// Mutable access to the underlying SoC (escape hatch).
    pub fn soc_mut(&mut self) -> &mut Soc {
        &mut self.soc
    }

    /// Unwrap back into the SoC.
    pub fn into_soc(self) -> Soc {
        self.soc
    }

    /// Node index of the accelerator tile at grid position `(x, y)`.
    pub fn tile_at(&self, x: u16, y: u16) -> usize {
        self.soc.cfg.node_of(x, y)
    }

    /// Tile indices of all MRA tiles.
    pub fn mra_tiles(&self) -> Vec<usize> {
        self.soc.mra_tiles()
    }

    /// Freeze the complete simulation state into a [`SocSnapshot`].
    ///
    /// The session is untouched and keeps running; resuming the
    /// snapshot (with unchanged frequencies) is bit-identical to
    /// continuing this session — counters, sampler traces, and
    /// [`PhaseReport`]s all agree exactly. Errors only if the
    /// functional backend cannot be duplicated (PJRT; the default
    /// `RefCompute` always can).
    pub fn snapshot(&self) -> crate::Result<SocSnapshot> {
        Ok(SocSnapshot {
            soc: self.soc.fork()?,
            staged: self.staged.clone(),
        })
    }

    /// Fork a new independent session from `snap`. The snapshot is
    /// reusable: every call forks a fresh simulation from the same
    /// instant.
    pub fn resume(snap: &SocSnapshot) -> crate::Result<Self> {
        Ok(Self {
            soc: snap.soc.fork()?,
            staged: snap.staged.clone(),
        })
    }

    /// Stage `sets` functional input sets for MRA tile `tile`.
    pub fn stage(&mut self, tile: usize, sets: usize) -> crate::Result<&mut Self> {
        let ids = driver::stage_inputs_for(&mut self.soc, tile, sets)?;
        self.staged.insert(tile, ids);
        Ok(self)
    }

    /// Stage `sets` input sets on every MRA tile.
    pub fn stage_all(&mut self, sets: usize) -> crate::Result<&mut Self> {
        for tile in self.soc.mra_tiles() {
            self.stage(tile, sets)?;
        }
        Ok(self)
    }

    /// Block ids staged on `tile` (for functional output validation).
    pub fn staged(&self, tile: usize) -> &[Vec<BlockId>] {
        self.staged.get(&tile).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Select the simulation engine (default: event-driven). This is the
    /// single engine-selection surface — the CLI's `--engine` flag and
    /// [`crate::cluster::ClusterSpec::engine`] both route here.
    ///
    /// [`Reference`](crate::sim::EngineMode::Reference) ticks every
    /// component on every edge — the equivalence oracle the other two
    /// are tested against. [`IdleAware`](crate::sim::EngineMode::IdleAware)
    /// scans component deadlines per edge and coalesces quiescent
    /// spans. [`EventDriven`](crate::sim::EngineMode::EventDriven) pops
    /// components from per-island min-heaps so each edge costs only the
    /// work that is actually due. Safe to call mid-run: the scheduler
    /// conservatively re-arms every component.
    pub fn engine(&mut self, mode: crate::sim::EngineMode) -> &mut Self {
        self.soc.set_engine(mode);
        self
    }

    /// Perf mode: skip the functional datapath on all MRA tiles except
    /// for the first invocation (timing is unaffected; Table I / Fig. 3
    /// runs use this).
    pub fn perf_only(&mut self) -> &mut Self {
        for tile in self.soc.mra_tiles() {
            self.soc.mra_mut(tile).functional_every_invocation = false;
        }
        self
    }

    /// Enable the first `n` traffic-generator tiles, disable the rest
    /// (Fig. 3's X axis).
    pub fn with_tg_load(&mut self, n: usize) -> &mut Self {
        self.soc.host_set_tg_active(n);
        self
    }

    /// Host write to an island's frequency register (run-time DFS).
    pub fn freq(&mut self, island: usize, mhz: u64) -> crate::Result<&mut Self> {
        self.soc.host_write_freq(island, mhz)?;
        Ok(self)
    }

    /// Schedule a host frequency write at future simulation time `at`.
    pub fn schedule_freq(&mut self, at: Ps, island: usize, mhz: u64) -> &mut Self {
        self.soc.schedule_freq(at, island, mhz);
        self
    }

    /// Enable the periodic sampler (MEM packets + island frequencies).
    pub fn sample_every(&mut self, interval: Ps) -> &mut Self {
        self.soc.enable_sampler(interval);
        self
    }

    /// Run the simulation for `dur` picoseconds (settling phase).
    pub fn warmup(&mut self, dur: Ps) -> &mut Self {
        self.soc.run_for(dur);
        self
    }

    /// Run until `tile` completes `n` more invocations or `cap` elapses
    /// (pipeline-fill warmup for slow accelerators).
    pub fn warmup_invocations(
        &mut self,
        tile: usize,
        n: u64,
        cap: Ps,
    ) -> crate::Result<&mut Self> {
        self.soc.try_mra(tile)?;
        run_until_invocations(&mut self.soc, tile, n, cap);
        Ok(self)
    }

    /// Run until absolute simulation time `t` (ps).
    pub fn run_until(&mut self, t: Ps) -> &mut Self {
        self.soc.run_until(t);
        self
    }

    /// Measure `tile` over a fixed window of `window` picoseconds and
    /// return the typed report. Errors (without advancing time) if
    /// `tile` is not an MRA tile.
    pub fn measure(&mut self, tile: usize, window: Ps) -> crate::Result<PhaseReport> {
        self.soc.try_mra(tile)?;
        let snap = CounterSnapshot::take(&self.soc, tile);
        self.soc.run_for(window);
        Ok(snap.report(&self.soc, tile))
    }

    /// Measure `tile` over `n` whole invocations (timed exactly; at most
    /// `cap` picoseconds). Invocation-aligned windows avoid the burst
    /// quantization of fixed windows when replicas run in lockstep.
    pub fn measure_invocations(
        &mut self,
        tile: usize,
        n: u64,
        cap: Ps,
    ) -> crate::Result<PhaseReport> {
        self.soc.try_mra(tile)?;
        let snap = CounterSnapshot::take(&self.soc, tile);
        run_until_invocations(&mut self.soc, tile, n, cap);
        Ok(snap.report(&self.soc, tile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{paper_soc, A1_POS, ISL_A1};
    use crate::scenario::ms;

    #[test]
    fn session_measures_like_a_throughput_probe() {
        // Session::measure must agree exactly with the low-level probe.
        let mkcfg = || paper_soc(("dfmul", 2), ("dfadd", 1));

        let mut soc = Soc::build(mkcfg(), Box::new(RefCompute::new())).unwrap();
        let a1 = soc.cfg.node_of(A1_POS.0, A1_POS.1);
        driver::stage_inputs_for(&mut soc, a1, 1).unwrap();
        soc.mra_mut(a1).functional_every_invocation = false;
        soc.run_for(ms(2));
        let probe = driver::ThroughputProbe::begin(&soc, a1);
        soc.run_for(ms(4));
        let probe_mbs = probe.mbs(&soc);

        let mut s = Session::new(mkcfg()).unwrap();
        s.stage(a1, 1).unwrap().perf_only().warmup(ms(2));
        let r = s.measure(a1, ms(4)).unwrap();
        assert_eq!(r.throughput_mbs, probe_mbs, "bit-identical to the probe");
        assert!(r.invocations > 0);
        assert_eq!(r.elapsed, ms(4));
    }

    #[test]
    fn phase_report_counts_window_deltas_only() {
        let mut s = Session::new(paper_soc(("dfmul", 1), ("dfadd", 1))).unwrap();
        let a1 = s.tile_at(A1_POS.0, A1_POS.1);
        s.stage(a1, 1).unwrap().perf_only().warmup(ms(3));
        let warm_inv = s.soc().host_read_counter(a1, CounterReg::Invocations);
        assert!(warm_inv > 0, "warmup completed invocations");
        let r = s.measure(a1, ms(3)).unwrap();
        let total = s.soc().host_read_counter(a1, CounterReg::Invocations);
        assert_eq!(r.invocations, total - warm_inv);
        assert!(r.rtt_ns > 0.0);
        assert!(r.pkts_in > 0 && r.pkts_out > 0);
    }

    #[test]
    fn dfs_phase_reduces_throughput() {
        let mut s = Session::new(paper_soc(("dfmul", 2), ("dfadd", 1))).unwrap();
        let a1 = s.tile_at(A1_POS.0, A1_POS.1);
        s.stage(a1, 1).unwrap().perf_only().warmup(ms(2));
        let fast = s.measure(a1, ms(4)).unwrap();
        s.freq(ISL_A1, 10).unwrap().warmup(100_000_000);
        let slow = s.measure(a1, ms(4)).unwrap();
        let ratio = slow.throughput_mbs / fast.throughput_mbs;
        assert!(
            (0.12..=0.40).contains(&ratio),
            "50->10 MHz should cut throughput ~5x: {:.2} -> {:.2}",
            fast.throughput_mbs,
            slow.throughput_mbs
        );
    }

    #[test]
    fn staged_blocks_are_recorded() {
        let mut s = Session::new(paper_soc(("dfadd", 1), ("dfadd", 1))).unwrap();
        let a1 = s.tile_at(A1_POS.0, A1_POS.1);
        s.stage(a1, 2).unwrap();
        assert_eq!(s.staged(a1).len(), 2);
        assert_eq!(s.staged(a1)[0].len(), 2, "dfadd: two input streams");
        assert!(s.staged(99).is_empty());
    }

    #[test]
    fn stage_on_non_mra_tile_errors() {
        let mut s = Session::new(paper_soc(("dfadd", 1), ("dfadd", 1))).unwrap();
        let mem = s.tile_at(0, 0);
        assert!(s.stage(mem, 1).is_err());
    }

    #[test]
    fn measuring_a_non_mra_tile_errors_without_advancing_time() {
        let mut s = Session::new(paper_soc(("dfadd", 1), ("dfadd", 1))).unwrap();
        let mem = s.tile_at(0, 0);
        let t0 = s.soc().now;
        assert!(s.measure(mem, ms(1)).is_err());
        assert!(s.measure_invocations(999, 1, ms(1)).is_err());
        assert!(s.warmup_invocations(mem, 1, ms(1)).is_err());
        assert_eq!(s.soc().now, t0, "failed phases must not advance time");
    }
}
