//! [`Scenario`]: a fluent, validated builder over [`SocConfig`].
//!
//! Supports arbitrary `WxH` grids, named frequency islands (fixed or
//! DFS-driven), and placement of any tile kind at any coordinate.
//! Placement errors (overlaps, out-of-grid coordinates, zero replicas)
//! are recorded as they happen and reported together — with actionable
//! messages — when [`Scenario::build`] runs, so a long fluent chain never
//! panics halfway through.

use std::fmt;
use std::ops::RangeInclusive;

use anyhow::{bail, Context};

use crate::config::{BridgeCfg, IslandSpec, NocParams, SocConfig, TileKind, TileSpec};
use crate::mem::MemParams;
use crate::tiles::DmaParams;

/// A reference to a frequency island: by declared name or by index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IslandRef {
    Name(String),
    Index(usize),
}

impl From<&str> for IslandRef {
    fn from(s: &str) -> Self {
        IslandRef::Name(s.to_string())
    }
}

impl From<String> for IslandRef {
    fn from(s: String) -> Self {
        IslandRef::Name(s)
    }
}

impl From<usize> for IslandRef {
    fn from(i: usize) -> Self {
        IslandRef::Index(i)
    }
}

impl fmt::Display for IslandRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IslandRef::Name(n) => write!(f, "{n:?}"),
            IslandRef::Index(i) => write!(f, "#{i}"),
        }
    }
}

/// Short human name for a tile kind, used in builder error messages.
fn kind_name(k: &TileKind) -> &'static str {
    match k {
        TileKind::Cpu => "CPU",
        TileKind::Mem => "MEM",
        TileKind::Io => "I/O",
        TileKind::Tg => "TG",
        TileKind::Accel { .. } => "accelerator",
    }
}

/// Fluent SoC scenario builder. See the [module docs](crate::scenario)
/// for the full quickstart.
#[derive(Debug, Clone)]
pub struct Scenario {
    name: Option<String>,
    width: u16,
    height: u16,
    seed: u64,
    islands: Vec<IslandSpec>,
    /// One slot per grid cell (row-major), filled by placement calls.
    /// `None` in the island slot means "the NoC island", resolved at
    /// `build()` time so a later `.noc_island()` call still applies.
    cells: Vec<Option<(TileKind, Option<IslandRef>)>>,
    /// Island for cells left unplaced (TGs), if any.
    fill: Option<IslandRef>,
    /// Island the NoC routers + MEM controller belong to (default: #0).
    noc_island: Option<IslandRef>,
    noc: NocParams,
    mem: MemParams,
    dma: DmaParams,
    bridge: BridgeCfg,
    cpu_poll_interval: u32,
    /// Deferred placement/declaration errors, reported by `build()`.
    errors: Vec<String>,
}

impl Scenario {
    /// Start a scenario on a `width x height` mesh.
    pub fn grid(width: u16, height: u16) -> Self {
        let mut errors = Vec::new();
        if width == 0 || height == 0 {
            errors.push(format!(
                "empty {width}x{height} grid — both dimensions must be >= 1"
            ));
        }
        Self {
            name: None,
            width,
            height,
            seed: 0xE5B,
            islands: Vec::new(),
            cells: vec![None; width as usize * height as usize],
            fill: None,
            noc_island: None,
            noc: NocParams::default(),
            mem: MemParams::default(),
            dma: DmaParams::default(),
            bridge: BridgeCfg::default(),
            cpu_poll_interval: 0,
            errors,
        }
    }

    /// Name the scenario (defaults to `scenario-WxH`).
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// Seed for all simulation randomness (determinism knob).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Declare a fixed-frequency island.
    pub fn island(mut self, name: &str, freq_mhz: u64) -> Self {
        self.declare_island(IslandSpec {
            name: name.to_string(),
            freq_mhz,
            dfs: false,
            min_mhz: freq_mhz,
            max_mhz: freq_mhz,
            step_mhz: 5,
        });
        self
    }

    /// Declare a DFS-driven island: initial `freq_mhz`, runtime range
    /// `range` MHz, actuator step `step_mhz`.
    pub fn island_dfs(
        mut self,
        name: &str,
        freq_mhz: u64,
        range: RangeInclusive<u64>,
        step_mhz: u64,
    ) -> Self {
        self.declare_island(IslandSpec {
            name: name.to_string(),
            freq_mhz,
            dfs: true,
            min_mhz: *range.start(),
            max_mhz: *range.end(),
            step_mhz,
        });
        self
    }

    fn declare_island(&mut self, spec: IslandSpec) {
        if self.islands.iter().any(|i| i.name == spec.name) {
            self.errors.push(format!(
                "island {:?} declared twice — island names must be unique",
                spec.name
            ));
            return;
        }
        self.islands.push(spec);
    }

    /// Choose the island the NoC routers and MEM controller clock in
    /// (default: the first declared island).
    pub fn noc_island(mut self, island: impl Into<IslandRef>) -> Self {
        self.noc_island = Some(island.into());
        self
    }

    /// Place any tile kind at `(x, y)` on `island` (by name or index).
    pub fn tile_at(
        mut self,
        x: u16,
        y: u16,
        kind: TileKind,
        island: impl Into<IslandRef>,
    ) -> Self {
        self.place(x, y, kind, Some(island.into()));
        self
    }

    /// Place the (unique) MEM tile; it clocks with the NoC island (as
    /// chosen by [`Scenario::noc_island`], even when called later).
    pub fn mem_at(mut self, x: u16, y: u16) -> Self {
        self.place(x, y, TileKind::Mem, None);
        self
    }

    /// Place the MEM tile on an explicit island.
    pub fn mem_at_on(self, x: u16, y: u16, island: impl Into<IslandRef>) -> Self {
        self.tile_at(x, y, TileKind::Mem, island)
    }

    /// Place a CPU tile on the NoC island (see `cpu_at_on` to choose).
    pub fn cpu_at(mut self, x: u16, y: u16) -> Self {
        self.place(x, y, TileKind::Cpu, None);
        self
    }

    /// Place a CPU tile on an explicit island.
    pub fn cpu_at_on(self, x: u16, y: u16, island: impl Into<IslandRef>) -> Self {
        self.tile_at(x, y, TileKind::Cpu, island)
    }

    /// Place an I/O tile on the NoC island (see `io_at_on` to choose).
    pub fn io_at(mut self, x: u16, y: u16) -> Self {
        self.place(x, y, TileKind::Io, None);
        self
    }

    /// Place an I/O tile on an explicit island.
    pub fn io_at_on(self, x: u16, y: u16, island: impl Into<IslandRef>) -> Self {
        self.tile_at(x, y, TileKind::Io, island)
    }

    /// Place a traffic-generator tile.
    pub fn tg_at(self, x: u16, y: u16, island: impl Into<IslandRef>) -> Self {
        self.tile_at(x, y, TileKind::Tg, island)
    }

    /// Place a multi-replica accelerator tile: `replicas` copies of
    /// `accel` behind one NoC node, clocked by `island`.
    pub fn accel_at(
        mut self,
        x: u16,
        y: u16,
        accel: &str,
        replicas: usize,
        island: impl Into<IslandRef>,
    ) -> Self {
        if replicas == 0 {
            self.errors.push(format!(
                "accelerator {accel:?} at ({x}, {y}): zero replicas — an MRA tile needs \
                 1 to 16 replicas"
            ));
            return self;
        }
        self.place(
            x,
            y,
            TileKind::Accel {
                accel: accel.to_string(),
                replicas,
            },
            Some(island.into()),
        );
        self
    }

    /// Fill every cell not explicitly placed with a TG tile on `island`.
    pub fn fill_tg(mut self, island: impl Into<IslandRef>) -> Self {
        self.fill = Some(island.into());
        self
    }

    /// Override the NoC microarchitecture parameters (FIFO depth,
    /// pipeline, synchronizer stages). The `island` field of the params
    /// is ignored — `build()` always sets it from
    /// [`Scenario::noc_island`] (default: island #0).
    pub fn with_noc(mut self, params: NocParams) -> Self {
        self.noc = params;
        self
    }

    /// Override the memory-controller parameters.
    pub fn with_mem(mut self, params: MemParams) -> Self {
        self.mem = params;
        self
    }

    /// Override the per-replica DMA parameters.
    pub fn with_dma(mut self, params: DmaParams) -> Self {
        self.dma = params;
        self
    }

    /// Override the MRA bridge parameters.
    pub fn with_bridge(mut self, params: BridgeCfg) -> Self {
        self.bridge = params;
        self
    }

    /// CPU monitor-poll interval in CPU cycles (0 = off).
    pub fn cpu_poll_interval(mut self, cycles: u32) -> Self {
        self.cpu_poll_interval = cycles;
        self
    }

    fn default_island_ref(&self) -> IslandRef {
        self.noc_island.clone().unwrap_or(IslandRef::Index(0))
    }

    fn place(&mut self, x: u16, y: u16, kind: TileKind, island: Option<IslandRef>) {
        if x >= self.width || y >= self.height {
            self.errors.push(format!(
                "{} tile at ({x}, {y}) is outside the {}x{} grid — valid coordinates are \
                 x < {}, y < {}",
                kind_name(&kind),
                self.width,
                self.height,
                self.width,
                self.height
            ));
            return;
        }
        let idx = y as usize * self.width as usize + x as usize;
        if let Some((existing, _)) = &self.cells[idx] {
            self.errors.push(format!(
                "cell ({x}, {y}) already holds a {} tile — cannot also place a {} there \
                 (one tile per cell)",
                kind_name(existing),
                kind_name(&kind)
            ));
            return;
        }
        self.cells[idx] = Some((kind, island));
    }

    fn resolve(&self, r: &IslandRef, what: &str) -> crate::Result<usize> {
        match r {
            IslandRef::Index(i) => {
                if *i >= self.islands.len() {
                    bail!(
                        "{what}: island index {i} out of range — {} island(s) declared \
                         ({})",
                        self.islands.len(),
                        self.declared_names()
                    );
                }
                Ok(*i)
            }
            IslandRef::Name(n) => self
                .islands
                .iter()
                .position(|i| &i.name == n)
                .with_context(|| {
                    format!(
                        "{what}: no island named {n:?} — declare it with .island()/\
                         .island_dfs() before use (declared: {})",
                        self.declared_names()
                    )
                }),
        }
    }

    fn declared_names(&self) -> String {
        if self.islands.is_empty() {
            "none".to_string()
        } else {
            self.islands
                .iter()
                .map(|i| format!("{:?}", i.name))
                .collect::<Vec<_>>()
                .join(", ")
        }
    }

    /// Resolve names, fill the grid, and validate into a [`SocConfig`].
    pub fn build(self) -> crate::Result<SocConfig> {
        if !self.errors.is_empty() {
            bail!("invalid scenario:\n  - {}", self.errors.join("\n  - "));
        }

        let noc_ref = self.default_island_ref();
        let noc_island = self.resolve(&noc_ref, "NoC island")?;

        let mut tiles = Vec::with_capacity(self.cells.len());
        let mut unfilled = Vec::new();
        let fill_island = match &self.fill {
            Some(r) => Some(self.resolve(r, "fill_tg island")?),
            None => None,
        };
        for (idx, cell) in self.cells.iter().enumerate() {
            let x = (idx % self.width as usize) as u16;
            let y = (idx / self.width as usize) as u16;
            match cell {
                Some((kind, isl)) => {
                    let island = match isl {
                        Some(r) => self
                            .resolve(r, &format!("{} tile at ({x}, {y})", kind_name(kind)))?,
                        None => noc_island,
                    };
                    tiles.push(TileSpec {
                        x,
                        y,
                        kind: kind.clone(),
                        island,
                    });
                }
                None => match fill_island {
                    Some(island) => tiles.push(TileSpec {
                        x,
                        y,
                        kind: TileKind::Tg,
                        island,
                    }),
                    None => unfilled.push((x, y)),
                },
            }
        }
        if !unfilled.is_empty() {
            bail!(
                "{} grid cell(s) unfilled (first: ({}, {})) — place a tile at every \
                 cell or call .fill_tg(island) to populate the rest with traffic \
                 generators",
                unfilled.len(),
                unfilled[0].0,
                unfilled[0].1
            );
        }

        let mems: Vec<(u16, u16)> = tiles
            .iter()
            .filter(|t| t.kind == TileKind::Mem)
            .map(|t| (t.x, t.y))
            .collect();
        if mems.is_empty() {
            bail!(
                "scenario has no MEM tile — every SoC needs exactly one memory tile; \
                 add .mem_at(x, y)"
            );
        }
        if mems.len() > 1 {
            bail!(
                "scenario has {} MEM tiles (at {:?}) — exactly one allowed",
                mems.len(),
                mems
            );
        }

        let mut noc = self.noc.clone();
        noc.island = noc_island;
        let cfg = SocConfig {
            name: self
                .name
                .clone()
                .unwrap_or_else(|| format!("scenario-{}x{}", self.width, self.height)),
            width: self.width,
            height: self.height,
            seed: self.seed,
            tiles,
            islands: self.islands,
            noc,
            mem: self.mem,
            dma: self.dma,
            bridge: self.bridge,
            cpu_poll_interval: self.cpu_poll_interval,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_island_base() -> Scenario {
        Scenario::grid(2, 2)
            .island_dfs("noc", 100, 10..=100, 5)
            .island("acc", 50)
    }

    #[test]
    fn minimal_scenario_builds() {
        let cfg = two_island_base()
            .mem_at(0, 0)
            .accel_at(1, 0, "dfmul", 2, "acc")
            .fill_tg("acc")
            .build()
            .unwrap();
        assert_eq!(cfg.tiles.len(), 4);
        assert_eq!(cfg.islands.len(), 2);
        assert_eq!(cfg.noc.island, 0);
        assert_eq!(
            cfg.tiles_where(|k| matches!(k, TileKind::Accel { .. })).len(),
            1
        );
        assert_eq!(cfg.tiles_where(|k| *k == TileKind::Tg).len(), 2);
    }

    #[test]
    fn islands_resolve_by_name_or_index() {
        let cfg = two_island_base()
            .mem_at(0, 0)
            .tg_at(1, 0, 1usize)
            .tg_at(0, 1, "acc")
            .tg_at(1, 1, "noc")
            .build()
            .unwrap();
        assert_eq!(cfg.tiles[1].island, 1);
        assert_eq!(cfg.tiles[2].island, 1);
        assert_eq!(cfg.tiles[3].island, 0);
    }

    #[test]
    fn overlap_reports_both_kinds() {
        let err = two_island_base()
            .mem_at(0, 0)
            .accel_at(0, 0, "dfadd", 1, "acc")
            .fill_tg("acc")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("already holds a MEM tile"), "{err}");
        assert!(err.contains("(0, 0)"), "{err}");
    }

    #[test]
    fn island_index_out_of_range_is_actionable() {
        let err = two_island_base()
            .mem_at(0, 0)
            .tg_at(1, 0, 7usize)
            .fill_tg("acc")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("island index 7 out of range"), "{err}");
        assert!(err.contains("2 island(s) declared"), "{err}");
    }

    #[test]
    fn unknown_island_name_lists_declared() {
        let err = two_island_base()
            .mem_at(0, 0)
            .fill_tg("turbo")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("no island named \"turbo\""), "{err}");
        assert!(err.contains("\"noc\""), "{err}");
        assert!(err.contains("\"acc\""), "{err}");
    }

    #[test]
    fn missing_mem_tile_is_actionable() {
        let err = two_island_base()
            .fill_tg("acc")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("no MEM tile"), "{err}");
        assert!(err.contains(".mem_at"), "{err}");
    }

    #[test]
    fn zero_replicas_is_actionable() {
        let err = two_island_base()
            .mem_at(0, 0)
            .accel_at(1, 1, "gsm", 0, "acc")
            .fill_tg("acc")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("zero replicas"), "{err}");
        assert!(err.contains("\"gsm\""), "{err}");
    }

    #[test]
    fn out_of_grid_placement_is_actionable() {
        let err = two_island_base()
            .mem_at(0, 0)
            .tg_at(5, 0, "acc")
            .fill_tg("acc")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("outside the 2x2 grid"), "{err}");
    }

    #[test]
    fn unfilled_cells_without_fill_error() {
        let err = two_island_base().mem_at(0, 0).build().unwrap_err().to_string();
        assert!(err.contains("unfilled"), "{err}");
        assert!(err.contains(".fill_tg"), "{err}");
    }

    #[test]
    fn duplicate_island_name_rejected() {
        let err = Scenario::grid(1, 1)
            .island("a", 50)
            .island("a", 60)
            .mem_at(0, 0)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("declared twice"), "{err}");
    }

    #[test]
    fn non_square_grids_build() {
        let cfg = Scenario::grid(6, 2)
            .island_dfs("all", 50, 10..=50, 5)
            .mem_at(0, 0)
            .cpu_at(1, 0)
            .accel_at(5, 1, "dfadd", 4, "all")
            .fill_tg("all")
            .build()
            .unwrap();
        assert_eq!(cfg.width, 6);
        assert_eq!(cfg.tiles.len(), 12);
        cfg.validate().unwrap();
    }

    #[test]
    fn multiple_errors_reported_together() {
        let err = Scenario::grid(2, 1)
            .island("a", 50)
            .mem_at(0, 0)
            .mem_at_on(0, 0, "a")
            .accel_at(9, 9, "dfadd", 1, "a")
            .accel_at(1, 0, "dfmul", 0, "a")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("already holds"), "{err}");
        assert!(err.contains("outside the 2x1 grid"), "{err}");
        assert!(err.contains("zero replicas"), "{err}");
    }
}
