//! [`ScenarioSet`]: evaluate independent scenarios across OS threads.
//!
//! Each worker claims the next unevaluated scenario off a shared atomic
//! cursor, builds its own `Soc` (simulations share nothing), and writes
//! the result into that scenario's slot — so results come back in
//! deterministic scenario-index order regardless of which worker ran
//! what, and a parallel run is bit-identical to a serial one.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Context;

use crate::config::presets::{paper_soc, A1_POS, A2_POS, ISL_A1, ISL_A2, ISL_NOC};
use crate::config::SocConfig;
use crate::util::Ps;

/// One paper-grid design point: which accelerator, how many replicas,
/// island frequencies, and placement — the struct that replaces
/// `evaluate_point`'s seven positional scalars.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub accel: String,
    pub replicas: usize,
    /// Frequency of the accelerator-under-test's island (MHz).
    pub accel_mhz: u64,
    /// Frequency of the NoC+MEM island (MHz).
    pub noc_mhz: u64,
    /// Placement: `true` = A1 (adjacent to MEM), `false` = A2 (far
    /// corner).
    pub near_mem: bool,
    /// Warmup before the measurement window (ps).
    pub warmup: Ps,
    /// Measurement window (ps).
    pub window: Ps,
}

impl ScenarioSpec {
    /// A spec with the Table-I defaults: A1 placement, accelerator
    /// island at 50 MHz, NoC at 100 MHz, 2 ms warmup, 20 ms window.
    pub fn new(accel: &str, replicas: usize) -> Self {
        Self {
            accel: accel.to_string(),
            replicas,
            accel_mhz: 50,
            noc_mhz: 100,
            near_mem: true,
            warmup: 2_000_000_000,
            window: 20_000_000_000,
        }
    }

    pub fn accel_mhz(mut self, mhz: u64) -> Self {
        self.accel_mhz = mhz;
        self
    }

    pub fn noc_mhz(mut self, mhz: u64) -> Self {
        self.noc_mhz = mhz;
        self
    }

    pub fn near_mem(mut self, near: bool) -> Self {
        self.near_mem = near;
        self
    }

    pub fn warmup(mut self, ps: Ps) -> Self {
        self.warmup = ps;
        self
    }

    pub fn window(mut self, ps: Ps) -> Self {
        self.window = ps;
        self
    }

    /// Grid position of the accelerator under test.
    pub fn position(&self) -> (u16, u16) {
        if self.near_mem {
            A1_POS
        } else {
            A2_POS
        }
    }

    /// Island index of the accelerator under test.
    pub fn island(&self) -> usize {
        if self.near_mem {
            ISL_A1
        } else {
            ISL_A2
        }
    }

    /// Materialize the paper's 4x4 SoC for this point (TGs idle; the
    /// non-measured accelerator slot holds a 1x dfadd as in Table I).
    /// Errors on an unknown accelerator or out-of-range replication —
    /// the two inputs the underlying preset would otherwise panic on.
    pub fn to_config(&self) -> crate::Result<SocConfig> {
        crate::tiles::AccelTiming::lookup(&self.accel)?;
        anyhow::ensure!(
            (1..=16).contains(&self.replicas),
            "{:?}: replication {} out of [1, 16]",
            self.accel,
            self.replicas
        );
        let ut = (self.accel.as_str(), self.replicas);
        let mut cfg = if self.near_mem {
            paper_soc(ut, ("dfadd", 1))
        } else {
            paper_soc(("dfadd", 1), ut)
        };
        cfg.islands[ISL_NOC].freq_mhz = self.noc_mhz;
        cfg.islands[self.island()].freq_mhz = self.accel_mhz;
        Ok(cfg)
    }
}

/// A batch of independent scenarios with serial and parallel runners.
pub struct ScenarioSet<T> {
    items: Vec<T>,
}

impl<T: Sync> ScenarioSet<T> {
    pub fn new(items: Vec<T>) -> Self {
        Self { items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn items(&self) -> &[T] {
        &self.items
    }

    pub fn into_items(self) -> Vec<T> {
        self.items
    }

    /// Evaluate every scenario on the calling thread, in order.
    pub fn run_serial<R>(&self, f: impl Fn(&T) -> crate::Result<R>) -> crate::Result<Vec<R>> {
        self.items
            .iter()
            .enumerate()
            .map(|(i, item)| f(item).with_context(|| format!("scenario #{i}")))
            .collect()
    }

    /// Evaluate scenarios across `available_parallelism` worker threads.
    /// One `Soc` per in-flight scenario, nothing shared; results are
    /// returned in scenario-index order, bit-identical to
    /// [`ScenarioSet::run_serial`].
    pub fn run_parallel<R: Send>(
        &self,
        f: impl Fn(&T) -> crate::Result<R> + Sync,
    ) -> crate::Result<Vec<R>> {
        self.run_with_threads(0, f)
    }

    /// Evaluate with an explicit worker count (`0` = auto). `1` degrades
    /// to the serial path.
    pub fn run_with_threads<R: Send>(
        &self,
        threads: usize,
        f: impl Fn(&T) -> crate::Result<R> + Sync,
    ) -> crate::Result<Vec<R>> {
        let n = self.items.len();
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
        } else {
            threads
        }
        .min(n.max(1));
        if threads <= 1 {
            return self.run_serial(f);
        }

        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<crate::Result<R>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&self.items[i]);
                    *slots[i].lock().expect("result slot poisoned") = Some(r);
                });
            }
        });

        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            let r = slot
                .into_inner()
                .expect("result slot poisoned")
                .expect("every scenario index was claimed by a worker");
            out.push(r.with_context(|| format!("scenario #{i}"))?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_results_keep_scenario_order() {
        let set = ScenarioSet::new((0..37usize).collect());
        let serial = set.run_serial(|&i| Ok(i * i)).unwrap();
        let parallel = set.run_with_threads(4, |&i| Ok(i * i)).unwrap();
        assert_eq!(serial, parallel);
        assert_eq!(parallel[10], 100);
    }

    #[test]
    fn errors_carry_the_scenario_index() {
        let set = ScenarioSet::new(vec![1u64, 2, 3]);
        let err = set
            .run_with_threads(2, |&i| {
                if i == 2 {
                    anyhow::bail!("boom")
                } else {
                    Ok(i)
                }
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("scenario #1"), "{err:#}");
    }

    #[test]
    fn single_item_sets_run() {
        let set = ScenarioSet::new(vec![5i32]);
        assert_eq!(set.run_parallel(|&i| Ok(i + 1)).unwrap(), vec![6]);
        let empty: ScenarioSet<i32> = ScenarioSet::new(vec![]);
        assert!(empty.run_parallel(|&i| Ok(i)).unwrap().is_empty());
        assert!(empty.is_empty());
    }

    #[test]
    fn spec_materializes_placement_and_frequencies() {
        let spec = ScenarioSpec::new("dfmul", 4)
            .accel_mhz(25)
            .noc_mhz(50)
            .near_mem(false);
        let cfg = spec.to_config().unwrap();
        assert_eq!(cfg.islands[ISL_NOC].freq_mhz, 50);
        assert_eq!(cfg.islands[ISL_A2].freq_mhz, 25);
        let pos = spec.position();
        assert_eq!(pos, A2_POS);
        let tile = &cfg.tiles[cfg.node_of(pos.0, pos.1)];
        assert_eq!(
            tile.kind,
            crate::config::TileKind::Accel {
                accel: "dfmul".into(),
                replicas: 4
            }
        );
        cfg.validate().unwrap();
    }

    #[test]
    fn spec_with_bad_inputs_errors_instead_of_panicking() {
        let err = ScenarioSpec::new("warpcore", 1).to_config().unwrap_err();
        assert!(err.to_string().contains("warpcore"), "{err}");
        let err = ScenarioSpec::new("dfmul", 0).to_config().unwrap_err();
        assert!(err.to_string().contains("out of [1, 16]"), "{err}");
        let err = ScenarioSpec::new("dfmul", 17).to_config().unwrap_err();
        assert!(err.to_string().contains("out of [1, 16]"), "{err}");
    }
}
