//! [`ScenarioSet`]: evaluate independent scenarios across OS threads.
//!
//! Each worker claims the next unevaluated scenario off a shared atomic
//! cursor, builds its own `Soc` (simulations share nothing), and writes
//! the result into that scenario's slot — so results come back in
//! deterministic scenario-index order regardless of which worker ran
//! what, and a parallel run is bit-identical to a serial one.
//!
//! The claim-loop pattern is generalized two ways for other subsystems:
//! `resolve_threads` turns a `0 = all cores / n = exactly n` knob
//! into a worker count, and `with_round_pool` keeps a pool of scoped
//! workers alive across repeated barrier-synchronized *rounds* of
//! index-claimed tasks — the shape the cluster engine needs, where
//! spawning fresh threads per barrier would dominate the barrier work.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use anyhow::Context;

use crate::config::presets::{paper_soc, A1_POS, A2_POS, ISL_A1, ISL_A2, ISL_NOC};
use crate::config::SocConfig;
use crate::util::Ps;

/// One paper-grid design point: which accelerator, how many replicas,
/// island frequencies, and placement — the struct that replaces
/// `evaluate_point`'s seven positional scalars.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub accel: String,
    pub replicas: usize,
    /// Frequency of the accelerator-under-test's island (MHz).
    pub accel_mhz: u64,
    /// Frequency of the NoC+MEM island (MHz).
    pub noc_mhz: u64,
    /// Placement: `true` = A1 (adjacent to MEM), `false` = A2 (far
    /// corner).
    pub near_mem: bool,
    /// Warmup before the measurement window (ps).
    pub warmup: Ps,
    /// Measurement window (ps).
    pub window: Ps,
}

impl ScenarioSpec {
    /// A spec with the Table-I defaults: A1 placement, accelerator
    /// island at 50 MHz, NoC at 100 MHz, 2 ms warmup, 20 ms window.
    pub fn new(accel: &str, replicas: usize) -> Self {
        Self {
            accel: accel.to_string(),
            replicas,
            accel_mhz: 50,
            noc_mhz: 100,
            near_mem: true,
            warmup: 2_000_000_000,
            window: 20_000_000_000,
        }
    }

    pub fn accel_mhz(mut self, mhz: u64) -> Self {
        self.accel_mhz = mhz;
        self
    }

    pub fn noc_mhz(mut self, mhz: u64) -> Self {
        self.noc_mhz = mhz;
        self
    }

    pub fn near_mem(mut self, near: bool) -> Self {
        self.near_mem = near;
        self
    }

    pub fn warmup(mut self, ps: Ps) -> Self {
        self.warmup = ps;
        self
    }

    pub fn window(mut self, ps: Ps) -> Self {
        self.window = ps;
        self
    }

    /// Grid position of the accelerator under test.
    pub fn position(&self) -> (u16, u16) {
        if self.near_mem {
            A1_POS
        } else {
            A2_POS
        }
    }

    /// Island index of the accelerator under test.
    pub fn island(&self) -> usize {
        if self.near_mem {
            ISL_A1
        } else {
            ISL_A2
        }
    }

    /// Materialize the paper's 4x4 SoC for this point (TGs idle; the
    /// non-measured accelerator slot holds a 1x dfadd as in Table I).
    /// Errors on an unknown accelerator or out-of-range replication —
    /// the two inputs the underlying preset would otherwise panic on.
    pub fn to_config(&self) -> crate::Result<SocConfig> {
        crate::tiles::AccelTiming::lookup(&self.accel)?;
        anyhow::ensure!(
            (1..=16).contains(&self.replicas),
            "{:?}: replication {} out of [1, 16]",
            self.accel,
            self.replicas
        );
        let ut = (self.accel.as_str(), self.replicas);
        let mut cfg = if self.near_mem {
            paper_soc(ut, ("dfadd", 1))
        } else {
            paper_soc(("dfadd", 1), ut)
        };
        cfg.islands[ISL_NOC].freq_mhz = self.noc_mhz;
        cfg.islands[self.island()].freq_mhz = self.accel_mhz;
        Ok(cfg)
    }
}

/// Resolve a worker-count knob against a job count: `0` = all cores
/// (`available_parallelism`), otherwise the value itself, clamped to
/// `jobs` so no worker sits permanently idle.
pub(crate) fn resolve_threads(threads: usize, jobs: usize) -> usize {
    let t = if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        threads
    };
    t.min(jobs.max(1))
}

/// Round-sequenced shared state for [`with_round_pool`] workers.
struct RoundState {
    /// Monotone round counter; workers wake when it moves past the last
    /// round they completed.
    epoch: u64,
    /// Task count of the current round.
    tasks: usize,
    /// Workers that have exhausted the current round's cursor.
    done: usize,
    stop: bool,
}

/// A persistent pool of scoped worker threads that execute repeated
/// *rounds* of index-claimed tasks. Created by [`with_round_pool`];
/// each [`RoundPool::round`] call fans indices `0..n` across the
/// workers (same atomic-cursor claim loop as
/// [`ScenarioSet::run_with_threads`]) and blocks until every index has
/// been processed — a barrier. The work closure is fixed at pool
/// creation; per-round inputs travel through whatever shared state the
/// caller gave it (e.g. a task slot per replica behind a `Mutex`).
pub(crate) struct RoundPool {
    state: Mutex<RoundState>,
    start: Condvar,
    finish: Condvar,
    next: AtomicUsize,
    workers: usize,
}

impl RoundPool {
    fn new(workers: usize) -> Self {
        Self {
            state: Mutex::new(RoundState {
                epoch: 0,
                tasks: 0,
                done: 0,
                stop: false,
            }),
            start: Condvar::new(),
            finish: Condvar::new(),
            next: AtomicUsize::new(0),
            workers,
        }
    }

    /// Fan task indices `0..n` across the pool and block until every
    /// worker has drained the round (all indices claimed and executed).
    pub fn round(&self, n: usize) {
        let mut st = self.state.lock().expect("round pool poisoned");
        self.next.store(0, Ordering::SeqCst);
        st.tasks = n;
        st.done = 0;
        st.epoch += 1;
        self.start.notify_all();
        while st.done < self.workers {
            st = self.finish.wait(st).expect("round pool poisoned");
        }
    }

    fn shutdown(&self) {
        let mut st = self.state.lock().expect("round pool poisoned");
        st.stop = true;
        self.start.notify_all();
    }

    fn worker_loop(&self, id: usize, work: &(impl Fn(usize, usize) + Sync)) {
        let mut seen = 0u64;
        loop {
            let n = {
                let mut st = self.state.lock().expect("round pool poisoned");
                while st.epoch == seen && !st.stop {
                    st = self.start.wait(st).expect("round pool poisoned");
                }
                if st.stop {
                    return;
                }
                seen = st.epoch;
                st.tasks
            };
            loop {
                let k = self.next.fetch_add(1, Ordering::SeqCst);
                if k >= n {
                    break;
                }
                work(id, k);
            }
            let mut st = self.state.lock().expect("round pool poisoned");
            st.done += 1;
            if st.done == self.workers {
                self.finish.notify_one();
            }
        }
    }
}

/// Run `body` with a live [`RoundPool`] of `workers` scoped threads,
/// each executing `work(worker_id, task_index)` for every claimed
/// index of every round. Workers are joined (via `std::thread::scope`)
/// before this returns, so `work` may freely borrow from the caller.
pub(crate) fn with_round_pool<R>(
    workers: usize,
    work: impl Fn(usize, usize) + Sync,
    body: impl FnOnce(&RoundPool) -> R,
) -> R {
    let pool = RoundPool::new(workers);
    let pool = &pool;
    let work = &work;
    std::thread::scope(|scope| {
        for id in 0..workers {
            scope.spawn(move || pool.worker_loop(id, work));
        }
        let out = body(pool);
        pool.shutdown();
        out
    })
}

/// A batch of independent scenarios with serial and parallel runners.
pub struct ScenarioSet<T> {
    items: Vec<T>,
}

impl<T: Sync> ScenarioSet<T> {
    pub fn new(items: Vec<T>) -> Self {
        Self { items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn items(&self) -> &[T] {
        &self.items
    }

    pub fn into_items(self) -> Vec<T> {
        self.items
    }

    /// Evaluate every scenario on the calling thread, in order.
    pub fn run_serial<R>(&self, f: impl Fn(&T) -> crate::Result<R>) -> crate::Result<Vec<R>> {
        self.items
            .iter()
            .enumerate()
            .map(|(i, item)| f(item).with_context(|| format!("scenario #{i}")))
            .collect()
    }

    /// Evaluate scenarios across `available_parallelism` worker threads.
    /// One `Soc` per in-flight scenario, nothing shared; results are
    /// returned in scenario-index order, bit-identical to
    /// [`ScenarioSet::run_serial`].
    pub fn run_parallel<R: Send>(
        &self,
        f: impl Fn(&T) -> crate::Result<R> + Sync,
    ) -> crate::Result<Vec<R>> {
        self.run_with_threads(0, f)
    }

    /// Evaluate with an explicit worker count (`0` = auto). `1` degrades
    /// to the serial path.
    pub fn run_with_threads<R: Send>(
        &self,
        threads: usize,
        f: impl Fn(&T) -> crate::Result<R> + Sync,
    ) -> crate::Result<Vec<R>> {
        let n = self.items.len();
        let threads = resolve_threads(threads, n);
        if threads <= 1 {
            return self.run_serial(f);
        }

        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<crate::Result<R>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&self.items[i]);
                    *slots[i].lock().expect("result slot poisoned") = Some(r);
                });
            }
        });

        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            let r = slot
                .into_inner()
                .expect("result slot poisoned")
                .expect("every scenario index was claimed by a worker");
            out.push(r.with_context(|| format!("scenario #{i}"))?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_results_keep_scenario_order() {
        let set = ScenarioSet::new((0..37usize).collect());
        let serial = set.run_serial(|&i| Ok(i * i)).unwrap();
        let parallel = set.run_with_threads(4, |&i| Ok(i * i)).unwrap();
        assert_eq!(serial, parallel);
        assert_eq!(parallel[10], 100);
    }

    #[test]
    fn errors_carry_the_scenario_index() {
        let set = ScenarioSet::new(vec![1u64, 2, 3]);
        let err = set
            .run_with_threads(2, |&i| {
                if i == 2 {
                    anyhow::bail!("boom")
                } else {
                    Ok(i)
                }
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("scenario #1"), "{err:#}");
    }

    #[test]
    fn single_item_sets_run() {
        let set = ScenarioSet::new(vec![5i32]);
        assert_eq!(set.run_parallel(|&i| Ok(i + 1)).unwrap(), vec![6]);
        let empty: ScenarioSet<i32> = ScenarioSet::new(vec![]);
        assert!(empty.run_parallel(|&i| Ok(i)).unwrap().is_empty());
        assert!(empty.is_empty());
    }

    #[test]
    fn resolve_threads_clamps_to_jobs() {
        assert_eq!(resolve_threads(3, 100), 3);
        assert_eq!(resolve_threads(8, 2), 2, "no idle workers past the jobs");
        assert_eq!(resolve_threads(5, 0), 1, "zero jobs still resolves to 1");
        assert!(resolve_threads(0, 100) >= 1, "auto is at least one worker");
    }

    #[test]
    fn round_pool_runs_every_index_of_every_round() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..13).map(|_| AtomicU64::new(0)).collect();
        let rounds = 7usize;
        with_round_pool(
            3,
            |_wid, k| {
                hits[k].fetch_add(1, Ordering::SeqCst);
            },
            |pool| {
                for _ in 0..rounds {
                    pool.round(hits.len());
                }
                // A barrier: every prior round fully drained before the
                // next starts, so counts are exact mid-stream too.
                pool.round(0);
            },
        );
        for (k, h) in hits.iter().enumerate() {
            assert_eq!(
                h.load(Ordering::SeqCst),
                rounds as u64,
                "index {k} ran once per round"
            );
        }
    }

    #[test]
    fn spec_materializes_placement_and_frequencies() {
        let spec = ScenarioSpec::new("dfmul", 4)
            .accel_mhz(25)
            .noc_mhz(50)
            .near_mem(false);
        let cfg = spec.to_config().unwrap();
        assert_eq!(cfg.islands[ISL_NOC].freq_mhz, 50);
        assert_eq!(cfg.islands[ISL_A2].freq_mhz, 25);
        let pos = spec.position();
        assert_eq!(pos, A2_POS);
        let tile = &cfg.tiles[cfg.node_of(pos.0, pos.1)];
        assert_eq!(
            tile.kind,
            crate::config::TileKind::Accel {
                accel: "dfmul".into(),
                replicas: 4
            }
        );
        cfg.validate().unwrap();
    }

    #[test]
    fn spec_with_bad_inputs_errors_instead_of_panicking() {
        let err = ScenarioSpec::new("warpcore", 1).to_config().unwrap_err();
        assert!(err.to_string().contains("warpcore"), "{err}");
        let err = ScenarioSpec::new("dfmul", 0).to_config().unwrap_err();
        assert!(err.to_string().contains("out of [1, 16]"), "{err}");
        let err = ScenarioSpec::new("dfmul", 17).to_config().unwrap_err();
        assert!(err.to_string().contains("out of [1, 16]"), "{err}");
    }
}
