//! # Vespa-Sim
//!
//! A prototype-based framework to design scalable heterogeneous SoCs with
//! fine-grained DFS — a full-system reproduction of Montanaro, Galimberti &
//! Zoni (ICCD 2024).
//!
//! The crate models a tile-based heterogeneous SoC (ESP-style) at cycle
//! level and implements the paper's three contributions as first-class
//! features:
//!
//! 1. **Multi-replica accelerator (MRA) tiles** — [`tiles::mra`] +
//!    [`axi::bridge`]: `K` replicas of a third-party accelerator share one
//!    NoC node behind an AXI4-Stream bridge.
//! 2. **Configurable-DFS frequency islands** — [`clock`]: every tile and
//!    router belongs to a frequency island driven by a fixed clock or a
//!    glitch-free dual-MMCM DFS actuator, reprogrammable at run time
//!    through memory-mapped frequency registers.
//! 3. **Run-time monitoring** — [`monitor`]: per-accelerator hardware
//!    counters (execution time, packets in/out, round-trip time) exposed
//!    over MMIO to both the CPU tile and the host.
//!
//! Accelerator datapaths execute *real* compute: JAX/Pallas kernels are
//! AOT-lowered at build time to HLO text and executed from the simulator's
//! hot path through the PJRT CPU client ([`runtime`]). Python never runs at
//! simulation time.

pub mod axi;
pub mod bench_harness;
pub mod cli;
pub mod clock;
pub mod config;
pub mod dse;
pub mod experiments;
pub mod mem;
pub mod monitor;
pub mod noc;
pub mod policy;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sim;
pub mod tiles;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
