//! # Vespa-Sim
//!
//! A prototype-based framework to design scalable heterogeneous SoCs with
//! fine-grained DFS — a full-system reproduction of Montanaro, Galimberti &
//! Zoni (ICCD 2024).
//!
//! The crate models a tile-based heterogeneous SoC (ESP-style) at cycle
//! level and implements the paper's three contributions as first-class
//! features:
//!
//! 1. **Multi-replica accelerator (MRA) tiles** — [`tiles::mra`] +
//!    [`axi::bridge`]: `K` replicas of a third-party accelerator share one
//!    NoC node behind an AXI4-Stream bridge.
//! 2. **Configurable-DFS frequency islands** — [`clock`]: every tile and
//!    router belongs to a frequency island driven by a fixed clock or a
//!    glitch-free dual-MMCM DFS actuator, reprogrammable at run time
//!    through memory-mapped frequency registers.
//! 3. **Run-time monitoring** — [`monitor`]: per-accelerator hardware
//!    counters (execution time, packets in/out, round-trip time) exposed
//!    over MMIO to both the CPU tile and the host.
//!
//! ## The Scenario/Session API
//!
//! [`scenario`] is the front door (see `docs/API.md` for the full tour).
//! Compose any SoC with the fluent [`scenario::Scenario`] builder —
//! arbitrary `WxH` grids, named frequency islands, any tile kind at any
//! coordinate — then drive it with declarative [`scenario::Session`]
//! phases that return typed [`scenario::PhaseReport`]s:
//!
//! ```text
//! let cfg = Scenario::grid(4, 4)
//!     .island_dfs("noc", 100, 10..=100, 5)
//!     .island_dfs("acc", 50, 10..=50, 5)
//!     .island("sys", 50)
//!     .mem_at(0, 0)
//!     .cpu_at_on(1, 0, "sys")
//!     .accel_at(0, 1, "dfmul", 2, "acc")
//!     .fill_tg("sys")
//!     .build()?;
//! let mut session = Session::new(cfg)?;
//! let tile = session.tile_at(0, 1);
//! session.stage(tile, 1)?.with_tg_load(4).warmup(ms(2));
//! let report = session.measure(tile, ms(5))?;  // -> PhaseReport
//! ```
//!
//! Batches of independent design points evaluate across every core with
//! [`scenario::ScenarioSet::run_parallel`] (bit-identical to the serial
//! path); [`dse::sweep`] and the `fig3`/`table1` experiments are built on
//! it, with [`scenario::ScenarioSpec`] naming one paper-grid point.
//!
//! Sessions can be deep-frozen with [`scenario::Session::snapshot`] and
//! forked any number of times ([`scenario::Session::resume`]),
//! bit-identically (`rust/tests/snapshot_fork.rs`). The warm-start sweep
//! planner ([`dse::SweepMode::WarmFork`]) builds on this: one warmed
//! base SoC per structure, one snapshot fork + run-time DFS retune per
//! frequency point, with a per-process memo cache on top — see
//! `docs/PERF.md` ("Warm-start sweeps").
//!
//! The original low-level surface remains for existing code:
//! [`config::presets::paper_soc`] is now a thin preset over the builder,
//! and `sim::stage_inputs_for` + `sim::ThroughputProbe` still exist as
//! the primitives `Session` is made of — prefer the Session API in new
//! code; the hand-rolled choreography is considered deprecated and no
//! longer appears anywhere in this crate's experiments or examples.
//!
//! ## Serving traffic
//!
//! [`serve`] layers *request serving* on top of sessions: arrival
//! processes ([`serve::Arrival`] — Poisson, bursts, traces, closed
//! loop), replica-aware dispatch across MRA tiles with bounded
//! admission queues ([`serve::DispatchPolicy`]), exact
//! p50/p95/p99/max latency reporting ([`serve::ServeReport`]), and a
//! queue-driven DFS governor ([`serve::QueueGovernor`]) that boosts an
//! island when queues or tail latency breach an SLO and relaxes it
//! when idle. Drive it with [`scenario::Session::serve`] or the
//! `vespa serve` CLI subcommand; `dse` sweeps can rank design points by
//! p99-under-SLO via [`dse::Objective::TailLatency`]. See
//! `docs/API.md` ("Serving traffic").
//!
//! ## Cluster serving
//!
//! [`cluster`] scales serving past one SoC: a
//! [`cluster::ClusterSpec`] fans one workload across N identical
//! replicas behind a front-end balancer (the
//! [`serve::DispatchPolicy`] semantics lifted to fleet scope), with an
//! optional SLO-driven [`cluster::Autoscaler`] that activates and
//! retires replicas with hysteresis — reactivations fork a
//! [`scenario::Session::snapshot`] warm base, so elasticity costs no
//! warmup. The merged [`cluster::ClusterReport`] keeps percentiles
//! exact via [`util::stats::Percentiles::merge`] and prices the run in
//! replica-seconds; `dse` ranks fleet sizes with
//! [`dse::Objective::Cluster`] and
//! [`dse::rank_by_replica_seconds_under_slo`]. Drive it with
//! `vespa cluster` or [`cluster::serve_cluster`]. See `docs/API.md`
//! ("Cluster serving").
//!
//! ## Fault injection & resilience
//!
//! [`fault`] turns the serving stack into a resilience testbed: a
//! deterministic, seed-driven [`fault::FaultPlan`] injects typed
//! faults — accelerator hang/slowdown, link flap/degrade, stuck DFS
//! actuators, whole-replica crashes — as pre-installed stall windows
//! in the simulated hardware, so the same seed + spec + plan is
//! bit-identical across engines and `--threads` counts (and an empty
//! plan is bit-identical to no fault subsystem at all). The
//! resilience half — [`fault::RetrySpec`] deadlines/backoff at the
//! admission gate, [`fault::HealthSpec`] eviction + warm-standby
//! replacement in the cluster engine — is accounted in a
//! [`fault::FaultLedger`] on every report. Drive it with
//! `--faults <spec>` on `vespa serve`/`vespa cluster`, rank designs
//! under chaos with [`dse::Objective::Robust`], and see `docs/API.md`
//! ("Fault injection & resilience") + `docs/PERF.md` (chaos bench).
//!
//! ## Observability
//!
//! [`telemetry`] makes the monitoring story a debugging surface:
//! [`telemetry::TraceSpec`] on a serve/cluster spec records
//! deterministic per-request spans (arrival → admission/retry → queue →
//! exec → completion, with fault annotations) into a bounded flight
//! recorder, exported as Chrome/Perfetto `trace_event` JSON
//! ([`telemetry::to_perfetto`]) and rendered as an ASCII waterfall
//! ([`report::waterfall`]); [`telemetry::MetricsRegistry`] snapshots
//! every report counter behind stable metric names (Prometheus text +
//! JSON); and [`telemetry::HostProfile`] exposes host-side engine
//! self-profiling through the benches. Traces are bit-identical across
//! engines and thread counts; `--trace/--trace-sample/--metrics` on
//! `vespa serve`/`vespa cluster`. See `docs/API.md` ("Observability").
//!
//! ## The engine core
//!
//! Simulation runs on an activity-tracking multi-clock engine
//! ([`sim::Soc`], [`sim::EngineMode`]): every tile, router, and sampler
//! speaks the unified [`sim::EventSource`] contract, promising its next
//! wake point as a typed [`sim::Deadline`] (island cycle, absolute
//! time, input-armed, or never). `EngineMode::IdleAware` scans those
//! deadlines per edge and coalesces globally quiescent spans by jumping
//! time straight to the next event (tile wake, flit ready-time, DFS
//! swap, schedule entry, or sampler deadline); `EngineMode::EventDriven`
//! goes further and keys every component into per-island updateable
//! min-heaps ([`sim::UpdateableMinHeap`]) so each edge touches only the
//! components that are actually due — cost scales with *activity*, not
//! grid size — and is the default. Both are bit-identical to
//! edge-by-edge stepping; the
//! original tick-everything loop remains as `EngineMode::Reference`,
//! the equivalence oracle (`rust/tests/engine_equivalence.rs`). Select
//! with [`scenario::Session::engine`] or `--engine reference|idle|event`
//! on the CLI. Engine architecture, bench workflow, `BENCH_*.json`
//! schema, and the CI perf gate are documented in `docs/PERF.md`.
//!
//! ## Functional datapaths
//!
//! Accelerator datapaths execute *real* compute: JAX/Pallas kernels are
//! AOT-lowered at build time to HLO text and executed from the simulator's
//! hot path through the PJRT CPU client ([`runtime`], behind the `pjrt`
//! feature). Python never runs at simulation time; builds without the
//! feature use the native [`runtime::RefCompute`] oracle.

pub mod axi;
pub mod bench_harness;
pub mod cli;
pub mod clock;
pub mod cluster;
pub mod config;
pub mod dse;
pub mod experiments;
pub mod fault;
pub mod mem;
pub mod monitor;
pub mod noc;
pub mod policy;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod tiles;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
