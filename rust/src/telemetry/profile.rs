//! Host-side engine self-profiling.
//!
//! Unlike [`Trace`](super::Trace) and the metrics registry, everything
//! here measures the **host machine** — wall-clock nanoseconds, barrier
//! counts, per-worker busy time — so it is explicitly non-deterministic
//! and never appears in a report or a determinism-gated export. It is
//! surfaced only through the `bench_harness` JSON of `grid_scale` /
//! `cluster_scale` (see `docs/PERF.md`).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Shared accumulator for the parallel cluster engine: the coordinator
/// adds one round per barrier, workers add their per-task busy time.
/// Atomic so the worker closures can write without locking; `Relaxed`
/// is enough because the totals are only read after the run joins.
#[derive(Debug, Default)]
pub struct HostProfile {
    /// Execution rounds (barriers) the coordinator ran.
    pub rounds: AtomicU64,
    /// Coordinator wall time spent inside execution rounds (ns).
    pub round_wall_ns: AtomicU64,
    /// Summed per-task worker busy time (ns).
    pub task_busy_ns: AtomicU64,
    /// Replica tasks executed.
    pub tasks: AtomicU64,
}

impl HostProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// One execution round completed, `wall_ns` of coordinator time.
    pub fn add_round(&self, wall_ns: u64) {
        self.rounds.fetch_add(1, Relaxed);
        self.round_wall_ns.fetch_add(wall_ns, Relaxed);
    }

    /// One replica task completed, `busy_ns` of worker time.
    pub fn add_task(&self, busy_ns: u64) {
        self.tasks.fetch_add(1, Relaxed);
        self.task_busy_ns.fetch_add(busy_ns, Relaxed);
    }

    pub fn rounds(&self) -> u64 {
        self.rounds.load(Relaxed)
    }

    pub fn round_wall_ns(&self) -> u64 {
        self.round_wall_ns.load(Relaxed)
    }

    pub fn task_busy_ns(&self) -> u64 {
        self.task_busy_ns.load(Relaxed)
    }

    pub fn tasks(&self) -> u64 {
        self.tasks.load(Relaxed)
    }

    /// Mean wall time per barrier round (ns), 0 with no rounds.
    pub fn mean_round_ns(&self) -> f64 {
        let r = self.rounds();
        if r == 0 {
            0.0
        } else {
            self.round_wall_ns() as f64 / r as f64
        }
    }

    /// Worker wait estimate: with `workers` lanes, the barrier "buys"
    /// `rounds * workers` lane-slots of wall time; busy time fills part
    /// of it, the rest is waiting (plus coordinator overhead). 0 when
    /// nothing ran or the estimate would go negative.
    pub fn est_wait_ns(&self, workers: usize) -> f64 {
        let capacity = self.round_wall_ns() as f64 * workers as f64;
        (capacity - self.task_busy_ns() as f64).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_rounds_and_tasks() {
        let p = HostProfile::new();
        p.add_round(100);
        p.add_round(300);
        p.add_task(50);
        p.add_task(70);
        p.add_task(30);
        assert_eq!(p.rounds(), 2);
        assert_eq!(p.round_wall_ns(), 400);
        assert_eq!(p.tasks(), 3);
        assert_eq!(p.task_busy_ns(), 150);
        assert_eq!(p.mean_round_ns(), 200.0);
        // 2 workers * 400 ns wall = 800 lane-ns; 150 busy => 650 waiting.
        assert_eq!(p.est_wait_ns(2), 650.0);
    }

    #[test]
    fn empty_profile_is_all_zero() {
        let p = HostProfile::new();
        assert_eq!(p.mean_round_ns(), 0.0);
        assert_eq!(p.est_wait_ns(8), 0.0);
    }
}
