//! Metrics registry snapshot: one flat, stably-named view over the
//! serve/cluster counters, latency distribution, fault ledger, island
//! frequencies, and per-tile accelerator counters.
//!
//! Metric names are a **stability contract** (documented in
//! `docs/API.md`): names ending in `_total` are monotonic counters over
//! the run, everything else is a point-in-time gauge. Exports are
//! deterministic byte-for-byte — values render through
//! [`fmt_f64`](crate::bench_harness::json::fmt_f64) and metrics keep
//! their registration order.

use crate::bench_harness::json::{fmt_f64, fmt_str};
use crate::sim::Soc;

/// One sample: a name, optional `(key, value)` labels, and a value.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: &'static str,
    pub labels: Vec<(&'static str, String)>,
    pub value: f64,
    /// `# HELP` line (shared by every sample of the same name).
    pub help: &'static str,
}

/// An ordered collection of [`Metric`]s with Prometheus-text and JSON
/// exporters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    pub metrics: Vec<Metric>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one sample. Call order is export order.
    pub fn push(
        &mut self,
        name: &'static str,
        labels: Vec<(&'static str, String)>,
        value: f64,
        help: &'static str,
    ) {
        self.metrics.push(Metric {
            name,
            labels,
            value,
            help,
        });
    }

    /// First sample with this name (and, when given, this label value).
    pub fn get(&self, name: &str, label: Option<(&str, &str)>) -> Option<f64> {
        self.metrics
            .iter()
            .find(|m| {
                m.name == name
                    && match label {
                        None => true,
                        Some((k, v)) => m.labels.iter().any(|(lk, lv)| *lk == k && lv == v),
                    }
            })
            .map(|m| m.value)
    }

    /// Snapshot a single-SoC [`ServeReport`](crate::serve::ServeReport).
    pub fn from_serve(r: &crate::serve::ServeReport) -> Self {
        let mut reg = Self::new();
        reg.requests(r.offered, r.admitted, r.dropped, r.completed, r.unfinished);
        reg.push("vespa_offered_rps", vec![], r.offered_rps, "Offered request rate over the load horizon");
        reg.push("vespa_achieved_rps", vec![], r.achieved_rps, "Completed request rate over the load horizon");
        reg.latency(&r.latency);
        reg.push("vespa_slo_attainment", vec![], r.slo_attainment, "Fraction of completed requests within the SLO (1 when unset)");
        for t in &r.per_tile {
            let l = vec![("tile", t.tile.to_string())];
            reg.push("vespa_tile_admitted_total", l.clone(), t.admitted as f64, "Requests admitted to a serving tile's queue");
            reg.push("vespa_tile_completed_total", l.clone(), t.completed as f64, "Requests completed by a serving tile");
            reg.push("vespa_tile_queue_depth_max", l, t.max_depth as f64, "Peak granted-but-uncompleted depth of a serving tile");
        }
        for (i, &mhz) in r.final_freq_mhz.iter().enumerate() {
            reg.push(
                "vespa_island_freq_mhz",
                vec![("island", i.to_string())],
                mhz as f64,
                "Island clock frequency when serving stopped",
            );
        }
        reg.faults(&r.faults);
        reg.trace(r.trace.as_ref());
        reg
    }

    /// Snapshot a fleet [`ClusterReport`](crate::cluster::ClusterReport).
    pub fn from_cluster(r: &crate::cluster::ClusterReport) -> Self {
        let mut reg = Self::new();
        reg.requests(r.offered, r.admitted, r.dropped, r.completed, r.unfinished);
        reg.push("vespa_offered_rps", vec![], r.offered_rps, "Offered request rate over the load horizon");
        reg.push("vespa_achieved_rps", vec![], r.achieved_rps, "Completed request rate over the load horizon");
        reg.latency(&r.latency);
        reg.push("vespa_slo_attainment", vec![], r.slo_attainment, "Fraction of completed requests within the SLO (1 when unset)");
        reg.push("vespa_cluster_fleet_size", vec![], r.fleet as f64, "Configured fleet size (autoscale ceiling)");
        reg.push("vespa_cluster_active_replicas", vec![], r.final_active as f64, "Replicas active when serving stopped");
        reg.push("vespa_cluster_spilled_total", vec![], r.spilled as f64, "Requests rejected at the front-end balancer");
        reg.push("vespa_cluster_replica_seconds", vec![], r.replica_seconds, "Cost proxy: summed active replica time");
        for p in &r.per_replica {
            let l = vec![("slot", p.slot.to_string())];
            reg.push("vespa_replica_admitted_total", l.clone(), p.admitted as f64, "Requests admitted by a fleet slot across its activations");
            reg.push("vespa_replica_completed_total", l.clone(), p.completed as f64, "Requests completed by a fleet slot across its activations");
            reg.push("vespa_replica_dropped_total", l, p.dropped as f64, "Requests dropped by a fleet slot across its activations");
        }
        reg.faults(&r.faults);
        reg.trace(r.trace.as_ref());
        reg
    }

    /// Add per-tile accelerator counters, MEM-tile traffic, and engine
    /// statistics from a live [`Soc`] (tiles with zero invocations are
    /// skipped).
    pub fn add_soc(&mut self, soc: &Soc) {
        for (i, c) in soc.mon.tiles.iter().enumerate() {
            if c.invocations == 0 {
                continue;
            }
            let l = vec![("tile", i.to_string())];
            self.push("vespa_accel_invocations_total", l.clone(), c.invocations as f64, "Completed accelerator invocations");
            self.push("vespa_accel_pkts_in_total", l.clone(), c.pkts_in as f64, "NoC packets into the accelerator tile");
            self.push("vespa_accel_pkts_out_total", l.clone(), c.pkts_out as f64, "NoC packets out of the accelerator tile");
            self.push("vespa_accel_rtt_mean_ps", l, c.rtt_mean(), "Mean DMA read round-trip time");
        }
        self.push("vespa_mem_pkts_in_total", vec![], soc.mon.mem_pkts_in as f64, "NoC packets delivered to the MEM tile");
        let es = &soc.engine_stats;
        self.push("vespa_engine_tile_ticks_total", vec![], es.tile_ticks as f64, "Tile ticks the engine executed");
        self.push("vespa_engine_router_ticks_total", vec![], es.router_ticks as f64, "Router ticks the engine executed");
        self.push("vespa_engine_skipped_tile_ticks_total", vec![], es.skipped_tile_ticks as f64, "Tile ticks skipped by idle-aware gating");
        self.push("vespa_engine_coalesced_spans_total", vec![], es.coalesced_spans as f64, "Quiescent spans the engine jumped");
        self.push("vespa_engine_heap_ops_total", vec![], soc.heap_ops() as f64, "Event-scheduler heap operations");
    }

    fn requests(&mut self, offered: u64, admitted: u64, dropped: u64, completed: u64, unfinished: u64) {
        self.push("vespa_requests_offered_total", vec![], offered as f64, "Requests generated by the arrival process");
        self.push("vespa_requests_admitted_total", vec![], admitted as f64, "Requests admitted into a serving queue");
        self.push("vespa_requests_dropped_total", vec![], dropped as f64, "Requests rejected with every candidate queue full");
        self.push("vespa_requests_completed_total", vec![], completed as f64, "Requests completed end to end");
        self.push("vespa_requests_unfinished", vec![], unfinished as f64, "Requests still in flight at the drain deadline");
    }

    fn latency(&mut self, l: &crate::serve::LatencyStats) {
        const HELP: &str = "End-to-end latency of completed requests (ms)";
        for (q, v) in [
            ("mean", l.mean_ms()),
            ("0.5", l.p50_ms()),
            ("0.95", l.p95_ms()),
            ("0.99", l.p99_ms()),
            ("max", l.max_ms()),
        ] {
            self.push("vespa_latency_ms", vec![("quantile", q.to_string())], v, HELP);
        }
    }

    fn faults(&mut self, f: &crate::fault::FaultLedger) {
        for (name, v, help) in [
            ("vespa_fault_injected_total", f.injected, "Fault windows + crashes the plan resolved"),
            ("vespa_fault_detected_total", f.detected, "Faults noticed by deadline or health probe"),
            ("vespa_fault_retried_total", f.retried, "Retry attempts scheduled"),
            ("vespa_fault_failed_over_total", f.failed_over, "Standby replicas activated to replace failed ones"),
            ("vespa_fault_evicted_total", f.evicted, "Replicas force-retired or evicted as wedged"),
            ("vespa_fault_lost_total", f.lost, "Requests lost after exhausting their retry budget"),
            ("vespa_fault_rescued_total", f.rescued, "Requests completed on a retry attempt"),
        ] {
            self.push(name, vec![], v as f64, help);
        }
    }

    fn trace(&mut self, t: Option<&super::Trace>) {
        let Some(t) = t else { return };
        self.push("vespa_trace_requests_total", vec![], t.total_requests as f64, "Requests seen by the tracer (sampled or not)");
        self.push("vespa_trace_recorded_total", vec![], t.recorded as f64, "Request spans recorded (passed the 1-in-N sample)");
        self.push("vespa_trace_evicted_total", vec![], t.evicted as f64, "Finished spans evicted by the flight-recorder bound");
    }

    /// Prometheus text exposition: one `# HELP`/`# TYPE` block per
    /// metric name (first-appearance order), `_total` names typed as
    /// counters, everything else as gauges.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut seen: Vec<&str> = Vec::new();
        for m in &self.metrics {
            if !seen.contains(&m.name) {
                seen.push(m.name);
                let ty = if m.name.ends_with("_total") { "counter" } else { "gauge" };
                out.push_str(&format!("# HELP {} {}\n# TYPE {} {ty}\n", m.name, m.help, m.name));
                for s in self.metrics.iter().filter(|s| s.name == m.name) {
                    let labels = if s.labels.is_empty() {
                        String::new()
                    } else {
                        format!(
                            "{{{}}}",
                            s.labels
                                .iter()
                                .map(|(k, v)| format!("{k}={}", fmt_str(v)))
                                .collect::<Vec<_>>()
                                .join(",")
                        )
                    };
                    out.push_str(&format!("{}{labels} {}\n", s.name, fmt_f64(s.value)));
                }
            }
        }
        out
    }

    /// JSON snapshot, parseable by
    /// [`json::parse`](crate::bench_harness::json::parse).
    pub fn to_json(&self) -> String {
        let items: Vec<String> = self
            .metrics
            .iter()
            .map(|m| {
                let labels = m
                    .labels
                    .iter()
                    .map(|(k, v)| format!("{}:{}", fmt_str(k), fmt_str(v)))
                    .collect::<Vec<_>>()
                    .join(",");
                format!(
                    "{{\"name\":{},\"labels\":{{{labels}}},\"value\":{}}}",
                    fmt_str(m.name),
                    fmt_f64(m.value),
                )
            })
            .collect();
        format!("{{\"kind\":\"metrics\",\"metrics\":[{}]}}\n", items.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::json::{self, Json};

    fn sample() -> MetricsRegistry {
        let mut reg = MetricsRegistry::new();
        reg.requests(100, 90, 10, 85, 5);
        reg.push(
            "vespa_tile_queue_depth_max",
            vec![("tile", "4".to_string())],
            7.0,
            "Peak depth",
        );
        reg.push(
            "vespa_tile_queue_depth_max",
            vec![("tile", "5".to_string())],
            3.0,
            "Peak depth",
        );
        reg
    }

    #[test]
    fn prometheus_text_shape() {
        let text = sample().to_prometheus();
        assert_eq!(
            text.matches("# TYPE vespa_tile_queue_depth_max gauge").count(),
            1,
            "one TYPE line per name:\n{text}"
        );
        assert!(text.contains("# TYPE vespa_requests_offered_total counter"));
        assert!(text.contains("vespa_requests_offered_total 100"));
        assert!(text.contains("vespa_tile_queue_depth_max{tile=\"4\"} 7"));
        assert!(text.contains("vespa_tile_queue_depth_max{tile=\"5\"} 3"));
    }

    #[test]
    fn json_roundtrips() {
        let reg = sample();
        let v = json::parse(&reg.to_json()).unwrap();
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("metrics"));
        let ms = v.get("metrics").and_then(Json::as_array).unwrap();
        assert_eq!(ms.len(), reg.metrics.len());
        let depth = ms
            .iter()
            .find(|m| {
                m.get("name").and_then(Json::as_str) == Some("vespa_tile_queue_depth_max")
            })
            .unwrap();
        assert_eq!(
            depth.get("labels").unwrap().get("tile").and_then(Json::as_str),
            Some("4")
        );
        assert_eq!(depth.get("value").and_then(Json::as_f64), Some(7.0));
    }

    #[test]
    fn get_filters_by_label() {
        let reg = sample();
        assert_eq!(reg.get("vespa_requests_dropped_total", None), Some(10.0));
        assert_eq!(
            reg.get("vespa_tile_queue_depth_max", Some(("tile", "5"))),
            Some(3.0)
        );
        assert_eq!(reg.get("vespa_tile_queue_depth_max", Some(("tile", "9"))), None);
        assert_eq!(reg.get("nope", None), None);
    }
}
