//! Chrome/Perfetto `trace_event` JSON export of a [`Trace`].
//!
//! One process (pid 0), one thread per [`Track`](super::Track) (tid =
//! track index, named via `"M"` metadata events). Each span renders as
//! up to three `"X"` duration slices on its serving track — `wait`
//! (arrival/backoff until admission), `queue` (admission until exec
//! start), `exec` (exec start until completion) — plus `"i"` instant
//! events for retries, crashes, drops and expiries. Timestamps are
//! microseconds (`ps / 1e6`), formatted with the deterministic
//! [`fmt_f64`](crate::bench_harness::json::fmt_f64), with the exact
//! picosecond stamps preserved in `args`. Load the file in
//! <https://ui.perfetto.dev> or `chrome://tracing`.

use crate::bench_harness::json::{fmt_f64, fmt_str};
use crate::util::Ps;

use super::{SpanEvent, Trace};

fn us(ps: Ps) -> String {
    fmt_f64(ps as f64 / 1e6)
}

/// One `"X"` duration slice.
fn slice(out: &mut Vec<String>, name: &str, cat: &str, tid: u16, t0: Ps, t1: Ps, id: u64) {
    out.push(format!(
        "{{\"name\":{},\"cat\":{},\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{\"span\":{},\"t0_ps\":{},\"t1_ps\":{}}}}}",
        fmt_str(name),
        fmt_str(cat),
        tid,
        us(t0),
        us(t1.saturating_sub(t0)),
        id,
        t0,
        t1,
    ));
}

/// One `"i"` instant marker (thread-scoped).
fn instant(out: &mut Vec<String>, name: &str, tid: u16, t: Ps, id: u64) {
    out.push(format!(
        "{{\"name\":{},\"cat\":\"mark\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{},\"ts\":{},\"args\":{{\"span\":{},\"t_ps\":{}}}}}",
        fmt_str(name),
        tid,
        us(t),
        id,
        t,
    ));
}

/// Render `trace` as Chrome `trace_event` JSON.
pub fn to_perfetto(trace: &Trace) -> String {
    let mut ev: Vec<String> = Vec::new();
    for (tid, track) in trace.tracks.iter().enumerate() {
        ev.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\"args\":{{\"name\":{}}}}}",
            tid,
            fmt_str(&track.name),
        ));
        ev.push(format!(
            "{{\"name\":\"thread_sort_index\",\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"args\":{{\"sort_index\":{tid}}}}}"
        ));
    }

    for span in &trace.spans {
        let id = span.id;
        // (since, track) of the segment currently open on a track.
        let mut queued: Option<(Ps, u16)> = None;
        let mut exec: Option<(Ps, u16)> = None;
        // Where the request has been waiting (arrival or last backoff).
        let mut waiting_since = span.t_arr;
        for &(t, e) in &span.events {
            match e {
                SpanEvent::Admit { track, attempt } => {
                    if t > waiting_since {
                        let cat = if attempt == 0 { "wait" } else { "backoff" };
                        slice(&mut ev, &format!("req {id} {cat}"), cat, track, waiting_since, t, id);
                    }
                    queued = Some((t, track));
                }
                SpanEvent::ExecStart { track, .. } => {
                    if let Some((t0, tid)) = queued.take() {
                        slice(&mut ev, &format!("req {id} queue"), "queue", tid, t0, t, id);
                    }
                    exec = Some((t, track));
                }
                SpanEvent::Complete { track, .. } => {
                    if let Some((t0, tid)) = exec.take() {
                        slice(&mut ev, &format!("req {id} exec"), "exec", tid, t0, t, id);
                    } else if let Some((t0, tid)) = queued.take() {
                        // Exec start not observed (e.g. pre-trace credit):
                        // render the whole residency as queue time.
                        slice(&mut ev, &format!("req {id} queue"), "queue", tid, t0, t, id);
                    }
                    instant(&mut ev, &format!("req {id} done"), track, t, id);
                }
                SpanEvent::Retry { attempt, .. } => {
                    if let Some((_, tid)) = queued.or(exec) {
                        instant(&mut ev, &format!("req {id} retry #{attempt}"), tid, t, id);
                    } else if let Some(track) = span.events.iter().find_map(|&(_, e)| match e {
                        SpanEvent::Admit { track, .. } => Some(track),
                        _ => None,
                    }) {
                        instant(&mut ev, &format!("req {id} retry #{attempt}"), track, t, id);
                    } else {
                        instant(&mut ev, &format!("req {id} retry #{attempt}"), 0, t, id);
                    }
                    waiting_since = t;
                }
                SpanEvent::Crashed { track } => {
                    if let Some((t0, tid)) = exec.take() {
                        slice(&mut ev, &format!("req {id} exec"), "exec", tid, t0, t, id);
                    }
                    if let Some((t0, tid)) = queued.take() {
                        slice(&mut ev, &format!("req {id} queue"), "queue", tid, t0, t, id);
                    }
                    instant(&mut ev, &format!("req {id} crashed"), track, t, id);
                    waiting_since = t;
                }
                SpanEvent::Dropped => instant(&mut ev, &format!("req {id} dropped"), 0, t, id),
                SpanEvent::Expired => instant(&mut ev, &format!("req {id} expired"), 0, t, id),
            }
        }
        // Unfinished at drain: close open segments at the last stamp so
        // the slice is visible (zero-length if nothing happened since).
        let t_end = span.t_last();
        if let Some((t0, tid)) = exec {
            slice(&mut ev, &format!("req {id} exec (unfinished)"), "exec", tid, t0, t_end, id);
        } else if let Some((t0, tid)) = queued {
            slice(&mut ev, &format!("req {id} queue (unfinished)"), "queue", tid, t0, t_end, id);
        }
    }

    format!(
        "{{\"displayTimeUnit\":\"ms\",\"otherData\":{{\"total_requests\":{},\"recorded\":{},\"evicted\":{}}},\"traceEvents\":[{}]}}\n",
        trace.total_requests,
        trace.recorded,
        trace.evicted,
        ev.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::super::{TraceSpec, Tracer};
    use super::*;
    use crate::bench_harness::json;

    fn sample_trace() -> Trace {
        let mut tr = Tracer::new(TraceSpec::new());
        tr.add_track("tile 4 (acc)".into(), 0, 4);
        tr.add_track("tile 5 (acc)".into(), 0, 5);
        let a = tr.arrive(1_000_000);
        tr.admit(a, 1_000_000, 0, 0);
        tr.exec_start(0, 2_000_000, 0);
        tr.complete(0, 5_000_000, 4_000_000);
        let b = tr.arrive(1_500_000);
        tr.retry(b, 1_500_000, 1_500_000, 3_000_000, 1, false);
        assert_eq!(tr.retry_pop(1_500_000, 1, false), b);
        tr.admit(b, 3_000_000, 1, 1);
        let c = tr.arrive(2_000_000);
        tr.admit(c, 2_000_000, 1, 0);
        tr.finish()
    }

    #[test]
    fn export_parses_and_names_tracks() {
        let out = to_perfetto(&sample_trace());
        let v = json::parse(&out).expect("valid JSON");
        let evs = v.get("traceEvents").unwrap().as_array().unwrap();
        // 2 metadata pairs + slices/instants.
        assert!(evs.len() > 4, "expected events, got {}", evs.len());
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["tile 4 (acc)", "tile 5 (acc)"]);
    }

    #[test]
    fn slices_cover_queue_and_exec() {
        let out = to_perfetto(&sample_trace());
        let v = json::parse(&out).expect("valid JSON");
        let evs = v.get("traceEvents").unwrap().as_array().unwrap();
        let cat = |c: &str| {
            evs.iter()
                .filter(|e| e.get("cat").and_then(|x| x.as_str()) == Some(c))
                .count()
        };
        assert_eq!(cat("queue"), 3, "req 0 queue + reqs 1/2 unfinished queue");
        assert_eq!(cat("exec"), 1);
        assert_eq!(cat("backoff"), 1, "req 1 waited out its retry backoff");
        // Span 0 queued from 1e6 ps to 2e6 ps = ts 1.0 us, dur 1.0 us.
        let q = evs
            .iter()
            .find(|e| e.get("cat").and_then(|x| x.as_str()) == Some("queue"))
            .unwrap();
        assert_eq!(q.get("ts").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(q.get("dur").unwrap().as_f64().unwrap(), 1.0);
    }
}
