//! Observability: deterministic request tracing, metrics export, and
//! host-side engine self-profiling.
//!
//! Three layers with very different determinism contracts:
//!
//! - [`trace`] — per-request [`RequestSpan`]s recorded by a [`Tracer`]
//!   into a bounded flight recorder. Stamps are **simulation time**
//!   only, so a [`Trace`] (and its [`to_perfetto`] export) is
//!   bit-identical across [`EngineMode`](crate::sim::EngineMode)s and
//!   `--threads {1,2,0}`. Enabled with a [`TraceSpec`] on
//!   [`ServeSpec`](crate::serve::ServeSpec) /
//!   [`ClusterSpec`](crate::cluster::ClusterSpec), or `--trace` on
//!   `vespa serve` / `vespa cluster`.
//! - [`metrics`] — a [`MetricsRegistry`] snapshot of the report
//!   counters behind stable names (Prometheus text + JSON). Also
//!   deterministic.
//! - [`profile`] — [`HostProfile`]: host wall-clock engine
//!   self-profiling. **Non-deterministic by design**, excluded from
//!   reports, surfaced only through bench JSON.

pub mod metrics;
pub mod perfetto;
pub mod profile;
pub mod trace;

pub use metrics::{Metric, MetricsRegistry};
pub use perfetto::to_perfetto;
pub use profile::HostProfile;
pub use trace::{RequestSpan, SpanEvent, Trace, TraceSpec, Track, Tracer};
