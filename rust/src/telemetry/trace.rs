//! Deterministic request spans and the bounded flight recorder.
//!
//! Every sampled request carries a [`RequestSpan`]: its original arrival
//! time plus a time-ordered list of [`SpanEvent`] phase transitions
//! (admission, accelerator exec start, completion, retries, crashes,
//! drops). All timestamps are **simulation time** ([`Ps`]), recorded at
//! the serve/cluster host loop's deterministic barriers — never host
//! wall clock — so a [`Trace`] is bit-identical across
//! [`EngineMode`](crate::sim::EngineMode)s and worker-thread counts.
//!
//! The [`Tracer`] is host-side bookkeeping that mirrors the dispatcher's
//! per-tile FIFOs with span ids (`None` sentinels keep unsampled
//! requests aligned), parks spans across retry backoffs keyed by the
//! retry heap's own `(orig, attempt, readmit)` identity, and bounds
//! memory with a flight-recorder ring of the most recent finished spans
//! plus a "slowest K" set that survives eviction.

use std::collections::{BTreeMap, VecDeque};

use crate::util::Ps;

/// Tracing configuration, carried on
/// [`ServeSpec`](crate::serve::ServeSpec) (and through it on
/// [`ClusterSpec`](crate::cluster::ClusterSpec)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSpec {
    /// Record every `sample`-th request (1 = trace everything). Requests
    /// that fall outside the sample still occupy sentinel slots in the
    /// tracer's FIFOs, so sampling never perturbs attribution.
    pub sample: u64,
    /// Always retain the `slowest` finished spans by latency, even after
    /// the ring evicts them.
    pub slowest: usize,
    /// Flight-recorder ring capacity (finished spans retained).
    pub capacity: usize,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self {
            sample: 1,
            slowest: 8,
            capacity: 4096,
        }
    }
}

impl TraceSpec {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request in `n` (clamped to at least 1).
    pub fn sample(mut self, n: u64) -> Self {
        self.sample = n.max(1);
        self
    }

    /// Always retain the `k` slowest finished spans.
    pub fn slowest(mut self, k: usize) -> Self {
        self.slowest = k;
        self
    }

    /// Flight-recorder ring capacity (clamped to at least 1).
    pub fn capacity(mut self, n: usize) -> Self {
        self.capacity = n.max(1);
        self
    }
}

/// One phase transition in a request's life, stamped with sim time by
/// the recording site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanEvent {
    /// Bound into a track's queue (`attempt` 0 = first admission).
    Admit { track: u16, attempt: u32 },
    /// An accelerator replica consumed this request's serve credit and
    /// began prefetching its inputs.
    ExecStart { track: u16, replica: u8 },
    /// Completion drained; `latency` is end-to-end from the *original*
    /// arrival, retries included.
    Complete { track: u16, latency: Ps },
    /// Rejected or crashed with retry budget left; readmission due at
    /// `due` as attempt `attempt`.
    Retry { due: Ps, attempt: u32 },
    /// In flight on a replica that was killed.
    Crashed { track: u16 },
    /// Rejected at admission with no retry budget — terminal.
    Dropped,
    /// Retry deadline expired (or the session drained) before
    /// readmission — terminal.
    Expired,
}

/// The recorded life of one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestSpan {
    /// Arrival ordinal (0-based, in arrival order) — stable across
    /// engines and thread counts.
    pub id: u64,
    /// Original arrival time (sim).
    pub t_arr: Ps,
    /// Phase transitions in recording order (non-decreasing time).
    pub events: Vec<(Ps, SpanEvent)>,
    /// End-to-end latency when the request completed.
    pub latency: Option<Ps>,
}

impl RequestSpan {
    /// Completion time, if the span finished successfully.
    pub fn t_done(&self) -> Option<Ps> {
        self.events.iter().rev().find_map(|&(t, e)| match e {
            SpanEvent::Complete { .. } => Some(t),
            _ => None,
        })
    }

    /// Last recorded timestamp (== `t_arr` for an empty span).
    pub fn t_last(&self) -> Ps {
        self.events.last().map_or(self.t_arr, |&(t, _)| t)
    }
}

/// One Perfetto track: a serving tile, qualified by cluster slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Track {
    /// Display name (`"tile 4 (a1)"`, or `"r2/tile 4"` in a cluster).
    pub name: String,
    /// Cluster slot (0 for single-SoC serve).
    pub slot: usize,
    /// Node id of the serving tile.
    pub tile: usize,
}

/// The exported artifact: tracks plus the retained spans, ordered by
/// span id. Attached to [`ServeReport`](crate::serve::ServeReport) /
/// [`ClusterReport`](crate::cluster::ClusterReport) when tracing is on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    pub spec: TraceSpec,
    pub tracks: Vec<Track>,
    /// Retained spans, ascending by id: the ring, the slowest-K set, and
    /// any spans still unfinished at drain.
    pub spans: Vec<RequestSpan>,
    /// Requests seen (sampled or not).
    pub total_requests: u64,
    /// Spans recorded (passed the 1-in-N sample).
    pub recorded: u64,
    /// Finished spans evicted by the ring bound (and not retained as
    /// slowest).
    pub evicted: u64,
}

impl Trace {
    /// The `k` slowest finished spans, slowest first (ties broken by
    /// id). `k = 0` means the spec's `slowest`.
    pub fn slowest(&self, k: usize) -> Vec<&RequestSpan> {
        let k = if k == 0 { self.spec.slowest } else { k };
        let mut done: Vec<&RequestSpan> =
            self.spans.iter().filter(|s| s.latency.is_some()).collect();
        done.sort_by_key(|s| (std::cmp::Reverse(s.latency.unwrap_or(0)), s.id));
        done.truncate(k);
        done
    }
}

/// Host-side recorder. All mutation happens at the serve/cluster host
/// loop's deterministic points (coordinator-side only in the parallel
/// cluster engine), so the finished [`Trace`] is engine- and
/// thread-count-invariant.
#[derive(Debug)]
pub struct Tracer {
    spec: TraceSpec,
    tracks: Vec<Track>,
    /// Per-track FIFO mirroring the dispatcher's `in_flight` queue.
    /// `None` = unsampled request holding its slot.
    fifo: Vec<VecDeque<Option<u64>>>,
    /// Per-track index of the next queued request to start exec.
    exec_cursor: Vec<usize>,
    /// Live spans by id (admitted or awaiting retry).
    live: BTreeMap<u64, RequestSpan>,
    /// Spans parked across a retry backoff, keyed by the retry heap's
    /// own identity. Tied keys pop FIFO — interchangeable requests, so
    /// the pairing is deterministic.
    parked: BTreeMap<(Ps, u32, bool), VecDeque<Option<u64>>>,
    /// Finished spans, oldest first (bounded by `spec.capacity`).
    ring: VecDeque<RequestSpan>,
    /// Evicted-but-retained slowest spans, ascending `(latency, id)`.
    slow: Vec<RequestSpan>,
    total: u64,
    recorded: u64,
    evicted: u64,
}

impl Tracer {
    pub fn new(spec: TraceSpec) -> Self {
        Self {
            spec,
            tracks: Vec::new(),
            fifo: Vec::new(),
            exec_cursor: Vec::new(),
            live: BTreeMap::new(),
            parked: BTreeMap::new(),
            ring: VecDeque::new(),
            slow: Vec::new(),
            total: 0,
            recorded: 0,
            evicted: 0,
        }
    }

    /// Register a track; returns its index (the Perfetto `tid`).
    pub fn add_track(&mut self, name: String, slot: usize, tile: usize) -> u16 {
        self.tracks.push(Track { name, slot, tile });
        self.fifo.push(VecDeque::new());
        self.exec_cursor.push(0);
        (self.tracks.len() - 1) as u16
    }

    /// A fresh request (attempt 0) arrived at `t_arr`. Returns its span
    /// handle — `None` when outside the 1-in-N sample (callers must
    /// still thread the `None` through admit/complete so FIFO slots stay
    /// aligned).
    pub fn arrive(&mut self, t_arr: Ps) -> Option<u64> {
        let ordinal = self.total;
        self.total += 1;
        if ordinal % self.spec.sample != 0 {
            return None;
        }
        self.recorded += 1;
        self.live.insert(
            ordinal,
            RequestSpan {
                id: ordinal,
                t_arr,
                events: Vec::new(),
                latency: None,
            },
        );
        Some(ordinal)
    }

    /// Recover the span parked for a retry popped with this identity.
    pub fn retry_pop(&mut self, orig: Ps, attempt: u32, readmit: bool) -> Option<u64> {
        let key = (orig, attempt, readmit);
        let id = self.parked.get_mut(&key).and_then(VecDeque::pop_front);
        if self.parked.get(&key).is_some_and(VecDeque::is_empty) {
            self.parked.remove(&key);
        }
        id.flatten()
    }

    /// The request was bound into `track`'s queue at `t`.
    pub fn admit(&mut self, id: Option<u64>, t: Ps, track: u16, attempt: u32) {
        self.record(id, t, SpanEvent::Admit { track, attempt });
        self.fifo[track as usize].push_back(id);
    }

    /// `track`'s accelerator consumed a serve credit at `t` on `replica`
    /// — attributed FIFO to the next queued request not yet executing.
    pub fn exec_start(&mut self, track: u16, t: Ps, replica: u8) {
        let ti = track as usize;
        let cur = self.exec_cursor[ti];
        if cur < self.fifo[ti].len() {
            let id = self.fifo[ti][cur];
            self.exec_cursor[ti] = cur + 1;
            self.record(id, t, SpanEvent::ExecStart { track, replica });
        }
    }

    /// `track`'s queue head completed at `t` with end-to-end `latency`.
    pub fn complete(&mut self, track: u16, t: Ps, latency: Ps) {
        let ti = track as usize;
        let id = self.fifo[ti].pop_front().flatten();
        self.exec_cursor[ti] = self.exec_cursor[ti].saturating_sub(1);
        self.record(id, t, SpanEvent::Complete { track, latency });
        if let Some(id) = id {
            if let Some(mut span) = self.live.remove(&id) {
                span.latency = Some(latency);
                self.retire(span);
            }
        }
    }

    /// A retry was scheduled at `t`, due at `due` as attempt `attempt`;
    /// the span parks under the retry heap's `(orig, attempt, readmit)`
    /// identity until [`Tracer::retry_pop`] recovers it.
    pub fn retry(&mut self, id: Option<u64>, t: Ps, orig: Ps, due: Ps, attempt: u32, readmit: bool) {
        self.record(id, t, SpanEvent::Retry { due, attempt });
        self.parked
            .entry((orig, attempt, readmit))
            .or_default()
            .push_back(id);
    }

    /// Rejected at admission with no retry budget — terminal.
    pub fn dropped(&mut self, id: Option<u64>, t: Ps) {
        self.finish_with(id, t, SpanEvent::Dropped);
    }

    /// Retry deadline expired (or drained unserved) — terminal.
    pub fn expired(&mut self, id: Option<u64>, t: Ps) {
        self.finish_with(id, t, SpanEvent::Expired);
    }

    /// A replica was killed: drain `track`'s whole queue in FIFO order,
    /// handing each parked-or-lost decision back to the caller (which
    /// mirrors the engine's own requeue loop). Returns the drained span
    /// handles.
    pub fn crash_track(&mut self, track: u16, t: Ps) -> Vec<Option<u64>> {
        let ti = track as usize;
        let ids: Vec<Option<u64>> = self.fifo[ti].drain(..).collect();
        self.exec_cursor[ti] = 0;
        for &id in &ids {
            self.record(id, t, SpanEvent::Crashed { track });
        }
        ids
    }

    fn record(&mut self, id: Option<u64>, t: Ps, ev: SpanEvent) {
        if let Some(id) = id {
            if let Some(span) = self.live.get_mut(&id) {
                span.events.push((t, ev));
            }
        }
    }

    fn finish_with(&mut self, id: Option<u64>, t: Ps, ev: SpanEvent) {
        self.record(id, t, ev);
        if let Some(id) = id {
            if let Some(span) = self.live.remove(&id) {
                self.retire(span);
            }
        }
    }

    /// Push a finished span into the ring, spilling the oldest into the
    /// slowest-K retention set (or the evicted count) when full.
    fn retire(&mut self, span: RequestSpan) {
        self.ring.push_back(span);
        if self.ring.len() > self.spec.capacity {
            let old = self.ring.pop_front().expect("ring non-empty");
            self.retain_slow(old);
        }
    }

    fn retain_slow(&mut self, span: RequestSpan) {
        let Some(lat) = span.latency.filter(|_| self.spec.slowest > 0) else {
            self.evicted += 1;
            return;
        };
        // Ascending (latency, Reverse-free id): index 0 is the fastest
        // retained span, the one a slower newcomer displaces.
        let key = |s: &RequestSpan| (s.latency.unwrap_or(0), u64::MAX - s.id);
        let pos = self
            .slow
            .binary_search_by_key(&(lat, u64::MAX - span.id), key)
            .unwrap_or_else(|p| p);
        self.slow.insert(pos, span);
        if self.slow.len() > self.spec.slowest {
            self.slow.remove(0);
            self.evicted += 1;
        }
    }

    /// Finish recording: unfinished spans are kept as-is (no synthetic
    /// terminal event), and everything retained is merged in id order.
    pub fn finish(mut self) -> Trace {
        let mut spans: Vec<RequestSpan> = Vec::with_capacity(
            self.ring.len() + self.slow.len() + self.live.len() + self.parked.len(),
        );
        spans.extend(self.ring.drain(..));
        spans.extend(self.slow.drain(..));
        // Parked spans whose retry never fired and queue residents at
        // drain: export them unfinished.
        for (_, ids) in std::mem::take(&mut self.parked) {
            for id in ids.into_iter().flatten() {
                if let Some(span) = self.live.remove(&id) {
                    spans.push(span);
                }
            }
        }
        spans.extend(std::mem::take(&mut self.live).into_values());
        spans.sort_by_key(|s| s.id);
        Trace {
            spec: self.spec,
            tracks: self.tracks,
            spans,
            total_requests: self.total,
            recorded: self.recorded,
            evicted: self.evicted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_track() -> Tracer {
        let mut tr = Tracer::new(TraceSpec::new());
        tr.add_track("tile 4 (acc)".into(), 0, 4);
        tr
    }

    #[test]
    fn records_the_happy_path() {
        let mut tr = one_track();
        let id = tr.arrive(100);
        tr.admit(id, 100, 0, 0);
        tr.exec_start(0, 150, 1);
        tr.complete(0, 400, 300);
        let t = tr.finish();
        assert_eq!(t.total_requests, 1);
        assert_eq!(t.recorded, 1);
        assert_eq!(t.spans.len(), 1);
        let s = &t.spans[0];
        assert_eq!(s.t_arr, 100);
        assert_eq!(s.latency, Some(300));
        assert_eq!(
            s.events,
            vec![
                (100, SpanEvent::Admit { track: 0, attempt: 0 }),
                (150, SpanEvent::ExecStart { track: 0, replica: 1 }),
                (400, SpanEvent::Complete { track: 0, latency: 300 }),
            ]
        );
    }

    #[test]
    fn sampling_keeps_fifo_slots_aligned() {
        let mut tr = Tracer::new(TraceSpec::new().sample(2));
        tr.add_track("t".into(), 0, 0);
        let a = tr.arrive(10); // sampled (ordinal 0)
        let b = tr.arrive(20); // skipped (ordinal 1)
        assert!(a.is_some() && b.is_none());
        // Admit in arrival order, complete in the same order: the
        // sentinel must absorb b's completion, not a's.
        tr.admit(a, 10, 0, 0);
        tr.admit(b, 20, 0, 0);
        tr.complete(0, 50, 40);
        tr.complete(0, 60, 40);
        let t = tr.finish();
        assert_eq!(t.total_requests, 2);
        assert_eq!(t.recorded, 1);
        assert_eq!(t.spans.len(), 1);
        assert_eq!(t.spans[0].latency, Some(40));
        assert_eq!(t.spans[0].t_done(), Some(50));
    }

    #[test]
    fn retry_parks_and_recovers_by_heap_identity() {
        let mut tr = one_track();
        let id = tr.arrive(100);
        tr.retry(id, 100, 100, 300, 1, false);
        assert_eq!(tr.retry_pop(100, 1, false), id);
        tr.admit(id, 300, 0, 1);
        tr.complete(0, 500, 400);
        let t = tr.finish();
        let s = &t.spans[0];
        assert_eq!(s.t_arr, 100, "rescued span keeps its original arrival");
        assert_eq!(s.latency, Some(400));
        assert!(matches!(s.events[0].1, SpanEvent::Retry { due: 300, attempt: 1 }));
    }

    #[test]
    fn tied_retry_keys_pop_fifo() {
        let mut tr = one_track();
        let a = tr.arrive(100);
        let b = tr.arrive(100);
        tr.retry(a, 100, 100, 200, 1, false);
        tr.retry(b, 100, 100, 200, 1, false);
        assert_eq!(tr.retry_pop(100, 1, false), a);
        assert_eq!(tr.retry_pop(100, 1, false), b);
        assert_eq!(tr.retry_pop(100, 1, false), None);
    }

    #[test]
    fn crash_drains_the_track_fifo() {
        let mut tr = one_track();
        let a = tr.arrive(10);
        let b = tr.arrive(20);
        tr.admit(a, 10, 0, 0);
        tr.admit(b, 20, 0, 0);
        tr.exec_start(0, 15, 0);
        let drained = tr.crash_track(0, 50);
        assert_eq!(drained, vec![a, b]);
        // Caller decides: a requeues, b is lost.
        tr.retry(a, 50, 10, 90, 1, true);
        tr.expired(b, 50);
        assert_eq!(tr.retry_pop(10, 1, true), a);
        tr.admit(a, 90, 0, 1);
        tr.complete(0, 120, 110);
        let t = tr.finish();
        assert_eq!(t.spans.len(), 2);
        assert_eq!(t.spans[0].latency, Some(110));
        assert_eq!(t.spans[1].latency, None);
        assert!(matches!(t.spans[1].events.last().unwrap().1, SpanEvent::Expired));
    }

    #[test]
    fn ring_bounds_memory_and_retains_slowest() {
        let mut tr = Tracer::new(TraceSpec::new().capacity(2).slowest(1));
        tr.add_track("t".into(), 0, 0);
        for (t_arr, lat) in [(0u64, 10u64), (1, 900), (2, 20), (3, 30), (4, 40)] {
            let id = tr.arrive(t_arr);
            tr.admit(id, t_arr, 0, 0);
            tr.complete(0, t_arr + lat, lat);
        }
        let t = tr.finish();
        // Ring holds the last 2 finished; span 1 (latency 900) survives
        // eviction via the slowest-1 set; spans 0 and 2 are evicted.
        let ids: Vec<u64> = t.spans.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 3, 4]);
        assert_eq!(t.evicted, 2);
        assert_eq!(t.slowest(1)[0].id, 1);
    }

    #[test]
    fn unfinished_spans_survive_finish() {
        let mut tr = one_track();
        let id = tr.arrive(5);
        tr.admit(id, 5, 0, 0);
        let t = tr.finish();
        assert_eq!(t.spans.len(), 1);
        assert_eq!(t.spans[0].latency, None);
    }
}
