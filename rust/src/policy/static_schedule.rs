//! Timed frequency program (the Fig. 4 experiment driver).

use crate::sim::Soc;
use crate::util::Ps;

use super::DfsPolicy;

/// A list of `(time, island, MHz)` steps applied as simulation time
/// passes them.
#[derive(Debug, Clone, Default)]
pub struct StaticSchedule {
    steps: Vec<(Ps, usize, u64)>,
    next: usize,
    /// Steps that were rejected by the island (range/grid violations).
    pub rejected: u64,
}

impl StaticSchedule {
    pub fn new(mut steps: Vec<(Ps, usize, u64)>) -> Self {
        steps.sort_by_key(|&(t, ..)| t);
        Self {
            steps,
            next: 0,
            rejected: 0,
        }
    }

    /// Remaining steps.
    pub fn pending(&self) -> usize {
        self.steps.len() - self.next
    }
}

impl DfsPolicy for StaticSchedule {
    fn on_sample(&mut self, soc: &mut Soc, now: Ps) {
        while self.next < self.steps.len() && self.steps[self.next].0 <= now {
            let (_, island, mhz) = self.steps[self.next];
            if soc.host_write_freq(island, mhz).is_err() {
                self.rejected += 1;
            }
            self.next += 1;
        }
    }

    fn name(&self) -> &'static str {
        "static-schedule"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::paper_soc;
    use crate::policy::run_with_policy;
    use crate::runtime::RefCompute;
    use crate::sim::Soc;

    #[test]
    fn applies_steps_in_order() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let mut sched = StaticSchedule::new(vec![
            (50_000_000, 1, 10),
            (10_000_000, 3, 25),
        ]);
        run_with_policy(&mut soc, &mut sched, 5_000_000, 100_000_000).unwrap();
        assert_eq!(sched.pending(), 0);
        assert_eq!(sched.rejected, 0);
        // After actuator latency both islands run the new frequencies.
        soc.run_until(150_000_000);
        assert_eq!(soc.islands[1].freq(soc.now).as_mhz(), 10);
        assert_eq!(soc.islands[3].freq(soc.now).as_mhz(), 25);
    }

    #[test]
    fn rejects_out_of_range_steps() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        // A1 island max is 50 MHz.
        let mut sched = StaticSchedule::new(vec![(1_000_000, 1, 100)]);
        run_with_policy(&mut soc, &mut sched, 1_000_000, 5_000_000).unwrap();
        assert_eq!(sched.rejected, 1);
    }
}
