//! Reactive DFS: the run-time optimization the paper's monitoring
//! infrastructure is built to enable.
//!
//! Control law (hysteresis bang-bang on observed round-trip time):
//! every interval, read the mean DMA RTT of the watched accelerator
//! tiles since the last sample. If it exceeds `rtt_high_ns`, step the
//! NoC island frequency up; if it is below `rtt_low_ns`, step down
//! (saving power on an under-utilized interconnect, cf. [7] in the
//! paper). Counters are read exactly as the CPU/host would read them —
//! through the monitor file.

use crate::monitor::CounterReg;
use crate::sim::Soc;
use crate::util::Ps;

use super::DfsPolicy;

/// The reactive policy.
#[derive(Debug, Clone)]
pub struct ReactiveDfs {
    /// Island to actuate (the NoC+MEM island in the paper preset).
    pub island: usize,
    /// Accelerator tiles whose RTT is watched.
    pub watch_tiles: Vec<usize>,
    pub rtt_high_ns: f64,
    pub rtt_low_ns: f64,
    pub step_mhz: u64,
    /// Last cumulative (sum, count) per watched tile.
    last: Vec<(u64, u64)>,
    /// Decisions taken: (time, new MHz).
    pub actions: Vec<(Ps, u64)>,
}

impl ReactiveDfs {
    pub fn new(island: usize, watch_tiles: Vec<usize>, rtt_high_ns: f64, rtt_low_ns: f64) -> Self {
        let n = watch_tiles.len();
        Self {
            island,
            watch_tiles,
            rtt_high_ns,
            rtt_low_ns,
            step_mhz: 10,
            last: vec![(0, 0); n],
            actions: Vec::new(),
        }
    }

    /// Mean RTT (ns) across watched tiles since the previous sample.
    fn window_rtt_ns(&mut self, soc: &Soc) -> Option<f64> {
        let mut dsum = 0u64;
        let mut dcnt = 0u64;
        for (i, &t) in self.watch_tiles.iter().enumerate() {
            let sum = soc.host_read_counter(t, CounterReg::RttSum);
            let cnt = soc.host_read_counter(t, CounterReg::RttCnt);
            dsum += sum - self.last[i].0;
            dcnt += cnt - self.last[i].1;
            self.last[i] = (sum, cnt);
        }
        (dcnt > 0).then(|| dsum as f64 / dcnt as f64 / 1e3)
    }
}

impl DfsPolicy for ReactiveDfs {
    fn on_sample(&mut self, soc: &mut Soc, now: Ps) {
        let Some(rtt) = self.window_rtt_ns(soc) else {
            return;
        };
        let cur = soc.islands[self.island].freq(now).as_mhz();
        let (min, max) = (
            soc.islands[self.island].min.as_mhz(),
            soc.islands[self.island].max.as_mhz(),
        );
        let target = if rtt > self.rtt_high_ns && cur < max {
            (cur + self.step_mhz).min(max)
        } else if rtt < self.rtt_low_ns && cur > min {
            cur.saturating_sub(self.step_mhz).max(min)
        } else {
            return;
        };
        if target != cur && soc.host_write_freq(self.island, target).is_ok() {
            self.actions.push((now, target));
        }
    }

    fn name(&self) -> &'static str {
        "reactive-rtt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{paper_soc, A2_POS};
    use crate::policy::run_with_policy;
    use crate::runtime::RefCompute;
    use crate::sim::{stage_inputs_for, Soc};

    /// Under heavy TG load at a slow NoC clock, RTTs blow up and the
    /// policy must boost the NoC island.
    #[test]
    fn boosts_noc_under_congestion() {
        let cfg = paper_soc(("dfmul", 4), ("dfmul", 4));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a2 = soc.cfg.node_of(A2_POS.0, A2_POS.1);
        stage_inputs_for(&mut soc, a2, 1).unwrap();
        soc.mra_mut(a2).functional_every_invocation = false;
        soc.host_write_freq(0, 10).unwrap(); // slow NoC
        soc.host_set_tg_active(11);
        soc.run_until(30_000_000); // let the DFS swap + traffic build

        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        run_with_policy(&mut soc, &mut pol, 50_000_000, 500_000_000).unwrap();
        assert!(
            !pol.actions.is_empty(),
            "policy should have boosted the NoC island"
        );
        let last = pol.actions.last().unwrap().1;
        assert!(last > 10, "frequency raised from 10 MHz, got {last}");
    }

    /// With no traffic at all, the policy steps the NoC island down.
    #[test]
    fn relaxes_idle_noc() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a2 = soc.cfg.node_of(A2_POS.0, A2_POS.1);
        stage_inputs_for(&mut soc, a2, 1).unwrap();
        soc.mra_mut(a2).functional_every_invocation = false;
        // NoC at 100 MHz, one lazy accelerator: RTTs are far below the
        // relax threshold, so the policy steps the island down.
        let mut pol = ReactiveDfs::new(0, vec![a2], 100_000.0, 20_000.0);
        run_with_policy(&mut soc, &mut pol, 100_000_000, 2_000_000_000).unwrap();
        assert!(!pol.actions.is_empty(), "policy should relax the NoC");
        assert!(pol.actions.iter().all(|&(_, f)| f < 100));
    }

    // -----------------------------------------------------------------
    // Direct unit tests of the control law: drive the monitor counters
    // by hand and call `on_sample` — no traffic, no policy-loop driver.
    // -----------------------------------------------------------------

    /// A paper SoC with the NoC island settled at `noc_mhz`.
    fn soc_at_noc_mhz(noc_mhz: u64) -> (Soc, usize) {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a2 = soc.cfg.node_of(A2_POS.0, A2_POS.1);
        if noc_mhz != 100 {
            soc.host_write_freq(0, noc_mhz).unwrap();
            soc.run_until(20_000_000); // past the actuator swap
        }
        (soc, a2)
    }

    /// Push one synthetic DMA round-trip of `rtt_ns` into the tile's
    /// counters (exactly what the hardware monitor would accumulate).
    fn inject_rtt(soc: &mut Soc, tile: usize, rtt_ns: u64) {
        let c = soc.mon.tile_mut(tile);
        c.rtt_sum += rtt_ns * 1_000; // ns -> ps
        c.rtt_count += 1;
    }

    #[test]
    fn boosts_one_step_when_window_rtt_degrades() {
        let (mut soc, a2) = soc_at_noc_mhz(50);
        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        // Degraded window: 5 us mean RTT >> 2 us boost threshold.
        inject_rtt(&mut soc, a2, 5_000);
        pol.on_sample(&mut soc, soc.now);
        assert_eq!(pol.actions.len(), 1);
        assert_eq!(pol.actions[0].1, 60, "one step_mhz up from 50");
    }

    #[test]
    fn relaxes_one_step_when_under_utilized() {
        let (mut soc, a2) = soc_at_noc_mhz(50);
        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        // 50 ns mean RTT: far below the 100 ns relax threshold.
        inject_rtt(&mut soc, a2, 50);
        pol.on_sample(&mut soc, soc.now);
        assert_eq!(pol.actions.len(), 1);
        assert_eq!(pol.actions[0].1, 40, "one step_mhz down from 50");
    }

    #[test]
    fn holds_between_thresholds_and_without_round_trips() {
        let (mut soc, a2) = soc_at_noc_mhz(50);
        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        // No completed round-trips in the window: no decision at all.
        pol.on_sample(&mut soc, soc.now);
        assert!(pol.actions.is_empty());
        // In-band RTT (hysteresis): still no action.
        inject_rtt(&mut soc, a2, 1_000);
        pol.on_sample(&mut soc, soc.now);
        assert!(pol.actions.is_empty());
    }

    #[test]
    fn window_deltas_reset_between_samples() {
        let (mut soc, a2) = soc_at_noc_mhz(50);
        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        // A degraded first window boosts...
        inject_rtt(&mut soc, a2, 5_000);
        pol.on_sample(&mut soc, soc.now);
        assert_eq!(pol.actions.len(), 1);
        // ...but the *cumulative* counters must not leak into the next
        // window: a calm second window (fast RTT) relaxes instead of
        // re-boosting on the stale 5 us sum.
        inject_rtt(&mut soc, a2, 50);
        pol.on_sample(&mut soc, soc.now);
        assert_eq!(pol.actions.len(), 2);
        assert!(pol.actions[1].1 < pol.actions[0].1, "{:?}", pol.actions);
    }

    #[test]
    fn clamps_at_the_island_range() {
        // At the 100 MHz NoC maximum a degraded RTT has nowhere to go.
        let (mut soc, a2) = soc_at_noc_mhz(100);
        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        inject_rtt(&mut soc, a2, 5_000);
        pol.on_sample(&mut soc, soc.now);
        assert!(pol.actions.is_empty(), "no boost past the range max");
        // At the 10 MHz minimum an idle NoC has nowhere to relax to.
        let (mut soc, a2) = soc_at_noc_mhz(10);
        let mut pol = ReactiveDfs::new(0, vec![a2], 2_000.0, 100.0);
        inject_rtt(&mut soc, a2, 50);
        pol.on_sample(&mut soc, soc.now);
        assert!(pol.actions.is_empty(), "no relax below the range min");
    }
}
