//! Per-island energy accounting — the metric fine-grained DFS exists to
//! optimize (the paper motivates Vespa with run-time optimization and
//! cites run-time power monitoring [7]; this is the corresponding
//! framework feature).
//!
//! Model: dynamic energy per island = `C_eff x cycles` with the cycle
//! count taken from the clock domains (dynamic power scales with f, so
//! energy scales with delivered cycles at fixed voltage — FPGAs do not
//! scale voltage with DFS), plus leakage proportional to wall time and
//! the island's configured-logic share. `C_eff` per island is derived
//! from the floorplan's LUT+FF counts (switching capacitance tracks
//! utilized logic).

use crate::config::{SocConfig, TileKind};
use crate::resources::{mra_area, AccelArea, Utilization};
use crate::sim::Soc;
use crate::util::Ps;

/// Energy model coefficients (relative units; absolute calibration would
/// need the board's power rails, which the paper does not report either).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Dynamic energy per (kLUT-equivalent x cycle).
    pub dyn_per_klut_cycle: f64,
    /// Leakage power per kLUT-equivalent (energy per second).
    pub leak_per_klut_s: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            dyn_per_klut_cycle: 1.0,
            leak_per_klut_s: 2.0e6,
        }
    }
}

/// Energy report for one run window.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// Per-island (name, kLUT-equivalent, cycles, energy).
    pub islands: Vec<(String, f64, u64, f64)>,
    pub total: f64,
    pub wall: Ps,
}

/// kLUT-equivalent switching weight of each island (LUT + FF/2 of the
/// tiles it contains; routers weigh on the NoC island).
pub fn island_weights(cfg: &SocConfig) -> crate::Result<Vec<f64>> {
    let mut w = vec![0f64; cfg.islands.len()];
    for t in &cfg.tiles {
        let u: Utilization = match &t.kind {
            TileKind::Accel { accel, replicas } => mra_area(&AccelArea::lookup(accel)?, *replicas),
            TileKind::Cpu => Utilization::new(55_000, 42_000, 40, 27),
            TileKind::Mem => Utilization::new(18_000, 16_000, 24, 0),
            TileKind::Io => Utilization::new(9_000, 9_500, 8, 0),
            TileKind::Tg => Utilization::new(6_700, 9_300, 2, 0),
        };
        w[t.island] += (u.lut as f64 + u.ff as f64 / 2.0) / 1000.0;
    }
    // NoC routers (3 planes x nodes) charge the NoC island.
    w[cfg.noc.island] += cfg.tiles.len() as f64 * 3.0;
    Ok(w)
}

/// Compute the energy spent so far on `soc` under `model`.
pub fn energy_report(soc: &Soc, model: &EnergyModel) -> crate::Result<EnergyReport> {
    let weights = island_weights(&soc.cfg)?;
    let wall = soc.now;
    let mut islands = Vec::new();
    let mut total = 0.0;
    for (i, d) in soc.islands.iter().enumerate() {
        let dynamic = model.dyn_per_klut_cycle * weights[i] * d.cycles as f64;
        let leak = model.leak_per_klut_s * weights[i] * wall as f64 / 1e12;
        let e = dynamic + leak;
        total += e;
        islands.push((d.name.clone(), weights[i], d.cycles, e));
    }
    Ok(EnergyReport {
        islands,
        total,
        wall,
    })
}

/// Energy per completed invocation on `tile` — the run-time
/// optimization objective a DFS policy can minimize.
pub fn energy_per_invocation(soc: &Soc, tile: usize, model: &EnergyModel) -> crate::Result<f64> {
    let inv = soc
        .host_read_counter(tile, crate::monitor::CounterReg::Invocations)
        .max(1);
    Ok(energy_report(soc, model)?.total / inv as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{paper_soc, ISL_NOC, ISL_TG};
    use crate::runtime::RefCompute;
    use crate::sim::Soc;

    #[test]
    fn weights_cover_all_islands() {
        let cfg = paper_soc(("dfmul", 4), ("gsm", 1));
        let w = island_weights(&cfg).unwrap();
        assert_eq!(w.len(), 5);
        assert!(w.iter().all(|&x| x > 0.0), "{w:?}");
        // The TG island holds 11 tiles: heaviest after CPU+IO/NoC.
        assert!(w[ISL_TG] > w[1], "{w:?}");
    }

    #[test]
    fn slower_clock_costs_less_energy() {
        let run = |noc_mhz: u64| -> f64 {
            let mut cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
            cfg.islands[ISL_NOC].freq_mhz = noc_mhz;
            let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
            soc.host_set_tg_active(4);
            soc.run_for(5_000_000_000);
            energy_report(&soc, &EnergyModel::default()).unwrap().total
        };
        let fast = run(100);
        let slow = run(20);
        assert!(
            slow < fast * 0.9,
            "NoC at 20 MHz must spend less: {slow:.0} vs {fast:.0}"
        );
    }

    #[test]
    fn energy_per_invocation_tradeoff_visible() {
        // dfmul 2x at accel 50 vs 10 MHz: the slow island saves island
        // energy but invocations take 5x longer (leakage + other islands
        // keep burning) — the classic race-to-idle tension the metric
        // exposes. We only assert the metric is finite and positive.
        let mut cfg = paper_soc(("dfmul", 2), ("dfadd", 1));
        cfg.islands[1].freq_mhz = 50;
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a1 = soc.cfg.node_of(crate::config::presets::A1_POS.0, crate::config::presets::A1_POS.1);
        crate::sim::stage_inputs_for(&mut soc, a1, 1).unwrap();
        soc.mra_mut(a1).functional_every_invocation = false;
        soc.run_for(3_000_000_000);
        let epi = energy_per_invocation(&soc, a1, &EnergyModel::default()).unwrap();
        assert!(epi.is_finite() && epi > 0.0);
    }
}
