//! Run-time DFS policies.
//!
//! The monitoring infrastructure exists "to support run-time optimization
//! policies and design space exploration" (§II-C). Two policies ship:
//!
//! * [`StaticSchedule`] — the timed frequency program Fig. 4 uses
//!   (stepping island clocks at fixed instants);
//! * [`ReactiveDfs`] — the run-time optimizer the paper motivates:
//!   boosts the NoC island when observed DMA round-trip times degrade,
//!   and relaxes it when the interconnect is under-utilized.

pub mod energy;
pub mod reactive;
pub mod static_schedule;

pub use energy::{energy_per_invocation, energy_report, EnergyModel, EnergyReport};
pub use reactive::ReactiveDfs;
pub use static_schedule::StaticSchedule;

use crate::sim::Soc;
use crate::util::Ps;

/// A run-time DFS policy driven by sampled monitor state.
pub trait DfsPolicy {
    /// Called at each policy interval; may issue frequency requests.
    fn on_sample(&mut self, soc: &mut Soc, now: Ps);

    fn name(&self) -> &'static str;
}

/// Drive a policy over a simulation run: invokes `policy.on_sample`
/// every `interval` ps while advancing the SoC to `t_end`.
pub fn run_with_policy(
    soc: &mut Soc,
    policy: &mut dyn DfsPolicy,
    interval: Ps,
    t_end: Ps,
) {
    let mut next = soc.now + interval;
    while soc.now < t_end {
        let target = next.min(t_end);
        soc.run_until(target);
        if soc.now >= next {
            policy.on_sample(soc, soc.now);
            next += interval;
        }
    }
}
