//! Run-time DFS policies.
//!
//! The monitoring infrastructure exists "to support run-time optimization
//! policies and design space exploration" (§II-C). Two policies ship:
//!
//! * [`StaticSchedule`] — the timed frequency program Fig. 4 uses
//!   (stepping island clocks at fixed instants);
//! * [`ReactiveDfs`] — the run-time optimizer the paper motivates:
//!   boosts the NoC island when observed DMA round-trip times degrade,
//!   and relaxes it when the interconnect is under-utilized.

pub mod energy;
pub mod reactive;
pub mod static_schedule;

pub use energy::{energy_per_invocation, energy_report, EnergyModel, EnergyReport};
pub use reactive::ReactiveDfs;
pub use static_schedule::StaticSchedule;

use crate::sim::Soc;
use crate::util::Ps;

/// A run-time DFS policy driven by sampled monitor state.
pub trait DfsPolicy {
    /// Called at each policy interval; may issue frequency requests.
    fn on_sample(&mut self, soc: &mut Soc, now: Ps);

    fn name(&self) -> &'static str;
}

/// Drive a policy over a simulation run: invokes `policy.on_sample`
/// every `interval` ps while advancing the SoC to `t_end`.
///
/// Errors on `interval == 0` (the loop could never advance past its
/// first sample point — historically an infinite loop). A horizon at or
/// before `soc.now` is a no-op: the simulation never runs backwards and
/// no samples fire.
pub fn run_with_policy(
    soc: &mut Soc,
    policy: &mut dyn DfsPolicy,
    interval: Ps,
    t_end: Ps,
) -> crate::Result<()> {
    anyhow::ensure!(
        interval > 0,
        "run_with_policy: interval must be positive (policy {:?} would never advance)",
        policy.name()
    );
    if t_end <= soc.now {
        return Ok(());
    }
    let mut next = soc.now + interval;
    while soc.now < t_end {
        let target = next.min(t_end);
        soc.run_until(target);
        if soc.now >= next {
            policy.on_sample(soc, soc.now);
            next += interval;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RefCompute;
    use crate::scenario::Scenario;

    struct CountingPolicy(usize);

    impl DfsPolicy for CountingPolicy {
        fn on_sample(&mut self, _soc: &mut Soc, _now: Ps) {
            self.0 += 1;
        }

        fn name(&self) -> &'static str {
            "counting"
        }
    }

    fn tiny_soc() -> Soc {
        let cfg = Scenario::grid(2, 2)
            .island("noc", 100)
            .noc_island("noc")
            .mem_at(0, 0)
            .io_at(1, 0)
            .fill_tg("noc")
            .build()
            .unwrap();
        Soc::build(cfg, Box::new(RefCompute::new())).unwrap()
    }

    /// Regression: `interval == 0` used to loop forever (`next` never
    /// advanced past `soc.now`); it must now be a clean error.
    #[test]
    fn zero_interval_is_an_error_not_a_hang() {
        let mut soc = tiny_soc();
        let mut pol = CountingPolicy(0);
        let err = run_with_policy(&mut soc, &mut pol, 0, 1_000_000).unwrap_err();
        assert!(err.to_string().contains("interval"), "{err}");
        assert_eq!(pol.0, 0, "no samples fired");
        assert_eq!(soc.now, 0, "time did not advance");
    }

    #[test]
    fn horizon_at_or_before_now_is_a_noop() {
        let mut soc = tiny_soc();
        soc.run_until(5_000_000);
        let mut pol = CountingPolicy(0);
        run_with_policy(&mut soc, &mut pol, 1_000, 5_000_000).unwrap();
        run_with_policy(&mut soc, &mut pol, 1_000, 1_000_000).unwrap();
        assert_eq!(pol.0, 0);
        assert_eq!(soc.now, 5_000_000, "time never runs backwards");
    }

    #[test]
    fn samples_fire_on_the_interval_grid() {
        let mut soc = tiny_soc();
        let mut pol = CountingPolicy(0);
        run_with_policy(&mut soc, &mut pol, 1_000_000, 10_000_000).unwrap();
        assert_eq!(pol.0, 10);
        assert_eq!(soc.now, 10_000_000);
    }
}
