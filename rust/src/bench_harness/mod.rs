//! Micro/macro benchmark harness (offline stand-in for criterion).
//!
//! Wall-clock measurement with warmup, configurable iteration counts,
//! and mean/median/min/max reporting. Bench binaries (`rust/benches/`,
//! `harness = false`) use [`Bench`] for timing sections and print the
//! paper-reproduction tables through [`crate::report`].

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>12?}  median {:>12?}  min {:>12?}  max {:>12?}",
            self.name, self.iters, self.mean, self.median, self.min, self.max
        )
    }

    /// Throughput in ops/s given `ops` per iteration.
    pub fn ops_per_sec(&self, ops: f64) -> f64 {
        ops / self.mean.as_secs_f64()
    }
}

/// The harness.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            iters: 10,
        }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self {
            warmup_iters,
            iters,
        }
    }

    /// Benchmark `f`, which receives the iteration index.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut(usize) -> T) -> BenchResult {
        for i in 0..self.warmup_iters {
            std::hint::black_box(f(i));
        }
        let mut times = Vec::with_capacity(self.iters);
        for i in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f(i));
            times.push(t0.elapsed());
        }
        times.sort();
        let sum: Duration = times.iter().sum();
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean: sum / self.iters as u32,
            median: times[self.iters / 2],
            min: times[0],
            max: times[self.iters - 1],
        }
    }
}

/// Parse `--quick` / `--iters N` style bench CLI args.
pub fn bench_args() -> (bool, Option<usize>) {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let iters = args
        .iter()
        .position(|a| a == "--iters")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok());
    (quick, iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(1, 5);
        let r = b.run("spin", |_| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn report_contains_name() {
        let b = Bench::new(0, 3);
        let r = b.run("named", |_| 1);
        assert!(r.report().contains("named"));
    }
}
