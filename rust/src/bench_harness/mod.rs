//! Micro/macro benchmark harness (offline stand-in for criterion).
//!
//! Wall-clock measurement with warmup, configurable iteration counts,
//! and mean/median/min/max reporting. Bench binaries (`rust/benches/`,
//! `harness = false`) use [`Bench`] for timing sections, print the
//! paper-reproduction tables through [`crate::report`], and persist a
//! machine-readable [`BenchReport`] as `BENCH_<name>.json` — the file
//! CI's bench-smoke job feeds to the `bench_gate` comparator (see
//! `docs/PERF.md` for the schema and the baseline-refresh flow).
//!
//! CLI: every bench accepts `--quick`, `--iters N`, `--threads N` and
//! `--json <path>` in both `--key value` and `--key=value` forms
//! ([`BenchArgs`] reuses the [`crate::cli`] parser, so bench binaries
//! and the main CLI accept the same syntax).

pub mod json;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::Context;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Operations represented by one iteration (None = not a throughput
    /// benchmark); `ops_per_s` in the JSON output is `ops / mean`.
    pub ops: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>12?}  median {:>12?}  min {:>12?}  max {:>12?}",
            self.name, self.iters, self.mean, self.median, self.min, self.max
        )
    }

    /// Throughput in ops/s given `ops` per iteration.
    pub fn ops_per_sec(&self, ops: f64) -> f64 {
        ops / self.mean.as_secs_f64()
    }

    /// Tag the result with its per-iteration operation count (drives the
    /// `ops_per_s` field of the JSON output).
    pub fn with_ops(mut self, ops: f64) -> Self {
        self.ops = Some(ops);
        self
    }

    /// One JSON object: name, iters, mean/median/min/max in ns, ops/s.
    pub fn to_json(&self) -> String {
        let ops_per_s = match self.ops {
            Some(ops) => json::fmt_f64(ops / self.mean.as_secs_f64()),
            None => "null".to_string(),
        };
        format!(
            "{{\"name\":{},\"iters\":{},\"mean_ns\":{},\"median_ns\":{},\"min_ns\":{},\"max_ns\":{},\"ops_per_s\":{}}}",
            json::fmt_str(&self.name),
            self.iters,
            self.mean.as_nanos(),
            self.median.as_nanos(),
            self.min.as_nanos(),
            self.max.as_nanos(),
            ops_per_s,
        )
    }
}

/// Machine-readable output of one bench binary: every [`BenchResult`]
/// plus free-form scalar metrics (speedups, efficiencies, edge rates).
#[derive(Debug, Clone, Default)]
pub struct BenchReport {
    /// Bench binary name (drives the default `BENCH_<name>.json` path).
    pub bench: String,
    pub results: Vec<BenchResult>,
    pub metrics: BTreeMap<String, f64>,
}

impl BenchReport {
    pub fn new(bench: impl Into<String>) -> Self {
        Self {
            bench: bench.into(),
            results: Vec::new(),
            metrics: BTreeMap::new(),
        }
    }

    /// Record a result (also returns it for chained printing).
    pub fn push(&mut self, r: BenchResult) -> &BenchResult {
        self.results.push(r);
        self.results.last().expect("just pushed")
    }

    /// Record a named scalar metric.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.insert(name.to_string(), value);
    }

    pub fn to_json(&self) -> String {
        let results: Vec<String> = self.results.iter().map(BenchResult::to_json).collect();
        let metrics: Vec<String> = self
            .metrics
            .iter()
            .map(|(k, v)| format!("{}:{}", json::fmt_str(k), json::fmt_f64(*v)))
            .collect();
        format!(
            "{{\"bench\":{},\"results\":[{}],\"metrics\":{{{}}}}}",
            json::fmt_str(&self.bench),
            results.join(","),
            metrics.join(",")
        )
    }

    /// `BENCH_<bench>.json` in the current directory.
    pub fn default_path(&self) -> PathBuf {
        PathBuf::from(format!("BENCH_{}.json", self.bench))
    }

    /// Write the report to `path` (or the default path) and return the
    /// written location.
    pub fn write(&self, path: Option<&Path>) -> crate::Result<PathBuf> {
        let path = path
            .map(Path::to_path_buf)
            .unwrap_or_else(|| self.default_path());
        std::fs::write(&path, self.to_json() + "\n")
            .with_context(|| format!("writing bench report to {}", path.display()))?;
        Ok(path)
    }
}

/// The harness.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            iters: 10,
        }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self {
            warmup_iters,
            iters,
        }
    }

    /// Benchmark `f`, which receives the iteration index.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut(usize) -> T) -> BenchResult {
        for i in 0..self.warmup_iters {
            std::hint::black_box(f(i));
        }
        let mut times = Vec::with_capacity(self.iters);
        for i in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f(i));
            times.push(t0.elapsed());
        }
        times.sort();
        let sum: Duration = times.iter().sum();
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean: sum / self.iters as u32,
            median: times[self.iters / 2],
            min: times[0],
            max: times[self.iters - 1],
            ops: None,
        }
    }
}

/// Parsed bench CLI. Shares the [`crate::cli`] parser with the main
/// binary, so `--iters=N`, `--iters N`, `--json=path` and `--json path`
/// all work (the pre-unification `bench_args` only accepted the
/// space-separated form). Unknown flags — e.g. the `--bench` flag cargo
/// appends — are tolerated.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BenchArgs {
    /// Reduced iteration counts / windows for smoke runs.
    pub quick: bool,
    /// Explicit iteration-count override.
    pub iters: Option<usize>,
    /// Worker-thread override for benches with a parallel section
    /// (`0` = all cores, matching
    /// [`ClusterSpec::threads`](field@crate::cluster::ClusterSpec::threads)).
    pub threads: Option<usize>,
    /// Output path override for the bench's JSON report
    /// (default: `BENCH_<name>.json`).
    pub json: Option<PathBuf>,
}

impl BenchArgs {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> crate::Result<Self> {
        let args = crate::cli::Args::parse_from(raw)?;
        let parse_usize = |key: &str| -> crate::Result<Option<usize>> {
            match args.opt(key) {
                None => Ok(None),
                Some(v) => Ok(Some(v.parse().with_context(|| {
                    format!("--{key} must be an integer, got {v:?}")
                })?)),
            }
        };
        Ok(Self {
            quick: args.flag("quick"),
            iters: parse_usize("iters")?,
            threads: parse_usize("threads")?,
            json: args.opt("json").map(PathBuf::from),
        })
    }

    /// Parse the process arguments; exits with a usage message on error
    /// (bench binaries have no recovery path).
    pub fn from_env() -> Self {
        match Self::parse(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(e) => {
                eprintln!(
                    "bench arguments: {e:#}\nusage: [--quick] [--iters N] [--threads N] [--json PATH]"
                );
                std::process::exit(2);
            }
        }
    }

    /// JSON output path as `Option<&Path>` for [`BenchReport::write`].
    pub fn json_path(&self) -> Option<&Path> {
        self.json.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(1, 5);
        let r = b.run("spin", |_| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn report_contains_name() {
        let b = Bench::new(0, 3);
        let r = b.run("named", |_| 1);
        assert!(r.report().contains("named"));
    }

    fn parse(s: &str) -> BenchArgs {
        BenchArgs::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn bench_args_space_separated() {
        let a = parse("--quick --iters 7 --json out.json");
        assert!(a.quick);
        assert_eq!(a.iters, Some(7));
        assert_eq!(a.json, Some(PathBuf::from("out.json")));
    }

    #[test]
    fn bench_args_key_equals_value() {
        // The form PR 1's CLI learned and the old bench parser dropped.
        let a = parse("--iters=12 --json=BENCH_x.json");
        assert!(!a.quick);
        assert_eq!(a.iters, Some(12));
        assert_eq!(a.json, Some(PathBuf::from("BENCH_x.json")));
    }

    #[test]
    fn bench_args_tolerates_cargos_bench_flag() {
        let a = parse("--bench --quick");
        assert!(a.quick);
        assert_eq!(a.iters, None);
    }

    #[test]
    fn bench_args_rejects_bad_iters() {
        assert!(BenchArgs::parse(["--iters".to_string(), "abc".to_string()]).is_err());
        assert!(BenchArgs::parse(["--iters=1.5".to_string()]).is_err());
    }

    #[test]
    fn bench_args_threads() {
        assert_eq!(parse("--threads 0").threads, Some(0));
        assert_eq!(parse("--threads=4").threads, Some(4));
        assert_eq!(parse("").threads, None);
        assert!(BenchArgs::parse(["--threads=two".to_string()]).is_err());
    }

    #[test]
    fn bench_args_defaults() {
        let a = parse("");
        assert_eq!(a, BenchArgs::default());
    }

    #[test]
    fn result_json_roundtrips() {
        let r = BenchResult {
            name: "noc/\"quoted\"".to_string(),
            iters: 5,
            mean: Duration::from_nanos(1_500),
            median: Duration::from_nanos(1_400),
            min: Duration::from_nanos(1_000),
            max: Duration::from_nanos(2_000),
            ops: Some(3_000.0),
        };
        let v = json::parse(&r.to_json()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "noc/\"quoted\"");
        assert_eq!(v.get("mean_ns").unwrap().as_f64().unwrap(), 1_500.0);
        // ops/s = 3000 ops / 1.5 us = 2e9.
        let ops = v.get("ops_per_s").unwrap().as_f64().unwrap();
        assert!((ops - 2e9).abs() / 2e9 < 1e-9, "{ops}");
    }

    #[test]
    fn bench_report_json_roundtrips() {
        let b = Bench::new(0, 3);
        let mut rep = BenchReport::new("unit");
        rep.push(b.run("a", |_| 1));
        rep.push(b.run("b", |_| 2).with_ops(10.0));
        rep.metric("speedup", 3.75);
        let v = json::parse(&rep.to_json()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "unit");
        let results = v.get("results").unwrap().as_array().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].get("name").unwrap().as_str().unwrap(), "b");
        let m = v.get("metrics").unwrap().get("speedup").unwrap();
        assert_eq!(m.as_f64().unwrap(), 3.75);
    }

    #[test]
    fn bench_report_default_path() {
        assert_eq!(
            BenchReport::new("noc_microbench").default_path(),
            PathBuf::from("BENCH_noc_microbench.json")
        );
    }
}
