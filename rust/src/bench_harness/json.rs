//! Minimal JSON reader/writer (offline stand-in for serde_json).
//!
//! Just enough for the bench pipeline: [`parse`] handles objects,
//! arrays, strings (with escapes), numbers, booleans and null — the
//! full value grammar the bench reports and `ci/bench_baseline.json`
//! use — and the `fmt_*` helpers emit valid JSON scalars. Not a
//! general-purpose parser: no streaming, and numbers are `f64`.

use anyhow::bail;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }
}

/// JSON string literal (quoted, escaped).
pub fn fmt_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number (non-finite values become `null`, which JSON lacks).
pub fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> crate::Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing content at byte {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> crate::Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!(
            "expected {:?} at byte {} (found {:?})",
            c as char,
            *pos,
            b.get(*pos).map(|&x| x as char)
        )
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        bail!("unexpected end of input");
    };
    match c {
        b'{' => parse_object(b, pos),
        b'[' => parse_array(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> crate::Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("invalid literal at byte {}", *pos)
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).expect("ascii digits");
    match s.parse::<f64>() {
        Ok(n) => Ok(Json::Num(n)),
        Err(_) => bail!("invalid number {s:?} at byte {start}"),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> crate::Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            bail!("unterminated string");
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    bail!("unterminated escape");
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok());
                        let Some(code) = hex else {
                            bail!("invalid \\u escape at byte {}", *pos);
                        };
                        *pos += 4;
                        // Surrogate pairs unsupported (bench names are BMP).
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => bail!("unknown escape \\{} at byte {}", e as char, *pos - 1),
                }
            }
            _ => {
                // Re-assemble UTF-8 multibyte sequences verbatim.
                let len = utf8_len(c);
                if len == 1 {
                    out.push(c as char);
                } else {
                    let end = *pos - 1 + len;
                    if end > b.len() {
                        bail!("truncated UTF-8 sequence");
                    }
                    match std::str::from_utf8(&b[*pos - 1..end]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => bail!("invalid UTF-8 in string"),
                    }
                    *pos = end;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    expect(b, pos, b'{')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        out.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a b\"").unwrap(), Json::Str("a b".to_string()));
    }

    #[test]
    fn nested_document() {
        let doc = r#"{"a": [1, 2, {"b": "x\ny"}], "c": {"d": null}, "e": 1e3}"#;
        let v = parse(doc).unwrap();
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Null));
        assert_eq!(v.get("e").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn escapes_roundtrip_through_fmt_str() {
        let ugly = "q\"b\\s\nnl\ttab";
        let v = parse(&fmt_str(ugly)).unwrap();
        assert_eq!(v.as_str(), Some(ugly));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"caf\u{e9} \\u0041\"").unwrap();
        assert_eq!(v.as_str(), Some("café A"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn fmt_f64_handles_nonfinite() {
        assert_eq!(fmt_f64(2.5), "2.5");
        assert_eq!(fmt_f64(f64::NAN), "null");
        assert_eq!(fmt_f64(f64::INFINITY), "null");
    }
}
