//! Fig. 2: the FPGA floorplan of the paper's SoC instance —
//! CPU, MEM, I/O, eleven TGs, A1 = dfsin, A2 = gsm.

use crate::config::presets::paper_soc;
use crate::config::SocConfig;
use crate::resources::{Floorplan, XC7V2000T};

/// The paper's Fig. 2 instance.
pub fn fig2_config() -> SocConfig {
    paper_soc(("dfsin", 1), ("gsm", 1))
}

/// Compute and render the floorplan.
pub fn run() -> crate::Result<(String, Floorplan)> {
    let cfg = fig2_config();
    let fp = Floorplan::compute(&cfg, &XC7V2000T)?;
    let rendered = fp.render(&cfg);
    Ok((rendered, fp))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_renders_and_fits() {
        let (s, fp) = run().unwrap();
        assert!(fp.fits);
        assert!(s.contains("dfsin"));
        assert!(s.contains("gsm"));
        // 11 TG cells in the grid.
        assert_eq!(s.matches("TG").count(), 11, "{s}");
    }
}
