//! Fig. 3: throughput of 4x compute-bound (adpcm) and memory-bound
//! (dfmul) accelerators in the A2 tile versus the number of active TG
//! cores (0..=11), with the NoC at 10 MHz and accelerators/TGs at 50 MHz.
//!
//! Expected shape (paper): adpcm stays ~flat up to ~7 TGs; dfmul
//! collapses steeply from the first active TGs because the 10 MHz
//! NoC+MEM island caps deliverable bandwidth at ~40 MB/s, which the TGs
//! exhaust.

use crate::config::presets::{paper_soc, A2_POS, ISL_NOC};
use crate::report::Table;
use crate::scenario::{ScenarioSet, Session};
use crate::util::Ps;

/// One measured point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub tg_active: usize,
    pub thr_mbs: f64,
}

/// Measure `accel` (replication `k`) in A2 with `tg` active TGs.
///
/// Timing is invocation-aligned (time to complete a fixed invocation
/// count, not invocations per fixed window): with TGs off the replicas
/// run in lockstep and complete in bursts of `k`, which quantizes
/// window-based measurements badly.
pub fn measure_point(
    accel: &str,
    k: usize,
    tg: usize,
    warmup: Ps,
    window: Ps,
) -> crate::Result<Point> {
    let mut cfg = paper_soc(("dfadd", 1), (accel, k));
    cfg.islands[ISL_NOC].freq_mhz = 10; // NoC+MEM at 10 MHz (paper setup)
    let mut session = Session::new(cfg)?;
    let tile = session.tile_at(A2_POS.0, A2_POS.1);
    session.stage(tile, 1)?.perf_only().with_tg_load(tg);

    // Warmup: fill the replica pipelines (at least 2 invocation rounds),
    // then settle. Measure: whole invocation rounds, timed exactly.
    session
        .warmup_invocations(tile, 2 * k as u64, warmup.max(1) * 20)?
        .warmup(warmup);
    let rounds = 4u64;
    let report = session.measure_invocations(tile, rounds * k as u64, window * 40)?;
    Ok(Point {
        tg_active: tg,
        thr_mbs: report.throughput_mbs,
    })
}

/// Full Fig. 3 sweep for one accelerator: the 12 TG points run as
/// independent scenarios across threads, results in TG order.
pub fn sweep(accel: &str, k: usize, warmup: Ps, window: Ps) -> crate::Result<Vec<Point>> {
    sweep_points(accel, k, &(0..=11).collect::<Vec<_>>(), warmup, window)
}

/// Sweep an explicit list of TG counts.
pub fn sweep_points(
    accel: &str,
    k: usize,
    tg_counts: &[usize],
    warmup: Ps,
    window: Ps,
) -> crate::Result<Vec<Point>> {
    ScenarioSet::new(tg_counts.to_vec())
        .run_parallel(|&tg| measure_point(accel, k, tg, warmup, window))
}

/// Run the figure: both accelerators, rendered side by side.
pub fn run(warmup: Ps, window: Ps) -> crate::Result<(Table, Vec<Point>, Vec<Point>)> {
    let adpcm = sweep("adpcm", 4, warmup, window)?;
    let dfmul = sweep("dfmul", 4, warmup, window)?;
    let mut t = Table::new(
        "Fig. 3 — A2 throughput vs active TG cores (NoC@10MHz, accel@50MHz)",
        &["TGs", "adpcm 4x MB/s", "dfmul 4x MB/s"],
    );
    for i in 0..adpcm.len() {
        t.row(&[
            i.to_string(),
            format!("{:.2}", adpcm[i].thr_mbs),
            format!("{:.2}", dfmul[i].thr_mbs),
        ]);
    }
    Ok((t, adpcm, dfmul))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The figure's headline shape: dfmul (memory-bound) loses most of
    /// its throughput under full TG pressure; adpcm (compute-bound)
    /// barely moves with a few TGs active.
    #[test]
    fn memory_bound_collapses_compute_bound_holds() {
        let w = 2_000_000_000; // 2 ms warmup
        let win = 6_000_000_000; // 6 ms window
        let dfmul0 = measure_point("dfmul", 4, 0, w, win).unwrap().thr_mbs;
        let dfmul11 = measure_point("dfmul", 4, 11, w, win).unwrap().thr_mbs;
        assert!(
            dfmul11 < dfmul0 * 0.55,
            "dfmul should collapse: {dfmul0:.2} -> {dfmul11:.2}"
        );

        // adpcm 4x: one invocation takes ~23 ms per replica — the warmup
        // must cover the pipeline fill and the window several invocations.
        let aw = 30_000_000_000; // 30 ms warmup
        let awin = 50_000_000_000; // 50 ms window
        let adpcm0 = measure_point("adpcm", 4, 0, aw, awin).unwrap().thr_mbs;
        let adpcm4 = measure_point("adpcm", 4, 4, aw, awin).unwrap().thr_mbs;
        assert!(
            adpcm4 > adpcm0 * 0.8,
            "adpcm should hold: {adpcm0:.2} -> {adpcm4:.2}"
        );
    }

    /// The parallel sweep must agree point-for-point with serial
    /// measurement (each point is an independent, seeded simulation).
    #[test]
    fn parallel_sweep_matches_serial_points() {
        let w = 1_000_000_000;
        let win = 4_000_000_000;
        let tgs = [0usize, 6, 11];
        let par = sweep_points("dfmul", 2, &tgs, w, win).unwrap();
        for (i, &tg) in tgs.iter().enumerate() {
            let serial = measure_point("dfmul", 2, tg, w, win).unwrap();
            assert_eq!(par[i], serial, "tg={tg}");
        }
    }
}
