//! Paper-experiment runners, shared by the CLI (`vespa table1`, ...)
//! and the bench binaries (`cargo bench --bench table1`, ...).
//!
//! Each runner regenerates one table or figure of the paper's evaluation
//! (§III) on the simulated SoC and returns both the rendered report and
//! the raw numbers so benches/tests can assert the *shape* of the result
//! (who wins, by what factor, where crossovers fall).

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod table1;

use crate::monitor::CounterReg;
use crate::sim::Soc;
use crate::util::Ps;

/// Run until `tile` has completed `n` more invocations (or `cap` time
/// elapses). Returns elapsed ps.
pub fn run_until_invocations(soc: &mut Soc, tile: usize, n: u64, cap: Ps) -> Ps {
    let start = soc.now;
    let target = soc.host_read_counter(tile, CounterReg::Invocations) + n;
    let cap_t = start + cap;
    while soc.host_read_counter(tile, CounterReg::Invocations) < target && soc.now < cap_t {
        // 20 us slices: fine enough that the measurement window aligns
        // with invocation completion (sub-5% quantization even for the
        // fastest accelerators), coarse enough to amortize loop overhead.
        let next = (soc.now + 20_000_000).min(cap_t);
        soc.run_until(next);
    }
    soc.now - start
}
