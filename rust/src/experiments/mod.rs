//! Paper-experiment runners, shared by the CLI (`vespa table1`, ...)
//! and the bench binaries (`cargo bench --bench table1`, ...).
//!
//! Each runner regenerates one table or figure of the paper's evaluation
//! (§III) on the simulated SoC and returns both the rendered report and
//! the raw numbers so benches/tests can assert the *shape* of the result
//! (who wins, by what factor, where crossovers fall).
//!
//! All measurement choreography goes through [`crate::scenario::Session`]
//! (stage → warmup → measure); the multi-point experiments (`fig3`,
//! `table1`) fan their independent simulations out across threads with
//! [`crate::scenario::ScenarioSet`].

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod table1;

// Historical home of this helper; it now lives with the Session API.
pub use crate::scenario::run_until_invocations;
