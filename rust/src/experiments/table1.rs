//! Table I: FPGA resource utilization and throughput of baseline and
//! 2x/4x multi-replica accelerator tiles.
//!
//! Setup per the paper (§III-A): accelerator in A1 (adjacent to MEM),
//! NoC+MEM island at 100 MHz, accelerator island at 50 MHz, all TGs
//! disabled — best-case throughput. The 15 (accelerator, K) cells are
//! independent simulations and run across threads via [`ScenarioSet`].

use crate::config::presets::{paper_soc, A1_POS};
use crate::report::Table;
use crate::resources::{mra_area, AccelArea, Utilization};
use crate::scenario::{ScenarioSet, Session};

/// Paper throughput values (MB/s) for comparison: (accel, [1x, 2x, 4x]).
pub const PAPER_THR: [(&str, [f64; 3]); 5] = [
    ("adpcm", [1.40, 2.76, 5.41]),
    ("dfadd", [9.22, 16.88, 26.06]),
    ("dfmul", [8.70, 15.07, 26.06]),
    ("dfsin", [0.33, 0.65, 1.24]),
    ("gsm", [4.61, 8.90, 16.67]),
];

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub accel: String,
    pub k: usize,
    pub area: Utilization,
    pub thr_mbs: f64,
    pub paper_thr_mbs: f64,
}

/// Measure the throughput of `accel` at replication `k` (A1 placement).
pub fn measure_throughput(accel: &str, k: usize, invocations: u64) -> crate::Result<f64> {
    let cfg = paper_soc((accel, k), ("dfadd", 1));
    let mut session = Session::new(cfg)?;
    let tile = session.tile_at(A1_POS.0, A1_POS.1);
    session.stage(tile, 1)?.perf_only();

    // Warm up: let the first invocations fill the pipeline; then time a
    // whole number of invocations exactly.
    session.warmup_invocations(tile, k as u64, 400_000_000_000)?;
    let report = session.measure_invocations(tile, invocations, 2_000_000_000_000)?;
    Ok(report.throughput_mbs)
}

/// Run the full Table I reproduction. `invocations` controls the
/// measurement window (larger = tighter estimates). The 15 cells
/// evaluate in parallel, in deterministic row order.
pub fn run(invocations: u64) -> crate::Result<(Table, Vec<Row>)> {
    let mut cells = Vec::new();
    for (accel, paper) in PAPER_THR {
        for (ki, &k) in [1usize, 2, 4].iter().enumerate() {
            cells.push((accel, k, paper[ki]));
        }
    }
    let rows = ScenarioSet::new(cells).run_parallel(|&(accel, k, paper_thr)| {
        let thr = measure_throughput(accel, k, invocations * k as u64)?;
        Ok(Row {
            accel: accel.to_string(),
            k,
            area: mra_area(&AccelArea::lookup(accel)?, k),
            thr_mbs: thr,
            paper_thr_mbs: paper_thr,
        })
    })?;

    let mut t = Table::new(
        "Table I — FPGA resources and throughput of 1x/2x/4x MRA tiles",
        &[
            "accel", "K", "LUT", "FF", "BRAM", "DSP", "thr MB/s", "paper MB/s", "ratio",
        ],
    );
    for r in &rows {
        t.row(&[
            r.accel.clone(),
            r.k.to_string(),
            r.area.lut.to_string(),
            r.area.ff.to_string(),
            r.area.bram.to_string(),
            r.area.dsp.to_string(),
            format!("{:.2}", r.thr_mbs),
            format!("{:.2}", r.paper_thr_mbs),
            format!("{:.2}", r.thr_mbs / r.paper_thr_mbs),
        ]);
    }
    Ok((t, rows))
}

/// Average throughput increments vs. baseline (the table's "Incr." row).
pub fn average_increments(rows: &[Row]) -> (f64, f64) {
    let mut r2 = 0.0;
    let mut r4 = 0.0;
    let mut n = 0.0;
    for chunk in rows.chunks(3) {
        let base = chunk[0].thr_mbs;
        if base > 0.0 {
            r2 += chunk[1].thr_mbs / base;
            r4 += chunk[2].thr_mbs / base;
            n += 1.0;
        }
    }
    (r2 / n, r4 / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline calibration check: simulated 1x dfmul throughput in
    /// the Table I scenario lands near the paper's 8.70 MB/s.
    #[test]
    fn dfmul_baseline_near_paper() {
        let thr = measure_throughput("dfmul", 1, 6).unwrap();
        assert!(
            (thr - 8.70).abs() / 8.70 < 0.15,
            "dfmul 1x: {thr:.2} MB/s vs paper 8.70"
        );
    }

    /// Replication must scale throughput: 2x strictly faster than 1x.
    #[test]
    fn replication_scales_dfadd() {
        let t1 = measure_throughput("dfadd", 1, 4).unwrap();
        let t2 = measure_throughput("dfadd", 2, 8).unwrap();
        let ratio = t2 / t1;
        assert!(
            (1.5..=2.1).contains(&ratio),
            "2x/1x ratio {ratio:.2} (t1 {t1:.2}, t2 {t2:.2})"
        );
    }
}
