//! Fig. 4: memory incoming traffic (Mpkt/s) over time while stepping
//! the frequency islands at run time.
//!
//! Both A1 and A2 carry 4x dfmul; all TGs are active. The frequency
//! program steps (a) the accelerator islands through 10/30/50 MHz —
//! which the paper shows to have *negligible* impact on memory traffic —
//! and then (b) the TG island and NoC+MEM island up — which increases
//! memory pressure drastically.

use crate::config::presets::{paper_soc, ISL_A1, ISL_A2, ISL_NOC, ISL_TG};
use crate::monitor::TimeSeries;
use crate::report::Table;
use crate::scenario::Session;
use crate::util::Ps;

/// A phase of the frequency program.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub accel_mhz: u64,
    pub tg_mhz: u64,
    pub noc_mhz: u64,
}

/// The experiment's phase program (compressed from the paper's run).
pub const PHASES: [Phase; 6] = [
    // (a) accel frequency steps, TG+NoC low: traffic ~flat.
    Phase { accel_mhz: 10, tg_mhz: 10, noc_mhz: 20 },
    Phase { accel_mhz: 30, tg_mhz: 10, noc_mhz: 20 },
    Phase { accel_mhz: 50, tg_mhz: 10, noc_mhz: 20 },
    // (b) TG and NoC step up: traffic rises drastically.
    Phase { accel_mhz: 50, tg_mhz: 30, noc_mhz: 50 },
    Phase { accel_mhz: 50, tg_mhz: 50, noc_mhz: 100 },
    Phase { accel_mhz: 50, tg_mhz: 50, noc_mhz: 100 },
];

/// Result: sampled series plus per-phase mean traffic.
pub struct Fig4Result {
    pub pkts_rate: TimeSeries,
    pub freq_series: Vec<TimeSeries>,
    pub phase_mpkts: Vec<f64>,
    pub phase_len: Ps,
}

/// Run the experiment. `phase_len` is the duration of each phase.
pub fn run(phase_len: Ps, sample_interval: Ps) -> crate::Result<Fig4Result> {
    let mut cfg = paper_soc(("dfmul", 4), ("dfmul", 4));
    cfg.islands[ISL_NOC].freq_mhz = 20;
    cfg.islands[ISL_A1].freq_mhz = 10;
    cfg.islands[ISL_A2].freq_mhz = 10;
    cfg.islands[ISL_TG].freq_mhz = 10;
    let mut session = Session::new(cfg)?;
    session
        .stage_all(1)?
        .perf_only()
        .with_tg_load(11)
        .sample_every(sample_interval);

    for (i, ph) in PHASES.iter().enumerate() {
        let t0 = i as u64 * phase_len;
        session
            .schedule_freq(t0, ISL_A1, ph.accel_mhz)
            .schedule_freq(t0, ISL_A2, ph.accel_mhz)
            .schedule_freq(t0, ISL_TG, ph.tg_mhz)
            .schedule_freq(t0, ISL_NOC, ph.noc_mhz);
    }
    session.run_until(PHASES.len() as u64 * phase_len);

    let soc = session.soc();
    let sampler = soc.sampler.as_ref().expect("sampler enabled");
    let pkts = sampler.series("mem_pkts_in").unwrap().clone();
    let rate = pkts.to_rate();
    let freq_series: Vec<TimeSeries> = sampler.series.iter().skip(1).cloned().collect();

    // Mean Mpkt/s per phase (skip the first third of each phase: DFS
    // actuator latency + settling).
    let mut phase_mpkts = Vec::new();
    for i in 0..PHASES.len() {
        let lo = i as u64 * phase_len + phase_len / 3;
        let hi = (i as u64 + 1) * phase_len;
        phase_mpkts.push(rate.mean_in(lo, hi) / 1e6);
    }

    Ok(Fig4Result {
        pkts_rate: rate,
        freq_series,
        phase_mpkts,
        phase_len,
    })
}

/// Render the per-phase summary table.
pub fn render_table(r: &Fig4Result) -> Table {
    let mut t = Table::new(
        "Fig. 4 — memory incoming traffic vs island frequencies",
        &["phase", "accel MHz", "TG MHz", "NoC MHz", "Mpkt/s"],
    );
    for (i, ph) in PHASES.iter().enumerate() {
        t.row(&[
            i.to_string(),
            ph.accel_mhz.to_string(),
            ph.tg_mhz.to_string(),
            ph.noc_mhz.to_string(),
            format!("{:.3}", r.phase_mpkts[i]),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shape check: accel-frequency steps (phases 0->2) move traffic by
    /// far less than the TG/NoC steps (phases 2->4).
    #[test]
    fn tg_noc_dominate_memory_traffic() {
        let r = run(30_000_000_000, 1_000_000_000).unwrap(); // 30 ms phases
        let accel_delta = (r.phase_mpkts[2] - r.phase_mpkts[0]).abs();
        let tg_delta = r.phase_mpkts[4] - r.phase_mpkts[2];
        assert!(
            tg_delta > 3.0 * accel_delta.max(0.001),
            "TG/NoC delta {tg_delta:.3} vs accel delta {accel_delta:.3} (phases {:?})",
            r.phase_mpkts
        );
        assert!(r.phase_mpkts[4] > r.phase_mpkts[0], "{:?}", r.phase_mpkts);
    }
}
