//! Functional data blocks.
//!
//! The NoC timing model moves flits; the *numbers* an accelerator
//! consumes and produces live here. A [`Block`] is one accelerator-stream
//! buffer (f32 or i32 words); DMA messages reference blocks by id, the
//! MRA tile hands them to the PJRT executable, and results come back as
//! new blocks. The store is free-listed so steady-state simulation does
//! not allocate.

/// Handle to a block in the store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub u32);

/// A typed buffer of words (one AXI stream's worth of data).
#[derive(Debug, Clone, PartialEq)]
pub enum Block {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Block {
    /// Number of 32-bit words.
    pub fn words(&self) -> usize {
        match self {
            Block::F32(v) => v.len(),
            Block::I32(v) => v.len(),
        }
    }

    /// Size in bytes.
    pub fn bytes(&self) -> usize {
        self.words() * 4
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Block::F32(v) => Some(v),
            Block::I32(_) => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Block::I32(v) => Some(v),
            Block::F32(_) => None,
        }
    }
}

/// Free-listed arena of blocks.
#[derive(Debug, Default, Clone)]
pub struct BlockStore {
    slots: Vec<Option<Block>>,
    free: Vec<u32>,
    live: usize,
}

impl BlockStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, b: Block) -> BlockId {
        self.live += 1;
        if let Some(i) = self.free.pop() {
            self.slots[i as usize] = Some(b);
            BlockId(i)
        } else {
            self.slots.push(Some(b));
            BlockId((self.slots.len() - 1) as u32)
        }
    }

    pub fn get(&self, id: BlockId) -> &Block {
        self.slots[id.0 as usize]
            .as_ref()
            .expect("use of released block")
    }

    pub fn get_mut(&mut self, id: BlockId) -> &mut Block {
        self.slots[id.0 as usize]
            .as_mut()
            .expect("use of released block")
    }

    pub fn release(&mut self, id: BlockId) {
        assert!(
            self.slots[id.0 as usize].take().is_some(),
            "double release of block {id:?}"
        );
        self.free.push(id.0);
        self.live -= 1;
    }

    pub fn live(&self) -> usize {
        self.live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_release() {
        let mut s = BlockStore::new();
        let id = s.insert(Block::F32(vec![1.0, 2.0]));
        assert_eq!(s.get(id).words(), 2);
        assert_eq!(s.get(id).bytes(), 8);
        s.release(id);
        assert_eq!(s.live(), 0);
    }

    #[test]
    fn slots_reused() {
        let mut s = BlockStore::new();
        let a = s.insert(Block::I32(vec![1]));
        s.release(a);
        let b = s.insert(Block::I32(vec![2]));
        assert_eq!(a.0, b.0);
        assert_eq!(s.get(b).as_i32().unwrap(), &[2]);
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut s = BlockStore::new();
        let a = s.insert(Block::I32(vec![1]));
        s.release(a);
        s.release(a);
    }

    #[test]
    fn typed_accessors() {
        let b = Block::F32(vec![1.5]);
        assert!(b.as_f32().is_some());
        assert!(b.as_i32().is_none());
    }
}
