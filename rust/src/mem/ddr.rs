//! DDR memory-controller timing model.
//!
//! The MEM tile terminates DMA requests from the whole SoC. The model is
//! a single-channel controller: a bounded request queue, a fixed access
//! latency (row activation + CAS, folded into one constant), and a data
//! bus that produces one beat per controller cycle. The controller runs
//! at the NoC island's clock (as in the paper, where the NoC interconnect
//! and memory controller share a frequency island) — which is exactly why
//! running the NoC island at 10 MHz caps deliverable bandwidth at
//! 4 B x 10 MHz = 40 MB/s and produces Fig. 3's memory-bound collapse.
//!
//! Requests are served in arrival order (the NoC's round-robin fairness
//! upstream already interleaves requesters), one burst occupying the bus
//! for its full beat count — so concurrent requesters share bandwidth
//! approximately fairly, the property Fig. 3 and Fig. 4 rely on.

use std::collections::VecDeque;

use crate::util::Ps;

/// Controller parameters.
#[derive(Debug, Clone)]
pub struct MemParams {
    /// Fixed service latency in controller cycles (activation + CAS +
    /// controller pipeline). ESP's MIG path is ~20-30 cycles.
    pub access_cycles: u64,
    /// Request queue depth; requests beyond this are back-pressured into
    /// the NoC (the ejection FIFO stops draining).
    pub queue_depth: usize,
}

impl Default for MemParams {
    fn default() -> Self {
        Self {
            // Per-burst overhead: controller pipeline + (amortized) row
            // activation. 12 cycles over a 16-beat burst ~= the ~55-60%
            // streaming efficiency of a MIG-class controller.
            access_cycles: 12,
            // Deep enough to absorb every requester's outstanding bursts
            // (11 TGs x 4 + 4 replicas x 4): service order then follows
            // arrival order and closed-loop bandwidth sharing becomes
            // proportional to each requester's outstanding budget — the
            // fairness Figs. 3-4 rely on.
            queue_depth: 64,
        }
    }
}

/// A DMA burst enqueued at the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemRequest {
    pub addr: u64,
    pub beats: u16,
    pub is_write: bool,
    /// Opaque routing info echoed in the response (source node, tag).
    pub src: u16,
    pub tag: u32,
    /// Functional payload reference for reads (block to serve data from)
    /// — carried through untouched.
    pub block: u32,
    pub offset: u32,
}

/// A completed burst ready to be packetized back into the NoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemResponse {
    pub req: MemRequest,
    /// Completion time (last beat leaves the controller).
    pub done_at: Ps,
}

/// Controller statistics (Fig. 4's "incoming packets to memory" counter
/// lives at the MEM tile NI; these are internal-quality counters).
#[derive(Debug, Clone, Copy, Default)]
pub struct MemStats {
    pub reads: u64,
    pub writes: u64,
    pub beats: u64,
    /// Cycles the data bus was busy.
    pub busy_cycles: u64,
    /// Peak queue occupancy observed.
    pub peak_queue: usize,
}

/// The controller.
#[derive(Debug, Clone)]
pub struct MemController {
    params: MemParams,
    queue: VecDeque<(Ps, MemRequest)>, // (arrival, request)
    /// Time the data bus becomes free.
    bus_free_at: Ps,
    done: VecDeque<MemResponse>,
    pub stats: MemStats,
}

impl MemController {
    pub fn new(params: MemParams) -> Self {
        Self {
            params,
            queue: VecDeque::new(),
            bus_free_at: 0,
            done: VecDeque::new(),
            stats: MemStats::default(),
        }
    }

    /// Whether a new request can be accepted (queue not full).
    pub fn can_accept(&self) -> bool {
        self.queue.len() < self.params.queue_depth
    }

    /// Enqueue a request arriving at `now`.
    pub fn accept(&mut self, req: MemRequest, now: Ps) {
        assert!(self.can_accept(), "mem queue overflow");
        self.queue.push_back((now, req));
        self.stats.peak_queue = self.stats.peak_queue.max(self.queue.len());
    }

    /// One controller cycle at `now` with the island's current `period`.
    /// Starts at most one burst per cycle; completed bursts move to the
    /// response queue.
    pub fn tick(&mut self, now: Ps, period: Ps) {
        if let Some(&(_arrival, req)) = self.queue.front() {
            // The burst can start once the bus is free and the fixed
            // access latency has elapsed from *service start* (modelled
            // as: completion = max(now, bus_free) + access + beats).
            if self.bus_free_at <= now {
                self.queue.pop_front();
                let start = now + self.params.access_cycles * period;
                let done_at = start + req.beats as u64 * period;
                self.bus_free_at = done_at;
                self.stats.beats += req.beats as u64;
                self.stats.busy_cycles += self.params.access_cycles + req.beats as u64;
                if req.is_write {
                    self.stats.writes += 1;
                } else {
                    self.stats.reads += 1;
                }
                self.done.push_back(MemResponse { req, done_at });
            }
        }
    }

    /// Pop a response whose data has fully left the controller by `now`.
    pub fn pop_done(&mut self, now: Ps) -> Option<MemResponse> {
        match self.done.front() {
            Some(r) if r.done_at <= now => self.done.pop_front(),
            _ => None,
        }
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn pending_responses(&self) -> usize {
        self.done.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(beats: u16, tag: u32) -> MemRequest {
        MemRequest {
            addr: 0x1000,
            beats,
            is_write: false,
            src: 3,
            tag,
            block: 0,
            offset: 0,
        }
    }

    #[test]
    fn single_burst_latency() {
        let mut m = MemController::new(MemParams {
            access_cycles: 10,
            queue_depth: 4,
        });
        let period = 10_000; // 100 MHz
        m.accept(req(16, 1), 0);
        m.tick(0, period);
        // done = 0 + (10 + 16) * 10_000
        assert!(m.pop_done(259_999).is_none());
        let r = m.pop_done(260_000).unwrap();
        assert_eq!(r.req.tag, 1);
        assert_eq!(m.stats.reads, 1);
        assert_eq!(m.stats.beats, 16);
    }

    #[test]
    fn bursts_serialize_on_bus() {
        let mut m = MemController::new(MemParams {
            access_cycles: 0,
            queue_depth: 4,
        });
        let period = 10_000;
        m.accept(req(4, 1), 0);
        m.accept(req(4, 2), 0);
        m.tick(0, period); // burst 1: done at 40_000
        m.tick(10_000, period); // bus busy, nothing starts
        assert_eq!(m.pending_responses(), 1);
        m.tick(40_000, period); // burst 2: done at 80_000
        let r1 = m.pop_done(40_000).unwrap();
        assert_eq!(r1.req.tag, 1);
        let r2 = m.pop_done(80_000).unwrap();
        assert_eq!(r2.req.tag, 2);
    }

    #[test]
    fn queue_backpressure() {
        let mut m = MemController::new(MemParams {
            access_cycles: 0,
            queue_depth: 2,
        });
        m.accept(req(1, 1), 0);
        m.accept(req(1, 2), 0);
        assert!(!m.can_accept());
    }

    #[test]
    fn slower_clock_slower_service() {
        // Same burst at 100 MHz vs 10 MHz: 10x the service time — the
        // Fig. 3/4 mechanism in miniature.
        for (period, expect) in [(10_000u64, 200_000u64), (100_000, 2_000_000)] {
            let mut m = MemController::new(MemParams {
                access_cycles: 4,
                queue_depth: 4,
            });
            m.accept(req(16, 9), 0);
            m.tick(0, period);
            assert!(m.pop_done(expect - 1).is_none());
            assert!(m.pop_done(expect).is_some());
        }
    }

    #[test]
    fn write_counted_separately() {
        let mut m = MemController::new(MemParams::default());
        let mut w = req(8, 5);
        w.is_write = true;
        m.accept(w, 0);
        m.tick(0, 10_000);
        assert_eq!(m.stats.writes, 1);
        assert_eq!(m.stats.reads, 0);
    }
}
