//! Memory subsystem: the functional backing store ([`BlockStore`]) and
//! the DDR controller timing model ([`MemController`]) that lives in the
//! MEM tile.

pub mod blocks;
pub mod ddr;

pub use blocks::{Block, BlockId, BlockStore};
pub use ddr::{MemController, MemParams, MemRequest, MemResponse, MemStats};
