//! Run-time monitoring infrastructure (paper contribution 3).
//!
//! Each accelerator tile carries four selectively-enabled hardware
//! counters — execution time, incoming packets, outgoing packets, and
//! DMA round-trip time — exposed as memory-mapped registers reachable
//! both from software on the SoC's CPU tile and from the host through
//! the I/O tile (the proFPGA USB-serial path on the real system).
//!
//! The execution-time counter resets automatically when the accelerator
//! starts computing and stops when it completes; the other three are
//! reset manually through the CTRL register (§II-C).

pub mod counters;
pub mod mmio;
pub mod sampler;

pub use counters::{AccelCounters, CounterSel, MonitorFile};
pub use mmio::{decode, CounterReg, MmioTarget, FREQ_BASE, MONITOR_BASE, TILE_STRIDE};
pub use sampler::{Sample, Sampler, TimeSeries};
