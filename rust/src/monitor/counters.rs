//! Per-accelerator-tile hardware counters.

use crate::util::Ps;

/// Selectable statistics (§II-C: up to four per accelerator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum CounterSel {
    ExecTime = 0,
    PktsIn = 1,
    PktsOut = 2,
    RoundTrip = 3,
}

/// Counter block of one accelerator tile.
#[derive(Debug, Clone, Default)]
pub struct AccelCounters {
    /// Enable mask (bit per [`CounterSel`]). Disabled counters hold.
    pub enable: u8,
    /// Execution time in island-clock cycles. Auto-resets when the tile
    /// starts a computation, stops when it completes.
    pub exec_cycles: u64,
    /// Wall-clock span of the last/current computation (ps), to convert
    /// cycle counts under DFS into time.
    pub exec_start: Ps,
    pub exec_end: Ps,
    /// Whether a computation is in flight (exec counter running).
    pub running: bool,
    /// NoC packets into the tile (manually reset).
    pub pkts_in: u64,
    /// NoC packets out of the tile (manually reset).
    pub pkts_out: u64,
    /// Sum of DMA read round-trip times (ps) and completed round-trips.
    pub rtt_sum: u64,
    pub rtt_count: u64,
    /// Completed accelerator invocations (drives throughput readouts).
    pub invocations: u64,
}

impl AccelCounters {
    pub fn new() -> Self {
        Self {
            enable: 0x0F, // all four statistics enabled by default
            ..Self::default()
        }
    }

    fn enabled(&self, sel: CounterSel) -> bool {
        self.enable & (1 << sel as u8) != 0
    }

    /// Computation started: auto-reset and run the exec-time counter.
    pub fn on_start(&mut self, now: Ps) {
        if self.enabled(CounterSel::ExecTime) {
            self.exec_cycles = 0;
            self.exec_start = now;
            self.exec_end = now;
            self.running = true;
        }
    }

    /// One island-clock cycle elapsed while computing.
    pub fn on_exec_cycle(&mut self) {
        if self.running {
            self.exec_cycles += 1;
        }
    }

    /// `n` island-clock cycles elapsed while computing — bulk credit for
    /// cycles the idle-aware engine skipped while the tile's only work
    /// was a running computation.
    pub fn on_exec_cycles(&mut self, n: u64) {
        if self.running {
            self.exec_cycles += n;
        }
    }

    /// Computation completed: stop the exec-time counter.
    pub fn on_complete(&mut self, now: Ps) {
        if self.running {
            self.exec_end = now;
            self.running = false;
        }
    }

    /// One accelerator invocation (replica block computation) finished.
    pub fn on_invocation(&mut self) {
        self.invocations += 1;
    }

    pub fn on_pkt_in(&mut self) {
        if self.enabled(CounterSel::PktsIn) {
            self.pkts_in += 1;
        }
    }

    pub fn on_pkt_out(&mut self) {
        if self.enabled(CounterSel::PktsOut) {
            self.pkts_out += 1;
        }
    }

    /// A DMA read round-trip completed (request issue -> data arrival).
    pub fn on_round_trip(&mut self, rtt: Ps) {
        if self.enabled(CounterSel::RoundTrip) {
            self.rtt_sum += rtt;
            self.rtt_count += 1;
        }
    }

    /// Manual reset (CTRL bit 1): clears the manually-reset counters
    /// (§II-C — all but exec time, which auto-resets).
    pub fn manual_reset(&mut self) {
        self.pkts_in = 0;
        self.pkts_out = 0;
        self.rtt_sum = 0;
        self.rtt_count = 0;
        self.invocations = 0;
    }

    /// Mean round-trip time in ps (0 when no samples).
    pub fn rtt_mean(&self) -> f64 {
        if self.rtt_count == 0 {
            0.0
        } else {
            self.rtt_sum as f64 / self.rtt_count as f64
        }
    }
}

/// All monitor blocks of the SoC, indexed by tile.
#[derive(Debug, Default, Clone)]
pub struct MonitorFile {
    pub tiles: Vec<AccelCounters>,
    /// Packets delivered to the MEM tile (Fig. 4's incoming-traffic
    /// counter), kept at SoC scope because the MEM tile is unique.
    pub mem_pkts_in: u64,
    /// Data beats delivered to the MEM tile.
    pub mem_beats_in: u64,
}

impl MonitorFile {
    pub fn new(tiles: usize) -> Self {
        Self {
            tiles: (0..tiles).map(|_| AccelCounters::new()).collect(),
            mem_pkts_in: 0,
            mem_beats_in: 0,
        }
    }

    pub fn tile(&self, i: usize) -> &AccelCounters {
        &self.tiles[i]
    }

    pub fn tile_mut(&mut self, i: usize) -> &mut AccelCounters {
        &mut self.tiles[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_time_auto_resets_on_start() {
        let mut c = AccelCounters::new();
        c.on_start(1000);
        for _ in 0..5 {
            c.on_exec_cycle();
        }
        c.on_complete(6000);
        c.on_invocation();
        assert_eq!(c.exec_cycles, 5);
        assert_eq!(c.invocations, 1);
        c.on_start(7000);
        assert_eq!(c.exec_cycles, 0, "auto reset");
        assert!(c.running);
    }

    #[test]
    fn disabled_counters_hold() {
        let mut c = AccelCounters::new();
        c.enable = 0; // everything off
        c.on_pkt_in();
        c.on_round_trip(100);
        c.on_start(0);
        c.on_exec_cycle();
        assert_eq!(c.pkts_in, 0);
        assert_eq!(c.rtt_count, 0);
        assert_eq!(c.exec_cycles, 0);
    }

    #[test]
    fn manual_reset_spares_exec_time() {
        let mut c = AccelCounters::new();
        c.on_start(0);
        c.on_exec_cycle();
        c.on_pkt_in();
        c.on_pkt_out();
        c.on_round_trip(500);
        c.manual_reset();
        assert_eq!(c.pkts_in, 0);
        assert_eq!(c.pkts_out, 0);
        assert_eq!(c.rtt_count, 0);
        assert_eq!(c.exec_cycles, 1, "exec time is auto-reset only");
    }

    #[test]
    fn rtt_mean() {
        let mut c = AccelCounters::new();
        assert_eq!(c.rtt_mean(), 0.0);
        c.on_round_trip(100);
        c.on_round_trip(300);
        assert_eq!(c.rtt_mean(), 200.0);
    }
}
