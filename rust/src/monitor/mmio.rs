//! Memory map of the monitoring and frequency registers.
//!
//! ```text
//! 0x6000_0000 + island*0x10 + 0x0   FREQ   (R/W, MHz)
//! 0x6000_0000 + island*0x10 + 0x8   BUSY   (R, DFS actuator in flight)
//! 0x8000_0000 + tile*0x100  + 0x00  CTRL   (bit0 enable-mask write strobe,
//!                                           bit1 manual counter reset)
//! 0x8000_0000 + tile*0x100  + 0x08  EXEC_TIME   (island cycles)
//! 0x8000_0000 + tile*0x100  + 0x10  PKTS_IN
//! 0x8000_0000 + tile*0x100  + 0x18  PKTS_OUT
//! 0x8000_0000 + tile*0x100  + 0x20  RTT_SUM     (ps)
//! 0x8000_0000 + tile*0x100  + 0x28  RTT_CNT
//! 0x8000_0000 + tile*0x100  + 0x30  INVOCATIONS
//! ```

/// Base of the frequency-register block (owned by the I/O tile).
pub const FREQ_BASE: u64 = 0x6000_0000;
/// Stride between islands' register pairs.
pub const FREQ_STRIDE: u64 = 0x10;
/// Base of the per-tile monitor blocks.
pub const MONITOR_BASE: u64 = 0x8000_0000;
/// Stride between tiles' monitor blocks.
pub const TILE_STRIDE: u64 = 0x100;

/// Registers within a tile's monitor block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterReg {
    Ctrl,
    ExecTime,
    PktsIn,
    PktsOut,
    RttSum,
    RttCnt,
    Invocations,
}

/// Decoded MMIO target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmioTarget {
    IslandFreq(usize),
    IslandBusy(usize),
    Counter(usize, CounterReg),
    Invalid,
}

/// Address of an island's FREQ register.
pub fn island_freq_addr(island: usize) -> u64 {
    FREQ_BASE + island as u64 * FREQ_STRIDE
}

/// Address of a tile counter register.
pub fn counter_addr(tile: usize, reg: CounterReg) -> u64 {
    let off = match reg {
        CounterReg::Ctrl => 0x00,
        CounterReg::ExecTime => 0x08,
        CounterReg::PktsIn => 0x10,
        CounterReg::PktsOut => 0x18,
        CounterReg::RttSum => 0x20,
        CounterReg::RttCnt => 0x28,
        CounterReg::Invocations => 0x30,
    };
    MONITOR_BASE + tile as u64 * TILE_STRIDE + off
}

/// Decode an MMIO address.
pub fn decode(addr: u64) -> MmioTarget {
    if (FREQ_BASE..MONITOR_BASE).contains(&addr) {
        let off = addr - FREQ_BASE;
        let island = (off / FREQ_STRIDE) as usize;
        match off % FREQ_STRIDE {
            0x0 => MmioTarget::IslandFreq(island),
            0x8 => MmioTarget::IslandBusy(island),
            _ => MmioTarget::Invalid,
        }
    } else if addr >= MONITOR_BASE {
        let off = addr - MONITOR_BASE;
        let tile = (off / TILE_STRIDE) as usize;
        let reg = match off % TILE_STRIDE {
            0x00 => CounterReg::Ctrl,
            0x08 => CounterReg::ExecTime,
            0x10 => CounterReg::PktsIn,
            0x18 => CounterReg::PktsOut,
            0x20 => CounterReg::RttSum,
            0x28 => CounterReg::RttCnt,
            0x30 => CounterReg::Invocations,
            _ => return MmioTarget::Invalid,
        };
        MmioTarget::Counter(tile, reg)
    } else {
        MmioTarget::Invalid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_freq() {
        for island in 0..8 {
            assert_eq!(
                decode(island_freq_addr(island)),
                MmioTarget::IslandFreq(island)
            );
            assert_eq!(
                decode(island_freq_addr(island) + 8),
                MmioTarget::IslandBusy(island)
            );
        }
    }

    #[test]
    fn roundtrip_counters() {
        use CounterReg::*;
        for tile in [0usize, 3, 15] {
            for reg in [Ctrl, ExecTime, PktsIn, PktsOut, RttSum, RttCnt, Invocations] {
                assert_eq!(
                    decode(counter_addr(tile, reg)),
                    MmioTarget::Counter(tile, reg)
                );
            }
        }
    }

    #[test]
    fn invalid_addresses() {
        assert_eq!(decode(0x1000), MmioTarget::Invalid);
        assert_eq!(decode(FREQ_BASE + 0xC), MmioTarget::Invalid);
        assert_eq!(decode(MONITOR_BASE + 0x48), MmioTarget::Invalid);
    }
}
