//! Host-side periodic sampling of monitor counters into time series —
//! the mechanism behind Fig. 4's traffic-vs-time plot.

use crate::util::Ps;

/// One sampled point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub t: Ps,
    pub value: f64,
}

/// A named time series.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimeSeries {
    pub name: String,
    pub samples: Vec<Sample>,
}

impl TimeSeries {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            samples: Vec::new(),
        }
    }

    pub fn push(&mut self, t: Ps, value: f64) {
        self.samples.push(Sample { t, value });
    }

    /// Convert a cumulative-counter series into a rate series
    /// (delta value / delta time, per second). Empty and single-sample
    /// series have no deltas and convert to an empty series; pairs with
    /// a non-increasing timestamp contribute nothing (never NaN/inf).
    pub fn to_rate(&self) -> TimeSeries {
        let mut out = TimeSeries::new(format!("{}_rate", self.name));
        if self.samples.len() < 2 {
            return out;
        }
        for w in self.samples.windows(2) {
            let dt = w[1].t.saturating_sub(w[0].t) as f64 / 1e12; // ps -> s
            if dt > 0.0 {
                out.push(w[1].t, (w[1].value - w[0].value) / dt);
            }
        }
        out
    }

    /// Largest sample value (0.0 for an empty series).
    pub fn max(&self) -> f64 {
        self.samples.iter().map(|s| s.value).fold(0.0, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.value).sum::<f64>() / self.samples.len() as f64
    }

    /// Mean of the samples with `t` in `[lo, hi)`.
    pub fn mean_in(&self, lo: Ps, hi: Ps) -> f64 {
        let vals: Vec<f64> = self
            .samples
            .iter()
            .filter(|s| s.t >= lo && s.t < hi)
            .map(|s| s.value)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

/// Periodic sampler: fires every `interval` ps and records counters
/// selected by a closure over the SoC state.
#[derive(Debug, Clone)]
pub struct Sampler {
    pub interval: Ps,
    next_at: Ps,
    pub series: Vec<TimeSeries>,
}

impl Sampler {
    pub fn new(interval: Ps, names: &[&str]) -> Self {
        Self {
            interval,
            next_at: 0,
            series: names.iter().map(|n| TimeSeries::new(*n)).collect(),
        }
    }

    /// Whether a sample is due at `now`.
    pub fn due(&self, now: Ps) -> bool {
        now >= self.next_at
    }

    /// Absolute time of the next sampling deadline. The idle-aware
    /// engine treats this as a wakeup event: coalesced spans stop short
    /// of it so the sample lands on the exact same edge as under
    /// edge-by-edge stepping.
    pub fn next_due(&self) -> Ps {
        self.next_at
    }

    /// Record one sample row (values aligned with the configured names).
    pub fn record(&mut self, now: Ps, values: &[f64]) {
        debug_assert_eq!(values.len(), self.series.len());
        for (s, &v) in self.series.iter_mut().zip(values) {
            s.push(now, v);
        }
        self.next_at = now + self.interval;
    }

    pub fn series(&self, name: &str) -> Option<&TimeSeries> {
        self.series.iter().find(|s| s.name == name)
    }
}

/// The sampler as an engine [`EventSource`]: its deadline is the next
/// sampling instant and firing records one row. `Ctx` is the value row,
/// aligned with the configured series names.
impl crate::sim::event::EventSource for Sampler {
    type Ctx<'a> = &'a [f64];

    fn next_deadline(&self, _ctx: &Self::Ctx<'_>) -> crate::sim::event::Deadline {
        crate::sim::event::Deadline::At(self.next_at)
    }

    fn fire(&mut self, now: Ps, ctx: &mut Self::Ctx<'_>) -> crate::sim::event::Outcome {
        if !self.due(now) {
            return crate::sim::event::Outcome::at(false, self.next_at);
        }
        self.record(now, *ctx);
        crate::sim::event::Outcome::at(true, self.next_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_conversion() {
        let mut ts = TimeSeries::new("pkts");
        // 1000 packets per ms => 1e6 pkt/s.
        ts.push(0, 0.0);
        ts.push(1_000_000_000, 1000.0);
        ts.push(2_000_000_000, 2000.0);
        let rate = ts.to_rate();
        assert_eq!(rate.samples.len(), 2);
        assert!((rate.samples[0].value - 1e6).abs() < 1.0);
    }

    #[test]
    fn sampler_cadence() {
        let mut s = Sampler::new(100, &["a"]);
        assert!(s.due(0));
        s.record(0, &[1.0]);
        assert!(!s.due(99));
        assert!(s.due(100));
        s.record(100, &[2.0]);
        assert_eq!(s.series("a").unwrap().samples.len(), 2);
    }

    #[test]
    fn sampler_as_event_source() {
        use crate::sim::event::{Deadline, EventSource};
        let mut s = Sampler::new(100, &["a"]);
        let row = [7.0];
        let mut ctx: &[f64] = &row;
        assert_eq!(s.next_deadline(&ctx), Deadline::At(0));
        let out = s.fire(0, &mut ctx);
        assert!(out.did_work);
        assert_eq!(out.next, Deadline::At(100));
        // Early fire before the cadence point records nothing.
        let out = s.fire(99, &mut ctx);
        assert!(!out.did_work);
        assert_eq!(s.series("a").unwrap().samples.len(), 1);
    }

    #[test]
    fn rate_of_empty_and_single_sample_series_is_empty() {
        let ts = TimeSeries::new("x");
        assert!(ts.to_rate().samples.is_empty());
        let mut ts = TimeSeries::new("x");
        ts.push(1_000, 42.0);
        assert!(ts.to_rate().samples.is_empty());
        // Duplicate/inverted timestamps contribute no sample (no NaN).
        let mut ts = TimeSeries::new("x");
        ts.push(1_000, 1.0);
        ts.push(1_000, 2.0);
        ts.push(500, 3.0);
        let rate = ts.to_rate();
        assert!(rate.samples.iter().all(|s| s.value.is_finite()));
        assert!(rate.samples.is_empty());
    }

    #[test]
    fn max_of_empty_series_is_zero() {
        let ts = TimeSeries::new("x");
        assert_eq!(ts.max(), 0.0);
        let mut ts = TimeSeries::new("x");
        ts.push(0, 3.0);
        ts.push(10, 7.0);
        assert_eq!(ts.max(), 7.0);
    }

    #[test]
    fn mean_in_empty_or_inverted_window_is_zero() {
        let ts = TimeSeries::new("x");
        assert_eq!(ts.mean_in(0, 100), 0.0);
        let mut ts = TimeSeries::new("x");
        ts.push(10, 5.0);
        assert_eq!(ts.mean_in(20, 30), 0.0, "empty window");
        assert_eq!(ts.mean_in(30, 20), 0.0, "inverted window");
        assert_eq!(ts.mean_in(0, 20), 5.0);
    }

    #[test]
    fn windowed_mean() {
        let mut ts = TimeSeries::new("x");
        for i in 0..10 {
            ts.push(i * 10, i as f64);
        }
        assert_eq!(ts.mean_in(0, 50), 2.0); // samples 0..4
        assert_eq!(ts.mean_in(50, 100), 7.0); // samples 5..9
    }
}
