//! Minimal CLI argument parsing (offline stand-in for clap): subcommand
//! plus `--key value` / `--key=value` / `--flag` options, with typed
//! getters (including signed values) and a usage renderer.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// Every `vespa` subcommand with its one-line description — the single
/// registry behind the usage banner, so `--help` can never silently
/// omit a subcommand (`rust/src/main.rs` smoke-tests that each entry
/// appears and dispatches).
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("run", "simulate a SoC described by a config file"),
    ("serve", "serve open-loop traffic with replica-aware dispatch"),
    ("cluster", "serve one workload across a fleet of SoC replicas"),
    ("table1", "reproduce Table I (area + throughput, 1x/2x/4x)"),
    ("fig2", "reproduce Fig. 2 (floorplan)"),
    ("fig3", "reproduce Fig. 3 (throughput vs TG pressure)"),
    ("fig4", "reproduce Fig. 4 (memory traffic vs DFS)"),
    ("dse", "replication/frequency/fleet design-space sweep"),
    ("validate", "parse + validate a config file"),
    ("accels", "list the accelerator DB"),
    ("artifacts-check", "load artifacts and cross-check PJRT vs native"),
];

/// The `usage:` header line listing every registered subcommand.
pub fn usage_header() -> String {
    let names: Vec<&str> = SUBCOMMANDS.iter().map(|(name, _)| *name).collect();
    format!("usage: vespa <{}> [options]", names.join("|"))
}

/// One indented `name  description` line per registered subcommand.
pub fn subcommand_lines() -> String {
    let width = SUBCOMMANDS
        .iter()
        .map(|(name, _)| name.len())
        .max()
        .unwrap_or(0);
    SUBCOMMANDS
        .iter()
        .map(|(name, desc)| format!("  {name:width$}  {desc}"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    ///
    /// Option values bind in two ways: `--key value` (the next token,
    /// unless it starts with `--` — a leading single `-` is fine, so
    /// `--offset -5` parses as key/value) and `--key=value` (everything
    /// after the first `=`, so `--offset=-5` also works). A `--name`
    /// with neither becomes a boolean flag.
    pub fn parse_from<I: IntoIterator<Item = String>>(raw: I) -> crate::Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((key, value)) = name.split_once('=') {
                    if key.is_empty() {
                        bail!("malformed option {a:?}: empty name before `=`");
                    }
                    out.options.insert(key.to_string(), value.to_string());
                    continue;
                }
                // `--key value` when the next token is not an option;
                // `--flag` otherwise.
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> crate::Result<Self> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> crate::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be an integer, got {v:?}")),
        }
    }

    /// Signed integer option (`--offset -5` or `--offset=-5`).
    pub fn opt_i64(&self, name: &str, default: i64) -> crate::Result<i64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be a signed integer, got {v:?}")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> crate::Result<usize> {
        Ok(self.opt_u64(name, default as u64)? as usize)
    }

    pub fn opt_str(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig3 --accel dfmul --replicas 4 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("fig3"));
        assert_eq!(a.opt("accel"), Some("dfmul"));
        assert_eq!(a.opt_u64("replicas", 1).unwrap(), 4);
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_equals_value_syntax() {
        let a = parse("dse --accel=gsm --window-ms=12 --quick");
        assert_eq!(a.opt("accel"), Some("gsm"));
        assert_eq!(a.opt_u64("window-ms", 0).unwrap(), 12);
        assert!(a.flag("quick"));
        assert!(!a.flag("accel=gsm"), "--key=value must not become a flag");
        assert!(a.options.keys().all(|k| !k.contains('=')));
    }

    #[test]
    fn key_equals_value_keeps_later_equals_signs() {
        let a = parse("run --define a=b=c");
        assert_eq!(a.opt("define"), Some("a=b=c"));
        let a = parse("run --define=a=b=c");
        assert_eq!(a.opt("define"), Some("a=b=c"));
    }

    #[test]
    fn negative_numeric_values() {
        // Space-separated: `-5` does not start with `--`, so it binds.
        let a = parse("tune --offset -5 --gain -2");
        assert_eq!(a.opt_i64("offset", 0).unwrap(), -5);
        assert_eq!(a.opt_i64("gain", 0).unwrap(), -2);
        // `=`-separated negative.
        let a = parse("tune --offset=-17");
        assert_eq!(a.opt_i64("offset", 0).unwrap(), -17);
        // Default passes through untouched.
        assert_eq!(a.opt_i64("missing", -3).unwrap(), -3);
    }

    #[test]
    fn empty_key_rejected() {
        assert!(Args::parse_from(["--=v".to_string()]).is_err());
        assert!(Args::parse_from(["--".to_string()]).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("run config.toml extra");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["config.toml", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.opt_u64("n", 7).unwrap(), 7);
        assert_eq!(a.opt_str("s", "d"), "d");
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse("x --n abc");
        assert!(a.opt_u64("n", 0).is_err());
        assert!(a.opt_i64("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn usage_lists_every_subcommand() {
        let header = usage_header();
        let lines = subcommand_lines();
        for (name, desc) in SUBCOMMANDS {
            assert!(header.contains(name), "usage header missing {name:?}");
            assert!(lines.contains(name), "subcommand lines missing {name:?}");
            assert!(lines.contains(desc), "description missing for {name:?}");
        }
        for known in ["serve", "cluster", "dse"] {
            assert!(
                SUBCOMMANDS.iter().any(|(name, _)| *name == known),
                "registry must include {known:?}"
            );
        }
    }
}
