//! Minimal CLI argument parsing (offline stand-in for clap): subcommand
//! plus `--key value` / `--key=value` / `--flag` options, with typed
//! getters (including signed values) and a usage renderer.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// Every `vespa` subcommand with its one-line description — the single
/// registry behind the usage banner, so `--help` can never silently
/// omit a subcommand (`rust/src/main.rs` smoke-tests that each entry
/// appears and dispatches).
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("run", "simulate a SoC described by a config file"),
    ("serve", "serve open-loop traffic with replica-aware dispatch"),
    ("cluster", "serve one workload across a fleet of SoC replicas"),
    ("table1", "reproduce Table I (area + throughput, 1x/2x/4x)"),
    ("fig2", "reproduce Fig. 2 (floorplan)"),
    ("fig3", "reproduce Fig. 3 (throughput vs TG pressure)"),
    ("fig4", "reproduce Fig. 4 (memory traffic vs DFS)"),
    ("dse", "replication/frequency/fleet design-space sweep"),
    ("validate", "parse + validate a config file"),
    ("accels", "list the accelerator DB"),
    ("artifacts-check", "load artifacts and cross-check PJRT vs native"),
];

/// Known `--option`/`--flag` names per subcommand — the registry behind
/// [`validate_known`], which rejects typos (`--replcas`) with a
/// did-you-mean hint instead of silently falling back to defaults.
/// Every [`SUBCOMMANDS`] entry (plus aliases) has a row; a subcommand
/// absent from both lists skips validation entirely.
pub const KNOWN_OPTIONS: &[(&str, &[&str])] = &[
    ("run", &["artifacts", "duration-ms", "tg", "engine"]),
    (
        "serve",
        &[
            "artifacts",
            "accel",
            "replicas",
            "rps",
            "duration-ms",
            "policy",
            "queue",
            "seed",
            "slo-ms",
            "tile",
            "engine",
            "json",
            "governor",
            "faults",
            "retry",
            "retry-backoff-us",
            "deadline-ms",
            "trace",
            "trace-sample",
            "metrics",
        ],
    ),
    (
        "cluster",
        &[
            "artifacts",
            "accel",
            "tile-replicas",
            "replicas",
            "rps",
            "duration-ms",
            "balancer",
            "policy",
            "queue",
            "seed",
            "slo-ms",
            "engine",
            "threads",
            "min-replicas",
            "json",
            "autoscale",
            "governor",
            "faults",
            "retry",
            "retry-backoff-us",
            "deadline-ms",
            "health",
            "evict-after",
            "drain-deadline-ms",
            "trace",
            "trace-sample",
            "metrics",
        ],
    ),
    ("table1", &["invocations"]),
    ("fig2", &[]),
    ("floorplan", &[]),
    ("fig3", &["window-ms", "warmup-ms"]),
    ("fig4", &["phase-ms"]),
    (
        "dse",
        &[
            "accel",
            "serve-rps",
            "serve-ms",
            "slo-ms",
            "fleets",
            "threads",
            "wide",
            "quick",
            "warm",
            "serial",
            "autoscale",
            "faults",
            "retry",
            "retry-backoff-us",
            "deadline-ms",
        ],
    ),
    ("validate", &[]),
    ("accels", &[]),
    ("artifacts-check", &["artifacts"]),
];

/// Reject any `--name` (option or flag) the subcommand does not read,
/// with a did-you-mean hint for near misses. Unknown or absent
/// subcommands pass through (the dispatcher prints usage for those).
pub fn validate_known(args: &Args) -> crate::Result<()> {
    let Some(sub) = args.subcommand.as_deref() else {
        return Ok(());
    };
    let Some((_, known)) = KNOWN_OPTIONS.iter().find(|(name, _)| *name == sub) else {
        return Ok(());
    };
    for key in args
        .options
        .keys()
        .map(String::as_str)
        .chain(args.flags.iter().map(String::as_str))
    {
        if !known.contains(&key) {
            let hint = did_you_mean(key, known)
                .map(|k| format!(" (did you mean --{k}?)"))
                .unwrap_or_default();
            bail!("{sub}: unknown option --{key}{hint}");
        }
    }
    Ok(())
}

/// Closest known name within edit distance 2, preferring the smallest
/// distance (ties break on registry order).
fn did_you_mean(key: &str, known: &[&'static str]) -> Option<&'static str> {
    known
        .iter()
        .map(|&k| (edit_distance(key, k), k))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, k)| k)
}

/// Plain Levenshtein distance, O(|a|*|b|) with a rolling row.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// The `usage:` header line listing every registered subcommand.
pub fn usage_header() -> String {
    let names: Vec<&str> = SUBCOMMANDS.iter().map(|(name, _)| *name).collect();
    format!("usage: vespa <{}> [options]", names.join("|"))
}

/// One indented `name  description` line per registered subcommand.
pub fn subcommand_lines() -> String {
    let width = SUBCOMMANDS
        .iter()
        .map(|(name, _)| name.len())
        .max()
        .unwrap_or(0);
    SUBCOMMANDS
        .iter()
        .map(|(name, desc)| format!("  {name:width$}  {desc}"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    ///
    /// Option values bind in two ways: `--key value` (the next token,
    /// unless it starts with `--` — a leading single `-` is fine, so
    /// `--offset -5` parses as key/value) and `--key=value` (everything
    /// after the first `=`, so `--offset=-5` also works). A `--name`
    /// with neither becomes a boolean flag.
    pub fn parse_from<I: IntoIterator<Item = String>>(raw: I) -> crate::Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((key, value)) = name.split_once('=') {
                    if key.is_empty() {
                        bail!("malformed option {a:?}: empty name before `=`");
                    }
                    out.options.insert(key.to_string(), value.to_string());
                    continue;
                }
                // `--key value` when the next token is not an option;
                // `--flag` otherwise.
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> crate::Result<Self> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> crate::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be an integer, got {v:?}")),
        }
    }

    /// Signed integer option (`--offset -5` or `--offset=-5`).
    pub fn opt_i64(&self, name: &str, default: i64) -> crate::Result<i64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be a signed integer, got {v:?}")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> crate::Result<usize> {
        Ok(self.opt_u64(name, default as u64)? as usize)
    }

    pub fn opt_str(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig3 --accel dfmul --replicas 4 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("fig3"));
        assert_eq!(a.opt("accel"), Some("dfmul"));
        assert_eq!(a.opt_u64("replicas", 1).unwrap(), 4);
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_equals_value_syntax() {
        let a = parse("dse --accel=gsm --window-ms=12 --quick");
        assert_eq!(a.opt("accel"), Some("gsm"));
        assert_eq!(a.opt_u64("window-ms", 0).unwrap(), 12);
        assert!(a.flag("quick"));
        assert!(!a.flag("accel=gsm"), "--key=value must not become a flag");
        assert!(a.options.keys().all(|k| !k.contains('=')));
    }

    #[test]
    fn key_equals_value_keeps_later_equals_signs() {
        let a = parse("run --define a=b=c");
        assert_eq!(a.opt("define"), Some("a=b=c"));
        let a = parse("run --define=a=b=c");
        assert_eq!(a.opt("define"), Some("a=b=c"));
    }

    #[test]
    fn negative_numeric_values() {
        // Space-separated: `-5` does not start with `--`, so it binds.
        let a = parse("tune --offset -5 --gain -2");
        assert_eq!(a.opt_i64("offset", 0).unwrap(), -5);
        assert_eq!(a.opt_i64("gain", 0).unwrap(), -2);
        // `=`-separated negative.
        let a = parse("tune --offset=-17");
        assert_eq!(a.opt_i64("offset", 0).unwrap(), -17);
        // Default passes through untouched.
        assert_eq!(a.opt_i64("missing", -3).unwrap(), -3);
    }

    #[test]
    fn empty_key_rejected() {
        assert!(Args::parse_from(["--=v".to_string()]).is_err());
        assert!(Args::parse_from(["--".to_string()]).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("run config.toml extra");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["config.toml", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.opt_u64("n", 7).unwrap(), 7);
        assert_eq!(a.opt_str("s", "d"), "d");
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse("x --n abc");
        assert!(a.opt_u64("n", 0).is_err());
        assert!(a.opt_i64("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected_with_hint() {
        let a = parse("cluster --replcas 4");
        let err = validate_known(&a).unwrap_err().to_string();
        assert!(err.contains("unknown option --replcas"), "{err}");
        assert!(err.contains("did you mean --replicas"), "{err}");
        // Flags are validated too.
        let a = parse("cluster --helth");
        let err = validate_known(&a).unwrap_err().to_string();
        assert!(err.contains("did you mean --health"), "{err}");
        // Far-off names get no hint, just the rejection.
        let a = parse("serve --zzzzzzzz 1");
        let err = validate_known(&a).unwrap_err().to_string();
        assert!(err.contains("unknown option --zzzzzzzz"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn known_options_pass_validation() {
        for cmd in [
            "serve --rps 500 --faults crash@r0:at=1ms --retry 3 --governor",
            "cluster --replicas 4 --health --drain-deadline-ms 20 --autoscale",
            "dse --serve-rps 1000 --fleets 1,2 --quick",
            "run --duration-ms 5 --tg 2",
            "nonsense --whatever 1", // unregistered subcommands pass through
        ] {
            let a = parse(cmd);
            assert!(validate_known(&a).is_ok(), "rejected {cmd:?}");
        }
    }

    #[test]
    fn every_subcommand_has_a_known_options_row() {
        for (name, _) in SUBCOMMANDS {
            assert!(
                KNOWN_OPTIONS.iter().any(|(n, _)| n == name),
                "KNOWN_OPTIONS missing a row for {name:?}"
            );
        }
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("replcas", "replicas"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn usage_lists_every_subcommand() {
        let header = usage_header();
        let lines = subcommand_lines();
        for (name, desc) in SUBCOMMANDS {
            assert!(header.contains(name), "usage header missing {name:?}");
            assert!(lines.contains(name), "subcommand lines missing {name:?}");
            assert!(lines.contains(desc), "description missing for {name:?}");
        }
        for known in ["serve", "cluster", "dse"] {
            assert!(
                SUBCOMMANDS.iter().any(|(name, _)| *name == known),
                "registry must include {known:?}"
            );
        }
    }
}
