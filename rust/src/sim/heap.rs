//! Updateable binary min-heap keyed by dense component ids.
//!
//! The event-driven engine needs three operations the standard library's
//! `BinaryHeap` cannot do: *update-or-push* (re-key a component already
//! in the heap), *remove-one* (drop a specific component's entry), and
//! keyed membership tests — all in `O(log n)`. This heap pairs the
//! entry array with a dense `component -> slot` position map, so keyed
//! access never scans.
//!
//! Determinism: entries order by `(key, component)`, so equal deadlines
//! pop in ascending component order — the engine relies on this to
//! reproduce the reference engine's intra-edge tick order exactly.

/// Position-map sentinel: the component holds no entry.
const ABSENT: u32 = u32::MAX;

/// A binary min-heap over `(key, component)` with `O(log n)` keyed
/// update and removal via a dense position map.
///
/// Components are dense `u32` ids in `[0, n_comps)`; each holds at most
/// one entry. `Clone` deep-copies the full scheduler state (simulation
/// forking).
#[derive(Debug, Clone)]
pub struct UpdateableMinHeap<K> {
    /// Heap-ordered `(key, comp)` pairs; index 0 is the minimum.
    entries: Vec<(K, u32)>,
    /// `pos[comp]` = index of that component's entry, or [`ABSENT`].
    pos: Vec<u32>,
    /// Lifetime count of mutating operations (set / pop / remove) —
    /// self-profiling only, never consulted by the engine.
    ops: u64,
}

impl<K: Copy + Ord> UpdateableMinHeap<K> {
    /// An empty heap able to hold components `0..n_comps`.
    pub fn new(n_comps: usize) -> Self {
        Self {
            entries: Vec::with_capacity(n_comps),
            pos: vec![ABSENT; n_comps],
            ops: 0,
        }
    }

    /// Mutating-operation count since construction (set/pop/remove).
    pub fn ops(&self) -> u64 {
        self.ops
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, comp: u32) -> bool {
        self.pos[comp as usize] != ABSENT
    }

    /// Current key of `comp`, if it holds an entry.
    pub fn key_of(&self, comp: u32) -> Option<K> {
        let i = self.pos[comp as usize];
        if i == ABSENT {
            None
        } else {
            Some(self.entries[i as usize].0)
        }
    }

    /// The minimum `(key, comp)` without removing it.
    pub fn peek(&self) -> Option<(K, u32)> {
        self.entries.first().copied()
    }

    /// Remove and return the minimum `(key, comp)`.
    pub fn pop(&mut self) -> Option<(K, u32)> {
        let top = *self.entries.first()?;
        self.remove_index(0);
        self.ops += 1;
        Some(top)
    }

    /// Update-or-push: (re)key `comp`, inserting it if absent.
    pub fn set(&mut self, comp: u32, key: K) {
        self.ops += 1;
        let i = self.pos[comp as usize];
        if i == ABSENT {
            self.entries.push((key, comp));
            let last = self.entries.len() - 1;
            self.pos[comp as usize] = last as u32;
            self.sift_up(last);
        } else {
            let i = i as usize;
            let old = self.entries[i].0;
            self.entries[i].0 = key;
            if key < old {
                self.sift_up(i);
            } else {
                self.sift_down(i);
            }
        }
    }

    /// Decrease-only update: key `comp` to `key` unless it already holds
    /// an earlier (or equal) deadline. The engine's input-wake discipline
    /// — a notification may only move a wake *earlier* — is enforced
    /// here, so a pending earlier wake can never be lost.
    pub fn update_min(&mut self, comp: u32, key: K) {
        if let Some(k) = self.key_of(comp) {
            if k <= key {
                return;
            }
        }
        self.set(comp, key);
    }

    /// Remove-one: drop `comp`'s entry if present. Returns whether an
    /// entry was removed.
    pub fn remove(&mut self, comp: u32) -> bool {
        let i = self.pos[comp as usize];
        if i == ABSENT {
            return false;
        }
        self.remove_index(i as usize);
        self.ops += 1;
        true
    }

    /// Drop every entry (position map included).
    pub fn clear(&mut self) {
        for &(_, c) in &self.entries {
            self.pos[c as usize] = ABSENT;
        }
        self.entries.clear();
    }

    fn remove_index(&mut self, i: usize) {
        let last = self.entries.len() - 1;
        let removed = self.entries[i].1;
        if i != last {
            self.swap(i, last);
        }
        self.entries.pop();
        self.pos[removed as usize] = ABSENT;
        if i < self.entries.len() {
            // The displaced entry may need to move either way.
            let i = self.sift_up(i);
            self.sift_down(i);
        }
    }

    fn swap(&mut self, a: usize, b: usize) {
        self.entries.swap(a, b);
        self.pos[self.entries[a].1 as usize] = a as u32;
        self.pos[self.entries[b].1 as usize] = b as u32;
    }

    fn sift_up(&mut self, mut i: usize) -> usize {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.entries[parent] <= self.entries[i] {
                break;
            }
            self.swap(parent, i);
            i = parent;
        }
        i
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            let mut m = i;
            if l < self.entries.len() && self.entries[l] < self.entries[m] {
                m = l;
            }
            let r = l + 1;
            if r < self.entries.len() && self.entries[r] < self.entries[m] {
                m = r;
            }
            if m == i {
                return;
            }
            self.swap(i, m);
            i = m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::BTreeSet;

    #[test]
    fn pops_in_key_order_with_comp_tiebreak() {
        let mut h = UpdateableMinHeap::new(8);
        h.set(3, 50u64);
        h.set(1, 20);
        h.set(7, 20);
        h.set(0, 90);
        assert_eq!(h.peek(), Some((20, 1)));
        assert_eq!(h.pop(), Some((20, 1)));
        assert_eq!(h.pop(), Some((20, 7)));
        assert_eq!(h.pop(), Some((50, 3)));
        assert_eq!(h.pop(), Some((90, 0)));
        assert_eq!(h.pop(), None);
        assert!(h.is_empty());
    }

    #[test]
    fn update_or_push_rekeys_in_place() {
        let mut h = UpdateableMinHeap::new(4);
        h.set(2, 100u64);
        h.set(1, 200);
        // Decrease: comp 1 overtakes comp 2.
        h.set(1, 10);
        assert_eq!(h.peek(), Some((10, 1)));
        assert_eq!(h.key_of(1), Some(10));
        // Increase: comp 1 falls behind again; still exactly one entry.
        h.set(1, 300);
        assert_eq!(h.len(), 2);
        assert_eq!(h.pop(), Some((100, 2)));
        assert_eq!(h.pop(), Some((300, 1)));
    }

    #[test]
    fn update_min_never_delays() {
        let mut h = UpdateableMinHeap::new(4);
        h.update_min(0, 50u64);
        assert_eq!(h.key_of(0), Some(50));
        h.update_min(0, 80); // later: ignored
        assert_eq!(h.key_of(0), Some(50));
        h.update_min(0, 30); // earlier: applied
        assert_eq!(h.key_of(0), Some(30));
    }

    #[test]
    fn remove_one_from_the_middle() {
        let mut h = UpdateableMinHeap::new(8);
        for c in 0..8u32 {
            h.set(c, (c as u64) * 10 + 5);
        }
        assert!(h.remove(3));
        assert!(!h.remove(3), "second removal is a no-op");
        assert!(!h.contains(3));
        let popped: Vec<u32> = std::iter::from_fn(|| h.pop()).map(|(_, c)| c).collect();
        assert_eq!(popped, vec![0, 1, 2, 4, 5, 6, 7]);
    }

    #[test]
    fn clear_resets_membership() {
        let mut h = UpdateableMinHeap::new(4);
        h.set(0, 1u64);
        h.set(3, 2);
        h.clear();
        assert!(h.is_empty());
        assert!(!h.contains(0) && !h.contains(3));
        h.set(3, 7); // usable again after clear
        assert_eq!(h.pop(), Some((7, 3)));
    }

    /// Randomized model check: set/update_min/remove/pop against a
    /// `BTreeSet<(key, comp)>` oracle.
    #[test]
    fn matches_ordered_set_model() {
        const COMPS: u32 = 24;
        let mut rng = SplitMix64::new(0xB0A7_5EED);
        let mut h = UpdateableMinHeap::new(COMPS as usize);
        let mut model: BTreeSet<(u64, u32)> = BTreeSet::new();
        let mut key: Vec<Option<u64>> = vec![None; COMPS as usize];

        for _ in 0..4000 {
            let comp = rng.next_below(COMPS as u64) as u32;
            let k = rng.next_below(1000);
            match rng.next_below(4) {
                0 => {
                    if let Some(old) = key[comp as usize] {
                        model.remove(&(old, comp));
                    }
                    model.insert((k, comp));
                    key[comp as usize] = Some(k);
                    h.set(comp, k);
                }
                1 => {
                    let effective = match key[comp as usize] {
                        Some(old) if old <= k => old,
                        Some(old) => {
                            model.remove(&(old, comp));
                            model.insert((k, comp));
                            k
                        }
                        None => {
                            model.insert((k, comp));
                            k
                        }
                    };
                    key[comp as usize] = Some(effective);
                    h.update_min(comp, k);
                }
                2 => {
                    let had = key[comp as usize].take();
                    if let Some(old) = had {
                        model.remove(&(old, comp));
                    }
                    assert_eq!(h.remove(comp), had.is_some());
                }
                _ => {
                    let want = model.iter().next().copied();
                    assert_eq!(h.pop(), want);
                    if let Some((_, c)) = want {
                        model.remove(&want.unwrap());
                        key[c as usize] = None;
                    }
                }
            }
            assert_eq!(h.len(), model.len());
            assert_eq!(h.peek(), model.iter().next().copied());
            for c in 0..COMPS {
                assert_eq!(h.key_of(c), key[c as usize]);
                assert_eq!(h.contains(c), key[c as usize].is_some());
            }
        }
    }
}
