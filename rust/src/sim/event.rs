//! The unified component-stepping contract.
//!
//! Before this module, every schedulable component spoke its own wake
//! dialect: tiles returned a `TickOutcome { did_work, wake_cycle }` with
//! a `WAKE_ON_INPUT` sentinel, routers returned a bare `bool`, link
//! FIFOs exposed `head_ready_at`, and the sampler had `due`/`next_due`.
//! [`EventSource`] replaces all of them: a component reports *when it
//! next needs to run* as a typed [`Deadline`] and is stepped through
//! [`fire`](EventSource::fire), which returns an [`Outcome`] carrying
//! the next deadline.
//!
//! # The deadline contract
//!
//! A deadline is a *conservative promise*: running the component any
//! time **before** its deadline must be a provable no-op, and the engine
//! is free to run it early (it does, whenever an input wake arrives).
//! The two timed variants deliberately use different clocks:
//!
//! * [`Deadline::Cycle`] counts **island cycles** (the component's own
//!   clock), so a DFS retune of the island never invalidates a sleeping
//!   component — cycles convert to absolute time only transiently, when
//!   the engine probes for a coalescable quiescent span, and spans never
//!   cross a retiming.
//! * [`Deadline::At`] is **absolute picoseconds** — the `ready_at` stamp
//!   of a buffered flit, or the sampler's next due time. These come from
//!   producers and are exact, not period-derived.
//!
//! [`Deadline::OnInput`] parks the component entirely: only a producer
//! pushing into one of its input FIFOs can give it work, and the engine
//! re-arms it from that push notification. [`Deadline::Never`] is the
//! same minus the input edge (nothing will ever wake it).

use crate::util::Ps;

/// When a component next needs to run. See the [module](self) contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deadline {
    /// Run at/after this island-cycle count (the component's own clock;
    /// immune to DFS retunes). `Cycle(0)` means "due at the next edge".
    Cycle(u64),
    /// Run at/after this absolute simulation time (flit `ready_at` or
    /// sampler cadence).
    At(Ps),
    /// Nothing to do until a producer pushes into an input FIFO.
    OnInput,
    /// Nothing will ever give this component work.
    Never,
}

/// What a [`fire`](EventSource::fire) did and when to run next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Outcome {
    /// The step changed observable state (packets, counters, compute).
    pub did_work: bool,
    /// Next deadline, replacing the component's previous registration.
    pub next: Deadline,
}

impl Outcome {
    /// Run me again next cycle.
    pub fn active(did_work: bool, cycle: u64) -> Self {
        Self {
            did_work,
            next: Deadline::Cycle(cycle + 1),
        }
    }

    /// Nothing to do before island cycle `wake_cycle` (barring input).
    pub fn sleep_until(did_work: bool, wake_cycle: u64) -> Self {
        Self {
            did_work,
            next: Deadline::Cycle(wake_cycle),
        }
    }

    /// Nothing to do until an input flit arrives.
    pub fn on_input(did_work: bool) -> Self {
        Self {
            did_work,
            next: Deadline::OnInput,
        }
    }

    /// Nothing to do before absolute time `at`.
    pub fn at(did_work: bool, at: Ps) -> Self {
        Self {
            did_work,
            next: Deadline::At(at),
        }
    }
}

/// A schedulable simulation component.
///
/// Implementors: [`Tile`](crate::tiles::Tile) (`Ctx` =
/// [`TileCtx`](crate::tiles::TileCtx)), [`Router`](crate::noc::Router)
/// (`Ctx` = [`RouterCtx`](crate::noc::RouterCtx)), and
/// [`Sampler`](crate::monitor::Sampler) (`Ctx` = the sample row).
///
/// `Ctx` is a generic-associated type because each component borrows a
/// different slice of engine state for the duration of one step; the
/// engine assembles the right context per fire.
pub trait EventSource {
    /// Shared engine state this component touches while stepping.
    type Ctx<'a>;

    /// Current registration deadline, derived from component state.
    /// Must be conservative: running before it is a no-op.
    fn next_deadline(&self, ctx: &Self::Ctx<'_>) -> Deadline;

    /// Step the component once at time `now`. The returned
    /// [`Outcome::next`] replaces its registration.
    fn fire(&mut self, now: Ps, ctx: &mut Self::Ctx<'_>) -> Outcome;
}
