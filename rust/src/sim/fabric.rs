//! Fabric construction: routers, link FIFOs, and NI attach points for
//! every plane of the mesh.

use crate::config::SocConfig;
use crate::noc::{
    LinkFifo, LinkId, Mesh, NodeId, OutputRef, Port, Router, NUM_PLANES, NUM_PORTS,
};

/// The physical interconnect: all planes' routers plus the shared link
/// arena (router-to-router links, NI inject/eject FIFOs). `Clone`
/// deep-copies every FIFO and router (wormhole grants, stats) so a
/// forked simulation continues bit-identically.
#[derive(Clone)]
pub struct Fabric {
    pub mesh: Mesh,
    pub links: Vec<LinkFifo>,
    /// Routers, indexed `plane * nodes + node`.
    pub routers: Vec<Router>,
    /// Per node: inject link (NI -> router local in) per plane.
    pub inject: Vec<[LinkId; NUM_PLANES]>,
    /// Per node: eject link (router local out -> NI) per plane.
    pub eject: Vec<[LinkId; NUM_PLANES]>,
}

impl Fabric {
    /// Build the fabric for `cfg`. `tile_islands[node]` is the frequency
    /// island of the tile at that node (for CDC stamping on ejection).
    pub fn build(cfg: &SocConfig, tile_islands: &[usize]) -> Self {
        let mesh = Mesh::new(cfg.width, cfg.height);
        let nodes = mesh.nodes();
        let depth = cfg.noc.fifo_depth;

        let mut links: Vec<LinkFifo> = Vec::new();
        let mut alloc = |cap: usize| -> LinkId {
            links.push(LinkFifo::new(cap));
            LinkId((links.len() - 1) as u32)
        };

        // Per plane and node: 5 router input FIFOs (N,S,E,W,Local) and
        // one eject FIFO. The local input FIFO doubles as the inject link.
        let mut inputs = vec![[LinkId(0); NUM_PORTS]; nodes * NUM_PLANES];
        let mut eject = vec![[LinkId(0); NUM_PLANES]; nodes];
        let mut inject = vec![[LinkId(0); NUM_PLANES]; nodes];
        for p in 0..NUM_PLANES {
            for n in 0..nodes {
                for port in 0..NUM_PORTS {
                    inputs[p * nodes + n][port] = alloc(depth);
                }
                inject[n][p] = inputs[p * nodes + n][Port::Local.index()];
                eject[n][p] = alloc(depth);
            }
        }

        let mut routers = Vec::with_capacity(nodes * NUM_PLANES);
        for p in 0..NUM_PLANES {
            for n in 0..nodes {
                let node = NodeId(n as u16);
                let mut outputs: [Option<OutputRef>; NUM_PORTS] = [None; NUM_PORTS];
                for port in [Port::North, Port::South, Port::East, Port::West] {
                    if let Some(nb) = mesh.neighbor(node, port) {
                        outputs[port.index()] = Some(OutputRef {
                            link: inputs[p * nodes + nb.index()][port.opposite().index()],
                            dst_island: cfg.noc.island,
                        });
                    }
                }
                outputs[Port::Local.index()] = Some(OutputRef {
                    link: eject[n][p],
                    dst_island: tile_islands[n],
                });
                routers.push(Router::new(
                    node,
                    cfg.noc.island,
                    inputs[p * nodes + n],
                    outputs,
                ));
            }
        }

        Self {
            mesh,
            links,
            routers,
            inject,
            eject,
        }
    }

    /// Total flits forwarded by all routers.
    pub fn total_flits(&self) -> u64 {
        self.routers.iter().map(|r| r.stats.flits).sum()
    }

    /// Quiescence probe for the idle-aware engine.
    ///
    /// Returns `None` when the fabric needs per-cycle ticking right now:
    /// some router holds a wormhole grant (it accrues stall statistics
    /// every cycle) or some FIFO head is already visible at `now`.
    /// Otherwise returns the earliest future `ready_at` among buffered
    /// flits — the instant fabric work can next appear — or `Ps::MAX`
    /// when every FIFO (router inputs, injects, and ejects) is empty.
    pub fn next_flit_event(&self, now: crate::util::Ps) -> Option<crate::util::Ps> {
        for r in &self.routers {
            if r.holds_grant() {
                return None;
            }
        }
        let mut next = crate::util::Ps::MAX;
        for l in &self.links {
            if let Some(rt) = l.head_ready_at() {
                if rt <= now {
                    return None;
                }
                next = next.min(rt);
            }
        }
        Some(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::paper_soc;

    #[test]
    fn paper_fabric_dimensions() {
        let cfg = paper_soc(("dfsin", 1), ("gsm", 1));
        let islands: Vec<usize> = cfg.tiles.iter().map(|t| t.island).collect();
        let f = Fabric::build(&cfg, &islands);
        assert_eq!(f.routers.len(), 16 * NUM_PLANES);
        // 16 nodes x 3 planes x (5 inputs + 1 eject) FIFOs.
        assert_eq!(f.links.len(), 16 * NUM_PLANES * 6);
    }

    #[test]
    fn edges_have_no_dangling_outputs() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let islands: Vec<usize> = cfg.tiles.iter().map(|t| t.island).collect();
        let f = Fabric::build(&cfg, &islands);
        // Corner node 0: North and West must be None.
        let r = &f.routers[0];
        assert!(r.outputs[Port::North.index()].is_none());
        assert!(r.outputs[Port::West.index()].is_none());
        assert!(r.outputs[Port::East.index()].is_some());
        assert!(r.outputs[Port::Local.index()].is_some());
    }

    #[test]
    fn neighbor_links_are_symmetric() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let islands: Vec<usize> = cfg.tiles.iter().map(|t| t.island).collect();
        let f = Fabric::build(&cfg, &islands);
        let nodes = f.mesh.nodes();
        // Router n's East output feeds the East-neighbour's West input.
        for n in 0..nodes {
            let node = NodeId(n as u16);
            if let Some(nb) = f.mesh.neighbor(node, Port::East) {
                let out = f.routers[n].outputs[Port::East.index()].unwrap();
                let want = f.routers[nb.index()].inputs[Port::West.index()];
                assert_eq!(out.link, want);
            }
        }
    }
}
