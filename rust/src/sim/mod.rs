//! The SoC simulator: fabric construction, the multi-clock event engine,
//! and the host-side workload driver.

pub mod driver;
pub mod fabric;
pub mod soc;

pub use driver::{input_shapes, stage_inputs_for, ThroughputProbe};
pub use fabric::Fabric;
pub use soc::{EngineMode, EngineStats, Soc};
