//! The SoC simulator: fabric construction, the multi-clock event engine,
//! and the host-side workload driver.

pub mod driver;
pub mod event;
pub mod fabric;
pub mod heap;
mod sched;
pub mod soc;

pub use driver::{input_shapes, stage_inputs_for, ThroughputProbe};
pub use event::{Deadline, EventSource, Outcome};
pub use fabric::Fabric;
pub use heap::UpdateableMinHeap;
pub use soc::{EngineMode, EngineStats, Soc};
