//! Scheduler state for the event-driven engine: per-island updateable
//! min-heaps of component deadlines plus the link-to-consumer map that
//! turns producer pushes into input wakes.
//!
//! # Component ids
//!
//! Routers occupy ids `0..n_routers` in fabric order
//! (`plane * nodes + node`); tile `ti` is id `n_routers + ti`. Sorting a
//! due-set ascending therefore reproduces the reference engine's
//! intra-edge order exactly — all routers (plane-major), then tiles in
//! node order — which is what keeps [`EngineMode::EventDriven`] bit-
//! identical to [`EngineMode::Reference`].
//!
//! # Why two heaps per island
//!
//! [`Deadline::Cycle`] keys count island cycles and survive DFS retunes
//! untouched; [`Deadline::At`] keys are absolute flit `ready_at` stamps.
//! Keeping them in separate heaps means a retune never has to re-key
//! anything — the engine just pops whichever heads are due at each edge.
//!
//! # The wake invariant
//!
//! Every non-empty link FIFO's consumer always holds a heap entry keyed
//! at or before the instant its head flit becomes visible. Producers
//! maintain it through [`EventSched::wake_input`] after every push, and
//! consumers re-arm their own inputs when they fire. The invariant is
//! what makes the engine's `O(islands)` quiescence probe sound: if no
//! heap head is due, no component can do work.
//!
//! [`EngineMode::EventDriven`]: super::soc::EngineMode::EventDriven
//! [`EngineMode::Reference`]: super::soc::EngineMode::Reference
//! [`Deadline::Cycle`]: super::event::Deadline::Cycle
//! [`Deadline::At`]: super::event::Deadline::At

use crate::noc::LinkId;
use crate::util::Ps;

use super::fabric::Fabric;
use super::heap::UpdateableMinHeap;

/// Per-island deadline heaps plus component/link topology maps.
/// `Clone` deep-copies the full scheduler (simulation forking).
#[derive(Clone)]
pub(crate) struct EventSched {
    /// Routers are components `0..n_routers`; tile `ti` is
    /// `n_routers + ti`.
    pub n_routers: usize,
    /// Frequency island of each component (routers: the NoC island).
    island: Vec<u32>,
    /// `link -> component consuming that FIFO`: router input FIFOs
    /// (inject links included — they are the local input) feed their
    /// router; eject FIFOs feed the tile at that node.
    link_consumer: Vec<u32>,
    /// Per island: cycle-keyed deadlines (island cycles).
    pub cycle: Vec<UpdateableMinHeap<u64>>,
    /// Per island: absolute-time input wakes (`ready_at` stamps).
    pub at: Vec<UpdateableMinHeap<Ps>>,
    /// Scratch: components due at the edge being stepped.
    pub due: Vec<u32>,
}

impl EventSched {
    /// Build the scheduler for a fabric and arm every component at its
    /// island's next edge.
    pub fn build(
        fabric: &Fabric,
        tile_islands: &[usize],
        noc_island: usize,
        n_islands: usize,
    ) -> Self {
        let n_routers = fabric.routers.len();
        let n_comps = n_routers + tile_islands.len();

        let mut island = vec![0u32; n_comps];
        for isl in island.iter_mut().take(n_routers) {
            *isl = noc_island as u32;
        }
        for (ti, &isl) in tile_islands.iter().enumerate() {
            island[n_routers + ti] = isl as u32;
        }

        let mut link_consumer = vec![0u32; fabric.links.len()];
        for (r, router) in fabric.routers.iter().enumerate() {
            for l in router.inputs {
                link_consumer[l.0 as usize] = r as u32;
            }
        }
        for (n, planes) in fabric.eject.iter().enumerate() {
            for l in planes {
                link_consumer[l.0 as usize] = (n_routers + n) as u32;
            }
        }

        let mut sched = Self {
            n_routers,
            island,
            link_consumer,
            cycle: (0..n_islands).map(|_| UpdateableMinHeap::new(n_comps)).collect(),
            at: (0..n_islands).map(|_| UpdateableMinHeap::new(n_comps)).collect(),
            due: Vec::with_capacity(n_comps),
        };
        sched.rearm();
        sched
    }

    /// Forget everything and mark every component due at its island's
    /// next edge. Conservative by construction: each component
    /// re-derives its true deadline from the [`Outcome`] of that first
    /// fire, so re-arming is always safe (engine switches, resumes).
    ///
    /// [`Outcome`]: super::event::Outcome
    pub fn rearm(&mut self) {
        for h in &mut self.cycle {
            h.clear();
        }
        for h in &mut self.at {
            h.clear();
        }
        for comp in 0..self.island.len() as u32 {
            self.cycle[self.island[comp as usize] as usize].set(comp, 0);
        }
    }

    /// Total mutating heap operations across every island heap —
    /// self-profiling counter surfaced through [`Soc::heap_ops`].
    ///
    /// [`Soc::heap_ops`]: super::soc::Soc::heap_ops
    pub fn heap_ops(&self) -> u64 {
        self.cycle.iter().map(|h| h.ops()).sum::<u64>()
            + self.at.iter().map(|h| h.ops()).sum::<u64>()
    }

    /// Component id of tile `ti`.
    pub fn tile_comp(&self, tile: usize) -> u32 {
        (self.n_routers + tile) as u32
    }

    /// Host code mutated tile `tile`: its sleep reasoning is void, so it
    /// must re-evaluate at its island's next edge.
    pub fn wake_tile(&mut self, tile: usize) {
        let comp = self.tile_comp(tile);
        self.cycle[self.island[comp as usize] as usize].set(comp, 0);
    }

    /// A producer pushed into `link` (head visible from `ready_at`):
    /// ensure the consumer runs no later than that. Decrease-only, so an
    /// earlier pending wake is never lost.
    pub fn wake_input(&mut self, link: LinkId, ready_at: Ps) {
        let comp = self.link_consumer[link.0 as usize];
        self.at[self.island[comp as usize] as usize].update_min(comp, ready_at);
    }
}
