//! Host-side workload driver helpers: staging functional inputs for MRA
//! tiles and measuring throughput through the monitoring counters, the
//! way the paper's experiments do.
//!
//! Higher-level choreography (warmup/measure phases, typed reports,
//! parallel scenario evaluation) lives in [`crate::scenario`]; the
//! helpers here are the low-level building blocks it is made of.
//!
//! All helpers drive the SoC through `run_until`/`run_for`, so they get
//! the idle-aware engine's span coalescing for free (see
//! [`crate::sim::soc`] and `docs/PERF.md`); measurement windows are
//! engine-invariant because coalescing is bit-identical to edge-by-edge
//! stepping.

use crate::mem::{Block, BlockId};
use crate::monitor::CounterReg;
use crate::tiles::AccelTiming;
use crate::util::{Ps, SplitMix64};

use super::soc::Soc;

/// Generate and stage `sets` functional input sets for MRA tile `tile`,
/// with data shaped per the accelerator's manifest geometry. Returns the
/// staged block ids, or an error if `tile` is not an MRA tile or its
/// accelerator is unknown.
pub fn stage_inputs_for(
    soc: &mut Soc,
    tile: usize,
    sets: usize,
) -> crate::Result<Vec<Vec<BlockId>>> {
    let accel = soc.try_mra(tile)?.accel.clone();
    let shapes = input_shapes(&accel)?;
    let mut rng = SplitMix64::new(soc.cfg.seed ^ (tile as u64) << 32 ^ 0x57A6E);
    let mut all = Vec::new();
    for _ in 0..sets {
        let ids: Vec<BlockId> = shapes
            .iter()
            .map(|&(words, int)| {
                let block = if int {
                    Block::I32(
                        (0..words)
                            .map(|_| rng.range_i64(-32768, 32767) as i32)
                            .collect(),
                    )
                } else {
                    Block::F32((0..words).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                };
                soc.blocks.insert(block)
            })
            .collect();
        all.push(ids);
    }
    soc.try_mra_mut(tile)?.stage_inputs(all.clone());
    Ok(all)
}

/// (words, is_int) per input stream, derived from the accelerator timing
/// table — the single in-crate source of the `python/compile/model.py`
/// geometry (cross-checked against `bytes_in` by the timing tests and
/// against the artifacts manifest at SoC build time).
pub fn input_shapes(accel: &str) -> crate::Result<Vec<(usize, bool)>> {
    let timing = AccelTiming::lookup(accel)?;
    Ok(timing
        .input_streams
        .iter()
        .map(|s| (s.words, s.int))
        .collect())
}

/// Throughput measurement window over the monitoring counters, as the
/// paper's host tooling does: reset, run, read invocations.
///
/// Prefer [`crate::scenario::Session::measure`] for new code — it wraps
/// this choreography in one call and returns a typed
/// [`crate::scenario::PhaseReport`] with counter deltas.
pub struct ThroughputProbe {
    tile: usize,
    start: Ps,
    inv0: u64,
}

impl ThroughputProbe {
    /// Begin a measurement window on `tile`.
    pub fn begin(soc: &Soc, tile: usize) -> Self {
        Self {
            tile,
            start: soc.now,
            inv0: soc.host_read_counter(tile, CounterReg::Invocations),
        }
    }

    /// Completed invocations since the window began.
    pub fn invocations(&self, soc: &Soc) -> u64 {
        soc.host_read_counter(self.tile, CounterReg::Invocations) - self.inv0
    }

    /// Throughput in MB/s credited per the accelerator's stream bytes.
    pub fn mbs(&self, soc: &Soc) -> f64 {
        let dt_s = (soc.now - self.start) as f64 / 1e12;
        if dt_s <= 0.0 {
            return 0.0;
        }
        let credit = soc.mra(self.tile).timing.credit_bytes as f64;
        self.invocations(soc) as f64 * credit / 1e6 / dt_s
    }

    /// Mean DMA round-trip time observed in the window (ns). Note: reads
    /// the cumulative counters, so callers wanting a clean window should
    /// `manual_reset` first (or use `Session::measure`, which computes
    /// the in-window mean from counter deltas).
    pub fn rtt_ns(&self, soc: &Soc) -> f64 {
        let c = soc.mon.tile(self.tile);
        c.rtt_mean() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{paper_soc, A1_POS, MEM_POS};
    use crate::runtime::RefCompute;

    #[test]
    fn staged_inputs_match_geometry() {
        let cfg = paper_soc(("dfadd", 2), ("gsm", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a1 = soc.cfg.node_of(A1_POS.0, A1_POS.1);
        let sets = stage_inputs_for(&mut soc, a1, 2).unwrap();
        assert_eq!(sets.len(), 2);
        assert_eq!(sets[0].len(), 2, "dfadd has two input streams");
        assert_eq!(soc.blocks.get(sets[0][0]).words(), 1024);
    }

    #[test]
    fn input_shapes_cover_all_accels_and_reject_unknown() {
        assert_eq!(input_shapes("dfadd").unwrap(), vec![(1024, false); 2]);
        assert_eq!(input_shapes("dfsin").unwrap(), vec![(1024, false)]);
        assert_eq!(input_shapes("adpcm").unwrap(), vec![(64 * 128, true)]);
        assert_eq!(input_shapes("gsm").unwrap(), vec![(160 * 128, false)]);
        let err = input_shapes("nope").unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
    }

    #[test]
    fn staging_a_non_mra_tile_errors_instead_of_panicking() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let mem = soc.cfg.node_of(MEM_POS.0, MEM_POS.1);
        let err = stage_inputs_for(&mut soc, mem, 1).unwrap_err().to_string();
        assert!(err.contains("mem"), "{err}");
        let err = stage_inputs_for(&mut soc, 999, 1).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    /// End-to-end smoke: a 1x dfadd in A1 completes invocations and the
    /// functional outputs match the native oracle exactly.
    #[test]
    fn dfadd_runs_end_to_end_with_functional_output() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a1 = soc.cfg.node_of(A1_POS.0, A1_POS.1);
        let ids = stage_inputs_for(&mut soc, a1, 1).unwrap();
        let probe = ThroughputProbe::begin(&soc, a1);
        // dfadd 1x at ~9.2 MB/s needs ~445 us per invocation; run 3 ms.
        soc.run_for(3_000_000_000);
        let inv = probe.invocations(&soc);
        assert!(inv >= 2, "expected >=2 invocations, got {inv}");

        // Functional check: last_outputs == a + b.
        let a = soc.blocks.get(ids[0][0]).as_f32().unwrap().to_vec();
        let b = soc.blocks.get(ids[0][1]).as_f32().unwrap().to_vec();
        let out = soc.mra(a1).last_outputs[0].as_f32().unwrap();
        for i in 0..a.len() {
            assert!((out[i] - (a[i] + b[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn throughput_probe_reports_positive_mbs() {
        let cfg = paper_soc(("dfmul", 1), ("dfadd", 1));
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a1 = soc.cfg.node_of(A1_POS.0, A1_POS.1);
        stage_inputs_for(&mut soc, a1, 1).unwrap();
        soc.run_for(1_000_000_000); // warmup 1 ms
        let probe = ThroughputProbe::begin(&soc, a1);
        soc.run_for(3_000_000_000);
        let mbs = probe.mbs(&soc);
        assert!(mbs > 1.0, "throughput {mbs:.2} MB/s");
        assert!(mbs < 20.0, "throughput {mbs:.2} MB/s implausibly high");
    }
}
