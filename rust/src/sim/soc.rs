//! The assembled SoC and its multi-clock event engine.
//!
//! Time advances edge-by-edge: a binary heap holds each frequency
//! island's next rising edge; popping the earliest edge ticks that
//! island's routers and tiles one cycle, honouring DFS retiming (an
//! island whose actuator swapped frequency re-schedules at its new
//! period). Determinism: heap ties break on island index; all randomness
//! is seeded from the config.
//!
//! # The three engines
//!
//! All three modes share the edge heap, host schedule, and sampler
//! plumbing and differ only in how much per-edge work they elide (see
//! `docs/PERF.md` for the full architecture):
//!
//! * [`EngineMode::Reference`] ticks every router and every tile of the
//!   edge's island, unconditionally — the bit-exactness oracle.
//! * [`EngineMode::IdleAware`] skips components that are
//!   provably idle: every tile tick returns an
//!   [`Outcome`](crate::tiles::Outcome) naming its next
//!   [`Deadline`](crate::tiles::Deadline), routers keep their
//!   empty-FIFO fast path, and after a fully quiet edge the engine
//!   probes global quiescence and bulk-delivers edges up to the next
//!   event via [`ClockDomain::advance_span`].
//! * [`EngineMode::EventDriven`] (the default) inverts the loop:
//!   components register their deadlines in per-island updateable
//!   min-heaps (see [`super::heap::UpdateableMinHeap`]) and each edge
//!   pops only the components actually due, so per-edge cost scales
//!   with *activity*, not grid size. Producer pushes re-arm consumers
//!   through the link-to-consumer map; quiescence probing is
//!   `O(islands)` because the heap heads already bound every
//!   component's next wake.
//!
//! Every elision is a no-op by construction, so all engines are
//! bit-identical to [`EngineMode::Reference`] — enforced across serve,
//! cluster, and mid-run retune paths in
//! `rust/tests/engine_equivalence.rs`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Context};

use crate::clock::domain::{ClockDomain, IslandId};
use crate::config::{SocConfig, TileKind};
use crate::mem::BlockStore;
use crate::monitor::{MonitorFile, Sampler};
use crate::noc::{ClockView, NodeId, PacketArena, RouterCtx};
use crate::runtime::AccelCompute;
use crate::tiles::{cpu::CpuTile, io::IoTile, mem_tile::MemTile, mra::MraTile, tg::TgTile};
use crate::tiles::{AccelTiming, NetIface, Tile, TileCtx};
use crate::util::time::Freq;
use crate::util::{Ps, SplitMix64};

use super::event::{Deadline, EventSource};
use super::fabric::Fabric;
use super::sched::EventSched;

/// Which step loop the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineMode {
    /// Skip provably no-op component ticks and coalesce globally
    /// quiescent spans.
    IdleAware,
    /// Tick every router and every tile on every edge — the
    /// pre-idle-aware engine, kept as the equivalence oracle.
    Reference,
    /// Pop only due components from per-island updateable min-heaps of
    /// [`Deadline`]s — per-edge cost scales with activity, not grid
    /// size (the default).
    #[default]
    EventDriven,
}

impl EngineMode {
    /// Parse a CLI engine name (the `--engine` flag).
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "reference" | "ref" => Self::Reference,
            "idle" | "idle-aware" => Self::IdleAware,
            "event" | "event-driven" => Self::EventDriven,
            other => bail!("unknown engine {other:?} (expected reference|idle|event)"),
        })
    }
}

/// Idle-aware engine telemetry (all zero under [`EngineMode::Reference`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Quiescent spans jumped.
    pub coalesced_spans: u64,
    /// Edges delivered in bulk inside those spans.
    pub coalesced_edges: u64,
    /// Tile ticks actually executed.
    pub tile_ticks: u64,
    /// Tile ticks skipped because the tile was asleep with no input.
    pub skipped_tile_ticks: u64,
    /// Router ticks actually executed (event-driven engine only; the
    /// other engines tick routers unconditionally and leave this 0).
    pub router_ticks: u64,
}

/// The simulated SoC.
pub struct Soc {
    pub cfg: SocConfig,
    pub islands: Vec<ClockDomain>,
    pub fabric: Fabric,
    pub tiles: Vec<Tile>,
    pub arena: PacketArena,
    pub blocks: BlockStore,
    pub mon: MonitorFile,
    pub compute: Box<dyn AccelCompute>,
    pub now: Ps,
    view: ClockView,
    island_tiles: Vec<Vec<usize>>,
    heap: BinaryHeap<Reverse<(Ps, usize)>>,
    /// Optional periodic sampler (Fig. 4 instrumentation).
    pub sampler: Option<Sampler>,
    /// Pending host frequency schedule: (time, island, MHz), sorted.
    schedule: Vec<(Ps, usize, u64)>,
    schedule_next: usize,
    /// Total edges processed (engine throughput metric). Bulk-delivered
    /// edges count exactly as stepped ones, so this is engine-invariant.
    pub edges: u64,
    /// Engine selection. Prefer [`Soc::set_engine`] (it re-arms the
    /// event scheduler); direct assignment is safe only before the
    /// first `run_*`/`step` call, while the scheduler still holds its
    /// conservative build-time state.
    pub engine: EngineMode,
    pub engine_stats: EngineStats,
    /// Per-tile registration [`Deadline`] (the idle-aware engine's wake
    /// set). `Cycle(0)` = due immediately.
    tile_next: Vec<Deadline>,
    /// Event-driven scheduler state (per-island deadline heaps).
    sched: EventSched,
    /// Scratch: tiles due this edge (reused to avoid per-edge allocs).
    due_tiles: Vec<usize>,
    /// The last processed edge did no work — gates coalescing attempts.
    quiet_edge: bool,
}

impl Soc {
    /// Build a SoC from a validated config and a functional backend.
    pub fn build(cfg: SocConfig, compute: Box<dyn AccelCompute>) -> crate::Result<Self> {
        cfg.validate()?;
        let mut rng = SplitMix64::new(cfg.seed);

        let islands: Vec<ClockDomain> = cfg
            .islands
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                if spec.dfs {
                    ClockDomain::dfs(
                        IslandId(i),
                        spec.name.clone(),
                        Freq::mhz(spec.freq_mhz),
                        Freq::mhz(spec.min_mhz),
                        Freq::mhz(spec.max_mhz),
                        spec.step_mhz,
                    )
                } else {
                    ClockDomain::fixed(IslandId(i), spec.name.clone(), Freq::mhz(spec.freq_mhz))
                }
            })
            .collect();

        let mut tile_islands = vec![0usize; cfg.tiles.len()];
        for t in &cfg.tiles {
            tile_islands[cfg.node_of(t.x, t.y)] = t.island;
        }
        let fabric = Fabric::build(&cfg, &tile_islands);

        let mem_spec = cfg.mem_tile();
        let mem_node = NodeId(cfg.node_of(mem_spec.x, mem_spec.y) as u16);

        // Build tiles in node order.
        let mut tiles_by_node: Vec<Option<Tile>> = (0..cfg.tiles.len()).map(|_| None).collect();
        for spec in &cfg.tiles {
            let n = cfg.node_of(spec.x, spec.y);
            let ni = NetIface::new(
                NodeId(n as u16),
                spec.island,
                cfg.noc.island,
                fabric.inject[n],
                fabric.eject[n],
            );
            let tile = match &spec.kind {
                TileKind::Mem => Tile::Mem(MemTile::new(ni, n, cfg.mem.clone())),
                TileKind::Cpu => Tile::Cpu(CpuTile::new(ni, n, cfg.cpu_poll_interval)),
                TileKind::Io => Tile::Io(IoTile::new(ni, n)),
                TileKind::Tg => Tile::Tg(TgTile::new(
                    ni,
                    n,
                    mem_node,
                    cfg.dma.burst_beats,
                    cfg.dma.max_outstanding,
                    rng.fork(),
                )),
                TileKind::Accel { accel, replicas } => {
                    let timing = AccelTiming::lookup(accel)?;
                    let bp = crate::axi::BridgeParams {
                        replicas: *replicas,
                        replica_fifo_depth: cfg.bridge.replica_fifo_depth,
                        tile_fifo_depth: cfg.bridge.tile_fifo_depth,
                        switch_cycles: cfg.bridge.switch_cycles,
                    };
                    Tile::Mra(Box::new(MraTile::new(
                        ni,
                        n,
                        accel,
                        *replicas,
                        timing,
                        cfg.dma,
                        bp,
                        mem_node,
                    )))
                }
            };
            tiles_by_node[n] = Some(tile);
        }
        let tiles: Vec<Tile> = tiles_by_node.into_iter().map(Option::unwrap).collect();

        // CPU polls every accelerator tile by default.
        let accel_targets: Vec<(NodeId, usize)> = tiles
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, Tile::Mra(_)))
            .map(|(i, _)| (NodeId(i as u16), i))
            .collect();
        let mut tiles = tiles;
        for t in &mut tiles {
            if let Tile::Cpu(c) = t {
                c.poll_targets = accel_targets.clone();
            }
        }

        let mut island_tiles = vec![Vec::new(); islands.len()];
        for (n, &isl) in tile_islands.iter().enumerate() {
            island_tiles[isl].push(n);
        }

        let view = ClockView {
            periods: islands.iter().map(|d| d.period(0)).collect(),
            last_edges: vec![0; islands.len()],
            pipeline: cfg.noc.pipeline,
            sync_stages: cfg.noc.sync_stages,
        };

        let mut heap = BinaryHeap::new();
        for (i, d) in islands.iter().enumerate() {
            heap.push(Reverse((d.next_edge(0), i)));
        }

        let sched = EventSched::build(&fabric, &tile_islands, cfg.noc.island, islands.len());

        let mon = MonitorFile::new(cfg.tiles.len());
        let n_tiles = cfg.tiles.len();
        Ok(Self {
            cfg,
            islands,
            fabric,
            tiles,
            arena: PacketArena::new(),
            blocks: BlockStore::new(),
            mon,
            compute,
            now: 0,
            view,
            island_tiles,
            heap,
            sampler: None,
            schedule: Vec::new(),
            schedule_next: 0,
            edges: 0,
            engine: EngineMode::default(),
            engine_stats: EngineStats::default(),
            tile_next: vec![Deadline::Cycle(0); n_tiles],
            sched,
            due_tiles: Vec::with_capacity(n_tiles),
            quiet_edge: false,
        })
    }

    /// Deep-copy the complete simulation state into an independent SoC.
    ///
    /// Everything observable is captured — tiles (DMA pipelines, NI FIFO
    /// bookkeeping, per-tile RNGs), NoC routers/links with in-flight
    /// flits, the packet arena, block store, clock domains with
    /// in-flight DFS retimings, monitor counters, sampler traces, the
    /// host schedule cursor, the edge heap, and the engine's wake/quiet
    /// bookkeeping — so continuing the fork is bit-identical to
    /// continuing `self` (proven in `rust/tests/snapshot_fork.rs`). The
    /// two simulations share nothing afterwards.
    ///
    /// Errors only if the functional backend cannot be duplicated
    /// ([`AccelCompute::fork`] — the PJRT backend's compiled executables
    /// cannot; the native `RefCompute` always can).
    pub fn fork(&self) -> crate::Result<Self> {
        Ok(Self {
            cfg: self.cfg.clone(),
            islands: self.islands.clone(),
            fabric: self.fabric.clone(),
            tiles: self.tiles.clone(),
            arena: self.arena.clone(),
            blocks: self.blocks.clone(),
            mon: self.mon.clone(),
            compute: self.compute.fork()?,
            now: self.now,
            view: self.view.clone(),
            island_tiles: self.island_tiles.clone(),
            heap: self.heap.clone(),
            sampler: self.sampler.clone(),
            schedule: self.schedule.clone(),
            schedule_next: self.schedule_next,
            edges: self.edges,
            engine: self.engine,
            engine_stats: self.engine_stats,
            tile_next: self.tile_next.clone(),
            sched: self.sched.clone(),
            due_tiles: self.due_tiles.clone(),
            quiet_edge: self.quiet_edge,
        })
    }

    /// Node index of the (unique) MEM tile.
    pub fn mem_node(&self) -> usize {
        let s = self.cfg.mem_tile();
        self.cfg.node_of(s.x, s.y)
    }

    /// Tile indices of all MRA tiles.
    pub fn mra_tiles(&self) -> Vec<usize> {
        self.tiles
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, Tile::Mra(_)))
            .map(|(i, _)| i)
            .collect()
    }

    /// Mutable access to an MRA tile. Mutable host access may change
    /// anything about the tile, so its engine wake point is reset — the
    /// next edge re-evaluates it from scratch.
    pub fn mra_mut(&mut self, tile: usize) -> &mut MraTile {
        self.wake_tile(tile);
        match &mut self.tiles[tile] {
            Tile::Mra(m) => m,
            _ => panic!("tile {tile} is not an MRA tile"),
        }
    }

    /// Force a tile awake (any direct mutation of tile state from host
    /// code invalidates the engine's sleep reasoning for that tile).
    /// Updates both engines' wake state — cheap, and keeps a later
    /// engine switch sound.
    fn wake_tile(&mut self, tile: usize) {
        if let Some(w) = self.tile_next.get_mut(tile) {
            *w = Deadline::Cycle(0);
            self.sched.wake_tile(tile);
        }
    }

    pub fn mra(&self, tile: usize) -> &MraTile {
        match &self.tiles[tile] {
            Tile::Mra(m) => m,
            _ => panic!("tile {tile} is not an MRA tile"),
        }
    }

    /// Fallible access to an MRA tile (for host-driver paths that take
    /// user-supplied tile indices).
    pub fn try_mra(&self, tile: usize) -> crate::Result<&MraTile> {
        match self.tiles.get(tile) {
            Some(Tile::Mra(m)) => Ok(m),
            Some(t) => bail!(
                "tile {tile} is a {:?} tile, not an accelerator (MRA)",
                t.kind_name()
            ),
            None => bail!("tile index {tile} out of range ({} tiles)", self.tiles.len()),
        }
    }

    /// Fallible mutable access to an MRA tile.
    pub fn try_mra_mut(&mut self, tile: usize) -> crate::Result<&mut MraTile> {
        self.wake_tile(tile);
        let n = self.tiles.len();
        match self.tiles.get_mut(tile) {
            Some(Tile::Mra(m)) => Ok(m),
            Some(t) => bail!(
                "tile {tile} is a {:?} tile, not an accelerator (MRA)",
                t.kind_name()
            ),
            None => bail!("tile index {tile} out of range ({n} tiles)"),
        }
    }

    // ---------------------------------------------------------------
    // Host (USB-serial) access paths. Direct application is documented
    // in DESIGN.md: observability/config writes from the host do not
    // perturb NoC timing on the real system either (dedicated link).
    // ---------------------------------------------------------------

    /// Host write to an island's frequency register.
    pub fn host_write_freq(&mut self, island: usize, mhz: u64) -> crate::Result<Ps> {
        self.islands
            .get_mut(island)
            .context("no such island")?
            .request_freq(Freq::mhz(mhz), self.now)
            .map_err(Into::into)
    }

    /// Schedule a host frequency write at a future simulation time.
    pub fn schedule_freq(&mut self, at: Ps, island: usize, mhz: u64) {
        self.schedule.push((at, island, mhz));
        self.schedule.sort_by_key(|&(t, ..)| t);
        self.schedule_next = 0;
    }

    /// Install one resolved fault ([`crate::fault`]), shifting its
    /// windows by `base` into this SoC's absolute local time. Tile
    /// faults stall the MRA tile, link faults flap the inject/eject
    /// FIFOs at the tile's NoC node, island faults wedge the DFS
    /// actuator. Invalid targets surface as errors, never panics.
    pub fn install_fault(
        &mut self,
        fault: &crate::fault::CompFault,
        base: Ps,
    ) -> crate::Result<()> {
        let windows: Vec<(Ps, Ps)> = fault
            .windows
            .iter()
            .map(|&(s, e)| (base.saturating_add(s), base.saturating_add(e)))
            .collect();
        match fault.target {
            crate::fault::CompTarget::Tile(t) => {
                self.try_mra_mut(t)
                    .context("tile fault target")?
                    .add_stall_windows(&windows);
            }
            crate::fault::CompTarget::Link(t) => {
                if t >= self.fabric.inject.len() {
                    bail!(
                        "link fault target t{t} out of range ({} nodes)",
                        self.fabric.inject.len()
                    );
                }
                let ids: Vec<_> = self.fabric.inject[t]
                    .iter()
                    .chain(self.fabric.eject[t].iter())
                    .copied()
                    .collect();
                for id in ids {
                    self.fabric.links[id.0 as usize].add_fault_windows(&windows);
                }
            }
            crate::fault::CompTarget::Island(i) => {
                let n = self.islands.len();
                self.islands
                    .get_mut(i)
                    .with_context(|| format!("island fault target i{i} out of range ({n} islands)"))?
                    .add_stuck_windows(&windows);
            }
        }
        Ok(())
    }

    /// Enable the first `n` TG tiles (Fig. 3's X axis), disable the rest.
    pub fn host_set_tg_active(&mut self, n: usize) {
        let mut seen = 0;
        for (ti, t) in self.tiles.iter_mut().enumerate() {
            if let Tile::Tg(tg) = t {
                tg.enabled = seen < n;
                seen += 1;
                // A just-enabled (or disabled) TG must re-evaluate its
                // wake point on the next edge.
                self.tile_next[ti] = Deadline::Cycle(0);
                self.sched.wake_tile(ti);
            }
        }
    }

    /// Number of TG tiles.
    pub fn tg_count(&self) -> usize {
        self.tiles
            .iter()
            .filter(|t| matches!(t, Tile::Tg(_)))
            .count()
    }

    /// Host read of a monitor counter.
    pub fn host_read_counter(&self, tile: usize, reg: crate::monitor::CounterReg) -> u64 {
        use crate::monitor::CounterReg as R;
        let c = self.mon.tile(tile);
        match reg {
            R::Ctrl => c.enable as u64,
            R::ExecTime => c.exec_cycles,
            R::PktsIn => c.pkts_in,
            R::PktsOut => c.pkts_out,
            R::RttSum => c.rtt_sum,
            R::RttCnt => c.rtt_count,
            R::Invocations => c.invocations,
        }
    }

    /// Install the default Fig.-4-style sampler: cumulative MEM packets
    /// plus each island's frequency, every `interval` ps.
    pub fn enable_sampler(&mut self, interval: Ps) {
        let mut names = vec!["mem_pkts_in".to_string()];
        for isl in &self.cfg.islands {
            names.push(format!("freq_{}", isl.name));
        }
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        self.sampler = Some(Sampler::new(interval, &name_refs));
    }

    // ---------------------------------------------------------------
    // Engine
    // ---------------------------------------------------------------

    /// Select the engine. Safe at any point, including mid-run: the
    /// event scheduler re-arms conservatively (every component due at
    /// its island's next edge), so each re-derives its true deadline on
    /// first fire.
    pub fn set_engine(&mut self, mode: EngineMode) {
        self.engine = mode;
        self.sched.rearm();
        for w in &mut self.tile_next {
            *w = Deadline::Cycle(0);
        }
        self.quiet_edge = false;
    }

    /// Total mutating scheduler-heap operations so far — a self-profiling
    /// counter (zero outside [`EngineMode::EventDriven`]); it never feeds
    /// back into simulation behaviour.
    pub fn heap_ops(&self) -> u64 {
        self.sched.heap_ops()
    }

    /// Process one clock edge; returns the new simulation time.
    pub fn step(&mut self) -> Ps {
        match self.engine {
            EngineMode::IdleAware => self.step_idle_aware(),
            EngineMode::Reference => self.reference_step(),
            EngineMode::EventDriven => self.step_event(),
        }
    }

    /// Shared edge prologue: pop the earliest edge, apply due host
    /// schedule entries, deliver the edge to its island's clock domain.
    /// Returns (edge time, island, whether a schedule entry applied).
    fn begin_edge(&mut self) -> (Ps, usize, bool) {
        let Reverse((t, i)) = self.heap.pop().expect("at least one island");
        self.now = t;
        self.edges += 1;

        let mut scheduled = false;
        while self.schedule_next < self.schedule.len() && self.schedule[self.schedule_next].0 <= t
        {
            let (_, island, mhz) = self.schedule[self.schedule_next];
            let _ = self.host_write_freq(island, mhz);
            self.schedule_next += 1;
            scheduled = true;
        }

        self.islands[i].edge_delivered(t);
        self.view.last_edges[i] = t;
        self.view.periods[i] = self.islands[i].period(t);
        (t, i, scheduled)
    }

    /// Shared edge epilogue: record a due sample and re-schedule the
    /// island's next edge. Returns whether a sample was recorded.
    fn end_edge(&mut self, t: Ps, i: usize) -> bool {
        let mut sampled = false;
        if let Some(s) = &mut self.sampler {
            if s.due(t) {
                let mut row = vec![self.mon.mem_pkts_in as f64];
                for d in &self.islands {
                    row.push(d.freq(t).as_mhz() as f64);
                }
                s.record(t, &row);
                sampled = true;
            }
        }
        self.heap.push(Reverse((self.islands[i].next_edge(t), i)));
        sampled
    }

    /// The original engine: tick every router and every tile of the
    /// edge's island, unconditionally. Kept as the equivalence oracle
    /// for the idle-aware path.
    fn reference_step(&mut self) -> Ps {
        let (t, i, _) = self.begin_edge();

        // Routers of this island (all planes).
        if i == self.cfg.noc.island {
            let Fabric {
                mesh,
                links,
                routers,
                ..
            } = &mut self.fabric;
            for r in routers.iter_mut() {
                r.tick(t, mesh, links, &self.view);
            }
        }

        // Tiles of this island.
        let cycle = self.islands[i].cycles;
        {
            let Self {
                fabric,
                tiles,
                arena,
                blocks,
                mon,
                compute,
                islands,
                view,
                island_tiles,
                ..
            } = self;
            let mut ctx = TileCtx {
                now: t,
                cycle,
                mesh: &fabric.mesh,
                links: &mut fabric.links,
                view,
                arena,
                blocks,
                compute: compute.as_mut(),
                mon,
                islands,
            };
            for &ti in &island_tiles[i] {
                tiles[ti].tick(&mut ctx);
            }
        }

        self.end_edge(t, i);
        t
    }

    /// The idle-aware engine: tick only routers with work and tiles that
    /// are due (wake point reached or a flit visible in an eject FIFO),
    /// and flag fully quiet edges so `run_until` can try coalescing.
    fn step_idle_aware(&mut self) -> Ps {
        let (t, i, scheduled) = self.begin_edge();
        let mut restless = scheduled;

        if i == self.cfg.noc.island {
            let Fabric {
                mesh,
                links,
                routers,
                ..
            } = &mut self.fabric;
            for r in routers.iter_mut() {
                if r.tick(t, mesh, links, &self.view) {
                    restless = true;
                }
            }
        }

        // Collect the due-set before ticking: flits pushed *during* this
        // edge carry `ready_at > t` (pipeline/CDC stamps are strictly
        // future), so nothing ticked here can make another tile due at
        // this same edge — the pre-computed set is exact.
        let cycle = self.islands[i].cycles;
        self.due_tiles.clear();
        for &ti in &self.island_tiles[i] {
            let due = match self.tile_next[ti] {
                Deadline::Cycle(w) => w <= cycle,
                Deadline::At(at) => at <= t,
                Deadline::OnInput | Deadline::Never => false,
            } || self.fabric.eject[ti].iter().any(|l| {
                self.fabric.links[l.0 as usize]
                    .head_ready_at()
                    .is_some_and(|rt| rt <= t)
            });
            if due {
                self.due_tiles.push(ti);
            } else {
                self.engine_stats.skipped_tile_ticks += 1;
            }
        }
        self.engine_stats.tile_ticks += self.due_tiles.len() as u64;

        {
            let Self {
                fabric,
                tiles,
                arena,
                blocks,
                mon,
                compute,
                islands,
                view,
                due_tiles,
                tile_next,
                ..
            } = self;
            let mut ctx = TileCtx {
                now: t,
                cycle,
                mesh: &fabric.mesh,
                links: &mut fabric.links,
                view,
                arena,
                blocks,
                compute: compute.as_mut(),
                mon,
                islands,
            };
            for &ti in due_tiles.iter() {
                let out = tiles[ti].fire(t, &mut ctx);
                tile_next[ti] = out.next;
                let imminent = matches!(out.next, Deadline::Cycle(w) if w <= cycle + 1)
                    || matches!(out.next, Deadline::At(at) if at <= t);
                if out.did_work || imminent {
                    restless = true;
                }
            }
        }

        if self.end_edge(t, i) {
            restless = true;
        }
        self.quiet_edge = !restless;
        t
    }

    /// The event-driven engine: pop only components whose registered
    /// [`Deadline`] is due at this edge from the island's updateable
    /// min-heaps, fire them in component order (routers in fabric
    /// order, then tiles in node order — the reference engine's exact
    /// intra-edge order), and re-register each from its
    /// [`Outcome`](super::event::Outcome). Producer pushes re-arm
    /// consumers through the link-to-consumer map, preserving the wake
    /// invariant the `O(islands)` coalescing probe relies on.
    fn step_event(&mut self) -> Ps {
        let (t, i, scheduled) = self.begin_edge();
        let mut restless = scheduled;
        let cycle = self.islands[i].cycles;

        {
            let Self {
                fabric,
                tiles,
                arena,
                blocks,
                mon,
                compute,
                islands,
                view,
                sched,
                engine_stats,
                ..
            } = self;

            // Drain this island's due set: cycle deadlines reached and
            // input wakes whose `ready_at` has passed. Flits pushed
            // *during* this edge carry strictly-future stamps, so the
            // pre-drained set is exact — nothing fired here can make
            // another component due at this same edge.
            sched.due.clear();
            while let Some((w, c)) = sched.cycle[i].peek() {
                if w > cycle {
                    break;
                }
                sched.cycle[i].pop();
                sched.due.push(c);
            }
            while let Some((at, c)) = sched.at[i].peek() {
                if at > t {
                    break;
                }
                sched.at[i].pop();
                sched.due.push(c);
            }
            sched.due.sort_unstable();
            sched.due.dedup();

            let due = std::mem::take(&mut sched.due);
            for &comp in &due {
                // A component drained from one heap may still hold an
                // entry in the other; drop it so the post-fire
                // reschedule below is its sole registration (outcomes
                // and link scans re-derive everything from state).
                sched.cycle[i].remove(comp);
                sched.at[i].remove(comp);
                let out;
                if (comp as usize) < sched.n_routers {
                    let r = comp as usize;
                    engine_stats.router_ticks += 1;
                    let mut rctx = RouterCtx {
                        cycle,
                        mesh: &fabric.mesh,
                        links: &mut fabric.links,
                        view,
                    };
                    out = fabric.routers[r].fire(t, &mut rctx);
                    // Producer-side wakes: whoever consumes this
                    // router's output links is due when the (possibly
                    // new) head turns visible.
                    for out_ref in fabric.routers[r].outputs.iter().flatten() {
                        if let Some(rt) = fabric.links[out_ref.link.0 as usize].head_ready_at() {
                            sched.wake_input(out_ref.link, rt);
                        }
                    }
                } else {
                    let ti = comp as usize - sched.n_routers;
                    engine_stats.tile_ticks += 1;
                    let mut ctx = TileCtx {
                        now: t,
                        cycle,
                        mesh: &fabric.mesh,
                        links: &mut fabric.links,
                        view: &*view,
                        arena: &mut *arena,
                        blocks: &mut *blocks,
                        compute: compute.as_mut(),
                        mon: &mut *mon,
                        islands: &mut *islands,
                    };
                    out = tiles[ti].fire(t, &mut ctx);
                    // The tile may have left flits it could not take in
                    // its eject FIFOs — re-arm on the earliest head.
                    let mut pending: Option<Ps> = None;
                    for l in fabric.eject[ti] {
                        if let Some(rt) = fabric.links[l.0 as usize].head_ready_at() {
                            pending = Some(pending.map_or(rt, |p| p.min(rt)));
                        }
                    }
                    if let Some(rt) = pending {
                        sched.at[i].update_min(comp, rt);
                    }
                    // Whatever it injected wakes the local router when
                    // the head becomes visible.
                    for l in fabric.inject[ti] {
                        if let Some(rt) = fabric.links[l.0 as usize].head_ready_at() {
                            sched.wake_input(l, rt);
                        }
                    }
                }

                if out.did_work {
                    restless = true;
                }
                match out.next {
                    Deadline::Cycle(w) => {
                        sched.cycle[i].set(comp, w);
                        if w <= cycle + 1 {
                            restless = true;
                        }
                    }
                    Deadline::At(at) => {
                        sched.at[i].update_min(comp, at);
                        if at <= t {
                            restless = true;
                        }
                    }
                    Deadline::OnInput | Deadline::Never => {}
                }
            }
            sched.due = due; // hand the scratch allocation back
        }

        if self.end_edge(t, i) {
            restless = true;
        }
        self.quiet_edge = !restless;
        t
    }

    /// Attempt to coalesce a quiescent span: when no component can do
    /// work before a known future event, bulk-deliver every island edge
    /// up to just before that event (bounded by `t_end`). Returns true
    /// if any edges were delivered in bulk.
    fn try_coalesce(&mut self, t_end: Ps) -> bool {
        // Fabric: a held grant or visible flit needs per-cycle ticking;
        // buffered future flits bound the span by their `ready_at`.
        let Some(flit_event) = self.fabric.next_flit_event(self.now) else {
            return false;
        };
        let mut next_event = flit_event;

        // Clocks and tiles: every tile must be asleep. Sleeping wake
        // cycles convert to times under the current period — valid
        // because the span is also bounded by any pending DFS retiming.
        for (i, d) in self.islands.iter().enumerate() {
            if let Some(swap) = d.pending_retime() {
                if swap <= self.now {
                    return false;
                }
                next_event = next_event.min(swap);
            }
            let p = d.period(self.now);
            for &ti in &self.island_tiles[i] {
                match self.tile_next[ti] {
                    Deadline::OnInput | Deadline::Never => {}
                    Deadline::At(at) => {
                        if at <= self.now {
                            return false;
                        }
                        next_event = next_event.min(at);
                    }
                    Deadline::Cycle(w) => {
                        if w <= d.cycles {
                            return false; // an awake tile: no span
                        }
                        let dt = (w - d.cycles).saturating_mul(p);
                        next_event = next_event.min(d.last_edge().saturating_add(dt));
                    }
                }
            }
        }

        let Some(next_event) = self.host_event_bound(next_event) else {
            return false;
        };
        self.advance_all(t_end, next_event)
    }

    /// Event-mode quiescence probe — `O(islands)`, no component scan.
    ///
    /// The scheduler's wake invariant (every component with possible
    /// work holds a heap entry at or before the instant that work turns
    /// actionable) means the per-island heap heads already bound the
    /// whole system's next activity. Cycle keys convert to absolute
    /// times under the current period, valid because the span is also
    /// bounded by any pending DFS retiming — the same argument the
    /// idle-aware probe makes per tile.
    fn try_coalesce_event(&mut self, t_end: Ps) -> bool {
        let mut next_event = Ps::MAX;
        for (i, d) in self.islands.iter().enumerate() {
            if let Some(swap) = d.pending_retime() {
                if swap <= self.now {
                    return false;
                }
                next_event = next_event.min(swap);
            }
            if let Some((w, _)) = self.sched.cycle[i].peek() {
                if w <= d.cycles {
                    return false; // a due component: no span
                }
                let dt = (w - d.cycles).saturating_mul(d.period(self.now));
                next_event = next_event.min(d.last_edge().saturating_add(dt));
            }
            if let Some((at, _)) = self.sched.at[i].peek() {
                if at <= self.now {
                    return false;
                }
                next_event = next_event.min(at);
            }
        }

        let Some(next_event) = self.host_event_bound(next_event) else {
            return false;
        };
        self.advance_all(t_end, next_event)
    }

    /// Host schedule entries and sampler deadlines bound any quiescent
    /// span. Returns `None` when one is already due (no span possible).
    fn host_event_bound(&self, mut next_event: Ps) -> Option<Ps> {
        if self.schedule_next < self.schedule.len() {
            let at = self.schedule[self.schedule_next].0;
            if at <= self.now {
                return None;
            }
            next_event = next_event.min(at);
        }
        if let Some(s) = &self.sampler {
            let at = s.next_due();
            if at <= self.now {
                return None;
            }
            next_event = next_event.min(at);
        }
        Some(next_event)
    }

    /// Bulk-deliver every island edge strictly before `next_event`
    /// (bounded by `t_end`; the event's own edge runs through the
    /// normal step path) and resync the view and edge heap. Returns
    /// true if any edges were delivered.
    fn advance_all(&mut self, t_end: Ps, next_event: Ps) -> bool {
        let target = t_end.min(next_event.saturating_sub(1));
        if target <= self.now {
            return false;
        }
        let mut delivered = 0;
        for d in self.islands.iter_mut() {
            delivered += d.advance_span(target);
        }
        if delivered == 0 {
            return false;
        }
        self.edges += delivered;
        self.engine_stats.coalesced_spans += 1;
        self.engine_stats.coalesced_edges += delivered;
        for (i, d) in self.islands.iter().enumerate() {
            self.view.last_edges[i] = d.last_edge();
            self.view.periods[i] = d.period(d.last_edge());
        }
        self.now = target;
        self.heap.clear();
        for (i, d) in self.islands.iter().enumerate() {
            self.heap.push(Reverse((d.next_edge(d.last_edge()), i)));
        }
        true
    }

    /// Run the engine until simulated time `t_end`.
    pub fn run_until(&mut self, t_end: Ps) {
        loop {
            if self.quiet_edge {
                // One attempt per quiet edge: a failed probe stays
                // failed until some edge does work again.
                match self.engine {
                    EngineMode::IdleAware => {
                        self.try_coalesce(t_end);
                    }
                    EngineMode::EventDriven => {
                        self.try_coalesce_event(t_end);
                    }
                    EngineMode::Reference => {}
                }
                self.quiet_edge = false;
            }
            let due = self
                .heap
                .peek()
                .map(|Reverse((t, _))| *t <= t_end)
                .unwrap_or(false);
            if !due {
                break;
            }
            self.step();
        }
        self.now = t_end;
    }

    /// Run for `dur` more picoseconds.
    pub fn run_for(&mut self, dur: Ps) {
        let end = self.now + dur;
        self.run_until(end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::paper_soc;
    use crate::runtime::RefCompute;

    fn build_paper(a1: (&str, usize), a2: (&str, usize)) -> Soc {
        Soc::build(paper_soc(a1, a2), Box::new(RefCompute::new())).unwrap()
    }

    #[test]
    fn builds_and_steps() {
        let mut soc = build_paper(("dfadd", 1), ("dfmul", 1));
        let t0 = soc.step();
        assert!(t0 > 0);
        soc.run_until(1_000_000); // 1 us
        assert!(soc.edges > 50);
        assert_eq!(soc.now, 1_000_000);
    }

    #[test]
    fn edges_are_monotonic() {
        let mut soc = build_paper(("dfadd", 1), ("dfadd", 1));
        let mut last = 0;
        for _ in 0..1000 {
            let t = soc.step();
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn island_cycle_counts_match_frequencies() {
        let mut soc = build_paper(("dfadd", 1), ("dfadd", 1));
        soc.run_until(10_000_000); // 10 us
        // NoC at 100 MHz: ~1000 cycles; A1 at 50 MHz: ~500.
        let noc = soc.islands[0].cycles;
        let a1 = soc.islands[1].cycles;
        assert!((990..=1010).contains(&noc), "noc {noc}");
        assert!((495..=505).contains(&a1), "a1 {a1}");
    }

    #[test]
    fn dfs_request_changes_island_rate() {
        let mut soc = build_paper(("dfadd", 1), ("dfadd", 1));
        soc.run_until(1_000_000);
        soc.host_write_freq(1, 10).unwrap(); // A1: 50 -> 10 MHz
        soc.run_until(2_000_000);
        let cycles_before_swap = soc.islands[1].cycles;
        // After the actuator latency (11 us default) the island slows to
        // 10 MHz: over the next 10 us it gains only ~100 cycles.
        soc.run_until(13_000_000);
        let at_swap = soc.islands[1].cycles;
        soc.run_until(23_000_000);
        let after = soc.islands[1].cycles;
        let slow_rate = (after - at_swap) as f64 / 10.0; // cycles/us
        assert!(slow_rate < 15.0, "slow rate {slow_rate} (want ~10)");
        assert!(cycles_before_swap > 0);
    }

    #[test]
    fn tg_activation_counts() {
        let mut soc = build_paper(("adpcm", 4), ("dfmul", 4));
        assert_eq!(soc.tg_count(), 11);
        soc.host_set_tg_active(7);
        let active = soc
            .tiles
            .iter()
            .filter(|t| matches!(t, Tile::Tg(tg) if tg.enabled))
            .count();
        assert_eq!(active, 7);
    }

    #[test]
    fn tgs_generate_memory_traffic() {
        let mut soc = build_paper(("dfadd", 1), ("dfadd", 1));
        soc.host_set_tg_active(4);
        soc.run_until(200_000_000); // 200 us
        assert!(soc.mon.mem_pkts_in > 50, "mem pkts {}", soc.mon.mem_pkts_in);
        // Responses flow back: TGs complete round trips.
        let completed: u64 = soc
            .tiles
            .iter()
            .map(|t| match t {
                Tile::Tg(tg) => tg.completed,
                _ => 0,
            })
            .sum();
        assert!(completed > 20, "completed {completed}");
    }

    /// A small SoC with no self-driven traffic (TGs disabled, no MRA,
    /// CPU not polling): the idle-aware engine should coalesce almost
    /// the whole run.
    fn quiet_soc() -> Soc {
        let cfg = crate::scenario::Scenario::grid(2, 2)
            .island("noc", 100)
            .island("tg", 50)
            .noc_island("noc")
            .mem_at(0, 0)
            .io_at_on(1, 0, "tg")
            .fill_tg("tg")
            .build()
            .unwrap();
        Soc::build(cfg, Box::new(RefCompute::new())).unwrap()
    }

    #[test]
    fn idle_engine_coalesces_quiescent_spans() {
        let mut soc = quiet_soc();
        soc.set_engine(EngineMode::IdleAware);
        soc.run_until(10_000_000_000); // 10 ms
        assert_eq!(soc.now, 10_000_000_000);
        assert!(
            soc.engine_stats.coalesced_edges > 0,
            "{:?}",
            soc.engine_stats
        );
        // Bulk-delivered edges keep the counters exact: 10 ms at
        // 100 MHz / 50 MHz.
        assert_eq!(soc.islands[0].cycles, 1_000_000);
        assert_eq!(soc.islands[1].cycles, 500_000);
        assert_eq!(soc.edges, 1_500_000);
    }

    #[test]
    fn reference_engine_never_coalesces() {
        let mut soc = quiet_soc();
        soc.engine = EngineMode::Reference;
        soc.run_until(1_000_000); // 1 us
        assert_eq!(soc.engine_stats.coalesced_edges, 0);
        assert_eq!(soc.islands[0].cycles, 100);
    }

    #[test]
    fn event_engine_coalesces_quiescent_spans() {
        let mut soc = quiet_soc();
        soc.set_engine(EngineMode::EventDriven);
        soc.run_until(10_000_000_000); // 10 ms
        assert_eq!(soc.now, 10_000_000_000);
        assert!(
            soc.engine_stats.coalesced_edges > 0,
            "{:?}",
            soc.engine_stats
        );
        // Bulk-delivered edges keep the counters exact.
        assert_eq!(soc.islands[0].cycles, 1_000_000);
        assert_eq!(soc.islands[1].cycles, 500_000);
        assert_eq!(soc.edges, 1_500_000);
    }

    #[test]
    fn event_engine_carries_traffic_and_host_wakes() {
        let mut soc = build_paper(("dfadd", 1), ("dfadd", 1));
        soc.set_engine(EngineMode::EventDriven);
        soc.host_set_tg_active(4);
        soc.run_until(200_000_000); // 200 us
        assert!(soc.mon.mem_pkts_in > 50, "mem pkts {}", soc.mon.mem_pkts_in);
        let completed: u64 = soc
            .tiles
            .iter()
            .map(|t| match t {
                Tile::Tg(tg) => tg.completed,
                _ => 0,
            })
            .sum();
        assert!(completed > 20, "completed {completed}");
    }

    #[test]
    fn event_engine_applies_schedule_entries() {
        let mut soc = quiet_soc();
        soc.set_engine(EngineMode::EventDriven);
        soc.schedule_freq(4_000_000_000, 0, 100); // no-op write, fixed island
        soc.run_until(10_000_000_000);
        assert_eq!(soc.schedule_next, 1);
        assert!(soc.engine_stats.coalesced_edges > 0);
    }

    #[test]
    fn engine_switch_mid_run_stays_exact() {
        let mut soc = quiet_soc();
        soc.run_until(2_000_000_000); // event-driven (default)
        soc.set_engine(EngineMode::IdleAware);
        soc.run_until(6_000_000_000);
        soc.set_engine(EngineMode::EventDriven);
        soc.run_until(10_000_000_000);
        assert_eq!(soc.islands[0].cycles, 1_000_000);
        assert_eq!(soc.islands[1].cycles, 500_000);
        assert_eq!(soc.edges, 1_500_000);
    }

    #[test]
    fn sleeping_tiles_wake_on_host_toggle() {
        let mut soc = quiet_soc();
        soc.run_until(5_000_000_000); // all tiles asleep by now
        assert!(soc.engine_stats.coalesced_edges > 0);
        soc.host_set_tg_active(2);
        soc.run_until(10_000_000_000);
        assert!(
            soc.mon.mem_pkts_in > 50,
            "woken TGs must reach memory: {}",
            soc.mon.mem_pkts_in
        );
    }

    #[test]
    fn coalescing_stops_at_schedule_entries() {
        let mut soc = quiet_soc();
        soc.schedule_freq(4_000_000_000, 0, 100); // no-op write, fixed island
        soc.run_until(10_000_000_000);
        // The entry applied (consumed), even though the whole run is
        // quiescent and heavily coalesced.
        assert_eq!(soc.schedule_next, 1);
        assert!(soc.engine_stats.coalesced_edges > 0);
    }

    #[test]
    fn packet_arena_drains() {
        let mut soc = build_paper(("dfadd", 1), ("dfadd", 1));
        soc.host_set_tg_active(2);
        soc.run_until(100_000_000);
        soc.host_set_tg_active(0);
        soc.run_until(200_000_000);
        // All in-flight packets eventually delivered and released.
        assert!(
            soc.arena.live() < 40,
            "arena leak: {} live",
            soc.arena.live()
        );
    }
}
