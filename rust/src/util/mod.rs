//! Shared utilities: deterministic PRNG, time/frequency arithmetic,
//! online statistics, and a minimal property-testing harness.
//!
//! These exist because the build is fully offline: `rand`, `proptest`,
//! and friends are not available, and the simulator needs deterministic,
//! seedable randomness anyway (runs must be bit-reproducible).

pub mod proptest;
pub mod rng;
pub mod stats;
pub mod time;

pub use rng::SplitMix64;
pub use stats::{Histogram, OnlineStats, Percentiles};
pub use time::{Freq, Ps, MHZ};
