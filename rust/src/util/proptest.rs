//! Minimal property-testing harness (offline stand-in for `proptest`).
//!
//! `forall` draws `cases` random inputs from a generator and asserts the
//! property on each; on failure it reports the failing seed so the case
//! can be replayed deterministically:
//!
//! ```
//! use vespa::util::proptest::forall;
//! forall(0xBEEF, 100, |r| r.range_i64(0, 100), |x| {
//!     assert!(*x >= 0 && *x <= 100);
//! });
//! ```

use super::rng::SplitMix64;

/// Run `prop` on `cases` values drawn by `gen`. Panics with the failing
/// case index and seed on the first violation.
pub fn forall<T: core::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut SplitMix64) -> T,
    mut prop: impl FnMut(&T),
) {
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        // Fork per case so a property that consumes randomness cannot
        // shift later cases (replays stay aligned).
        let mut case_rng = rng.fork();
        let value = gen(&mut case_rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&value)));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases} (seed {seed:#x})\ninput: {value:?}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(1, 200, |r| r.next_below(10), |x| assert!(*x < 10));
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        forall(2, 50, |r| r.next_below(10), |x| assert!(*x < 5));
    }

    #[test]
    fn replays_are_deterministic() {
        let mut a = Vec::new();
        forall(3, 20, |r| r.next_u64(), |x| a.push(*x));
        let mut b = Vec::new();
        forall(3, 20, |r| r.next_u64(), |x| b.push(*x));
        assert_eq!(a, b);
    }
}
