//! Online statistics used by the monitoring infrastructure and the
//! benchmark harness: Welford mean/variance, min/max, and a fixed-bucket
//! histogram with percentile queries.

/// Streaming mean/variance/min/max accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator). Zero for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-width-bucket histogram over `[lo, hi)` with overflow buckets,
/// supporting approximate percentile queries. Used for latency (RTT)
/// distributions in the monitoring reports.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; buckets],
            under: 0,
            over: 0,
            count: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = ((x - self.lo) / w) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate percentile (bucket upper edge). `q` in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = self.under;
        if seen >= target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return self.lo + w * (i as f64 + 1.0);
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn stats_empty() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn stats_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i * 37 % 13) as f64).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..40] {
            a.push(x);
        }
        for &x in &xs[40..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..100 {
            h.push(i as f64 + 0.5);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(0.5);
        assert!((49.0..=52.0).contains(&p50), "p50 {p50}");
        let p99 = h.percentile(0.99);
        assert!((98.0..=100.0).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn histogram_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(50.0);
        h.push(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.percentile(1.0), 10.0);
    }
}
