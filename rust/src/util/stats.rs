//! Online statistics used by the monitoring infrastructure and the
//! benchmark harness: Welford mean/variance, min/max, a fixed-bucket
//! histogram with approximate percentile queries, and exact sample
//! percentiles ([`Percentiles`]) for tail-latency reporting.

/// Streaming mean/variance/min/max accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator). Zero for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-width-bucket histogram over `[lo, hi)` with overflow buckets,
/// supporting approximate percentile queries. Used for latency (RTT)
/// distributions in the monitoring reports.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; buckets],
            under: 0,
            over: 0,
            count: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = ((x - self.lo) / w) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate percentile (bucket upper edge). `q` in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = self.under;
        if seen >= target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return self.lo + w * (i as f64 + 1.0);
            }
        }
        self.hi
    }
}

/// Exact empirical quantiles over a finite sample set (nearest-rank
/// method: the q-quantile of n sorted samples is the `ceil(q*n)`-th
/// smallest). Unlike [`Histogram::percentile`] there is no bucketing
/// error — the returned value is always one of the observed samples —
/// which is what tail-latency SLO checks need (`crate::serve` reports
/// p50/p95/p99 through this type).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Percentiles {
    sorted: Vec<f64>,
}

impl Percentiles {
    /// Build from raw samples. Rejects NaN (a NaN would poison the sort
    /// order and every quantile after it); infinities are allowed and
    /// sort to the extremes.
    pub fn from_samples(samples: &[f64]) -> crate::Result<Self> {
        if let Some(bad) = samples.iter().position(|x| x.is_nan()) {
            anyhow::bail!("percentiles: sample #{bad} is NaN");
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        Ok(Self { sorted })
    }

    pub fn count(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Exact nearest-rank quantile; `q` is clamped to `[0, 1]`. Returns
    /// 0.0 on an empty sample (matching [`OnlineStats`]'s conventions).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.sorted.len();
        if n == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * n as f64).ceil() as usize;
        self.sorted[rank.clamp(1, n) - 1]
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(0.0)
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    /// Exact merge of two sample sets: the result holds every sample of
    /// both inputs, so `a.merge(&b)` is identical to
    /// [`Percentiles::from_samples`] over the concatenated raw samples —
    /// no summarization error, unlike mergeable sketches. A linear
    /// two-pointer merge of the already-sorted vectors (`O(n + m)`,
    /// cheaper than re-sorting). This is how
    /// [`crate::cluster::ClusterReport`] combines per-replica latency
    /// distributions into fleet-wide percentiles.
    pub fn merge(&self, other: &Percentiles) -> Percentiles {
        let (a, b) = (&self.sorted, &other.sorted);
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            if a[i].total_cmp(&b[j]).is_le() {
                out.push(a[i]);
                i += 1;
            } else {
                out.push(b[j]);
                j += 1;
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        Percentiles { sorted: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn stats_empty() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn stats_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i * 37 % 13) as f64).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..40] {
            a.push(x);
        }
        for &x in &xs[40..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..100 {
            h.push(i as f64 + 0.5);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(0.5);
        assert!((49.0..=52.0).contains(&p50), "p50 {p50}");
        let p99 = h.percentile(0.99);
        assert!((98.0..=100.0).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn histogram_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(50.0);
        h.push(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.percentile(1.0), 10.0);
    }

    #[test]
    fn percentiles_exact_on_small_samples() {
        // Nearest-rank: on one sample every quantile is that sample.
        let p = Percentiles::from_samples(&[7.0]).unwrap();
        assert_eq!(p.quantile(0.0), 7.0);
        assert_eq!(p.p50(), 7.0);
        assert_eq!(p.p99(), 7.0);
        assert_eq!(p.max(), 7.0);
        // Two samples: p50 is the 1st (ceil(0.5*2) = 1), p99 the 2nd.
        let p = Percentiles::from_samples(&[10.0, 20.0]).unwrap();
        assert_eq!(p.p50(), 10.0);
        assert_eq!(p.p99(), 20.0);
    }

    #[test]
    fn percentiles_exact_on_odd_counts() {
        // 1..=5: p50 = ceil(0.5*5) = 3rd smallest = 3.
        let p = Percentiles::from_samples(&[5.0, 1.0, 4.0, 2.0, 3.0]).unwrap();
        assert_eq!(p.count(), 5);
        assert_eq!(p.p50(), 3.0);
        assert_eq!(p.quantile(0.2), 1.0);
        assert_eq!(p.quantile(0.21), 2.0);
        assert_eq!(p.min(), 1.0);
        assert_eq!(p.max(), 5.0);
        assert_eq!(p.mean(), 3.0);
    }

    #[test]
    fn percentiles_duplicate_heavy() {
        // 97 zeros and 3 spikes: p95 must still be 0, p99 a spike —
        // exactly where bucketed histograms smear.
        let mut xs = vec![0.0; 97];
        xs.extend([100.0, 100.0, 100.0]);
        let p = Percentiles::from_samples(&xs).unwrap();
        assert_eq!(p.p50(), 0.0);
        assert_eq!(p.p95(), 0.0);
        assert_eq!(p.p99(), 100.0);
        assert_eq!(p.max(), 100.0);
    }

    #[test]
    fn percentiles_reject_nan() {
        let err = Percentiles::from_samples(&[1.0, f64::NAN, 2.0]).unwrap_err();
        assert!(err.to_string().contains("NaN"), "{err}");
        assert!(err.to_string().contains("#1"), "{err}");
    }

    #[test]
    fn percentiles_empty_and_clamped_q() {
        let p = Percentiles::from_samples(&[]).unwrap();
        assert!(p.is_empty());
        assert_eq!(p.quantile(0.5), 0.0);
        assert_eq!(p.max(), 0.0);
        let p = Percentiles::from_samples(&[3.0, 9.0]).unwrap();
        assert_eq!(p.quantile(-1.0), 3.0);
        assert_eq!(p.quantile(2.0), 9.0);
    }

    #[test]
    fn percentiles_merge_equals_from_concat() {
        let a = Percentiles::from_samples(&[5.0, 1.0, 9.0]).unwrap();
        let b = Percentiles::from_samples(&[2.0, 9.0, 0.5, 7.0]).unwrap();
        let merged = a.merge(&b);
        let concat =
            Percentiles::from_samples(&[5.0, 1.0, 9.0, 2.0, 9.0, 0.5, 7.0]).unwrap();
        assert_eq!(merged, concat, "merge is exact, not a sketch");
        assert_eq!(merged.count(), a.count() + b.count());
        // Merging with an empty set is the identity.
        let empty = Percentiles::default();
        assert_eq!(a.merge(&empty), a);
        assert_eq!(empty.merge(&a), a);
        assert_eq!(empty.merge(&empty).count(), 0);
    }

    /// Property: for arbitrary sample-set pairs, merge(a, b) equals
    /// from_samples(a ++ b) exactly, and the merged quantiles are
    /// monotone in q and bracketed by the inputs' extremes.
    #[test]
    fn percentiles_merge_property() {
        use crate::util::proptest::forall;
        forall(
            0x4E16,
            200,
            |r| {
                let gen_one = |r: &mut crate::util::SplitMix64| {
                    let n = r.index(48);
                    (0..n).map(|_| r.index(16) as f64 * 1.25).collect::<Vec<f64>>()
                };
                let a = gen_one(r);
                let b = gen_one(r);
                (a, b)
            },
            |(xs, ys)| {
                let a = Percentiles::from_samples(xs).unwrap();
                let b = Percentiles::from_samples(ys).unwrap();
                let merged = a.merge(&b);
                let mut concat = xs.clone();
                concat.extend_from_slice(ys);
                assert_eq!(merged, Percentiles::from_samples(&concat).unwrap());
                assert_eq!(merged.count(), xs.len() + ys.len());
                // Monotone quantiles on the merged set.
                assert!(merged.p50() <= merged.p95());
                assert!(merged.p95() <= merged.p99());
                assert!(merged.p99() <= merged.max());
                // Extremes come from the inputs.
                if !merged.is_empty() {
                    let lo = if a.is_empty() {
                        b.min()
                    } else if b.is_empty() {
                        a.min()
                    } else {
                        a.min().min(b.min())
                    };
                    assert_eq!(merged.min(), lo);
                    assert_eq!(merged.max(), a.max().max(b.max()));
                }
            },
        );
    }

    /// Property: quantiles are monotone in q (p50 <= p95 <= p99 <= max)
    /// on arbitrary sample sets, including duplicate-heavy ones.
    #[test]
    fn percentiles_monotone_property() {
        use crate::util::proptest::forall;
        forall(
            0x9E7C,
            200,
            |r| {
                let n = 1 + r.index(64);
                // Coarse values force heavy duplication in many cases.
                (0..n).map(|_| r.index(8) as f64 * 2.5).collect::<Vec<f64>>()
            },
            |xs| {
                let p = Percentiles::from_samples(xs).unwrap();
                assert!(p.p50() <= p.p95());
                assert!(p.p95() <= p.p99());
                assert!(p.p99() <= p.max());
                assert!(p.min() <= p.p50());
            },
        );
    }
}
