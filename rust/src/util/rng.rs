//! SplitMix64 — the simulator's only randomness source.
//!
//! Chosen over a larger generator because every consumer needs (a) cheap
//! forking (one u64 of state), (b) bit-stable streams across platforms,
//! and (c) no external crate. Quality is more than sufficient for
//! traffic-generation jitter and DSE sampling.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Fork an independent child stream (used to give each tile its own
    /// generator so tick ordering cannot perturb another tile's stream).
    pub fn fork(&mut self) -> Self {
        Self::new(self.next_u64() ^ 0x9E37_79B9_7F4A_7C15)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias is
        // negligible for simulator purposes (bound << 2^64).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo) as u64 + 1) as i64
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector() {
        // First outputs for seed 0 (cross-checked against the reference
        // SplitMix64 implementation).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = SplitMix64::new(7);
        let mut fork = a.fork();
        let x: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..64).map(|_| fork.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(3);
        for bound in [1u64, 2, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = SplitMix64::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            match r.range_i64(0, 3) {
                0 => lo_seen = true,
                3 => hi_seen = true,
                1 | 2 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
