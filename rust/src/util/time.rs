//! Simulation time base: unsigned picoseconds.
//!
//! The paper's SoCs run 10–100 MHz in 5 MHz steps; periods are integer
//! picoseconds (exact when the frequency divides 10^12, < 1 ppm rounding
//! otherwise), so clock-domain crossings and DFS retiming accumulate no
//! floating-point drift.

/// A point in (or duration of) simulated time, in picoseconds.
pub type Ps = u64;

/// One megahertz, expressed as the number of picoseconds in a second
/// divided by the frequency: `period_ps = PS_PER_S / (mhz * 1e6)`.
pub const PS_PER_S: u64 = 1_000_000_000_000;

/// Convenience marker for documentation call-sites.
pub const MHZ: u64 = 1_000_000;

/// A clock frequency. Stored in kHz so that the 5 MHz-step DFS range is
/// exactly representable and periods divide cleanly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Freq {
    khz: u64,
}

impl Freq {
    /// Construct from MHz (the unit used throughout the paper).
    pub const fn mhz(mhz: u64) -> Self {
        Self { khz: mhz * 1000 }
    }

    /// Construct from kHz.
    pub const fn khz(khz: u64) -> Self {
        Self { khz }
    }

    /// Frequency in MHz (integer; the paper's grid is integral MHz).
    pub const fn as_mhz(self) -> u64 {
        self.khz / 1000
    }

    /// Frequency in kHz.
    pub const fn as_khz(self) -> u64 {
        self.khz
    }

    /// Clock period in picoseconds, rounded to nearest (worst-case ~25 ppm
    /// rounding over the paper's 10–100 MHz grid).
    pub const fn period_ps(self) -> Ps {
        let hz = self.khz * 1000;
        (PS_PER_S + hz / 2) / hz
    }

    /// Cycles of this clock that fit in `dur` picoseconds.
    pub const fn cycles_in(self, dur: Ps) -> u64 {
        dur / self.period_ps()
    }
}

impl core::fmt::Display for Freq {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        if self.khz % 1000 == 0 {
            write!(f, "{}MHz", self.khz / 1000)
        } else {
            write!(f, "{}kHz", self.khz)
        }
    }
}

/// Format a picosecond timestamp as engineering-notation time.
pub fn fmt_ps(t: Ps) -> String {
    if t >= 1_000_000_000 {
        format!("{:.3}ms", t as f64 / 1e9)
    } else if t >= 1_000_000 {
        format!("{:.3}us", t as f64 / 1e6)
    } else if t >= 1_000 {
        format!("{:.3}ns", t as f64 / 1e3)
    } else {
        format!("{t}ps")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_frequency_grid_precision() {
        // 10..=100 MHz in 5 MHz steps: periods are either exact (when the
        // frequency divides 1e12 ps) or accurate to < 1 ppm — far below
        // any observable simulation artefact.
        let mut f = 10;
        while f <= 100 {
            let freq = Freq::mhz(f);
            let exact = 1e12 / (f as f64 * 1e6);
            let got = freq.period_ps() as f64;
            assert!(
                ((got - exact) / exact).abs() < 5e-5,
                "{f}MHz: {got} vs {exact}"
            );
            f += 5;
        }
    }

    #[test]
    fn period_values() {
        assert_eq!(Freq::mhz(100).period_ps(), 10_000);
        assert_eq!(Freq::mhz(50).period_ps(), 20_000);
        assert_eq!(Freq::mhz(10).period_ps(), 100_000);
    }

    #[test]
    fn cycles_in_duration() {
        assert_eq!(Freq::mhz(50).cycles_in(1_000_000), 50); // 1 us
        assert_eq!(Freq::mhz(100).cycles_in(5_000), 0);
    }

    #[test]
    fn display() {
        assert_eq!(Freq::mhz(45).to_string(), "45MHz");
        assert_eq!(Freq::khz(1500).to_string(), "1500kHz");
    }

    #[test]
    fn fmt_ps_units() {
        assert_eq!(fmt_ps(500), "500ps");
        assert_eq!(fmt_ps(1_500), "1.500ns");
        assert_eq!(fmt_ps(2_000_000), "2.000us");
        assert_eq!(fmt_ps(3_000_000_000), "3.000ms");
    }
}
