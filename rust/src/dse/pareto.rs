//! Pareto filtering for (cost, benefit) design points.

/// Indices of the Pareto-optimal points for (minimize cost, maximize
/// benefit). Stable order (by cost ascending).
///
/// NaN-safe: ordering uses [`f64::total_cmp`], so a degenerate point
/// (e.g. a zero-sample measurement window producing NaN throughput)
/// sorts deterministically instead of panicking the whole sweep, and
/// any point with a NaN cost or benefit is skipped outright — an
/// incomparable point is never reported as optimal.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[b].1.total_cmp(&points[a].1))
    });
    let mut front = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for i in idx {
        let (cost, benefit) = points[i];
        if cost.is_nan() || benefit.is_nan() {
            continue;
        }
        if benefit > best {
            front.push(i);
            best = benefit;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn simple_front() {
        // (cost, benefit)
        let pts = [(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (4.0, 4.0)];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]); // (3.0, 2.0) dominated by (2.0, 3.0)
    }

    #[test]
    fn single_point() {
        assert_eq!(pareto_front(&[(5.0, 5.0)]), vec![0]);
    }

    /// A degenerate zero-sample window can hand the sweep a NaN
    /// throughput; the front must not panic and must not admit the NaN
    /// point.
    #[test]
    fn nan_points_do_not_panic_or_enter_the_front() {
        let pts = [
            (1.0, 1.0),
            (f64::NAN, f64::NAN),
            (2.0, 3.0),
            (3.0, f64::NAN),
            (f64::NAN, 5.0), // NaN *cost* must be excluded too
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 2]);
        // All-NaN input: deterministic, non-panicking, empty front.
        let all = [(f64::NAN, f64::NAN); 3];
        assert!(pareto_front(&all).is_empty());
    }

    #[test]
    fn prop_front_members_not_dominated() {
        forall(
            0xDA7E,
            200,
            |r| {
                let n = r.next_below(20) as usize + 1;
                (0..n)
                    .map(|_| (r.next_f64() * 100.0, r.next_f64() * 100.0))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let front = pareto_front(pts);
                assert!(!front.is_empty());
                for &i in &front {
                    for (j, q) in pts.iter().enumerate() {
                        if i == j {
                            continue;
                        }
                        let dominated =
                            q.0 <= pts[i].0 && q.1 >= pts[i].1 && (q.0 < pts[i].0 || q.1 > pts[i].1);
                        assert!(!dominated, "front point {i} dominated by {j}");
                    }
                }
            },
        );
    }
}
