//! Pareto filtering for (cost, benefit) design points.

/// Indices of the Pareto-optimal points for (minimize cost, maximize
/// benefit). Stable order (by cost ascending).
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[b].1.partial_cmp(&points[a].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for i in idx {
        if points[i].1 > best {
            front.push(i);
            best = points[i].1;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn simple_front() {
        // (cost, benefit)
        let pts = [(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (4.0, 4.0)];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]); // (3.0, 2.0) dominated by (2.0, 3.0)
    }

    #[test]
    fn single_point() {
        assert_eq!(pareto_front(&[(5.0, 5.0)]), vec![0]);
    }

    #[test]
    fn prop_front_members_not_dominated() {
        forall(
            0xDA7E,
            200,
            |r| {
                let n = r.next_below(20) as usize + 1;
                (0..n)
                    .map(|_| (r.next_f64() * 100.0, r.next_f64() * 100.0))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let front = pareto_front(pts);
                assert!(!front.is_empty());
                for &i in &front {
                    for (j, q) in pts.iter().enumerate() {
                        if i == j {
                            continue;
                        }
                        let dominated =
                            q.0 <= pts[i].0 && q.1 >= pts[i].1 && (q.0 < pts[i].0 || q.1 > pts[i].1);
                        assert!(!dominated, "front point {i} dominated by {j}");
                    }
                }
            },
        );
    }
}
