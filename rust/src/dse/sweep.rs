//! Replication / frequency / placement sweeps over [`ScenarioSpec`]
//! design points, evaluated serially or across threads via
//! [`ScenarioSet`].

use crate::resources::{mra_area, AccelArea, Utilization, XC7V2000T};
use crate::scenario::{ScenarioSet, ScenarioSpec, Session};
use crate::tiles::AccelTiming;
use crate::util::Ps;

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DsePoint {
    pub accel: String,
    pub replicas: usize,
    pub accel_mhz: u64,
    pub noc_mhz: u64,
    pub near_mem: bool,
    pub area: Utilization,
    pub throughput_mbs: f64,
}

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepParams {
    pub accel: String,
    pub replications: Vec<usize>,
    pub accel_mhz: Vec<u64>,
    pub noc_mhz: Vec<u64>,
    pub placements: Vec<bool>, // true = A1 (near MEM), false = A2
    /// Simulated measurement window per point.
    pub window: Ps,
    /// Warmup before the window.
    pub warmup: Ps,
}

impl SweepParams {
    /// A quick default sweep for `accel`.
    pub fn quick(accel: &str) -> Self {
        Self {
            accel: accel.to_string(),
            replications: vec![1, 2, 4],
            accel_mhz: vec![50],
            noc_mhz: vec![100],
            placements: vec![true],
            window: 20_000_000_000, // 20 ms
            warmup: 2_000_000_000,
        }
    }

    /// Expand the cross product into scenario specs (replication-major
    /// order, matching the historical serial sweep).
    pub fn specs(&self) -> Vec<ScenarioSpec> {
        let mut out = Vec::new();
        for &k in &self.replications {
            for &am in &self.accel_mhz {
                for &nm in &self.noc_mhz {
                    for &near in &self.placements {
                        out.push(
                            ScenarioSpec::new(&self.accel, k)
                                .accel_mhz(am)
                                .noc_mhz(nm)
                                .near_mem(near)
                                .warmup(self.warmup)
                                .window(self.window),
                        );
                    }
                }
            }
        }
        out
    }
}

/// Evaluate one design point by simulation (TGs off, as Table I).
pub fn evaluate_point(spec: &ScenarioSpec) -> crate::Result<DsePoint> {
    // to_config() pre-validates name and replication, so user-typed CLI
    // input gets a clean error rather than the preset's panic.
    let cfg = spec.to_config()?;
    let timing = AccelTiming::lookup(&spec.accel)?;
    let mut session = Session::new(cfg)?;
    let pos = spec.position();
    let tile = session.tile_at(pos.0, pos.1);
    session.stage(tile, 1)?.perf_only();

    // Scale the measurement to the accelerator's invocation time so slow
    // accelerators (gsm: ~18 ms, adpcm: ~23 ms per invocation at 50 MHz)
    // still complete several invocations in the window.
    let inv_ps = timing.compute_cycles * 1_000_000 / spec.accel_mhz.max(1);
    let warmup = spec.warmup.max(2 * inv_ps);
    let window = spec.window.max(8 * inv_ps / spec.replicas as u64 + inv_ps);

    session.warmup(warmup);
    let report = session.measure(tile, window)?;

    let area = mra_area(&AccelArea::lookup(&spec.accel)?, spec.replicas);
    Ok(DsePoint {
        accel: spec.accel.clone(),
        replicas: spec.replicas,
        accel_mhz: spec.accel_mhz,
        noc_mhz: spec.noc_mhz,
        near_mem: spec.near_mem,
        area,
        throughput_mbs: report.throughput_mbs,
    })
}

/// Run a full sweep across all available cores. Results are ordered by
/// design-point index and bit-identical to [`sweep_replication_serial`]
/// (each point simulates in its own `Soc`, seeded from the config).
pub fn sweep_replication(p: &SweepParams) -> crate::Result<Vec<DsePoint>> {
    ScenarioSet::new(p.specs()).run_parallel(evaluate_point)
}

/// Serial reference path for the sweep (equivalence baseline, profiling).
pub fn sweep_replication_serial(p: &SweepParams) -> crate::Result<Vec<DsePoint>> {
    ScenarioSet::new(p.specs()).run_serial(evaluate_point)
}

/// Utilization check of a point against the paper's device.
pub fn fits_device(pt: &DsePoint) -> bool {
    pt.area.fits(&XC7V2000T.capacity)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_single_point_quickly() {
        // Short window: just prove the plumbing works end to end.
        let spec = ScenarioSpec::new("dfmul", 2)
            .warmup(500_000_000)
            .window(4_000_000_000);
        let pt = evaluate_point(&spec).unwrap();
        assert_eq!(pt.replicas, 2);
        assert!(pt.throughput_mbs > 0.5, "thr {}", pt.throughput_mbs);
        assert!(fits_device(&pt));
        assert!(pt.area.lut > 11_000);
    }

    #[test]
    fn unknown_accel_is_a_clean_error() {
        let spec = ScenarioSpec::new("warpcore", 1);
        let err = evaluate_point(&spec).unwrap_err().to_string();
        assert!(err.contains("warpcore"), "{err}");
    }

    #[test]
    fn specs_expand_in_replication_major_order() {
        let mut p = SweepParams::quick("dfadd");
        p.replications = vec![1, 2];
        p.placements = vec![true, false];
        let specs = p.specs();
        assert_eq!(specs.len(), 4);
        assert_eq!(
            specs.iter().map(|s| (s.replicas, s.near_mem)).collect::<Vec<_>>(),
            vec![(1, true), (1, false), (2, true), (2, false)]
        );
    }
}
