//! Replication / frequency / placement sweeps over [`ScenarioSpec`]
//! design points, evaluated serially or across threads via
//! [`ScenarioSet`].
//!
//! # Warm-start sweeps
//!
//! The paper's fine-grained DFS makes island frequencies a *run-time*
//! knob: retuning a frequency island does not change the SoC's
//! structure. [`SweepMode::WarmFork`] exploits exactly that. Points are
//! grouped by [structural key](SweepMode::WarmFork) (accelerator,
//! replication, placement, phase lengths); one base `Soc` per group is
//! built and warmed up at the preset's initial frequencies, snapshotted
//! ([`crate::scenario::Session::snapshot`]), and every frequency point
//! forks the snapshot, retunes through the DFS actuators
//! (`ClockDomain::request_freq`, the same path the host uses on
//! hardware), settles past the actuator swap, and measures. The
//! dominant warmup cost is paid once per structure instead of once per
//! frequency pair — see `docs/PERF.md` ("Warm-start sweeps") for the
//! exactness contract and `rust/benches/dse_sweep.rs` for the measured
//! speedup.
//!
//! Both [`sweep_replication`] paths additionally memoize evaluated
//! points in a per-process cache keyed by the canonicalized spec (plus
//! sweep mode and [`Objective`] fingerprint), so repeated points across
//! [`ScenarioSet`]s and Pareto iterations never re-simulate
//! ([`clear_memo`] resets it, e.g. between bench runs).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::clock::domain::FreqError;
use crate::cluster::{serve_cluster, AutoscaleSpec, ClusterSpec};
use crate::config::presets::ISL_NOC;
use crate::fault::HealthSpec;
use crate::resources::{mra_area, AccelArea, Utilization, XC7V2000T};
use crate::scenario::{ScenarioSet, ScenarioSpec, Session, SocSnapshot};
use crate::serve::{DispatchPolicy, ServeSpec};
use crate::tiles::AccelTiming;
use crate::util::Ps;

/// What a sweep optimizes for.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum Objective {
    /// Steady-state throughput over a warmup/measure window (Table I) —
    /// the historical metric.
    #[default]
    Throughput,
    /// Tail latency under served traffic: each point serves `spec`'s
    /// arrivals on its accelerator-under-test and is ranked by
    /// p99-under-SLO ([`rank_by_p99_under_slo`]) instead of raw MB/s.
    /// Serving starts from a quiescent accelerator, so these sweeps
    /// always evaluate cold regardless of [`SweepParams::mode`].
    TailLatency {
        /// Serving phase run at every point (`tiles` is overridden with
        /// the point's accelerator-under-test).
        spec: ServeSpec,
    },
    /// Fleet sizing: every design point is evaluated as a *cluster* of
    /// `fleets[i]` replica SoCs serving `serve`'s arrivals behind
    /// `balancer`, and ranked by replica-seconds-under-SLO
    /// ([`rank_by_replica_seconds_under_slo`]) — the fleet-size axis
    /// joins frequency and replication as a sweepable knob. Like
    /// [`Objective::TailLatency`], always evaluates cold.
    Cluster {
        /// Serving phase run at every (point, fleet) pair (`tiles` is
        /// overridden with the point's accelerator-under-test).
        serve: ServeSpec,
        /// Front-end balancer across replicas.
        balancer: DispatchPolicy,
        /// Optional elasticity; `min_replicas` is clamped to each fleet
        /// size.
        autoscale: Option<AutoscaleSpec>,
        /// Fleet sizes to sweep (each spec evaluates once per entry).
        fleets: Vec<usize>,
        /// Worker threads stepping each cluster's replicas
        /// ([`ClusterSpec::threads`](field@ClusterSpec::threads)):
        /// `0` = all cores, `1` = serial.
        /// Reports are bit-identical for every value, so this does NOT
        /// key the memo fingerprint.
        threads: usize,
    },
    /// Resilience: every design point serves `serve`'s arrivals as a
    /// `fleet`-replica cluster *while the spec's fault plan runs*
    /// (`serve.faults` + `serve.retry`, plus cluster-side health
    /// checks), and is ranked by p99-under-SLO
    /// ([`rank_by_p99_under_slo`]) — the design that rides through the
    /// fault schedule with the best tail wins. Always evaluates cold,
    /// like the other serving objectives.
    Robust {
        /// Serving phase (with its fault plan and retry policy) run at
        /// every point; `tiles` is overridden per point.
        serve: ServeSpec,
        /// Front-end balancer across replicas.
        balancer: DispatchPolicy,
        /// Health-check policy (eviction + warm-standby replacement).
        health: HealthSpec,
        /// Fleet size each point is evaluated at.
        fleet: usize,
        /// Worker threads per cluster; bit-identical reports, so NOT in
        /// the memo fingerprint.
        threads: usize,
    },
}

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DsePoint {
    pub accel: String,
    pub replicas: usize,
    pub accel_mhz: u64,
    pub noc_mhz: u64,
    pub near_mem: bool,
    pub area: Utilization,
    pub throughput_mbs: f64,
    /// Simulated time before the measurement window opened — the
    /// warmup *actually* run, making `evaluate_point`'s silent
    /// invocation-time floor observable (for a `WarmFork` point this is
    /// the shared base warmup plus the retune settle span).
    pub eff_warmup_ps: Ps,
    /// Length of the measurement window actually simulated (the spec's
    /// window, floored so slow accelerators complete enough
    /// invocations).
    pub eff_window_ps: Ps,
    /// Exact p99 end-to-end latency (ps) under
    /// [`Objective::TailLatency`]; `None` for throughput points or when
    /// nothing completed.
    pub p99_latency_ps: Option<f64>,
    /// Achieved completion rate (req/s) under serving objectives.
    pub achieved_rps: Option<f64>,
    /// Whether the serving SLO was met (p95 within the spec's SLO).
    pub slo_met: Option<bool>,
    /// Fleet size (replica SoCs) under [`Objective::Cluster`]; `None`
    /// for single-SoC points.
    pub fleet: Option<usize>,
    /// Cost proxy under [`Objective::Cluster`]: total active replica
    /// time in seconds ([`ClusterReport::replica_seconds`](crate::cluster::ClusterReport)).
    pub replica_seconds: Option<f64>,
}

/// How a sweep turns design points into simulations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SweepMode {
    /// Build and warm up a fresh `Soc` for every point — the reference
    /// path (bit-identical serial/parallel, no shared state).
    #[default]
    Cold,
    /// One warmed base `Soc` per structure (accelerator, replication,
    /// placement, phase lengths); frequency points fork its snapshot
    /// and retune at run time through the DFS actuators. Within a
    /// stated tolerance of [`SweepMode::Cold`] (see `docs/PERF.md`),
    /// and typically several times faster on frequency-major sweeps.
    WarmFork,
}

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepParams {
    pub accel: String,
    pub replications: Vec<usize>,
    pub accel_mhz: Vec<u64>,
    pub noc_mhz: Vec<u64>,
    pub placements: Vec<bool>, // true = A1 (near MEM), false = A2
    /// Simulated measurement window per point.
    pub window: Ps,
    /// Warmup before the window.
    pub warmup: Ps,
    /// Evaluation strategy (default [`SweepMode::Cold`]).
    pub mode: SweepMode,
    /// Worker threads (`0` = all cores, `1` = serial — deterministic
    /// wall-clock comparisons and profiling).
    pub threads: usize,
    /// What each point is scored on (default
    /// [`Objective::Throughput`]).
    pub objective: Objective,
}

impl SweepParams {
    /// A quick default sweep for `accel`.
    pub fn quick(accel: &str) -> Self {
        Self {
            accel: accel.to_string(),
            replications: vec![1, 2, 4],
            accel_mhz: vec![50],
            noc_mhz: vec![100],
            placements: vec![true],
            window: 20_000_000_000, // 20 ms
            warmup: 2_000_000_000,
            mode: SweepMode::Cold,
            threads: 0,
            objective: Objective::Throughput,
        }
    }

    /// Expand the cross product into scenario specs (replication-major
    /// order, matching the historical serial sweep).
    pub fn specs(&self) -> Vec<ScenarioSpec> {
        let mut out = Vec::new();
        for &k in &self.replications {
            for &am in &self.accel_mhz {
                for &nm in &self.noc_mhz {
                    for &near in &self.placements {
                        out.push(
                            ScenarioSpec::new(&self.accel, k)
                                .accel_mhz(am)
                                .noc_mhz(nm)
                                .near_mem(near)
                                .warmup(self.warmup)
                                .window(self.window),
                        );
                    }
                }
            }
        }
        out
    }
}

/// The warmup/window `evaluate_point` actually simulates for `spec`:
/// the spec's values, floored to the accelerator's invocation time so
/// slow accelerators (gsm: ~18 ms, adpcm: ~23 ms per invocation at
/// 50 MHz) still complete several invocations. Surfaced per point in
/// [`DsePoint::eff_warmup_ps`] / [`DsePoint::eff_window_ps`] so
/// Table-I reproductions can report what was actually simulated.
pub fn effective_phases(spec: &ScenarioSpec) -> crate::Result<(Ps, Ps)> {
    let timing = AccelTiming::lookup(&spec.accel)?;
    let inv_ps = invocation_ps(&timing, spec.accel_mhz);
    let warmup = spec.warmup.max(2 * inv_ps);
    // `.max(1)`: a replicas=0 spec must reach `to_config`'s clean
    // validation error, not divide by zero here.
    let window = spec
        .window
        .max(8 * inv_ps / spec.replicas.max(1) as u64 + inv_ps);
    Ok((warmup, window))
}

/// One invocation's duration at `accel_mhz`, in ps.
fn invocation_ps(timing: &AccelTiming, accel_mhz: u64) -> Ps {
    timing.compute_cycles * 1_000_000 / accel_mhz.max(1)
}

// ---------------------------------------------------------------------
// Per-process memo cache.
// ---------------------------------------------------------------------

/// Canonicalized identity of a design point under a sweep mode — used
/// as the cache key *itself* (hash-then-equality in the map, so hash
/// collisions cannot return the wrong point). Fields: accel, replicas,
/// accel/NoC MHz, placement, effective warmup/window, raw
/// warmup/window (WarmFork only), mode, objective fingerprint (empty
/// for throughput; the full serving spec's debug form otherwise).
type MemoKey = (String, usize, u64, u64, bool, Ps, Ps, Ps, Ps, SweepMode, String);

/// Cache-key component for the sweep objective. The serving spec's
/// `Debug` form is deterministic and covers every field that changes a
/// serving result, so two objectives share an entry iff they simulate
/// identically.
fn objective_fingerprint(objective: &Objective) -> String {
    match objective {
        Objective::Throughput => String::new(),
        Objective::TailLatency { spec } => format!("{spec:?}"),
        // The fleet size is appended per work item by the sweep driver
        // (one spec evaluates once per entry in `fleets`). `threads` is
        // deliberately absent: every thread count produces a
        // bit-identical ClusterReport, so memoized points are shared
        // across serial and parallel sweeps.
        Objective::Cluster {
            serve,
            balancer,
            autoscale,
            fleets: _,
            threads: _,
        } => format!("cluster:{serve:?}/{balancer:?}/{autoscale:?}"),
        Objective::Robust {
            serve,
            balancer,
            health,
            fleet,
            threads: _,
        } => format!("robust:{serve:?}/{balancer:?}/{health:?}/fleet={fleet}"),
    }
}

fn memo() -> &'static Mutex<HashMap<MemoKey, DsePoint>> {
    static MEMO: OnceLock<Mutex<HashMap<MemoKey, DsePoint>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Build the canonical key for `spec` under `mode`. A cold run is fully
/// determined by the *effective* warmup/window, so those are
/// canonicalized (two specs that simulate identically share one
/// entry). A warm-fork run additionally depends on the raw spec phases
/// — they size the shared base warmup via [`StructuralKey`] — so
/// WarmFork keys include them too.
fn memo_key(
    spec: &ScenarioSpec,
    mode: SweepMode,
    objective: &Objective,
) -> crate::Result<MemoKey> {
    let (eff_warmup, eff_window) = effective_phases(spec)?;
    let (raw_warmup, raw_window) = match mode {
        SweepMode::Cold => (0, 0),
        SweepMode::WarmFork => (spec.warmup, spec.window),
    };
    Ok((
        spec.accel.clone(),
        spec.replicas,
        spec.accel_mhz,
        spec.noc_mhz,
        spec.near_mem,
        eff_warmup,
        eff_window,
        raw_warmup,
        raw_window,
        mode,
        objective_fingerprint(objective),
    ))
}

/// The memo only ever holds fully-evaluated points, so a panic while
/// some *other* thread held the lock cannot leave a half-written entry
/// — recover from poisoning instead of cascading the panic into every
/// later sweep in the process.
fn memo_lock() -> std::sync::MutexGuard<'static, HashMap<MemoKey, DsePoint>> {
    memo().lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn memo_get(key: &MemoKey) -> Option<DsePoint> {
    memo_lock().get(key).cloned()
}

fn memo_put(key: MemoKey, pt: &DsePoint) {
    memo_lock().insert(key, pt.clone());
}

/// Number of memoized design points in this process.
pub fn memo_len() -> usize {
    memo_lock().len()
}

/// Drop every memoized design point (benches do this between timed
/// runs; sweeps after a simulator change in the same process should
/// too).
pub fn clear_memo() {
    memo_lock().clear();
}

// ---------------------------------------------------------------------
// Cold evaluation.
// ---------------------------------------------------------------------

/// Evaluate one design point by simulation (TGs off, as Table I),
/// cold-building a fresh `Soc`. Not memoized — this is the reference
/// entry point; the sweep drivers wrap it with the cache.
pub fn evaluate_point(spec: &ScenarioSpec) -> crate::Result<DsePoint> {
    // to_config() pre-validates name and replication, so user-typed CLI
    // input gets a clean error rather than the preset's panic.
    let cfg = spec.to_config()?;
    let mut session = Session::new(cfg)?;
    let pos = spec.position();
    let tile = session.tile_at(pos.0, pos.1);
    session.stage(tile, 1)?.perf_only();

    let (warmup, window) = effective_phases(spec)?;
    session.warmup(warmup);
    let report = session.measure(tile, window)?;
    point_from_report(spec, report.start, report.elapsed, report.throughput_mbs)
}

/// Evaluate one design point under served traffic: build the SoC cold,
/// serve `serve`'s arrivals on the accelerator-under-test, and score
/// the point by its tail latency (`serve.tiles` is overridden with that
/// tile). Throughput is still reported — as the *achieved* credited
/// bytes over the offered-load horizon, not a steady-state window.
pub fn evaluate_point_serving(
    spec: &ScenarioSpec,
    serve: &ServeSpec,
) -> crate::Result<DsePoint> {
    let cfg = spec.to_config()?;
    let mut session = Session::new(cfg)?;
    let pos = spec.position();
    let tile = session.tile_at(pos.0, pos.1);
    let mut sspec = serve.clone();
    sspec.tiles = vec![tile];
    let report = session.serve(&sspec)?;

    let timing = AccelTiming::lookup(&spec.accel)?;
    let dur_s = report.duration as f64 / 1e12;
    let throughput_mbs =
        report.completed as f64 * timing.credit_bytes as f64 / 1e6 / dur_s;
    let mut pt = point_from_report(spec, 0, report.elapsed, throughput_mbs)?;
    pt.p99_latency_ps = (report.completed > 0).then_some(report.latency.p99_ps);
    pt.achieved_rps = Some(report.achieved_rps);
    pt.slo_met = report.slo_met;
    Ok(pt)
}

/// Evaluate one design point as a fleet: `fleet` replicas of the
/// point's SoC serve `serve`'s arrivals behind `balancer` (optionally
/// autoscaled, with `min_replicas` clamped to the fleet). Scored like a
/// serving point — p99, achieved rps, SLO — plus the cluster's
/// replica-seconds cost proxy. `area` stays per-SoC; multiply by
/// [`DsePoint::fleet`] for fleet totals.
pub fn evaluate_point_cluster(
    spec: &ScenarioSpec,
    serve: &ServeSpec,
    balancer: DispatchPolicy,
    autoscale: Option<&AutoscaleSpec>,
    fleet: usize,
    threads: usize,
) -> crate::Result<DsePoint> {
    let cfg = spec.to_config()?;
    let pos = spec.position();
    let mut sspec = serve.clone();
    sspec.tiles = vec![cfg.node_of(pos.0, pos.1)];
    let mut cspec = ClusterSpec::new(fleet, sspec)
        .balancer(balancer)
        .threads(threads);
    if let Some(a) = autoscale {
        let mut a = a.clone();
        a.min_replicas = a.min_replicas.clamp(1, fleet.max(1));
        cspec = cspec.autoscale(a);
    }
    let report = serve_cluster(cfg, &cspec)?;

    let timing = AccelTiming::lookup(&spec.accel)?;
    let dur_s = report.duration as f64 / 1e12;
    let throughput_mbs =
        report.completed as f64 * timing.credit_bytes as f64 / 1e6 / dur_s;
    let mut pt = point_from_report(spec, 0, report.elapsed, throughput_mbs)?;
    pt.p99_latency_ps = (report.completed > 0).then_some(report.latency.p99_ps);
    pt.achieved_rps = Some(report.achieved_rps);
    pt.slo_met = report.slo_met;
    pt.fleet = Some(fleet);
    pt.replica_seconds = Some(report.replica_seconds);
    Ok(pt)
}

/// Evaluate one design point under [`Objective::Robust`]: a
/// `fleet`-replica cluster serves `serve`'s arrivals with the spec's
/// fault plan injected and the full resilience stack on (admission
/// retry from `serve.retry`, cluster health checks from `health`).
/// Scored like a cluster point — p99, achieved rps, SLO,
/// replica-seconds.
pub fn evaluate_point_robust(
    spec: &ScenarioSpec,
    serve: &ServeSpec,
    balancer: DispatchPolicy,
    health: &HealthSpec,
    fleet: usize,
    threads: usize,
) -> crate::Result<DsePoint> {
    let cfg = spec.to_config()?;
    let pos = spec.position();
    let mut sspec = serve.clone();
    sspec.tiles = vec![cfg.node_of(pos.0, pos.1)];
    let cspec = ClusterSpec::new(fleet, sspec)
        .balancer(balancer)
        .health(health.clone())
        .threads(threads);
    let report = serve_cluster(cfg, &cspec)?;

    let timing = AccelTiming::lookup(&spec.accel)?;
    let dur_s = report.duration as f64 / 1e12;
    let throughput_mbs =
        report.completed as f64 * timing.credit_bytes as f64 / 1e6 / dur_s;
    let mut pt = point_from_report(spec, 0, report.elapsed, throughput_mbs)?;
    pt.p99_latency_ps = (report.completed > 0).then_some(report.latency.p99_ps);
    pt.achieved_rps = Some(report.achieved_rps);
    pt.slo_met = report.slo_met;
    pt.fleet = Some(fleet);
    pt.replica_seconds = Some(report.replica_seconds);
    Ok(pt)
}

fn point_from_report(
    spec: &ScenarioSpec,
    eff_warmup_ps: Ps,
    eff_window_ps: Ps,
    throughput_mbs: f64,
) -> crate::Result<DsePoint> {
    let area = mra_area(&AccelArea::lookup(&spec.accel)?, spec.replicas);
    Ok(DsePoint {
        accel: spec.accel.clone(),
        replicas: spec.replicas,
        accel_mhz: spec.accel_mhz,
        noc_mhz: spec.noc_mhz,
        near_mem: spec.near_mem,
        area,
        throughput_mbs,
        eff_warmup_ps,
        eff_window_ps,
        p99_latency_ps: None,
        achieved_rps: None,
        slo_met: None,
        fleet: None,
        replica_seconds: None,
    })
}

/// Rank points for a serving sweep: SLO-met points first (by p99
/// ascending), then points with latency data but no met SLO, then
/// points with no latency data at all; index order breaks exact ties.
/// Returns indices into `points`, best first.
pub fn rank_by_p99_under_slo(points: &[DsePoint]) -> Vec<usize> {
    let group = |p: &DsePoint| -> u8 {
        match (p.slo_met, p.p99_latency_ps) {
            (Some(true), _) => 0,
            (_, Some(_)) => 1,
            _ => 2,
        }
    };
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        let (pa, pb) = (&points[a], &points[b]);
        group(pa)
            .cmp(&group(pb))
            .then(
                pa.p99_latency_ps
                    .unwrap_or(f64::INFINITY)
                    .total_cmp(&pb.p99_latency_ps.unwrap_or(f64::INFINITY)),
            )
            .then(a.cmp(&b))
    });
    idx
}

/// Rank points for a fleet-sizing sweep: SLO-met points first by
/// replica-seconds ascending (the cheapest fleet that holds the SLO
/// wins), then points with cost data but a missed or unjudged SLO, then
/// points with no cost data; index order breaks exact ties. Returns
/// indices into `points`, best first.
pub fn rank_by_replica_seconds_under_slo(points: &[DsePoint]) -> Vec<usize> {
    let group = |p: &DsePoint| -> u8 {
        match (p.slo_met, p.replica_seconds) {
            (Some(true), _) => 0,
            (_, Some(_)) => 1,
            _ => 2,
        }
    };
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        let (pa, pb) = (&points[a], &points[b]);
        group(pa)
            .cmp(&group(pb))
            .then(
                pa.replica_seconds
                    .unwrap_or(f64::INFINITY)
                    .total_cmp(&pb.replica_seconds.unwrap_or(f64::INFINITY)),
            )
            .then(a.cmp(&b))
    });
    idx
}

// ---------------------------------------------------------------------
// Warm-fork planner.
// ---------------------------------------------------------------------

/// Frequencies every warm base SoC is built and warmed at — the paper
/// preset's initial DFS frequencies (also each island's range maximum,
/// so every on-grid target is reachable by a downward/no-op retune).
const BASE_ACCEL_MHZ: u64 = 50;
const BASE_NOC_MHZ: u64 = 100;

/// Everything that requires *rebuilding* a SoC. Island frequencies are
/// deliberately absent: they are the run-time DFS knob warm forking
/// exploits.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct StructuralKey {
    accel: String,
    replicas: usize,
    near_mem: bool,
    warmup: Ps,
    window: Ps,
}

impl StructuralKey {
    fn of(spec: &ScenarioSpec) -> Self {
        Self {
            accel: spec.accel.clone(),
            replicas: spec.replicas,
            near_mem: spec.near_mem,
            warmup: spec.warmup,
            window: spec.window,
        }
    }
}

/// Build, stage, and warm up one base session for `spec`'s structure at
/// the base frequencies, returning its snapshot and the tile under
/// test.
fn warm_base(spec: &ScenarioSpec) -> crate::Result<(SocSnapshot, usize)> {
    let base_spec = spec.clone().accel_mhz(BASE_ACCEL_MHZ).noc_mhz(BASE_NOC_MHZ);
    let cfg = base_spec.to_config()?;
    let mut session = Session::new(cfg)?;
    let pos = base_spec.position();
    let tile = session.tile_at(pos.0, pos.1);
    session.stage(tile, 1)?.perf_only();
    let (warmup, _) = effective_phases(&base_spec)?;
    session.warmup(warmup);
    Ok((session.snapshot()?, tile))
}

/// Fork the base snapshot and retune it to `spec`'s frequencies through
/// the DFS actuators, then run a settle span past the actuator swap
/// plus one invocation at the new rate. Errors if an island rejects the
/// target (off the 5 MHz grid / out of the DFS range) — the caller
/// falls back to a cold build for that point.
fn retune_fork(snap: &SocSnapshot, spec: &ScenarioSpec) -> crate::Result<Session> {
    let mut session = Session::resume(snap)?;
    let mut swap_at = session.soc().now;
    if spec.accel_mhz != BASE_ACCEL_MHZ {
        swap_at = swap_at.max(session.soc_mut().host_write_freq(spec.island(), spec.accel_mhz)?);
    }
    if spec.noc_mhz != BASE_NOC_MHZ {
        swap_at = swap_at.max(session.soc_mut().host_write_freq(ISL_NOC, spec.noc_mhz)?);
    }
    let timing = AccelTiming::lookup(&spec.accel)?;
    let settle_until = swap_at + invocation_ps(&timing, spec.accel_mhz);
    if settle_until > session.soc().now {
        session.run_until(settle_until);
    }
    Ok(session)
}

/// Warm-fork sweep over `specs`, in three passes:
///
/// 1. memo pre-pass and grouping by [`StructuralKey`] (serial, cheap);
/// 2. build + warm one base SoC per group with outstanding points, in
///    parallel across threads;
/// 3. evaluate every outstanding point in parallel, each forking its
///    group's shared snapshot, retuning, settling, and measuring.
///
/// Results come back in spec order, independent of thread scheduling.
fn sweep_warm_fork(specs: &[ScenarioSpec], threads: usize) -> crate::Result<Vec<DsePoint>> {
    let mut out: Vec<Option<DsePoint>> = vec![None; specs.len()];
    let mut groups: Vec<(StructuralKey, Vec<(usize, MemoKey)>)> = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let key = memo_key(spec, SweepMode::WarmFork, &Objective::Throughput)?;
        if let Some(hit) = memo_get(&key) {
            out[i] = Some(hit);
            continue;
        }
        let skey = StructuralKey::of(spec);
        match groups.iter_mut().find(|(k, _)| *k == skey) {
            Some((_, points)) => points.push((i, key)),
            None => groups.push((skey, vec![(i, key)])),
        }
    }

    // One warmed snapshot per structure (`bases[g]` serves group `g`).
    let base_specs: Vec<usize> = groups.iter().map(|(_, points)| points[0].0).collect();
    let bases: Vec<(SocSnapshot, usize)> =
        ScenarioSet::new(base_specs).run_with_threads(threads, |&i| warm_base(&specs[i]))?;

    // Fork, retune, and measure every outstanding point.
    let work: Vec<(usize, usize, MemoKey)> = groups
        .iter()
        .enumerate()
        .flat_map(|(g, (_, points))| points.iter().map(move |(i, key)| (g, *i, key.clone())))
        .collect();
    let evaluated = ScenarioSet::new(work).run_with_threads(threads, |(g, i, key)| {
        let (snap, tile) = &bases[*g];
        let spec = &specs[*i];
        let (_, window) = effective_phases(spec)?;
        let pt = match retune_fork(snap, spec) {
            Ok(mut session) => {
                let report = session.measure(*tile, window)?;
                point_from_report(spec, report.start, report.elapsed, report.throughput_mbs)?
            }
            // Target off the island's DFS grid/range: this point cannot
            // be reached by a run-time retune, so pay the cold build.
            // Anything other than a DFS rejection is a real failure and
            // must surface, not silently degrade the sweep to Cold.
            Err(e) if e.downcast_ref::<FreqError>().is_some() => evaluate_point(spec)?,
            Err(e) => return Err(e),
        };
        memo_put(key.clone(), &pt);
        Ok((*i, pt))
    })?;
    for (i, pt) in evaluated {
        out[i] = Some(pt);
    }
    out.into_iter()
        .enumerate()
        .map(|(i, pt)| {
            pt.ok_or_else(|| {
                anyhow::anyhow!("warm-fork sweep lost point {i}: neither memoized nor evaluated")
            })
        })
        .collect()
}

// ---------------------------------------------------------------------
// Sweep drivers.
// ---------------------------------------------------------------------

/// Run a full sweep according to `p.mode`, memoized per process.
///
/// [`SweepMode::Cold`] evaluates each point in its own `Soc` across all
/// available cores; results are ordered by design-point index and
/// bit-identical to [`sweep_replication_serial`]. In
/// [`SweepMode::WarmFork`] structurally identical points share one
/// warmed base simulation and differ only by a run-time DFS retune —
/// within a stated tolerance of `Cold` (see `docs/PERF.md`) and
/// typically several times faster on frequency-major sweeps.
pub fn sweep_replication(p: &SweepParams) -> crate::Result<Vec<DsePoint>> {
    let specs = p.specs();
    match (&p.objective, p.mode) {
        // Serving sweeps always evaluate cold: each point's tile must
        // start quiescent, so there is no warmup to amortize by forking.
        (Objective::TailLatency { spec: serve }, _) => {
            ScenarioSet::new(specs).run_with_threads(p.threads, |spec| {
                let key = memo_key(spec, SweepMode::Cold, &p.objective)?;
                if let Some(hit) = memo_get(&key) {
                    return Ok(hit);
                }
                let pt = evaluate_point_serving(spec, serve)?;
                memo_put(key, &pt);
                Ok(pt)
            })
        }
        (
            Objective::Robust {
                serve,
                balancer,
                health,
                fleet,
                threads,
            },
            _,
        ) => ScenarioSet::new(specs).run_with_threads(p.threads, |spec| {
            let key = memo_key(spec, SweepMode::Cold, &p.objective)?;
            if let Some(hit) = memo_get(&key) {
                return Ok(hit);
            }
            let pt = evaluate_point_robust(spec, serve, *balancer, health, *fleet, *threads)?;
            memo_put(key, &pt);
            Ok(pt)
        }),
        (Objective::Throughput, SweepMode::Cold) => {
            ScenarioSet::new(specs).run_with_threads(p.threads, |spec| {
                let key = memo_key(spec, SweepMode::Cold, &Objective::Throughput)?;
                if let Some(hit) = memo_get(&key) {
                    return Ok(hit);
                }
                let pt = evaluate_point(spec)?;
                memo_put(key, &pt);
                Ok(pt)
            })
        }
        // Cluster sweeps evaluate (spec x fleet) pairs, also always
        // cold; the memo key gets the fleet size appended since one
        // spec yields one point per fleet entry.
        (
            Objective::Cluster {
                serve,
                balancer,
                autoscale,
                fleets,
                threads,
            },
            _,
        ) => {
            let work: Vec<(ScenarioSpec, usize)> = specs
                .iter()
                .flat_map(|s| fleets.iter().map(move |&f| (s.clone(), f)))
                .collect();
            ScenarioSet::new(work).run_with_threads(p.threads, |(spec, fleet)| {
                let mut key = memo_key(spec, SweepMode::Cold, &p.objective)?;
                key.10 = format!("{}#fleet={fleet}", key.10);
                if let Some(hit) = memo_get(&key) {
                    return Ok(hit);
                }
                let pt = evaluate_point_cluster(
                    spec,
                    serve,
                    *balancer,
                    autoscale.as_ref(),
                    *fleet,
                    *threads,
                )?;
                memo_put(key, &pt);
                Ok(pt)
            })
        }
        (Objective::Throughput, SweepMode::WarmFork) => sweep_warm_fork(&specs, p.threads),
    }
}

/// Serial reference path for the sweep (equivalence baseline,
/// profiling). Always cold and never memoized, regardless of `p.mode`;
/// the objective is honoured.
pub fn sweep_replication_serial(p: &SweepParams) -> crate::Result<Vec<DsePoint>> {
    match &p.objective {
        Objective::Throughput => ScenarioSet::new(p.specs()).run_serial(evaluate_point),
        Objective::TailLatency { spec: serve } => ScenarioSet::new(p.specs())
            .run_serial(|spec| evaluate_point_serving(spec, serve)),
        Objective::Cluster {
            serve,
            balancer,
            autoscale,
            fleets,
            threads,
        } => {
            let work: Vec<(ScenarioSpec, usize)> = p
                .specs()
                .iter()
                .flat_map(|s| fleets.iter().map(move |&f| (s.clone(), f)))
                .collect();
            ScenarioSet::new(work).run_serial(|(spec, fleet)| {
                evaluate_point_cluster(spec, serve, *balancer, autoscale.as_ref(), *fleet, *threads)
            })
        }
        Objective::Robust {
            serve,
            balancer,
            health,
            fleet,
            threads,
        } => ScenarioSet::new(p.specs()).run_serial(|spec| {
            evaluate_point_robust(spec, serve, *balancer, health, *fleet, *threads)
        }),
    }
}

/// Utilization check of a point against the paper's device.
pub fn fits_device(pt: &DsePoint) -> bool {
    pt.area.fits(&XC7V2000T.capacity)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_single_point_quickly() {
        // Short window: just prove the plumbing works end to end.
        let spec = ScenarioSpec::new("dfmul", 2)
            .warmup(500_000_000)
            .window(4_000_000_000);
        let pt = evaluate_point(&spec).unwrap();
        assert_eq!(pt.replicas, 2);
        assert!(pt.throughput_mbs > 0.5, "thr {}", pt.throughput_mbs);
        assert!(fits_device(&pt));
        assert!(pt.area.lut > 11_000);
        // The silent warmup/window overrides are observable.
        let (warmup, window) = effective_phases(&spec).unwrap();
        assert_eq!(pt.eff_warmup_ps, warmup);
        assert_eq!(pt.eff_window_ps, window);
        assert!(pt.eff_warmup_ps >= 500_000_000);
        assert!(pt.eff_window_ps >= 4_000_000_000);
    }

    #[test]
    fn unknown_accel_is_a_clean_error() {
        let spec = ScenarioSpec::new("warpcore", 1);
        let err = evaluate_point(&spec).unwrap_err().to_string();
        assert!(err.contains("warpcore"), "{err}");
    }

    #[test]
    fn specs_expand_in_replication_major_order() {
        let mut p = SweepParams::quick("dfadd");
        p.replications = vec![1, 2];
        p.placements = vec![true, false];
        let specs = p.specs();
        assert_eq!(specs.len(), 4);
        assert_eq!(
            specs.iter().map(|s| (s.replicas, s.near_mem)).collect::<Vec<_>>(),
            vec![(1, true), (1, false), (2, true), (2, false)]
        );
    }

    #[test]
    fn effective_phases_floor_slow_accelerators() {
        // adpcm: 1.17 M cycles -> 23.4 ms per invocation at 50 MHz; a
        // 1 ms spec must be floored well past it.
        let spec = ScenarioSpec::new("adpcm", 1)
            .warmup(1_000_000)
            .window(1_000_000);
        let (warmup, window) = effective_phases(&spec).unwrap();
        assert!(warmup >= 2 * 23_400_000_000, "warmup {warmup}");
        assert!(window > warmup, "window {window}");
        // Fast points keep their spec values.
        let spec = ScenarioSpec::new("dfmul", 2)
            .warmup(5_000_000_000)
            .window(20_000_000_000);
        let (warmup, window) = effective_phases(&spec).unwrap();
        assert_eq!((warmup, window), (5_000_000_000, 20_000_000_000));
    }

    #[test]
    fn memo_keys_canonicalize_effective_phases() {
        // Cold: two specs whose raw warmups differ but whose *effective*
        // phases agree must share one cache entry; changing a frequency
        // or the mode must not.
        let thr = Objective::Throughput;
        let a = ScenarioSpec::new("dfmul", 1).warmup(1).window(1);
        let b = ScenarioSpec::new("dfmul", 1).warmup(2).window(2);
        assert_eq!(
            memo_key(&a, SweepMode::Cold, &thr).unwrap(),
            memo_key(&b, SweepMode::Cold, &thr).unwrap()
        );
        let c = ScenarioSpec::new("dfmul", 1).warmup(1).window(1).accel_mhz(25);
        assert_ne!(
            memo_key(&a, SweepMode::Cold, &thr).unwrap(),
            memo_key(&c, SweepMode::Cold, &thr).unwrap()
        );
        assert_ne!(
            memo_key(&a, SweepMode::Cold, &thr).unwrap(),
            memo_key(&a, SweepMode::WarmFork, &thr).unwrap()
        );
        // WarmFork: the raw phases size the shared base warmup, so
        // specs differing only in raw warmup must NOT share an entry.
        assert_ne!(
            memo_key(&a, SweepMode::WarmFork, &thr).unwrap(),
            memo_key(&b, SweepMode::WarmFork, &thr).unwrap()
        );
    }

    #[test]
    fn memo_keys_distinguish_objectives() {
        use crate::serve::Arrival;
        let a = ScenarioSpec::new("dfmul", 1).warmup(1).window(1);
        let thr = Objective::Throughput;
        let serve_1k = Objective::TailLatency {
            spec: ServeSpec::new(Arrival::Poisson { rps: 1000.0 }, 50_000_000_000),
        };
        let serve_2k = Objective::TailLatency {
            spec: ServeSpec::new(Arrival::Poisson { rps: 2000.0 }, 50_000_000_000),
        };
        let k_thr = memo_key(&a, SweepMode::Cold, &thr).unwrap();
        let k_1k = memo_key(&a, SweepMode::Cold, &serve_1k).unwrap();
        let k_2k = memo_key(&a, SweepMode::Cold, &serve_2k).unwrap();
        assert_ne!(k_thr, k_1k, "serving points must not hit throughput entries");
        assert_ne!(k_1k, k_2k, "different traffic, different entry");
        assert_eq!(k_1k, memo_key(&a, SweepMode::Cold, &serve_1k).unwrap());
    }

    #[test]
    fn serving_objective_scores_a_point_by_tail_latency() {
        use crate::serve::Arrival;
        // A light, short serving phase: just prove the plumbing — p99
        // and achieved rps populated, SLO judged, throughput credited.
        let spec = ScenarioSpec::new("dfmul", 2);
        let serve = ServeSpec::new(Arrival::Poisson { rps: 800.0 }, 30_000_000_000)
            .slo(20_000_000_000)
            .seed(7);
        let pt = evaluate_point_serving(&spec, &serve).unwrap();
        assert!(pt.p99_latency_ps.is_some());
        assert!(pt.p99_latency_ps.unwrap() > 0.0);
        assert!(pt.achieved_rps.unwrap() > 100.0, "{:?}", pt.achieved_rps);
        assert_eq!(pt.slo_met, Some(true), "p99 {:?}", pt.p99_latency_ps);
        assert!(pt.throughput_mbs > 0.0);
    }

    #[test]
    fn rank_by_p99_orders_met_then_latency() {
        let base = || DsePoint {
            accel: "dfmul".into(),
            replicas: 1,
            accel_mhz: 50,
            noc_mhz: 100,
            near_mem: true,
            area: Utilization::default(),
            throughput_mbs: 0.0,
            eff_warmup_ps: 0,
            eff_window_ps: 0,
            p99_latency_ps: None,
            achieved_rps: None,
            slo_met: None,
            fleet: None,
            replica_seconds: None,
        };
        let mut fast_met = base();
        fast_met.p99_latency_ps = Some(1e9);
        fast_met.slo_met = Some(true);
        let mut slow_met = base();
        slow_met.p99_latency_ps = Some(3e9);
        slow_met.slo_met = Some(true);
        let mut missed = base();
        missed.p99_latency_ps = Some(0.5e9);
        missed.slo_met = Some(false);
        let no_data = base();
        let pts = vec![no_data, missed, slow_met, fast_met];
        assert_eq!(rank_by_p99_under_slo(&pts), vec![3, 2, 1, 0]);
    }

    #[test]
    fn rank_by_replica_seconds_orders_cheapest_met_fleet_first() {
        let base = |fleet: usize, secs: Option<f64>, met: Option<bool>| DsePoint {
            accel: "dfmul".into(),
            replicas: 1,
            accel_mhz: 50,
            noc_mhz: 100,
            near_mem: true,
            area: Utilization::default(),
            throughput_mbs: 0.0,
            eff_warmup_ps: 0,
            eff_window_ps: 0,
            p99_latency_ps: None,
            achieved_rps: None,
            slo_met: met,
            fleet: Some(fleet),
            replica_seconds: secs,
        };
        let pts = vec![
            base(1, None, None),                // no data -> last
            base(4, Some(0.4), Some(true)),     // met but pricier
            base(2, Some(0.2), Some(true)),     // cheapest met -> first
            base(1, Some(0.1), Some(false)),    // cheap but missed
        ];
        assert_eq!(rank_by_replica_seconds_under_slo(&pts), vec![2, 1, 3, 0]);
    }

    #[test]
    fn memo_fingerprints_distinguish_cluster_objectives() {
        use crate::serve::Arrival;
        let serve = ServeSpec::new(Arrival::Poisson { rps: 1000.0 }, 50_000_000_000);
        let a = Objective::Cluster {
            serve: serve.clone(),
            balancer: DispatchPolicy::RoundRobin,
            autoscale: None,
            fleets: vec![1, 2],
            threads: 1,
        };
        let b = Objective::Cluster {
            serve: serve.clone(),
            balancer: DispatchPolicy::JoinShortestQueue,
            autoscale: None,
            fleets: vec![1, 2],
            threads: 1,
        };
        let c = Objective::Cluster {
            serve,
            balancer: DispatchPolicy::RoundRobin,
            autoscale: Some(AutoscaleSpec::new(1)),
            fleets: vec![1, 2],
            threads: 1,
        };
        let threaded = match a.clone() {
            Objective::Cluster {
                serve,
                balancer,
                autoscale,
                fleets,
                threads: _,
            } => Objective::Cluster {
                serve,
                balancer,
                autoscale,
                fleets,
                threads: 8,
            },
            other => other,
        };
        let fa = objective_fingerprint(&a);
        assert_ne!(fa, objective_fingerprint(&b), "balancer must key the cache");
        assert_ne!(fa, objective_fingerprint(&c), "autoscale must key the cache");
        assert_ne!(fa, objective_fingerprint(&Objective::Throughput));
        // Thread count never changes the report, so memoized points are
        // shared across thread counts.
        assert_eq!(
            fa,
            objective_fingerprint(&threaded),
            "threads must NOT key the cache"
        );
    }

    #[test]
    fn memo_fingerprints_distinguish_robust_objectives() {
        use crate::fault::{Fault, FaultPlan, RetrySpec};
        use crate::serve::Arrival;
        let serve = ServeSpec::new(Arrival::Poisson { rps: 1000.0 }, 50_000_000_000);
        let robust = |serve: ServeSpec, fleet: usize| Objective::Robust {
            serve,
            balancer: DispatchPolicy::JoinShortestQueue,
            health: HealthSpec::default(),
            fleet,
            threads: 1,
        };
        let plain = robust(serve.clone(), 2);
        let faulted = robust(
            serve.clone().faults(FaultPlan::new().with(Fault::ReplicaCrash {
                slot: 0,
                at: 1_000_000_000,
            })),
            2,
        );
        let retried = robust(serve.clone().retry(RetrySpec::new(3, 500_000_000)), 2);
        let bigger = robust(serve, 4);
        let fp = objective_fingerprint;
        assert_ne!(fp(&plain), fp(&faulted), "fault plan must key the cache");
        assert_ne!(fp(&plain), fp(&retried), "retry policy must key the cache");
        assert_ne!(fp(&plain), fp(&bigger), "fleet size must key the cache");
        assert_ne!(fp(&plain), fp(&Objective::Throughput));
        assert_eq!(fp(&plain), fp(&robust(
            ServeSpec::new(Arrival::Poisson { rps: 1000.0 }, 50_000_000_000),
            2,
        )));
    }

    #[test]
    fn zero_replica_specs_error_cleanly() {
        // The phase floors must not divide by zero; the spec still
        // fails validation with the pre-existing clean error.
        let spec = ScenarioSpec::new("dfmul", 0);
        assert!(effective_phases(&spec).is_ok());
        let err = evaluate_point(&spec).unwrap_err().to_string();
        assert!(err.contains("out of [1, 16]"), "{err}");
    }

    #[test]
    fn warm_groups_share_structure_not_frequency() {
        let mut p = SweepParams::quick("dfadd");
        p.replications = vec![1, 2];
        p.accel_mhz = vec![25, 50];
        p.noc_mhz = vec![50, 100];
        let specs = p.specs();
        let keys: Vec<StructuralKey> = specs.iter().map(StructuralKey::of).collect();
        // 8 points but only 2 structures (one per replication).
        assert_eq!(specs.len(), 8);
        assert_eq!(keys.iter().collect::<std::collections::HashSet<_>>().len(), 2);
    }
}
