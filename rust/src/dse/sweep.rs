//! Replication / frequency / placement sweeps.

use crate::config::presets::{paper_soc, A1_POS, A2_POS};
use crate::resources::{mra_area, AccelArea, Utilization, XC7V2000T};
use crate::runtime::RefCompute;
use crate::sim::{stage_inputs_for, Soc, ThroughputProbe};
use crate::util::Ps;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub accel: String,
    pub replicas: usize,
    pub accel_mhz: u64,
    pub noc_mhz: u64,
    pub near_mem: bool,
    pub area: Utilization,
    pub throughput_mbs: f64,
}

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepParams {
    pub accel: String,
    pub replications: Vec<usize>,
    pub accel_mhz: Vec<u64>,
    pub noc_mhz: Vec<u64>,
    pub placements: Vec<bool>, // true = A1 (near MEM), false = A2
    /// Simulated measurement window per point.
    pub window: Ps,
    /// Warmup before the window.
    pub warmup: Ps,
}

impl SweepParams {
    /// A quick default sweep for `accel`.
    pub fn quick(accel: &str) -> Self {
        Self {
            accel: accel.to_string(),
            replications: vec![1, 2, 4],
            accel_mhz: vec![50],
            noc_mhz: vec![100],
            placements: vec![true],
            window: 20_000_000_000, // 20 ms
            warmup: 2_000_000_000,
        }
    }
}

/// Evaluate one design point by simulation (TGs off, as Table I).
pub fn evaluate_point(
    accel: &str,
    replicas: usize,
    accel_mhz: u64,
    noc_mhz: u64,
    near_mem: bool,
    warmup: Ps,
    window: Ps,
) -> crate::Result<DsePoint> {
    let (a1, a2) = if near_mem {
        ((accel, replicas), ("dfadd", 1))
    } else {
        (("dfadd", 1), (accel, replicas))
    };
    let mut cfg = paper_soc(a1, a2);
    cfg.islands[0].freq_mhz = noc_mhz;
    let isl = if near_mem { 1 } else { 2 };
    cfg.islands[isl].freq_mhz = accel_mhz;
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new()))?;
    let pos = if near_mem { A1_POS } else { A2_POS };
    let tile = soc.cfg.node_of(pos.0, pos.1);
    stage_inputs_for(&mut soc, tile, 1);
    soc.mra_mut(tile).functional_every_invocation = false;

    // Scale the measurement to the accelerator's invocation time so slow
    // accelerators (gsm: ~18 ms, adpcm: ~23 ms per invocation at 50 MHz)
    // still complete several invocations in the window.
    let inv_ps = soc.mra(tile).timing.compute_cycles * 1_000_000 / accel_mhz.max(1);
    let warmup = warmup.max(2 * inv_ps);
    let window = window.max(8 * inv_ps / replicas as u64 + inv_ps);

    soc.run_for(warmup);
    let probe = ThroughputProbe::begin(&soc, tile);
    soc.run_for(window);
    let throughput_mbs = probe.mbs(&soc);

    let area = mra_area(&AccelArea::lookup(accel)?, replicas);
    Ok(DsePoint {
        accel: accel.to_string(),
        replicas,
        accel_mhz,
        noc_mhz,
        near_mem,
        area,
        throughput_mbs,
    })
}

/// Run a full sweep.
pub fn sweep_replication(p: &SweepParams) -> crate::Result<Vec<DsePoint>> {
    let mut out = Vec::new();
    for &k in &p.replications {
        for &am in &p.accel_mhz {
            for &nm in &p.noc_mhz {
                for &near in &p.placements {
                    out.push(evaluate_point(
                        &p.accel, k, am, nm, near, p.warmup, p.window,
                    )?);
                }
            }
        }
    }
    Ok(out)
}

/// Utilization check of a point against the paper's device.
pub fn fits_device(pt: &DsePoint) -> bool {
    pt.area.fits(&XC7V2000T.capacity)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_single_point_quickly() {
        // Short window: just prove the plumbing works end to end.
        let pt = evaluate_point("dfmul", 2, 50, 100, true, 500_000_000, 4_000_000_000).unwrap();
        assert_eq!(pt.replicas, 2);
        assert!(pt.throughput_mbs > 0.5, "thr {}", pt.throughput_mbs);
        assert!(fits_device(&pt));
        assert!(pt.area.lut > 11_000);
    }
}
