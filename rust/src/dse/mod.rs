//! Design-space exploration: sweep MRA replication factors, island
//! frequencies and placements; evaluate each point with the analytic
//! area model plus (optionally) a short simulation; report the Pareto
//! frontier of area vs. throughput — the workflow the paper's abstract
//! promises ("effectively exploring a multitude of solutions").

pub mod pareto;
pub mod sweep;

pub use pareto::pareto_front;
pub use sweep::{
    clear_memo, effective_phases, evaluate_point, memo_len, sweep_replication,
    sweep_replication_serial, DsePoint, SweepMode, SweepParams,
};
