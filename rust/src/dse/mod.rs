//! Design-space exploration: sweep MRA replication factors, island
//! frequencies and placements; evaluate each point with the analytic
//! area model plus (optionally) a short simulation; report the Pareto
//! frontier of area vs. throughput — the workflow the paper's abstract
//! promises ("effectively exploring a multitude of solutions").
//!
//! Points are scored by steady-state throughput by default; set
//! [`SweepParams::objective`] to [`Objective::TailLatency`] to serve
//! traffic at every point instead and rank by p99-under-SLO
//! ([`rank_by_p99_under_slo`], `vespa dse --serve-rps N --slo-ms M`), or
//! to [`Objective::Cluster`] to evaluate each point as a fleet of
//! replica SoCs and rank by replica-seconds-under-SLO
//! ([`rank_by_replica_seconds_under_slo`],
//! `vespa dse --serve-rps N --slo-ms M --fleets 1,2,4`).

pub mod pareto;
pub mod sweep;

pub use pareto::pareto_front;
pub use sweep::{
    clear_memo, effective_phases, evaluate_point, evaluate_point_cluster, evaluate_point_serving,
    memo_len, rank_by_p99_under_slo, rank_by_replica_seconds_under_slo, sweep_replication,
    sweep_replication_serial, DsePoint, Objective, SweepMode, SweepParams,
};
