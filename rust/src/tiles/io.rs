//! I/O (auxiliary) tile: hosts the frequency registers of all islands
//! and bridges the host (USB-serial on the real board).
//!
//! An `MmioWrite` to a FREQ register arriving over the config plane
//! triggers the corresponding island's DFS actuator; `MmioRead` returns
//! the current output frequency or the actuator-busy flag.

use crate::monitor::mmio::{decode, MmioTarget};
use crate::noc::Msg;
use crate::util::time::Freq;

use super::{ni::NetIface, Outcome, TileCtx};

/// The I/O tile.
#[derive(Debug, Clone)]
pub struct IoTile {
    pub ni: NetIface,
    pub tile_index: usize,
    /// Frequency-change requests applied (stats).
    pub freq_writes: u64,
    /// Requests rejected (bad island / out of range).
    pub freq_rejects: u64,
}

impl IoTile {
    pub fn new(ni: NetIface, tile_index: usize) -> Self {
        Self {
            ni,
            tile_index,
            freq_writes: 0,
            freq_rejects: 0,
        }
    }

    /// Apply a frequency-register write (shared with the host path).
    pub fn apply_freq_write(
        islands: &mut [crate::clock::domain::ClockDomain],
        island: usize,
        mhz: u64,
        now: crate::util::Ps,
    ) -> bool {
        if island >= islands.len() {
            return false;
        }
        islands[island].request_freq(Freq::mhz(mhz), now).is_ok()
    }

    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> Outcome {
        let mut did_work = false;
        for pkt in self.ni.tick_rx(ctx.links, ctx.now, 0) {
            did_work = true;
            let p = ctx.arena.get(pkt);
            let (src, msg) = (p.src, p.msg);
            match msg {
                Msg::MmioWrite { addr, value } => {
                    if let MmioTarget::IslandFreq(i) = decode(addr) {
                        if Self::apply_freq_write(ctx.islands, i, value, ctx.now) {
                            self.freq_writes += 1;
                        } else {
                            self.freq_rejects += 1;
                        }
                    }
                }
                Msg::MmioRead { addr, tag } => {
                    let value = match decode(addr) {
                        MmioTarget::IslandFreq(i) if i < ctx.islands.len() => {
                            ctx.islands[i].freq(ctx.now).as_mhz()
                        }
                        MmioTarget::IslandBusy(i) if i < ctx.islands.len() => {
                            // Busy while a DFS request is still in flight.
                            u64::from(ctx.islands[i].next_edge(ctx.now) == 0)
                        }
                        _ => 0,
                    };
                    self.ni
                        .send(ctx.arena, src, Msg::MmioResp { value, tag }, ctx.now);
                }
                other => debug_assert!(false, "I/O tile got unexpected {other:?}"),
            }
            ctx.arena.release(pkt);
        }
        self.ni.tick_tx(ctx.links, ctx.arena, ctx.view, ctx.now);
        if self.ni.tx_backlog() > 0 {
            Outcome::active(true, ctx.cycle)
        } else {
            Outcome::on_input(did_work)
        }
    }
}
