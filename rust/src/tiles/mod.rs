//! SoC tiles: CPU, memory, I/O, traffic generators, and the multi-replica
//! accelerator (MRA) tiles that are the paper's contribution 1.
//!
//! Every tile owns a [`ni::NetIface`] connecting it to its NoC node's
//! local router port. Tiles are ticked by the simulation engine at their
//! frequency island's clock edges and interact with shared state through
//! [`TileCtx`].

pub mod cpu;
pub mod io;
pub mod mem_tile;
pub mod mra;
pub mod ni;
pub mod tg;
pub mod timing;

pub use mra::{MraTile, ReplicaState, ServeGate};
pub use ni::NetIface;
pub use timing::{AccelTiming, DmaParams, StreamSpec};

use crate::clock::domain::ClockDomain;
use crate::mem::BlockStore;
use crate::monitor::MonitorFile;
use crate::noc::{ClockView, LinkFifo, Mesh, PacketArena};
use crate::runtime::AccelCompute;
use crate::util::Ps;

/// Sentinel wake cycle: the tile needs no unconditional tick — only a
/// flit arriving in one of its eject FIFOs can give it work.
pub const WAKE_ON_INPUT: u64 = u64::MAX;

/// What a tile's tick did and when the engine next has to tick it.
///
/// `wake_cycle` is expressed in *island cycles* (the tile's own clock),
/// not picoseconds, so a DFS retune of the island never invalidates a
/// sleeping tile's wake point — the engine converts cycles to time only
/// when it coalesces a quiescent span, and spans never cross a retiming.
/// The contract: until island cycle `wake_cycle`, ticking the tile is a
/// provable no-op *unless* a flit becomes visible in one of its eject
/// FIFOs first (the engine checks those each edge).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TickOutcome {
    /// The tick changed observable state (packets, counters, compute).
    pub did_work: bool,
    /// Island cycle at/after which the tile next needs an unconditional
    /// tick; [`WAKE_ON_INPUT`] = sleep until NoC input arrives.
    pub wake_cycle: u64,
}

impl TickOutcome {
    /// Tick me again next cycle.
    pub fn active(did_work: bool, cycle: u64) -> Self {
        Self {
            did_work,
            wake_cycle: cycle + 1,
        }
    }

    /// Nothing to do before island cycle `wake_cycle` (barring input).
    pub fn sleep_until(did_work: bool, wake_cycle: u64) -> Self {
        Self {
            did_work,
            wake_cycle,
        }
    }

    /// Nothing to do until a flit arrives for this tile.
    pub fn on_input(did_work: bool) -> Self {
        Self {
            did_work,
            wake_cycle: WAKE_ON_INPUT,
        }
    }
}

/// Shared state a tile may touch during its tick.
pub struct TileCtx<'a> {
    pub now: Ps,
    /// The tile's island-cycle number at this edge (cycles delivered so
    /// far, including this one). Tiles keep timers in this unit so a
    /// skipped stretch of no-op cycles costs them nothing.
    pub cycle: u64,
    pub mesh: &'a Mesh,
    /// The fabric's link-FIFO arena (NI inject/eject FIFOs included).
    pub links: &'a mut [LinkFifo],
    pub view: &'a ClockView,
    pub arena: &'a mut PacketArena,
    pub blocks: &'a mut BlockStore,
    pub compute: &'a mut dyn AccelCompute,
    pub mon: &'a mut MonitorFile,
    /// All clock domains (the I/O tile services frequency registers).
    pub islands: &'a mut [ClockDomain],
}

/// A tile instance (enum dispatch keeps the hot loop monomorphic).
/// `Clone` deep-copies the full tile state (NI FIFO bookkeeping, DMA
/// pipelines, RNGs) for simulation forking.
#[derive(Clone)]
pub enum Tile {
    Cpu(cpu::CpuTile),
    Mem(mem_tile::MemTile),
    Io(io::IoTile),
    Tg(tg::TgTile),
    Mra(Box<mra::MraTile>),
}

impl Tile {
    /// Tile index (== NoC node index) this tile sits at.
    pub fn node_index(&self) -> usize {
        self.ni().node.index()
    }

    pub fn ni(&self) -> &ni::NetIface {
        match self {
            Tile::Cpu(t) => &t.ni,
            Tile::Mem(t) => &t.ni,
            Tile::Io(t) => &t.ni,
            Tile::Tg(t) => &t.ni,
            Tile::Mra(t) => &t.ni,
        }
    }

    /// One island-clock cycle. The returned [`TickOutcome`] tells the
    /// engine when this tile next needs ticking.
    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> TickOutcome {
        match self {
            Tile::Cpu(t) => t.tick(ctx),
            Tile::Mem(t) => t.tick(ctx),
            Tile::Io(t) => t.tick(ctx),
            Tile::Tg(t) => t.tick(ctx),
            Tile::Mra(t) => t.tick(ctx),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Tile::Cpu(_) => "cpu",
            Tile::Mem(_) => "mem",
            Tile::Io(_) => "io",
            Tile::Tg(_) => "tg",
            Tile::Mra(_) => "mra",
        }
    }
}
