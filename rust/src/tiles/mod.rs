//! SoC tiles: CPU, memory, I/O, traffic generators, and the multi-replica
//! accelerator (MRA) tiles that are the paper's contribution 1.
//!
//! Every tile owns a [`ni::NetIface`] connecting it to its NoC node's
//! local router port. Tiles are ticked by the simulation engine at their
//! frequency island's clock edges and interact with shared state through
//! [`TileCtx`].

pub mod cpu;
pub mod io;
pub mod mem_tile;
pub mod mra;
pub mod ni;
pub mod tg;
pub mod timing;

pub use mra::{MraTile, ReplicaState, ServeGate};
pub use ni::NetIface;
pub use timing::{AccelTiming, DmaParams, StreamSpec};

/// Tiles speak the engine-wide stepping contract — see
/// [`crate::sim::event`] for the deadline semantics. Re-exported here
/// because every tile implementation returns an [`Outcome`].
pub use crate::sim::event::{Deadline, EventSource, Outcome};

use crate::clock::domain::ClockDomain;
use crate::mem::BlockStore;
use crate::monitor::MonitorFile;
use crate::noc::{ClockView, LinkFifo, Mesh, PacketArena};
use crate::runtime::AccelCompute;
use crate::util::Ps;

/// Shared state a tile may touch during its tick.
pub struct TileCtx<'a> {
    pub now: Ps,
    /// The tile's island-cycle number at this edge (cycles delivered so
    /// far, including this one). Tiles keep timers in this unit so a
    /// skipped stretch of no-op cycles costs them nothing.
    pub cycle: u64,
    pub mesh: &'a Mesh,
    /// The fabric's link-FIFO arena (NI inject/eject FIFOs included).
    pub links: &'a mut [LinkFifo],
    pub view: &'a ClockView,
    pub arena: &'a mut PacketArena,
    pub blocks: &'a mut BlockStore,
    pub compute: &'a mut dyn AccelCompute,
    pub mon: &'a mut MonitorFile,
    /// All clock domains (the I/O tile services frequency registers).
    pub islands: &'a mut [ClockDomain],
}

/// A tile instance (enum dispatch keeps the hot loop monomorphic).
/// `Clone` deep-copies the full tile state (NI FIFO bookkeeping, DMA
/// pipelines, RNGs) for simulation forking.
#[derive(Clone)]
pub enum Tile {
    Cpu(cpu::CpuTile),
    Mem(mem_tile::MemTile),
    Io(io::IoTile),
    Tg(tg::TgTile),
    Mra(Box<mra::MraTile>),
}

impl Tile {
    /// Tile index (== NoC node index) this tile sits at.
    pub fn node_index(&self) -> usize {
        self.ni().node.index()
    }

    pub fn ni(&self) -> &ni::NetIface {
        match self {
            Tile::Cpu(t) => &t.ni,
            Tile::Mem(t) => &t.ni,
            Tile::Io(t) => &t.ni,
            Tile::Tg(t) => &t.ni,
            Tile::Mra(t) => &t.ni,
        }
    }

    /// One island-clock cycle. The returned [`Outcome`] tells the
    /// engine when this tile next needs ticking.
    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> Outcome {
        match self {
            Tile::Cpu(t) => t.tick(ctx),
            Tile::Mem(t) => t.tick(ctx),
            Tile::Io(t) => t.tick(ctx),
            Tile::Tg(t) => t.tick(ctx),
            Tile::Mra(t) => t.tick(ctx),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Tile::Cpu(_) => "cpu",
            Tile::Mem(_) => "mem",
            Tile::Io(_) => "io",
            Tile::Tg(_) => "tg",
            Tile::Mra(_) => "mra",
        }
    }
}

impl EventSource for Tile {
    type Ctx<'a> = TileCtx<'a>;

    /// Registration deadline for a freshly (re)armed tile: due at its
    /// island's next edge. Conservative on purpose — the first fire's
    /// [`Outcome`] re-derives the true wake point from tile state, so
    /// the engine never has to reason about tile internals here.
    fn next_deadline(&self, _ctx: &TileCtx<'_>) -> Deadline {
        Deadline::Cycle(0)
    }

    fn fire(&mut self, now: Ps, ctx: &mut TileCtx<'_>) -> Outcome {
        debug_assert_eq!(now, ctx.now);
        self.tick(ctx)
    }
}
