//! SoC tiles: CPU, memory, I/O, traffic generators, and the multi-replica
//! accelerator (MRA) tiles that are the paper's contribution 1.
//!
//! Every tile owns a [`ni::NetIface`] connecting it to its NoC node's
//! local router port. Tiles are ticked by the simulation engine at their
//! frequency island's clock edges and interact with shared state through
//! [`TileCtx`].

pub mod cpu;
pub mod io;
pub mod mem_tile;
pub mod mra;
pub mod ni;
pub mod tg;
pub mod timing;

pub use mra::{MraTile, ReplicaState};
pub use ni::NetIface;
pub use timing::{AccelTiming, DmaParams, StreamSpec};

use crate::clock::domain::ClockDomain;
use crate::mem::BlockStore;
use crate::monitor::MonitorFile;
use crate::noc::{ClockView, LinkFifo, Mesh, PacketArena};
use crate::runtime::AccelCompute;
use crate::util::Ps;

/// Shared state a tile may touch during its tick.
pub struct TileCtx<'a> {
    pub now: Ps,
    pub mesh: &'a Mesh,
    /// The fabric's link-FIFO arena (NI inject/eject FIFOs included).
    pub links: &'a mut [LinkFifo],
    pub view: &'a ClockView,
    pub arena: &'a mut PacketArena,
    pub blocks: &'a mut BlockStore,
    pub compute: &'a mut dyn AccelCompute,
    pub mon: &'a mut MonitorFile,
    /// All clock domains (the I/O tile services frequency registers).
    pub islands: &'a mut [ClockDomain],
}

/// A tile instance (enum dispatch keeps the hot loop monomorphic).
pub enum Tile {
    Cpu(cpu::CpuTile),
    Mem(mem_tile::MemTile),
    Io(io::IoTile),
    Tg(tg::TgTile),
    Mra(Box<mra::MraTile>),
}

impl Tile {
    /// Tile index (== NoC node index) this tile sits at.
    pub fn node_index(&self) -> usize {
        self.ni().node.index()
    }

    pub fn ni(&self) -> &ni::NetIface {
        match self {
            Tile::Cpu(t) => &t.ni,
            Tile::Mem(t) => &t.ni,
            Tile::Io(t) => &t.ni,
            Tile::Tg(t) => &t.ni,
            Tile::Mra(t) => &t.ni,
        }
    }

    /// One island-clock cycle.
    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) {
        match self {
            Tile::Cpu(t) => t.tick(ctx),
            Tile::Mem(t) => t.tick(ctx),
            Tile::Io(t) => t.tick(ctx),
            Tile::Tg(t) => t.tick(ctx),
            Tile::Mra(t) => t.tick(ctx),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Tile::Cpu(_) => "cpu",
            Tile::Mem(_) => "mem",
            Tile::Io(_) => "io",
            Tile::Tg(_) => "tg",
            Tile::Mra(_) => "mra",
        }
    }
}
