//! CPU tile: a CVA6 stand-in that exercises the monitoring path from
//! software — it periodically polls accelerator counters over the
//! config NoC plane, as §II-C's "accessed via software executing on CPU
//! cores of the SoC" path.

use crate::monitor::mmio::{counter_addr, CounterReg};
use crate::noc::{Msg, NodeId};

use super::{ni::NetIface, TileCtx};

/// The CPU tile.
pub struct CpuTile {
    pub ni: NetIface,
    pub tile_index: usize,
    /// Nodes of the accelerator tiles to poll (with their tile indices).
    pub poll_targets: Vec<(NodeId, usize)>,
    /// Poll period in CPU cycles (0 = polling off).
    pub poll_interval: u32,
    countdown: u32,
    next_target: usize,
    tag: u32,
    /// Completed polls (read responses received).
    pub polls_completed: u64,
    /// Last polled value (software-visible register).
    pub last_value: u64,
}

impl CpuTile {
    pub fn new(ni: NetIface, tile_index: usize, poll_interval: u32) -> Self {
        Self {
            ni,
            tile_index,
            poll_targets: Vec::new(),
            poll_interval,
            countdown: poll_interval,
            next_target: 0,
            tag: 0,
            polls_completed: 0,
            last_value: 0,
        }
    }

    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) {
        for pkt in self.ni.tick_rx(ctx.links, ctx.now, 0) {
            if let Msg::MmioResp { value, .. } = ctx.arena.get(pkt).msg {
                self.polls_completed += 1;
                self.last_value = value;
            }
            ctx.arena.release(pkt);
        }

        if self.poll_interval > 0 && !self.poll_targets.is_empty() {
            if self.countdown > 0 {
                self.countdown -= 1;
            } else if self.ni.tx_backlog() < 4 {
                let (node, tile) = self.poll_targets[self.next_target];
                self.next_target = (self.next_target + 1) % self.poll_targets.len();
                let addr = counter_addr(tile, CounterReg::ExecTime);
                self.tag = self.tag.wrapping_add(1);
                self.ni.send(
                    ctx.arena,
                    node,
                    Msg::MmioRead {
                        addr,
                        tag: self.tag,
                    },
                    ctx.now,
                );
                self.countdown = self.poll_interval;
            }
        }

        self.ni.tick_tx(ctx.links, ctx.arena, ctx.view, ctx.now);
    }
}
