//! CPU tile: a CVA6 stand-in that exercises the monitoring path from
//! software — it periodically polls accelerator counters over the
//! config NoC plane, as §II-C's "accessed via software executing on CPU
//! cores of the SoC" path.

use crate::monitor::mmio::{counter_addr, CounterReg};
use crate::noc::{Msg, NodeId};

use super::{ni::NetIface, Outcome, TileCtx};

/// The CPU tile.
#[derive(Debug, Clone)]
pub struct CpuTile {
    pub ni: NetIface,
    pub tile_index: usize,
    /// Nodes of the accelerator tiles to poll (with their tile indices).
    pub poll_targets: Vec<(NodeId, usize)>,
    /// Poll period in CPU cycles (0 = polling off).
    pub poll_interval: u32,
    /// Island cycle at/after which the next poll fires. Absolute (not a
    /// per-tick countdown) so the poll cadence survives skipped no-op
    /// cycles unchanged; equal timing either way.
    next_poll_cycle: u64,
    next_target: usize,
    tag: u32,
    /// Completed polls (read responses received).
    pub polls_completed: u64,
    /// Last polled value (software-visible register).
    pub last_value: u64,
}

impl CpuTile {
    pub fn new(ni: NetIface, tile_index: usize, poll_interval: u32) -> Self {
        Self {
            ni,
            tile_index,
            poll_targets: Vec::new(),
            poll_interval,
            // The legacy countdown fired on the (interval+1)-th tick.
            next_poll_cycle: poll_interval as u64 + 1,
            next_target: 0,
            tag: 0,
            polls_completed: 0,
            last_value: 0,
        }
    }

    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> Outcome {
        let mut did_work = false;
        for pkt in self.ni.tick_rx(ctx.links, ctx.now, 0) {
            if let Msg::MmioResp { value, .. } = ctx.arena.get(pkt).msg {
                self.polls_completed += 1;
                self.last_value = value;
            }
            ctx.arena.release(pkt);
            did_work = true;
        }

        let polling = self.poll_interval > 0 && !self.poll_targets.is_empty();
        if polling && ctx.cycle >= self.next_poll_cycle && self.ni.tx_backlog() < 4 {
            let (node, tile) = self.poll_targets[self.next_target];
            self.next_target = (self.next_target + 1) % self.poll_targets.len();
            let addr = counter_addr(tile, CounterReg::ExecTime);
            self.tag = self.tag.wrapping_add(1);
            self.ni.send(
                ctx.arena,
                node,
                Msg::MmioRead {
                    addr,
                    tag: self.tag,
                },
                ctx.now,
            );
            self.next_poll_cycle = ctx.cycle + self.poll_interval as u64 + 1;
            did_work = true;
        }

        self.ni.tick_tx(ctx.links, ctx.arena, ctx.view, ctx.now);

        if self.ni.tx_backlog() > 0 {
            // Flits still to inject (or a poll deferred on backlog).
            Outcome::active(true, ctx.cycle)
        } else if polling {
            Outcome::sleep_until(did_work, self.next_poll_cycle)
        } else {
            Outcome::on_input(did_work)
        }
    }
}
