//! Per-accelerator timing models and DMA parameters.
//!
//! An accelerator invocation is one fixed-shape block computation (the
//! AOT-lowered Layer-2 function). Its timing on the FPGA is characterized
//! by the compute cycles per invocation (at the tile's island clock) and
//! the DMA geometry. The compute-cycle figures are calibrated from
//! Table I's baseline (1x) throughput at 50 MHz with an uncontended
//! NoC@100MHz — see DESIGN.md §4:
//!
//! `compute_cycles = 50e6 * credit_bytes / (thr_MBs * 1e6)`
//!
//! dfadd/dfmul carry *low* cycles-per-byte (their HLS pipelines are
//! shallow — they are memory-bound: DMA dominates whenever the NoC/MEM
//! path is slow or contended), while dfsin/adpcm are deeply compute-bound.

/// DMA engine parameters (per replica).
#[derive(Debug, Clone, Copy)]
pub struct DmaParams {
    /// Data words per burst (ESP DMA transfers cacheline-sized chunks).
    pub burst_beats: u16,
    /// Maximum outstanding read bursts per replica.
    pub max_outstanding: usize,
}

impl Default for DmaParams {
    fn default() -> Self {
        Self {
            burst_beats: 16,
            max_outstanding: 4,
        }
    }
}

/// Shape of one DMA input stream of an accelerator invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSpec {
    /// 32-bit words per invocation on this stream.
    pub words: usize,
    /// Integer lanes (i32) when true, f32 otherwise.
    pub int: bool,
}

/// dfadd/dfmul: two f32 (8,128) operand streams.
const DF_PAIR_STREAMS: [StreamSpec; 2] = [
    StreamSpec { words: 8 * 128, int: false },
    StreamSpec { words: 8 * 128, int: false },
];
/// dfsin: one f32 (8,128) stream.
const DF_SINGLE_STREAM: [StreamSpec; 1] = [StreamSpec { words: 8 * 128, int: false }];
/// adpcm: one i32 (64,128) PCM block.
const ADPCM_STREAMS: [StreamSpec; 1] = [StreamSpec { words: 64 * 128, int: true }];
/// gsm: one f32 (160,128) frame block.
const GSM_STREAMS: [StreamSpec; 1] = [StreamSpec { words: 160 * 128, int: false }];

/// Timing + geometry of one accelerator kind.
#[derive(Debug, Clone)]
pub struct AccelTiming {
    pub name: &'static str,
    /// Input bytes per invocation (sum over input streams).
    pub bytes_in: u32,
    /// Output bytes per invocation.
    pub bytes_out: u32,
    /// Bytes credited to throughput per invocation (what Table I's MB/s
    /// measures: the accelerator's processed stream).
    pub credit_bytes: u32,
    /// Busy cycles per invocation at the tile clock once inputs are
    /// buffered (the HLS pipeline's fill+drain time).
    pub compute_cycles: u64,
    /// Qualitative class from the paper (affects nothing; reporting only).
    pub memory_bound: bool,
    /// Per-stream input geometry (the streaming interface the AOT
    /// manifest records). `bytes_in` is the sum over these streams —
    /// asserted in tests; the host driver stages inputs from this table.
    pub input_streams: &'static [StreamSpec],
}

impl AccelTiming {
    /// Calibrated timing DB for the five CHStone accelerators.
    ///
    /// Geometry matches `python/compile/model.py` (and the artifacts
    /// manifest; checked at SoC build time):
    ///   dfadd/dfmul: in 2x(8,128) f32, out (8,128)  -> 8192 B / 4096 B
    ///   dfsin:       in  (8,128) f32, out (8,128)   -> 4096 B / 4096 B
    ///   adpcm:       in  (64,128) i32, out (64,128) -> 32768 B / 32768 B
    ///   gsm:         in  (160,128) f32, out (16+8,128) -> 81920 B / 12288 B
    ///
    /// `compute_cycles` from Table I baseline throughput @ 50 MHz:
    ///   adpcm 1.40 MB/s over 32768 B  -> 1_170_000 cyc
    ///   dfadd 9.22 MB/s over 4096 B   ->     22_212 cyc
    ///   dfmul 8.70 MB/s over 4096 B   ->     23_540 cyc
    ///   dfsin 0.33 MB/s over 4096 B   ->    620_606 cyc
    ///   gsm   4.61 MB/s over 81920 B  ->    888_503 cyc
    pub fn db() -> Vec<AccelTiming> {
        vec![
            AccelTiming {
                name: "adpcm",
                bytes_in: 64 * 128 * 4,
                bytes_out: 64 * 128 * 4,
                credit_bytes: 64 * 128 * 4,
                compute_cycles: 1_170_000,
                memory_bound: false,
                input_streams: &ADPCM_STREAMS,
            },
            AccelTiming {
                name: "dfadd",
                bytes_in: 2 * 8 * 128 * 4,
                bytes_out: 8 * 128 * 4,
                credit_bytes: 8 * 128 * 4,
                compute_cycles: 22_212,
                memory_bound: true,
                input_streams: &DF_PAIR_STREAMS,
            },
            AccelTiming {
                name: "dfmul",
                bytes_in: 2 * 8 * 128 * 4,
                bytes_out: 8 * 128 * 4,
                credit_bytes: 8 * 128 * 4,
                compute_cycles: 23_540,
                memory_bound: true,
                input_streams: &DF_PAIR_STREAMS,
            },
            AccelTiming {
                name: "dfsin",
                bytes_in: 8 * 128 * 4,
                bytes_out: 8 * 128 * 4,
                credit_bytes: 8 * 128 * 4,
                compute_cycles: 620_606,
                memory_bound: false,
                input_streams: &DF_SINGLE_STREAM,
            },
            AccelTiming {
                name: "gsm",
                bytes_in: 160 * 128 * 4,
                bytes_out: (16 + 8) * 128 * 4,
                credit_bytes: 160 * 128 * 4,
                compute_cycles: 888_503,
                memory_bound: false,
                input_streams: &GSM_STREAMS,
            },
        ]
    }

    pub fn lookup(name: &str) -> crate::Result<AccelTiming> {
        Self::db()
            .into_iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown accelerator {name:?}"))
    }

    /// Read bursts per invocation for a given DMA burst size.
    pub fn read_bursts(&self, burst_beats: u16) -> u32 {
        let beats = self.bytes_in / 4;
        beats.div_ceil(burst_beats as u32)
    }

    /// Write bursts per invocation.
    pub fn write_bursts(&self, burst_beats: u16) -> u32 {
        let beats = self.bytes_out / 4;
        beats.div_ceil(burst_beats as u32)
    }

    /// Ideal (uncontended, DMA-free) throughput in MB/s at `freq_mhz`.
    pub fn ideal_throughput_mbs(&self, freq_mhz: u64) -> f64 {
        self.credit_bytes as f64 * freq_mhz as f64 / self.compute_cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_has_all_five() {
        let names: Vec<&str> = AccelTiming::db().iter().map(|a| a.name).collect();
        assert_eq!(names, vec!["adpcm", "dfadd", "dfmul", "dfsin", "gsm"]);
    }

    #[test]
    fn calibration_matches_table1_baseline() {
        // ideal throughput at 50 MHz must land on the Table I baseline
        // within 1%.
        for (name, want) in [
            ("adpcm", 1.40),
            ("dfadd", 9.22),
            ("dfmul", 8.70),
            ("dfsin", 0.33),
            ("gsm", 4.61),
        ] {
            let t = AccelTiming::lookup(name).unwrap();
            let got = t.ideal_throughput_mbs(50);
            assert!(
                (got - want).abs() / want < 0.01,
                "{name}: {got:.3} vs {want}"
            );
        }
    }

    #[test]
    fn burst_counts() {
        let t = AccelTiming::lookup("dfadd").unwrap();
        assert_eq!(t.read_bursts(16), 128); // 2048 beats / 16
        assert_eq!(t.write_bursts(16), 64);
        let g = AccelTiming::lookup("gsm").unwrap();
        assert_eq!(g.read_bursts(16), 1280);
    }

    #[test]
    fn input_streams_sum_to_bytes_in() {
        // The per-stream geometry (what the host driver stages) must
        // agree with the aggregate DMA byte count used by the timing
        // model — one source of truth for python/compile/model.py shapes.
        for t in AccelTiming::db() {
            let words: usize = t.input_streams.iter().map(|s| s.words).sum();
            assert_eq!(words as u32 * 4, t.bytes_in, "{}", t.name);
            assert!(!t.input_streams.is_empty(), "{}", t.name);
        }
    }

    #[test]
    fn memory_bound_classification() {
        assert!(AccelTiming::lookup("dfmul").unwrap().memory_bound);
        assert!(!AccelTiming::lookup("adpcm").unwrap().memory_bound);
    }

    #[test]
    fn unknown_accel_rejected() {
        assert!(AccelTiming::lookup("bogus").is_err());
    }
}
