//! MEM tile: terminates DMA traffic at the DDR controller model and
//! counts Fig. 4's "incoming data packets to memory".

use crate::mem::{MemController, MemParams, MemRequest};
use crate::noc::{Msg, Plane};

use super::{ni::NetIface, Outcome, TileCtx};

/// The MEM tile.
#[derive(Debug, Clone)]
pub struct MemTile {
    pub ni: NetIface,
    pub tile_index: usize,
    pub ctrl: MemController,
}

impl MemTile {
    pub fn new(ni: NetIface, tile_index: usize, params: MemParams) -> Self {
        Self {
            ni,
            tile_index,
            ctrl: MemController::new(params),
        }
    }

    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> Outcome {
        let mut did_work = false;
        // The controller clocks with the tile's island (NoC+MEM share a
        // frequency island in the paper instance).
        let period = ctx.view.periods[self.ni.island];

        // Back-pressure the request plane when the controller queue is
        // full — the NoC absorbs it (ejection FIFO fills, then credits).
        let hold = if self.ctrl.can_accept() {
            0
        } else {
            1 << Plane::Request.index()
        };
        for pkt in self.ni.tick_rx(ctx.links, ctx.now, hold) {
            did_work = true;
            let p = ctx.arena.get(pkt);
            let (src, msg) = (p.src, p.msg);
            ctx.mon.mem_pkts_in += 1;
            match msg {
                Msg::MemRead { addr, beats, tag } => {
                    self.ctrl.accept(
                        MemRequest {
                            addr,
                            beats,
                            is_write: false,
                            src: src.0,
                            tag,
                            block: u32::MAX,
                            offset: 0,
                        },
                        ctx.now,
                    );
                }
                Msg::MemWrite {
                    addr, beats, tag, ..
                } => {
                    ctx.mon.mem_beats_in += beats as u64;
                    self.ctrl.accept(
                        MemRequest {
                            addr,
                            beats,
                            is_write: true,
                            src: src.0,
                            tag,
                            block: u32::MAX,
                            offset: 0,
                        },
                        ctx.now,
                    );
                }
                other => debug_assert!(false, "MEM tile got unexpected {other:?}"),
            }
            ctx.arena.release(pkt);
        }

        self.ctrl.tick(ctx.now, period);

        // Packetize completed bursts (throttled by the NI backlog so the
        // response path models the single ejection port).
        while self.ni.tx_backlog() < 8 {
            let Some(resp) = self.ctrl.pop_done(ctx.now) else {
                break;
            };
            let dst = crate::noc::NodeId(resp.req.src);
            let msg = if resp.req.is_write {
                Msg::MemWriteAck { tag: resp.req.tag }
            } else {
                Msg::MemReadResp {
                    beats: resp.req.beats,
                    tag: resp.req.tag,
                    block: crate::mem::BlockId(resp.req.block),
                    offset: resp.req.offset,
                }
            };
            self.ni.send(ctx.arena, dst, msg, ctx.now);
            did_work = true;
        }

        self.ni.tick_tx(ctx.links, ctx.arena, ctx.view, ctx.now);

        // The controller needs per-cycle ticks while anything is queued
        // or draining; with everything empty the tile is purely reactive.
        let busy = self.ctrl.queued() > 0
            || self.ctrl.pending_responses() > 0
            || self.ni.tx_backlog() > 0;
        if busy {
            Outcome::active(true, ctx.cycle)
        } else {
            Outcome::on_input(did_work)
        }
    }
}
