//! Tile network interface (NI): packetization between a tile and its
//! router's local port, one flit per plane per tile-clock cycle.
//!
//! The NI is where tile-island traffic crosses into the NoC island:
//! flits pushed towards the router are stamped with the resynchronizer
//! delay (see [`crate::noc::ClockView::ready_at`]), modelling the
//! dual-clock FIFOs at the island boundary (Fig. 1's *Resync* blocks).

use std::collections::VecDeque;

use crate::noc::{ClockView, LinkFifo, LinkId, Msg, NodeId, PacketArena, PacketId, NUM_PLANES};
use crate::util::Ps;

/// Per-plane NI endpoint state.
#[derive(Debug, Default, Clone)]
struct PlaneState {
    /// Packets queued for injection.
    tx: VecDeque<PacketId>,
    /// Flits of the front packet already injected.
    tx_sent: u16,
    /// Flits of the in-progress incoming packet received.
    rx_got: u16,
}

/// Packets completed in one rx tick (at most one per plane).
#[derive(Debug, Default, Clone, Copy)]
pub struct RxDone(pub [Option<PacketId>; NUM_PLANES]);

impl RxDone {
    pub fn iter(&self) -> impl Iterator<Item = PacketId> + '_ {
        self.0.iter().flatten().copied()
    }

    pub fn is_empty(&self) -> bool {
        self.0.iter().all(Option::is_none)
    }
}

impl IntoIterator for RxDone {
    type Item = PacketId;
    type IntoIter = core::iter::Flatten<core::array::IntoIter<Option<PacketId>, NUM_PLANES>>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.into_iter().flatten()
    }
}

/// The NI.
#[derive(Debug, Clone)]
pub struct NetIface {
    pub node: NodeId,
    /// Frequency island of the owning tile.
    pub island: usize,
    /// Island of the NoC routers (resync target).
    pub noc_island: usize,
    /// Inject link per plane (NI -> router local input).
    pub inject: [LinkId; NUM_PLANES],
    /// Eject link per plane (router local output -> NI).
    pub eject: [LinkId; NUM_PLANES],
    planes: [PlaneState; NUM_PLANES],
    /// Packets fully injected (stats).
    pub pkts_sent: u64,
    /// Packets fully received (stats).
    pub pkts_received: u64,
}

impl NetIface {
    pub fn new(
        node: NodeId,
        island: usize,
        noc_island: usize,
        inject: [LinkId; NUM_PLANES],
        eject: [LinkId; NUM_PLANES],
    ) -> Self {
        Self {
            node,
            island,
            noc_island,
            inject,
            eject,
            planes: Default::default(),
            pkts_sent: 0,
            pkts_received: 0,
        }
    }

    /// Queue a message for transmission. Returns the packet id.
    pub fn send(
        &mut self,
        arena: &mut PacketArena,
        dst: NodeId,
        msg: Msg,
        now: Ps,
    ) -> PacketId {
        let plane = msg.plane();
        let id = arena.alloc(self.node, dst, msg, now);
        self.planes[plane.index()].tx.push_back(id);
        id
    }

    /// Packets waiting (or in progress) for injection on any plane.
    pub fn tx_backlog(&self) -> usize {
        self.planes.iter().map(|p| p.tx.len()).sum()
    }

    /// One tile-clock cycle of the transmit side: inject up to one flit
    /// per plane.
    pub fn tick_tx(
        &mut self,
        links: &mut [LinkFifo],
        arena: &PacketArena,
        view: &ClockView,
        now: Ps,
    ) {
        for p in 0..NUM_PLANES {
            let st = &mut self.planes[p];
            let Some(&pkt) = st.tx.front() else { continue };
            let fifo = &mut links[self.inject[p].0 as usize];
            if !fifo.can_push() {
                continue;
            }
            let flit = arena.flit(pkt, st.tx_sent);
            let t = view.ready_at(now, self.island, self.noc_island);
            fifo.push(flit, t);
            st.tx_sent += 1;
            if flit.is_tail() {
                st.tx.pop_front();
                st.tx_sent = 0;
                self.pkts_sent += 1;
            }
        }
    }

    /// One tile-clock cycle of the receive side: eject up to one flit per
    /// plane; returns packets completed this cycle (tail received), at
    /// most one per plane — a fixed array, so the hot loop never
    /// allocates. Planes whose index is in `hold_planes` are
    /// back-pressured (the tile cannot accept more messages of that
    /// class — e.g. a full memory-controller queue).
    pub fn tick_rx(
        &mut self,
        links: &mut [LinkFifo],
        now: Ps,
        hold_planes: u8,
    ) -> RxDone {
        let mut done = RxDone::default();
        for p in 0..NUM_PLANES {
            if hold_planes & (1 << p) != 0 {
                continue;
            }
            let st = &mut self.planes[p];
            let fifo = &mut links[self.eject[p].0 as usize];
            if let Some(flit) = fifo.pop(now) {
                st.rx_got += 1;
                if flit.is_tail() {
                    debug_assert_eq!(st.rx_got, flit.len, "flit loss within packet");
                    st.rx_got = 0;
                    self.pkts_received += 1;
                    done.0[p] = Some(flit.packet);
                }
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::Msg;

    fn view() -> ClockView {
        ClockView {
            periods: vec![20_000, 10_000],
            last_edges: vec![0, 0],
            pipeline: 1,
            sync_stages: 2,
        }
    }

    fn ni_and_links() -> (NetIface, Vec<LinkFifo>) {
        let links: Vec<LinkFifo> = (0..6).map(|_| LinkFifo::new(2)).collect();
        let ni = NetIface::new(
            NodeId(0),
            0,
            1,
            [LinkId(0), LinkId(1), LinkId(2)],
            [LinkId(3), LinkId(4), LinkId(5)],
        );
        (ni, links)
    }

    #[test]
    fn injects_one_flit_per_cycle_with_cdc_stamp() {
        let (mut ni, mut links) = ni_and_links();
        let mut arena = PacketArena::new();
        ni.send(
            &mut arena,
            NodeId(3),
            Msg::MemRead {
                addr: 0,
                beats: 4,
                tag: 1,
            },
            0,
        );
        ni.tick_tx(&mut links, &arena, &view(), 20_000);
        assert_eq!(links[0].len(), 1);
        // Crossing island 0 -> 1 (period 10_000): visible at the second
        // 10 kps edge after 20_000 => 40_000.
        assert!(links[0].peek(39_999).is_none());
        assert!(links[0].peek(40_000).is_some());
    }

    #[test]
    fn multi_flit_packet_injected_over_cycles() {
        let (mut ni, mut links) = ni_and_links();
        let mut arena = PacketArena::new();
        ni.send(
            &mut arena,
            NodeId(3),
            Msg::MemReadResp {
                beats: 3,
                tag: 0,
                block: crate::mem::BlockId(0),
                offset: 0,
            },
            0,
        );
        // 4 flits total, inject fifo cap 2: two cycles fill it, then stall.
        ni.tick_tx(&mut links, &arena, &view(), 20_000);
        ni.tick_tx(&mut links, &arena, &view(), 40_000);
        ni.tick_tx(&mut links, &arena, &view(), 60_000);
        assert_eq!(links[1].len(), 2, "response plane fifo capped");
        assert_eq!(ni.pkts_sent, 0);
        // Drain and finish.
        links[1].pop(u64::MAX);
        links[1].pop(u64::MAX);
        ni.tick_tx(&mut links, &arena, &view(), 80_000);
        ni.tick_tx(&mut links, &arena, &view(), 100_000);
        assert_eq!(ni.pkts_sent, 1);
    }

    #[test]
    fn rx_completes_packet_on_tail() {
        let (mut ni, mut links) = ni_and_links();
        let mut arena = PacketArena::new();
        let pkt = arena.alloc(
            NodeId(3),
            NodeId(0),
            Msg::MemReadResp {
                beats: 1,
                tag: 9,
                block: crate::mem::BlockId(0),
                offset: 0,
            },
            0,
        );
        links[4].push(arena.flit(pkt, 0), 0);
        links[4].push(arena.flit(pkt, 1), 0);
        let d1 = ni.tick_rx(&mut links, 10, 0);
        assert!(d1.is_empty());
        let d2 = ni.tick_rx(&mut links, 20, 0);
        assert_eq!(d2.into_iter().collect::<Vec<_>>(), vec![pkt]);
        assert_eq!(ni.pkts_received, 1);
    }

    #[test]
    fn rx_hold_backpressures_plane() {
        let (mut ni, mut links) = ni_and_links();
        let mut arena = PacketArena::new();
        let pkt = arena.alloc(
            NodeId(3),
            NodeId(0),
            Msg::MemRead {
                addr: 0,
                beats: 1,
                tag: 0,
            },
            0,
        );
        links[3].push(arena.flit(pkt, 0), 0);
        let d = ni.tick_rx(&mut links, 10, 1 << 0); // hold Request plane
        assert!(d.is_empty());
        assert_eq!(links[3].len(), 1, "flit stays queued");
        let d = ni.tick_rx(&mut links, 20, 0);
        assert_eq!(d.iter().count(), 1);
    }
}
