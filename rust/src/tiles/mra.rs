//! Multi-replica accelerator (MRA) tile — paper contribution 1.
//!
//! `K` replicas of one HLS accelerator live behind the tile's
//! [`AxiBridge`]. Each replica runs an independent DMA-fetch → compute →
//! DMA-drain pipeline:
//!
//! * **Fetch** — the replica issues read-burst descriptors on its rdCtrl
//!   stream (bounded by `max_outstanding`); the tile converts bridge-muxed
//!   descriptors into `MemRead` packets; response data beats flow back
//!   through the bridge's rdData demux to the replica.
//! * **Compute** — once the invocation's input beats have arrived, the
//!   replica is busy for [`AccelTiming::compute_cycles`]. When the timer
//!   expires the *functional* result is produced by the PJRT executable
//!   (or the native reference backend) on the tile's staged input blocks.
//! * **Drain** — the replica streams the output through wrCtrl/wrData;
//!   the tile packetizes completed bursts into `MemWrite` packets.
//!
//! Throughput observed at the monitors therefore reflects compute time,
//! bridge contention (K-to-1 mux with per-burst grant switching), NoC
//! transit, resynchronizer crossings, and memory-controller queueing —
//! the full path the paper measures in Table I and Figs. 3-4.

use std::collections::VecDeque;

use crate::axi::bridge::UpStream;
use crate::axi::{AxiBridge, BridgeParams, StreamBeat};
use crate::mem::{Block, BlockId};
use crate::monitor::mmio::{self, CounterReg, MmioTarget};
use crate::noc::{Msg, NodeId};
use crate::util::Ps;

use super::timing::{AccelTiming, DmaParams};
use super::{ni::NetIface, Outcome, TileCtx};

/// Host-side admission state for traffic serving (see [`crate::serve`]).
///
/// When installed ([`MraTile::serve_begin`]) the tile's replicas may
/// start a *new* invocation (the first read burst of a fresh prefetch
/// round) only by consuming one host-granted credit; invocations already
/// in flight always run to completion. Each credited invocation that
/// finishes draining is tagged into [`ServeGate::completions`] with its
/// completion time and replica, so the serve dispatcher can attribute it
/// back to the request that paid the credit (FIFO per tile).
#[derive(Debug, Clone, Default)]
pub struct ServeGate {
    /// Invocation starts granted by the host but not yet consumed by a
    /// replica.
    pub credits: u64,
    /// Granted-but-not-completed invocations — the tile's serving queue
    /// depth as DFS policies observe it ([`MraTile::serve_backlog`]).
    pub backlog: u64,
    /// Completion log: `(time, replica)` per finished credited
    /// invocation, in completion order. Drained by the host.
    pub completions: VecDeque<(Ps, u8)>,
    /// Log `(time, replica)` into [`ServeGate::starts`] each time a
    /// replica consumes a credit. Off by default (zero cost); enabled by
    /// the serve engine when request tracing is on.
    pub record_starts: bool,
    /// Credit-consumption log (invocation starts), in consumption
    /// order. Drained by the host tracer.
    pub starts: VecDeque<(Ps, u8)>,
}

/// Snapshot of a replica's pipeline occupancy (debug/reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaState {
    /// Complete input sets buffered and ready to compute.
    pub inputs_ready: u32,
    /// Whether the compute pipeline is busy.
    pub computing: bool,
    /// Completed computations awaiting writeback.
    pub outputs_pending: u32,
}

/// One accelerator replica: three loosely-coupled engines (fetch,
/// compute, drain) sharing ping-pong buffers, as in ESP's DMA model —
/// the *next* invocation's input DMA overlaps the current computation.
#[derive(Debug, Clone)]
struct Replica {
    // fetch engine --------------------------------------------------
    /// Read bursts issued for the in-progress prefetch round.
    bursts_issued: u32,
    /// Read bursts in flight (ctrl issued, last data beat not yet seen).
    outstanding: usize,
    /// Input data beats received for the in-progress prefetch round.
    beats_received: u32,
    /// Complete input sets buffered (ping-pong: at most 2).
    inputs_ready: u32,
    /// Issue times of in-flight bursts (FIFO: per-replica responses
    /// return in order).
    inflight: VecDeque<Ps>,
    // compute engine ------------------------------------------------
    /// Island cycle at which the running computation completes; `None`
    /// = idle. Absolute (not a per-tick countdown) so a tile sleeping
    /// through a compute-bound stretch finishes on the exact same edge.
    compute_done_cycle: Option<u64>,
    // drain engine --------------------------------------------------
    /// Completed computations whose output is not yet written back.
    outputs_pending: u32,
    /// Write bursts whose descriptor has been pushed (current drain).
    wr_bursts_pushed: u32,
    /// Write data beats pushed (current drain).
    wr_beats_pushed: u32,
    /// Completed invocations (output fully drained).
    invocations: u64,
}

/// Input double-buffer depth (ESP ping-pong DMA buffers).
const INPUT_BUFFERS: u32 = 2;
/// Output buffers: one draining + one completing.
const OUTPUT_BUFFERS: u32 = 2;

impl Replica {
    fn new() -> Self {
        Self {
            bursts_issued: 0,
            outstanding: 0,
            beats_received: 0,
            inputs_ready: 0,
            inflight: VecDeque::new(),
            compute_done_cycle: None,
            outputs_pending: 0,
            wr_bursts_pushed: 0,
            wr_beats_pushed: 0,
            invocations: 0,
        }
    }

    fn state(&self) -> ReplicaState {
        ReplicaState {
            inputs_ready: self.inputs_ready,
            computing: self.compute_done_cycle.is_some(),
            outputs_pending: self.outputs_pending,
        }
    }
}

/// The MRA tile.
#[derive(Debug, Clone)]
pub struct MraTile {
    pub ni: NetIface,
    /// Tile index in the SoC (monitor-file slot).
    pub tile_index: usize,
    pub accel: String,
    pub timing: AccelTiming,
    pub dma: DmaParams,
    bridge: AxiBridge,
    replicas: Vec<Replica>,
    mem_node: NodeId,
    /// Replicas currently in Compute (drives the tile exec-time counter).
    computing: usize,
    /// Island cycle of the previous tick: a gap larger than one cycle
    /// means the engine skipped provably-no-op cycles, whose exec-time
    /// counts are credited in bulk on wake.
    last_cycle: u64,

    // -- tile-level packetization state --------------------------------
    /// Write bursts announced on wrCtrl awaiting data: (replica, beats).
    pending_writes: VecDeque<(u8, u16)>,
    /// wrData beats accumulated per replica.
    wr_data_avail: Vec<u32>,
    /// Delivered read-response bursts awaiting serialization into the
    /// bridge's tile-side rdData stream: (replica, beats left, total).
    rd_staging: VecDeque<(u8, u16)>,
    /// Rolling DMA address cursor (timing-only).
    addr_cursor: u64,

    // -- functional state ----------------------------------------------
    /// Input blocks for the accelerator function (staged by the driver;
    /// rotated per invocation when more than one set is staged).
    pub staged_inputs: Vec<Vec<BlockId>>,
    staged_cursor: usize,
    /// Outputs of the most recent invocation (validation hook).
    pub last_outputs: Vec<Block>,
    /// Invoke the functional backend on every invocation (true) or only
    /// on the first use of each staged input set (false — long benches).
    pub functional_every_invocation: bool,
    /// Cached outputs per staged input set (used when the flag is false).
    cached_outputs: Vec<Option<Vec<Block>>>,
    /// Total functional invocations actually executed.
    pub functional_calls: u64,

    // -- serving state -------------------------------------------------
    /// Admission gate for traffic serving; `None` (the default) is the
    /// classic free-running throughput mode.
    pub serve: Option<ServeGate>,

    // -- fault injection -----------------------------------------------
    /// Injected hang/slowdown windows (absolute local time, sorted,
    /// disjoint): inside a window the tile ticks as a provable no-op
    /// and promises its wake for the window end, which is identical
    /// across all engine modes. Empty outside chaos runs
    /// ([`crate::fault`]).
    stall_windows: Vec<(Ps, Ps)>,
}

impl MraTile {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        ni: NetIface,
        tile_index: usize,
        accel: &str,
        replicas: usize,
        timing: AccelTiming,
        dma: DmaParams,
        bridge_params: BridgeParams,
        mem_node: NodeId,
    ) -> Self {
        assert_eq!(bridge_params.replicas, replicas);
        Self {
            ni,
            tile_index,
            accel: accel.to_string(),
            timing,
            dma,
            bridge: AxiBridge::new(bridge_params),
            replicas: (0..replicas).map(|_| Replica::new()).collect(),
            mem_node,
            computing: 0,
            last_cycle: 0,
            pending_writes: VecDeque::new(),
            wr_data_avail: vec![0; replicas],
            rd_staging: VecDeque::new(),
            addr_cursor: 0,
            staged_inputs: Vec::new(),
            staged_cursor: 0,
            last_outputs: Vec::new(),
            functional_every_invocation: true,
            cached_outputs: Vec::new(),
            functional_calls: 0,
            serve: None,
            stall_windows: Vec::new(),
        }
    }

    /// Install hang/slowdown fault windows in absolute local time
    /// ([`crate::fault`]); merged with any already present.
    pub fn add_stall_windows(&mut self, windows: &[(Ps, Ps)]) {
        self.stall_windows.extend_from_slice(windows);
        crate::fault::normalize_windows(&mut self.stall_windows);
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Install (or reset) the serving admission gate: from now on a
    /// replica may start a new invocation only against a credit granted
    /// through [`MraTile::serve_grant`]. Invocations already in flight
    /// complete normally but are logged too — callers that need a clean
    /// ledger should quiesce the pipeline first (see
    /// [`MraTile::pipeline_idle`]) and call this again to reset.
    pub fn serve_begin(&mut self) {
        self.serve = Some(ServeGate::default());
    }

    /// Remove the admission gate, returning the tile to free-running
    /// throughput mode.
    pub fn serve_end(&mut self) {
        self.serve = None;
    }

    /// Enable or disable invocation-start logging on the serving gate
    /// (no-op unless serving). Disabling clears any pending entries.
    pub fn serve_record_starts(&mut self, on: bool) {
        if let Some(g) = &mut self.serve {
            g.record_starts = on;
            if !on {
                g.starts.clear();
            }
        }
    }

    /// Grant `n` invocation credits (no-op unless serving).
    pub fn serve_grant(&mut self, n: u64) {
        if let Some(g) = &mut self.serve {
            g.credits += n;
            g.backlog += n;
        }
    }

    /// Granted-but-not-completed invocations (0 when not serving) — the
    /// queue depth DFS policies such as
    /// [`crate::serve::QueueGovernor`] read at sample time.
    pub fn serve_backlog(&self) -> u64 {
        self.serve.as_ref().map_or(0, |g| g.backlog)
    }

    /// Whether every replica pipeline and tile-level FIFO is empty — no
    /// invocation is fetching, computing, or draining.
    pub fn pipeline_idle(&self) -> bool {
        self.replicas.iter().all(|r| {
            r.bursts_issued == 0
                && r.outstanding == 0
                && r.beats_received == 0
                && r.inputs_ready == 0
                && r.compute_done_cycle.is_none()
                && r.outputs_pending == 0
        }) && self.rd_staging.is_empty()
            && self.pending_writes.is_empty()
            && self.wr_data_avail.iter().all(|&n| n == 0)
    }

    pub fn invocations(&self) -> u64 {
        self.replicas.iter().map(|r| r.invocations).sum()
    }

    /// Pipeline snapshot of replica `r`.
    pub fn replica_state(&self, r: usize) -> ReplicaState {
        self.replicas[r].state()
    }

    /// Total input beats (words) of one invocation.
    fn in_beats(&self) -> u32 {
        self.timing.bytes_in / 4
    }

    fn out_beats(&self) -> u32 {
        self.timing.bytes_out / 4
    }

    /// Stage functional input sets (driver API). Each set is one vector
    /// of block ids matching the accelerator's manifest inputs.
    pub fn stage_inputs(&mut self, sets: Vec<Vec<BlockId>>) {
        self.cached_outputs = vec![None; sets.len()];
        self.staged_inputs = sets;
        self.staged_cursor = 0;
    }

    /// One tile-clock cycle.
    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> Outcome {
        // An injected hang freezes the whole tile: no rx/compute/tx
        // progress until the window ends. Every engine mode sees the
        // same no-op ticks (an early fire simply re-arms), so fault
        // timing is exact and engine-invariant.
        if !self.stall_windows.is_empty() {
            if let Some(until) = crate::fault::window_until(&self.stall_windows, ctx.now) {
                return Outcome::at(false, until);
            }
        }
        // Credit exec-time for skipped cycles: the engine only skips a
        // computing tile while every other engine is frozen, so each
        // missed cycle would have counted exactly one exec cycle.
        let elapsed = ctx.cycle.saturating_sub(self.last_cycle);
        if elapsed > 1 && self.computing > 0 {
            ctx.mon
                .tile_mut(self.tile_index)
                .on_exec_cycles(elapsed - 1);
        }
        self.last_cycle = ctx.cycle;

        self.rx(ctx);
        self.feed_rd_staging();
        self.bridge.tick();
        self.tick_replicas(ctx);
        self.packetize(ctx);
        self.ni.tick_tx(ctx.links, ctx.arena, ctx.view, ctx.now);
        self.outcome(ctx.cycle)
    }

    /// Post-tick wake computation: the tile must be ticked every cycle
    /// while any engine can make progress on its own; with everything
    /// drained and all replicas waiting, the only self-driven future
    /// event is a running computation's completion cycle.
    fn outcome(&self, cycle: u64) -> Outcome {
        let read_bursts = self.timing.read_bursts(self.dma.burst_beats);
        // A gated tile with zero credits cannot start a new prefetch
        // round, so it must not stay restless on that account (a credit
        // grant goes through host access, which wakes the tile).
        let can_start = self.serve.as_ref().is_none_or(|g| g.credits > 0);
        let restless = self.ni.tx_backlog() > 0
            || !self.rd_staging.is_empty()
            || !self.pending_writes.is_empty()
            || self.wr_data_avail.iter().any(|&n| n > 0)
            || !self.bridge.is_quiet()
            || self.replicas.iter().any(|r| {
                // Draining, startable, or able to issue another fetch.
                r.outputs_pending > 0
                    || (r.compute_done_cycle.is_none() && r.inputs_ready > 0)
                    || ((r.bursts_issued > 0 || (r.inputs_ready < INPUT_BUFFERS && can_start))
                        && r.bursts_issued < read_bursts
                        && r.outstanding < self.dma.max_outstanding)
            });
        if restless {
            return Outcome::active(true, cycle);
        }
        match self
            .replicas
            .iter()
            .filter_map(|r| r.compute_done_cycle)
            .min()
        {
            Some(done) => Outcome::sleep_until(true, done),
            None => Outcome::on_input(false),
        }
    }

    /// Deliver incoming packets.
    fn rx(&mut self, ctx: &mut TileCtx<'_>) {
        // Hold the response plane if staging is deep (finite reassembly
        // buffer): backpressure propagates into the NoC.
        let hold = if self.rd_staging.len() >= 8 {
            1 << crate::noc::Plane::Response.index()
        } else {
            0
        };
        for pkt in self.ni.tick_rx(ctx.links, ctx.now, hold) {
            let msg = ctx.arena.get(pkt).msg;
            ctx.mon.tile_mut(self.tile_index).on_pkt_in();
            match msg {
                Msg::MemReadResp { beats, tag, .. } => {
                    let replica = (tag >> 16) as u8;
                    self.rd_staging.push_back((replica, beats));
                }
                Msg::MemWriteAck { .. } => {}
                Msg::MmioRead { addr, tag } => {
                    let value = self.mmio_read(addr, ctx);
                    let src = ctx.arena.get(pkt).src;
                    self.ni
                        .send(ctx.arena, src, Msg::MmioResp { value, tag }, ctx.now);
                    ctx.mon.tile_mut(self.tile_index).on_pkt_out();
                }
                Msg::MmioWrite { addr, value } => {
                    self.mmio_write(addr, value, ctx);
                }
                other => {
                    debug_assert!(false, "MRA tile got unexpected {other:?}");
                }
            }
            ctx.arena.release(pkt);
        }
    }

    fn mmio_read(&self, addr: u64, ctx: &TileCtx<'_>) -> u64 {
        let c = ctx.mon.tile(self.tile_index);
        match mmio::decode(addr) {
            MmioTarget::Counter(_, reg) => match reg {
                CounterReg::Ctrl => c.enable as u64,
                CounterReg::ExecTime => c.exec_cycles,
                CounterReg::PktsIn => c.pkts_in,
                CounterReg::PktsOut => c.pkts_out,
                CounterReg::RttSum => c.rtt_sum,
                CounterReg::RttCnt => c.rtt_count,
                CounterReg::Invocations => c.invocations,
            },
            _ => 0,
        }
    }

    fn mmio_write(&mut self, addr: u64, value: u64, ctx: &mut TileCtx<'_>) {
        if let MmioTarget::Counter(_, CounterReg::Ctrl) = mmio::decode(addr) {
            let c = ctx.mon.tile_mut(self.tile_index);
            if value & 0b10 != 0 {
                c.manual_reset();
            }
            c.enable = (value & 0x0F) as u8;
        }
    }

    /// Serialize one staged response beat per cycle into the bridge's
    /// tile-side rdData stream.
    fn feed_rd_staging(&mut self) {
        let Some(&(replica, left)) = self.rd_staging.front() else {
            return;
        };
        if self
            .bridge
            .tile_rd_data
            .try_push(StreamBeat {
                replica,
                payload: 0,
                last: left == 1,
            })
        {
            if left == 1 {
                self.rd_staging.pop_front();
            } else {
                self.rd_staging.front_mut().unwrap().1 -= 1;
            }
        }
    }

    fn tick_replicas(&mut self, ctx: &mut TileCtx<'_>) {
        let in_beats = self.in_beats();
        let out_beats = self.out_beats();
        let read_bursts = self.timing.read_bursts(self.dma.burst_beats);
        let write_bursts = self.timing.write_bursts(self.dma.burst_beats);

        for r in 0..self.replicas.len() {
            // ---- rdData sink: consume one demuxed beat per cycle. ----
            if let Some(beat) = self.bridge.pop_rd_data(r) {
                let rep = &mut self.replicas[r];
                rep.beats_received += 1;
                if beat.last {
                    rep.outstanding -= 1;
                    if let Some(t_issue) = rep.inflight.pop_front() {
                        ctx.mon
                            .tile_mut(self.tile_index)
                            .on_round_trip(ctx.now - t_issue);
                    }
                }
            }

            // ---- fetch engine: prefetch up to INPUT_BUFFERS sets. ----
            {
                let rep = &mut self.replicas[r];
                // Continue the in-flight prefetch round, or start a new
                // one only while a ping-pong buffer is free — and, when
                // the serving gate is installed, only against a credit.
                let starting = rep.bursts_issued == 0;
                let credit_ok = match &self.serve {
                    Some(g) => !starting || g.credits > 0,
                    None => true,
                };
                let may_fetch =
                    (rep.bursts_issued > 0 || rep.inputs_ready < INPUT_BUFFERS) && credit_ok;
                if may_fetch
                    && rep.bursts_issued < read_bursts
                    && rep.outstanding < self.dma.max_outstanding
                    && self.bridge.can_push_up(UpStream::RdCtrl, r)
                {
                    let seq = rep.bursts_issued;
                    let ok = self.bridge.push_up(
                        UpStream::RdCtrl,
                        r,
                        StreamBeat {
                            replica: r as u8,
                            payload: seq as u64,
                            last: true,
                        },
                    );
                    debug_assert!(ok);
                    if starting {
                        if let Some(g) = &mut self.serve {
                            g.credits -= 1;
                            if g.record_starts {
                                g.starts.push_back((ctx.now, r as u8));
                            }
                        }
                    }
                    let rep = &mut self.replicas[r];
                    rep.inflight.push_back(ctx.now);
                    rep.bursts_issued += 1;
                    rep.outstanding += 1;
                }
                let rep = &mut self.replicas[r];
                if rep.beats_received >= in_beats {
                    rep.beats_received -= in_beats;
                    rep.inputs_ready += 1;
                    rep.bursts_issued = 0; // next prefetch round may begin
                }
            }

            // ---- compute engine. ----
            match self.replicas[r].compute_done_cycle {
                None => {
                    let rep = &mut self.replicas[r];
                    if rep.inputs_ready > 0 && rep.outputs_pending < OUTPUT_BUFFERS {
                        rep.inputs_ready -= 1;
                        rep.compute_done_cycle = Some(ctx.cycle + self.timing.compute_cycles);
                        if self.computing == 0 {
                            ctx.mon.tile_mut(self.tile_index).on_start(ctx.now);
                        }
                        self.computing += 1;
                    }
                }
                Some(done) => {
                    if ctx.cycle >= done {
                        self.finish_compute(r, ctx);
                    }
                }
            }

            // ---- drain engine. ----
            if self.replicas[r].outputs_pending > 0 {
                let rep = &self.replicas[r];
                let beats_announced = rep.wr_bursts_pushed * self.dma.burst_beats as u32;
                if rep.wr_bursts_pushed < write_bursts
                    && beats_announced <= rep.wr_beats_pushed
                    && self.bridge.can_push_up(UpStream::WrCtrl, r)
                {
                    let remaining_total = out_beats - beats_announced;
                    let burst = remaining_total.min(self.dma.burst_beats as u32) as u16;
                    self.bridge.push_up(
                        UpStream::WrCtrl,
                        r,
                        StreamBeat {
                            replica: r as u8,
                            payload: burst as u64,
                            last: true,
                        },
                    );
                    self.replicas[r].wr_bursts_pushed += 1;
                }
                let rep = &self.replicas[r];
                if rep.wr_beats_pushed < out_beats
                    && rep.wr_beats_pushed < rep.wr_bursts_pushed * self.dma.burst_beats as u32
                    && self.bridge.can_push_up(UpStream::WrData, r)
                {
                    let last = (rep.wr_beats_pushed + 1) % self.dma.burst_beats as u32 == 0
                        || rep.wr_beats_pushed + 1 == out_beats;
                    self.bridge.push_up(
                        UpStream::WrData,
                        r,
                        StreamBeat {
                            replica: r as u8,
                            payload: 0,
                            last,
                        },
                    );
                    self.replicas[r].wr_beats_pushed += 1;
                }
                let rep = &mut self.replicas[r];
                if rep.wr_beats_pushed >= out_beats {
                    rep.invocations += 1;
                    rep.outputs_pending -= 1;
                    rep.wr_bursts_pushed = 0;
                    rep.wr_beats_pushed = 0;
                    ctx.mon.tile_mut(self.tile_index).on_invocation();
                    // Serving: tag the completed invocation so the
                    // dispatcher can attribute it to a request.
                    if let Some(g) = &mut self.serve {
                        g.backlog = g.backlog.saturating_sub(1);
                        g.completions.push_back((ctx.now, r as u8));
                    }
                }
            }
        }

        if self.computing > 0 {
            ctx.mon.tile_mut(self.tile_index).on_exec_cycle();
        }
    }

    /// Compute finished on replica `r`: run the functional datapath.
    fn finish_compute(&mut self, r: usize, ctx: &mut TileCtx<'_>) {
        if !self.staged_inputs.is_empty() {
            let set = self.staged_cursor % self.staged_inputs.len();
            self.staged_cursor += 1;
            let run = self.functional_every_invocation || self.cached_outputs[set].is_none();
            if run {
                let ids = &self.staged_inputs[set];
                let inputs: Vec<&Block> = ids.iter().map(|&id| ctx.blocks.get(id)).collect();
                match ctx.compute.invoke(&self.accel, &inputs) {
                    Ok(outs) => {
                        self.functional_calls += 1;
                        self.cached_outputs[set] = Some(outs.clone());
                        self.last_outputs = outs;
                    }
                    Err(e) => panic!("functional invocation of {} failed: {e:#}", self.accel),
                }
            } else if let Some(outs) = &self.cached_outputs[set] {
                self.last_outputs = outs.clone();
            }
        }
        self.computing -= 1;
        if self.computing == 0 {
            ctx.mon.tile_mut(self.tile_index).on_complete(ctx.now);
        }
        let rep = &mut self.replicas[r];
        rep.compute_done_cycle = None;
        rep.outputs_pending += 1;
    }

    /// Convert bridge-muxed tile streams into NoC packets.
    fn packetize(&mut self, ctx: &mut TileCtx<'_>) {
        // rdCtrl descriptor -> MemRead packet (one per cycle).
        if self.ni.tx_backlog() < 16 {
            if let Some(beat) = self.bridge.tile_up[UpStream::RdCtrl as usize].pop() {
                let tag = ((beat.replica as u32) << 16) | (beat.payload as u32 & 0xFFFF);
                let addr = 0x1000_0000 + (self.tile_index as u64) * 0x10_0000 + self.addr_cursor;
                self.addr_cursor = (self.addr_cursor + self.dma.burst_beats as u64 * 4) % 0x10_0000;
                self.ni.send(
                    ctx.arena,
                    self.mem_node,
                    Msg::MemRead {
                        addr,
                        beats: self.dma.burst_beats,
                        tag,
                    },
                    ctx.now,
                );
                ctx.mon.tile_mut(self.tile_index).on_pkt_out();
            }
        }

        // wrCtrl descriptor -> pending write burst.
        if let Some(beat) = self.bridge.tile_up[UpStream::WrCtrl as usize].pop() {
            self.pending_writes
                .push_back((beat.replica, beat.payload as u16));
        }
        // wrData beat -> per-replica accumulation.
        if let Some(beat) = self.bridge.tile_up[UpStream::WrData as usize].pop() {
            self.wr_data_avail[beat.replica as usize] += 1;
        }
        // Completed write burst -> MemWrite packet.
        if let Some(&(r, beats)) = self.pending_writes.front() {
            if self.wr_data_avail[r as usize] >= beats as u32 && self.ni.tx_backlog() < 16 {
                self.pending_writes.pop_front();
                self.wr_data_avail[r as usize] -= beats as u32;
                let addr = 0x2000_0000 + (self.tile_index as u64) * 0x10_0000 + self.addr_cursor;
                self.ni.send(
                    ctx.arena,
                    self.mem_node,
                    Msg::MemWrite {
                        addr,
                        beats,
                        tag: (r as u32) << 16,
                        block: BlockId(u32::MAX), // timing-only payload
                        offset: 0,
                    },
                    ctx.now,
                );
                ctx.mon.tile_mut(self.tile_index).on_pkt_out();
            }
        }
    }
}
