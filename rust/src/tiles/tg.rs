//! Traffic-generator (TG) tile.
//!
//! The paper's TG tiles "generate traffic in the NoC interconnect and
//! implement dfadd accelerators, which were empirically observed to be
//! memory-bound" (§III). The model captures exactly that behaviour: a
//! stream of DMA read bursts to the MEM tile with a bounded number
//! outstanding, i.e. a latency-tolerant memory-bound requester. Enabling
//! `n` of them reproduces Fig. 3's X axis.

use std::collections::VecDeque;

use crate::noc::{Msg, NodeId};
use crate::util::{Ps, SplitMix64};

use super::{ni::NetIface, Outcome, TileCtx};

/// The TG tile.
#[derive(Debug, Clone)]
pub struct TgTile {
    pub ni: NetIface,
    pub tile_index: usize,
    /// Active at run time (host/CPU toggled; Fig. 3 sweeps this).
    pub enabled: bool,
    pub burst_beats: u16,
    pub max_outstanding: usize,
    /// Idle cycles between burst issues (0 = maximum pressure).
    pub gap_cycles: u32,
    outstanding: usize,
    seq: u32,
    /// First island cycle at which the next burst may issue. Absolute
    /// (the gap elapses in the background), so a sleeping TG wakes with
    /// its cadence intact.
    gap_until: u64,
    inflight: VecDeque<Ps>,
    rng: SplitMix64,
    mem_node: NodeId,
    /// Completed round trips (local stats; also in the monitor file).
    pub completed: u64,
}

impl TgTile {
    pub fn new(
        ni: NetIface,
        tile_index: usize,
        mem_node: NodeId,
        burst_beats: u16,
        max_outstanding: usize,
        rng: SplitMix64,
    ) -> Self {
        Self {
            ni,
            tile_index,
            enabled: false,
            burst_beats,
            max_outstanding,
            gap_cycles: 0,
            outstanding: 0,
            seq: 0,
            gap_until: 0,
            inflight: VecDeque::new(),
            rng,
            mem_node,
            completed: 0,
        }
    }

    pub fn tick(&mut self, ctx: &mut TileCtx<'_>) -> Outcome {
        let mut did_work = false;
        // Receive responses.
        for pkt in self.ni.tick_rx(ctx.links, ctx.now, 0) {
            did_work = true;
            let msg = ctx.arena.get(pkt).msg;
            ctx.mon.tile_mut(self.tile_index).on_pkt_in();
            if let Msg::MemReadResp { .. } = msg {
                self.outstanding -= 1;
                self.completed += 1;
                if let Some(t_issue) = self.inflight.pop_front() {
                    ctx.mon
                        .tile_mut(self.tile_index)
                        .on_round_trip(ctx.now - t_issue);
                }
            }
            ctx.arena.release(pkt);
        }

        // Issue new bursts.
        if ctx.cycle >= self.gap_until
            && self.enabled
            && self.outstanding < self.max_outstanding
            && self.ni.tx_backlog() < 8
        {
            let addr = 0x4000_0000
                + (self.tile_index as u64) * 0x10_0000
                + (self.rng.next_below(0x4000)) * 64;
            self.ni.send(
                ctx.arena,
                self.mem_node,
                Msg::MemRead {
                    addr,
                    beats: self.burst_beats,
                    tag: self.seq,
                },
                ctx.now,
            );
            self.inflight.push_back(ctx.now);
            self.seq = self.seq.wrapping_add(1);
            self.outstanding += 1;
            self.gap_until = ctx.cycle + self.gap_cycles as u64 + 1;
            ctx.mon.tile_mut(self.tile_index).on_pkt_out();
            did_work = true;
        }

        self.ni.tick_tx(ctx.links, ctx.arena, ctx.view, ctx.now);

        if self.ni.tx_backlog() > 0 {
            Outcome::active(true, ctx.cycle)
        } else if self.enabled && self.outstanding < self.max_outstanding {
            // Next issue is gated only by the gap (backlog is clear).
            Outcome::sleep_until(did_work, self.gap_until.max(ctx.cycle + 1))
        } else {
            // Saturated or disabled: a response (NoC input) unblocks us.
            Outcome::on_input(did_work)
        }
    }
}
