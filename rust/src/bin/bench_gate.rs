//! CI perf gate: compare `BENCH_*.json` reports against a checked-in
//! baseline and fail on regressions.
//!
//! ```text
//! bench_gate [--baseline ci/bench_baseline.json] [--tolerance 1.25]
//!            [--update] BENCH_noc_microbench.json [BENCH_...json ...]
//! ```
//!
//! The baseline (see `ci/bench_baseline.json`, schema in
//! `docs/PERF.md`) tracks two kinds of bounds:
//!
//! * `mean_ns` — wall-clock means per benchmark name; the gate fails
//!   when a current mean exceeds `baseline * tolerance` (default 1.25,
//!   i.e. a >25% regression).
//! * `min_metrics` — machine-independent lower bounds on report
//!   metrics, keyed `<bench>.<metric>` (e.g. the idle-aware engine's
//!   `noc_microbench.sparse_speedup_vs_reference >= 3`).
//! * `max_metrics` — machine-independent *upper* bounds, same key
//!   scheme (e.g. the autoscaler's cost claim
//!   `cluster_scale.autoscale_replica_seconds_vs_fixed_max <= 0.8`).
//!
//! Output is a GitHub-flavoured markdown table (append to
//! `$GITHUB_STEP_SUMMARY` in CI). `--update` rewrites the baseline's
//! `mean_ns` section from the current reports instead of gating —
//! the refresh flow after an intentional perf change (`min_metrics`
//! and `max_metrics` are hand-edited claims and are preserved).

use std::collections::BTreeMap;
use std::process::ExitCode;

use anyhow::{bail, Context};
use vespa::bench_harness::json::{self, Json};
use vespa::cli::Args;

struct Current {
    /// benchmark name -> mean ns.
    means: BTreeMap<String, f64>,
    /// `<bench>.<metric>` -> value.
    metrics: BTreeMap<String, f64>,
}

fn load_reports(paths: &[String]) -> vespa::Result<Current> {
    let mut means = BTreeMap::new();
    let mut metrics = BTreeMap::new();
    for path in paths {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {path}"))?;
        let doc = json::parse(&text).with_context(|| format!("parsing {path}"))?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .with_context(|| format!("{path}: missing \"bench\" field"))?
            .to_string();
        for r in doc
            .get("results")
            .and_then(Json::as_array)
            .unwrap_or_default()
        {
            let (Some(name), Some(mean)) = (
                r.get("name").and_then(Json::as_str),
                r.get("mean_ns").and_then(Json::as_f64),
            ) else {
                bail!("{path}: result entry without name/mean_ns");
            };
            means.insert(name.to_string(), mean);
        }
        if let Some(obj) = doc.get("metrics").and_then(Json::as_object) {
            for (k, v) in obj {
                if let Some(v) = v.as_f64() {
                    metrics.insert(format!("{bench}.{k}"), v);
                }
            }
        }
    }
    Ok(Current { means, metrics })
}

fn num_map(doc: &Json, key: &str) -> BTreeMap<String, f64> {
    doc.get(key)
        .and_then(Json::as_object)
        .unwrap_or_default()
        .iter()
        .filter_map(|(k, v)| v.as_f64().map(|v| (k.clone(), v)))
        .collect()
}

fn fmt_ms(ns: f64) -> String {
    format!("{:.1}", ns / 1e6)
}

fn run() -> vespa::Result<ExitCode> {
    let args = Args::from_env()?;
    // The subcommand slot eats the first positional; treat both as files.
    let mut files: Vec<String> = Vec::new();
    files.extend(args.subcommand.clone());
    files.extend(args.positional.clone());
    // `--update BENCH_x.json` greedily binds the report path as the
    // option's value — recover it as both the flag and a file.
    let mut update = args.flag("update");
    if let Some(v) = args.opt("update") {
        update = true;
        files.insert(0, v.to_string());
    }
    if files.is_empty() {
        bail!("usage: bench_gate [--baseline PATH] [--tolerance R] [--update] BENCH_*.json");
    }
    let baseline_path = args.opt_str("baseline", "ci/bench_baseline.json");

    let current = load_reports(&files)?;

    let baseline_text = std::fs::read_to_string(&baseline_path)
        .with_context(|| format!("reading baseline {baseline_path}"))?;
    let baseline = json::parse(&baseline_text).with_context(|| format!("parsing {baseline_path}"))?;
    let base_tol = baseline
        .get("tolerance")
        .and_then(Json::as_f64)
        .unwrap_or(1.25);
    let tolerance: f64 = match args.opt("tolerance") {
        None => base_tol,
        Some(v) => v
            .parse()
            .with_context(|| format!("--tolerance must be a number, got {v:?}"))?,
    };
    let base_means = num_map(&baseline, "mean_ns");
    let min_metrics = num_map(&baseline, "min_metrics");
    let max_metrics = num_map(&baseline, "max_metrics");

    if update {
        // Refresh `mean_ns` only: the baseline's own tolerance (not a
        // one-off --tolerance override), comment, and min_metrics are
        // preserved.
        let mut out = String::from("{\n");
        if let Some(c) = baseline.get("_comment").and_then(Json::as_str) {
            out.push_str(&format!("  \"_comment\": {},\n", json::fmt_str(c)));
        }
        out.push_str(&format!("  \"tolerance\": {},\n", json::fmt_f64(base_tol)));
        out.push_str("  \"mean_ns\": {\n");
        let means: Vec<String> = current
            .means
            .iter()
            .map(|(k, v)| format!("    {}: {}", json::fmt_str(k), json::fmt_f64(*v)))
            .collect();
        out.push_str(&means.join(",\n"));
        out.push_str("\n  },\n  \"min_metrics\": {\n");
        let mins: Vec<String> = min_metrics
            .iter()
            .map(|(k, v)| format!("    {}: {}", json::fmt_str(k), json::fmt_f64(*v)))
            .collect();
        out.push_str(&mins.join(",\n"));
        out.push_str("\n  },\n  \"max_metrics\": {\n");
        let maxs: Vec<String> = max_metrics
            .iter()
            .map(|(k, v)| format!("    {}: {}", json::fmt_str(k), json::fmt_f64(*v)))
            .collect();
        out.push_str(&maxs.join(",\n"));
        out.push_str("\n  }\n}\n");
        std::fs::write(&baseline_path, out)
            .with_context(|| format!("writing baseline {baseline_path}"))?;
        println!("updated {baseline_path} from {} report(s)", files.len());
        return Ok(ExitCode::SUCCESS);
    }

    let mut failures = 0usize;
    println!("## Bench gate (tolerance {tolerance:.2}x)\n");
    println!("| benchmark | baseline ms | current ms | ratio | status |");
    println!("|---|---:|---:|---:|---|");
    for (name, base) in &base_means {
        match current.means.get(name) {
            None => {
                failures += 1;
                println!("| {name} | {} | missing | — | ❌ missing |", fmt_ms(*base));
            }
            Some(cur) => {
                let ratio = cur / base;
                let ok = ratio <= tolerance;
                if !ok {
                    failures += 1;
                }
                println!(
                    "| {name} | {} | {} | {ratio:.2}x | {} |",
                    fmt_ms(*base),
                    fmt_ms(*cur),
                    if ok { "✅" } else { "❌ regression" }
                );
            }
        }
    }
    for (name, bound) in &min_metrics {
        match current.metrics.get(name) {
            None => {
                failures += 1;
                println!("| {name} | ≥ {bound:.2} | missing | — | ❌ missing |");
            }
            Some(cur) => {
                let ok = cur >= bound;
                if !ok {
                    failures += 1;
                }
                println!(
                    "| {name} | ≥ {bound:.2} | {cur:.2} | — | {} |",
                    if ok { "✅" } else { "❌ below bound" }
                );
            }
        }
    }
    for (name, bound) in &max_metrics {
        match current.metrics.get(name) {
            None => {
                failures += 1;
                println!("| {name} | ≤ {bound:.2} | missing | — | ❌ missing |");
            }
            Some(cur) => {
                let ok = cur <= bound;
                if !ok {
                    failures += 1;
                }
                println!(
                    "| {name} | ≤ {bound:.2} | {cur:.2} | — | {} |",
                    if ok { "✅" } else { "❌ above bound" }
                );
            }
        }
    }
    // Untracked benchmarks are informational only.
    for (name, cur) in &current.means {
        if !base_means.contains_key(name) {
            println!("| {name} | — | {} | — | ℹ️ untracked |", fmt_ms(*cur));
        }
    }
    println!();
    if failures > 0 {
        println!(
            "**{failures} gate failure(s).** Intentional change? Refresh with `cargo run --release --bin bench_gate -- --update --baseline {baseline_path} {}`.",
            files.join(" ")
        );
        Ok(ExitCode::FAILURE)
    } else {
        println!("All tracked benchmarks within bounds.");
        Ok(ExitCode::SUCCESS)
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("bench_gate: {e:#}");
            ExitCode::FAILURE
        }
    }
}
