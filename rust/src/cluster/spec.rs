//! [`ClusterSpec`]: one serving workload across a fleet of SoC replicas.

use crate::fault::HealthSpec;
use crate::serve::{Arrival, DispatchPolicy, ServeSpec};
use crate::sim::EngineMode;
use crate::util::Ps;

/// SLO-driven elasticity bounds and hysteresis for a cluster run.
///
/// The autoscaler samples on the cluster's `sample_interval` cadence and
/// judges each window exactly like a [`crate::serve::QueueGovernor`]
/// does — windowed p95 against the SLO plus mean backlog per active
/// replica — but actuates *fleet size* instead of frequency:
///
/// * **scale up** one replica after `up_windows` consecutive breached
///   windows (windowed p95 over the SLO, or backlog above
///   `backlog_high`);
/// * **scale down** one replica after `down_windows` consecutive calm
///   windows (windowed p95 under `relax_margin * SLO` and backlog at
///   most `backlog_low`). The victim drains its queue before retiring.
///
/// Streaks reset on any opposite or neutral window, so a noisy boundary
/// can't flap the fleet. Active count stays in
/// `[min_replicas, ClusterSpec::replicas]`.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleSpec {
    /// Never drain below this many active replicas.
    pub min_replicas: usize,
    /// Consecutive breached windows before a scale-up.
    pub up_windows: usize,
    /// Consecutive calm windows before a drain-then-retire.
    pub down_windows: usize,
    /// Breach when mean backlog per active replica exceeds this.
    pub backlog_high: f64,
    /// Calm only when mean backlog per active replica is at most this.
    pub backlog_low: f64,
    /// Calm only while windowed p95 < `relax_margin * SLO`.
    pub relax_margin: f64,
}

impl AutoscaleSpec {
    /// Defaults mirror [`crate::serve::GovernorSpec`]: react fast to
    /// breaches (2 windows), retire reluctantly (5 windows).
    pub fn new(min_replicas: usize) -> Self {
        Self {
            min_replicas,
            up_windows: 2,
            down_windows: 5,
            backlog_high: 4.0,
            backlog_low: 1.0,
            relax_margin: 0.5,
        }
    }

    pub fn up_windows(mut self, n: usize) -> Self {
        self.up_windows = n;
        self
    }

    pub fn down_windows(mut self, n: usize) -> Self {
        self.down_windows = n;
        self
    }
}

impl Default for AutoscaleSpec {
    fn default() -> Self {
        Self::new(1)
    }
}

/// One [`ServeSpec`] served by a fleet of up to `replicas` identical,
/// independent SoCs behind a front-end balancer.
///
/// The cluster clock starts at 0; arrivals come from
/// `spec.arrival.times(spec.seed, spec.duration)` exactly as a single
/// SoC's would, so the same seed + spec is bit-identical — fleet-level
/// determinism is the whole contract of
/// [`serve_cluster`](super::serve_cluster).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Fleet size: total replica slots (the autoscaler's upper bound).
    pub replicas: usize,
    /// The per-replica serving spec. `arrival`, `duration`, `drain`,
    /// `seed`, and `slo` describe the *cluster-level* workload; `tiles`,
    /// `policy`, `queue_capacity`, and `governor` configure each replica
    /// exactly as they would a lone [`Session::serve`](crate::scenario::Session::serve).
    pub spec: ServeSpec,
    /// Front-end balancer across replicas. Reuses [`DispatchPolicy`]
    /// semantics one level up: round-robin over replicas with space,
    /// join-shortest-backlog, or least-loaded (gate backlogs weighted by
    /// invocation cycles at each island's live DFS frequency).
    pub balancer: DispatchPolicy,
    /// Optional SLO-driven elasticity. Requires `spec.slo`.
    pub autoscale: Option<AutoscaleSpec>,
    /// Simulation engine for every replica (all three are bit-identical;
    /// see [`crate::sim::EngineMode`]). Default: event-driven.
    pub engine: EngineMode,
    /// Worker threads advancing replicas between cluster-clock barriers:
    /// `0` = all cores, `1` (the default) = the serial reference path.
    /// Every thread count produces a bit-identical
    /// [`ClusterReport`](super::ClusterReport) — parallelism only
    /// changes wall time.
    pub threads: usize,
    /// DFS retunes applied to the warm base before it is snapshotted:
    /// `(at, island, mhz)`, with `at` in replica-local time. Every
    /// replica inherits the schedule through the snapshot fork, so a
    /// mid-run retune hits each activation at the same local offset.
    pub freq_schedule: Vec<(Ps, usize, u64)>,
    /// Optional health checks on the sample cadence: evict wedged
    /// replicas and replace crashed/evicted ones from warm standby
    /// (see [`HealthSpec`]). `None` = no resilience, bit-identical to
    /// the pre-fault engine.
    pub health: Option<HealthSpec>,
    /// Maximum time a draining replica may hold a non-empty queue
    /// before it is force-retired with its queue dropped (counted on
    /// the replica). `None` = drain forever — a wedged replica then
    /// blocks scale-down indefinitely.
    pub drain_deadline: Option<Ps>,
}

impl ClusterSpec {
    pub fn new(replicas: usize, spec: ServeSpec) -> Self {
        Self {
            replicas,
            spec,
            balancer: DispatchPolicy::default(),
            autoscale: None,
            engine: EngineMode::default(),
            threads: 1,
            freq_schedule: Vec::new(),
            health: None,
            drain_deadline: None,
        }
    }

    /// Enable health-check-driven eviction + warm-standby replacement.
    pub fn health(mut self, spec: HealthSpec) -> Self {
        self.health = Some(spec);
        self
    }

    /// Bound how long a draining replica may hold a non-empty queue
    /// before being force-retired (queue dropped, counted).
    pub fn drain_deadline(mut self, d: Ps) -> Self {
        self.drain_deadline = Some(d);
        self
    }

    pub fn balancer(mut self, policy: DispatchPolicy) -> Self {
        self.balancer = policy;
        self
    }

    pub fn autoscale(mut self, spec: AutoscaleSpec) -> Self {
        self.autoscale = Some(spec);
        self
    }

    pub fn engine(mut self, mode: EngineMode) -> Self {
        self.engine = mode;
        self
    }

    /// Worker threads for the barrier loop: `0` = all cores, `1` =
    /// serial reference. The report is bit-identical either way.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Schedule a DFS retune on the warm base at replica-local time
    /// `at`: every replica (re)activation inherits it via the snapshot.
    pub fn schedule_freq(mut self, at: Ps, island: usize, mhz: u64) -> Self {
        self.freq_schedule.push((at, island, mhz));
        self
    }

    /// Record a deterministic request trace (rides on the inner
    /// [`ServeSpec`]; see
    /// [`TraceSpec`](crate::telemetry::TraceSpec)). The resulting
    /// [`ClusterReport::trace`](super::ClusterReport::trace) is
    /// bit-identical across engine modes and thread counts; tracing
    /// forces narrow barriers, so wide-span fast paths are disabled.
    pub fn trace(mut self, ts: crate::telemetry::TraceSpec) -> Self {
        self.spec.trace = Some(ts);
        self
    }

    pub(crate) fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            (1..=64).contains(&self.replicas),
            "cluster: replicas must be in 1..=64, got {}",
            self.replicas
        );
        anyhow::ensure!(self.spec.duration > 0, "cluster: duration must be positive");
        anyhow::ensure!(
            self.spec.queue_capacity > 0,
            "cluster: queue capacity must be at least 1"
        );
        anyhow::ensure!(
            !matches!(self.spec.arrival, Arrival::ClosedLoop { .. }),
            "cluster: the front-end balancer is open-loop; closed-loop \
             arrivals belong to a single-SoC serve phase"
        );
        if let Some(a) = &self.autoscale {
            anyhow::ensure!(
                (1..=self.replicas).contains(&a.min_replicas),
                "cluster: autoscale min_replicas must be in 1..={}, got {}",
                self.replicas,
                a.min_replicas
            );
            anyhow::ensure!(
                a.up_windows >= 1 && a.down_windows >= 1,
                "cluster: autoscale windows must be at least 1"
            );
            anyhow::ensure!(
                self.spec.slo.is_some(),
                "cluster: autoscaling needs an SLO to judge against (set spec.slo)"
            );
        }
        anyhow::ensure!(
            self.drain_deadline.is_none_or(|d| d > 0),
            "cluster: drain_deadline must be positive when set"
        );
        Ok(())
    }
}
