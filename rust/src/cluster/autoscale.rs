//! [`Autoscaler`]: the fleet-size control loop.
//!
//! Same shape as the [`crate::serve::QueueGovernor`] — windowed p95 +
//! backlog hysteresis — but its actuator is replica count, and its
//! decisions are *suggestions* the engine realizes (promote a draining
//! slot, resume a standby slot from the warm base, or mark a victim
//! draining). Keeping the decision pure makes it unit-testable without
//! a fleet.

use crate::fault::HealthSpec;
use crate::util::{Percentiles, Ps};

use super::spec::AutoscaleSpec;

/// What the autoscaler wants done after a sample window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Activate one more replica (promote draining or resume standby).
    Up,
    /// Drain-then-retire one active replica.
    Down,
}

/// The control loop. Feed completions with
/// [`observe_latency`](Autoscaler::observe_latency), then call
/// [`decide`](Autoscaler::decide) once per sample window.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub min: usize,
    pub max: usize,
    /// p95 latency target (ps).
    pub slo: Ps,
    up_windows: usize,
    down_windows: usize,
    backlog_high: f64,
    backlog_low: f64,
    relax_margin: f64,
    breach_streak: usize,
    calm_streak: usize,
    /// Latencies (ps) observed since the last decision.
    window: Vec<f64>,
    /// Fleet-size actions taken: `(time, new active count)`.
    pub actions: Vec<(Ps, usize)>,
}

impl Autoscaler {
    pub fn new(spec: &AutoscaleSpec, max: usize, slo: Ps) -> Self {
        Self {
            min: spec.min_replicas.min(max),
            max,
            slo,
            up_windows: spec.up_windows.max(1),
            down_windows: spec.down_windows.max(1),
            backlog_high: spec.backlog_high,
            backlog_low: spec.backlog_low,
            relax_margin: spec.relax_margin,
            breach_streak: 0,
            calm_streak: 0,
            window: Vec::new(),
            actions: Vec::new(),
        }
    }

    pub fn observe_latency(&mut self, latency: Ps) {
        self.window.push(latency as f64);
    }

    /// Judge the window that just closed and clear it. `active` is the
    /// current active-replica count, `mean_backlog` the mean outstanding
    /// requests per active replica.
    pub fn decide(&mut self, active: usize, mean_backlog: f64) -> ScaleDecision {
        let p95 = if self.window.is_empty() {
            None
        } else {
            Percentiles::from_samples(&self.window).ok().map(|p| p.p95())
        };
        self.window.clear();
        let slo = self.slo as f64;
        let breach = p95.is_some_and(|p| p > slo) || mean_backlog > self.backlog_high;
        // An empty window with an empty queue is calm (nothing to do is
        // the definition of over-provisioned).
        let relaxed = match p95 {
            Some(p) => p < self.relax_margin * slo,
            None => true,
        };
        let calm = relaxed && mean_backlog <= self.backlog_low;
        if breach {
            self.calm_streak = 0;
            self.breach_streak += 1;
            if self.breach_streak >= self.up_windows && active < self.max {
                self.breach_streak = 0;
                return ScaleDecision::Up;
            }
        } else if calm {
            self.breach_streak = 0;
            self.calm_streak += 1;
            if self.calm_streak >= self.down_windows && active > self.min {
                self.calm_streak = 0;
                return ScaleDecision::Down;
            }
        } else {
            self.breach_streak = 0;
            self.calm_streak = 0;
        }
        ScaleDecision::Hold
    }

    /// Record a realized fleet-size change.
    pub fn record(&mut self, now: Ps, active: usize) {
        self.actions.push((now, active));
    }
}

/// Per-slot health-check state for the cluster engine: a slot is
/// *wedged* when a sample window closes with a non-empty backlog and
/// zero new completions; [`HealthSpec::evict_after`] consecutive wedged
/// windows trigger eviction. Pure decisions, like [`Autoscaler`] — the
/// engine realizes them (requeue the queue, drop the session, activate
/// a warm standby).
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    spec: HealthSpec,
    /// Cumulative completions per slot at the previous health sample
    /// (for the current activation).
    last_completed: Vec<u64>,
    /// Consecutive wedged windows per slot.
    streaks: Vec<u32>,
}

impl HealthMonitor {
    pub fn new(spec: HealthSpec, slots: usize) -> Self {
        Self {
            spec,
            last_completed: vec![0; slots],
            streaks: vec![0; slots],
        }
    }

    /// Judge one sample window for an active `slot`. Returns `true`
    /// when the wedged streak reaches the eviction threshold (and
    /// resets it — the engine evicts exactly once per trigger).
    pub fn observe(&mut self, slot: usize, backlog: usize, completed: u64) -> bool {
        let wedged = backlog > 0 && completed == self.last_completed[slot];
        self.last_completed[slot] = completed;
        if !wedged {
            self.streaks[slot] = 0;
            return false;
        }
        self.streaks[slot] += 1;
        if self.spec.evict_after > 0 && self.streaks[slot] >= self.spec.evict_after {
            self.streaks[slot] = 0;
            return true;
        }
        false
    }

    /// Forget a slot's history (crashed, evicted, or reactivated — its
    /// completion counter restarts with the new activation).
    pub fn reset(&mut self, slot: usize) {
        self.streaks[slot] = 0;
        self.last_completed[slot] = 0;
    }

    /// Whether crashed/evicted replicas should be replaced from warm
    /// standby.
    pub fn replace(&self) -> bool {
        self.spec.replace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(up: usize, down: usize) -> Autoscaler {
        let spec = AutoscaleSpec::new(1).up_windows(up).down_windows(down);
        Autoscaler::new(&spec, 4, 1_000_000) // SLO 1 us
    }

    fn breach(a: &mut Autoscaler, active: usize) -> ScaleDecision {
        a.observe_latency(2_000_000); // 2x the SLO
        a.decide(active, 0.0)
    }

    fn calm(a: &mut Autoscaler, active: usize) -> ScaleDecision {
        a.observe_latency(100_000); // well under relax_margin * SLO
        a.decide(active, 0.0)
    }

    #[test]
    fn scale_up_needs_sustained_breach() {
        let mut a = scaler(3, 5);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Hold);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Hold);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Up);
        // Streak reset by the action: the next breach starts over.
        assert_eq!(breach(&mut a, 2), ScaleDecision::Hold);
    }

    #[test]
    fn calm_window_resets_breach_streak() {
        let mut a = scaler(2, 5);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Hold);
        assert_eq!(calm(&mut a, 1), ScaleDecision::Hold);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Hold);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Up);
    }

    #[test]
    fn scale_down_needs_sustained_calm_and_respects_min() {
        let mut a = scaler(2, 3);
        assert_eq!(calm(&mut a, 2), ScaleDecision::Hold);
        assert_eq!(calm(&mut a, 2), ScaleDecision::Hold);
        assert_eq!(calm(&mut a, 2), ScaleDecision::Down);
        // At the min bound calm never retires the last replica.
        for _ in 0..10 {
            assert_eq!(calm(&mut a, 1), ScaleDecision::Hold);
        }
    }

    #[test]
    fn scale_up_respects_max() {
        let mut a = scaler(1, 5);
        for _ in 0..10 {
            assert_eq!(breach(&mut a, 4), ScaleDecision::Hold);
        }
        // Drop below max and the standing breach fires immediately.
        assert_eq!(breach(&mut a, 3), ScaleDecision::Up);
    }

    #[test]
    fn backlog_alone_breaches_and_blocks_calm() {
        let mut a = scaler(1, 1);
        // No latencies at all: backlog above high is still a breach...
        assert_eq!(a.decide(1, 10.0), ScaleDecision::Up);
        // ...and a quiet window with backlog above low is neutral, not
        // calm (queues are holding work; don't retire capacity).
        assert_eq!(a.decide(2, 2.0), ScaleDecision::Hold);
        // Empty window + empty queue is calm.
        assert_eq!(a.decide(2, 0.0), ScaleDecision::Down);
    }

    #[test]
    fn health_monitor_needs_consecutive_wedged_windows() {
        let mut h = HealthMonitor::new(HealthSpec::new().evict_after(3), 2);
        // Progress (completions advanced) always resets the streak.
        assert!(!h.observe(0, 5, 10));
        assert!(!h.observe(0, 5, 10), "wedged x1");
        assert!(!h.observe(0, 5, 12), "progress resets");
        assert!(!h.observe(0, 5, 12));
        assert!(!h.observe(0, 5, 12));
        assert!(h.observe(0, 5, 12), "third consecutive wedged window evicts");
        assert!(!h.observe(0, 5, 12), "trigger resets the streak");
        // An empty backlog is never wedged, and slots are independent.
        for _ in 0..10 {
            assert!(!h.observe(1, 0, 0));
        }
    }

    #[test]
    fn health_monitor_evict_after_zero_never_evicts() {
        let mut h = HealthMonitor::new(HealthSpec::new().evict_after(0), 1);
        for _ in 0..20 {
            assert!(!h.observe(0, 9, 0));
        }
        assert!(h.replace());
        h.reset(0);
        assert!(!h.observe(0, 9, 0));
    }

    #[test]
    fn neutral_window_resets_both_streaks() {
        let mut a = scaler(2, 2);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Hold);
        // p95 between relax_margin*slo and slo: neither breach nor calm.
        a.observe_latency(700_000);
        assert_eq!(a.decide(1, 0.0), ScaleDecision::Hold);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Hold);
        assert_eq!(breach(&mut a, 1), ScaleDecision::Up);
    }
}
