//! Multi-SoC fleet serving: one workload, N replicas, one report.
//!
//! The paper's prototype is a single 4x4 SoC; its monitoring + DFS
//! story scales by multiplying *instances*, not grid size (the axis
//! ANDROMEDA and Open ESP both explore). This module serves one
//! [`ServeSpec`](crate::serve::ServeSpec) across a fleet of identical,
//! independent SoC replicas:
//!
//! * a **front-end balancer** reusing
//!   [`DispatchPolicy`](crate::serve::DispatchPolicy) semantics at
//!   cluster scope — round-robin, join-shortest-backlog, or
//!   least-loaded-replica (gate backlogs weighted by invocation cycles
//!   at each island's live DFS frequency);
//! * per-replica [`QueueGovernor`](crate::serve::QueueGovernor)s
//!   running unchanged underneath — frequency inside the box, fleet
//!   size outside it;
//! * an optional [`Autoscaler`] that activates and retires replicas
//!   against the SLO with hysteresis, using
//!   [`Session::snapshot`](crate::scenario::Session::snapshot) warm
//!   bases so a reactivated replica skips warmup entirely.
//!
//! Determinism contract: arrivals come from the spec seed via
//! [`util::rng`](crate::util::rng), every fleet iteration is in slot
//! order, and [`Percentiles::merge`](crate::util::Percentiles::merge)
//! combines per-replica sample sets exactly — so the same seed + spec
//! + config yields a **bit-identical** [`ClusterReport`]. The contract
//! holds for every [`ClusterSpec::threads`](field@ClusterSpec::threads)
//! value: replicas step on a
//! worker pool between cluster-clock barriers, but all cross-replica
//! decisions stay barrier-serialized in slot order (see
//! `docs/PERF.md`, "Parallel fleet execution").
//!
//! ```no_run
//! use vespa::cluster::{AutoscaleSpec, ClusterSpec};
//! use vespa::config::presets::paper_soc;
//! use vespa::scenario::ms;
//! use vespa::serve::{Arrival, ServeSpec};
//!
//! # fn main() -> vespa::Result<()> {
//! let cfg = paper_soc(("dfmul", 2), ("dfadd", 1));
//! let spec = ServeSpec::new(Arrival::Poisson { rps: 4000.0 }, ms(50))
//!     .slo(ms(5));
//! let report = ClusterSpec::new(4, spec)
//!     .autoscale(AutoscaleSpec::new(1))
//!     .run(cfg)?;
//! println!("{}", report.render());
//! # Ok(())
//! # }
//! ```

pub mod autoscale;
pub mod engine;
pub mod report;
pub mod spec;

pub use autoscale::{Autoscaler, HealthMonitor, ScaleDecision};
pub use engine::{serve_cluster, serve_cluster_with_profile};
pub use report::{ClusterReport, ReplicaReport};
pub use spec::{AutoscaleSpec, ClusterSpec};
