//! The cluster engine: one open-loop arrival stream fanned across N
//! independent SoC replicas by a front-end balancer, with an optional
//! SLO-driven autoscaler resizing the active fleet.
//!
//! Each replica is a full [`Session`] — its own SoC, NoC, DFS islands,
//! and per-replica [`QueueGovernor`] — advanced in lockstep on a shared
//! *cluster clock*. The clock starts at 0; replica-local SoC time is an
//! affine map fixed at activation (`local = local_base + (t - base)`),
//! so completions keep their exact tile-log timestamps when attributed
//! back to cluster-time arrivals.
//!
//! Elasticity uses the warm-base trick from the sweep engine: the spec's
//! tiles are staged, gated, and settled **once**, then snapshotted;
//! every (re)activation forks that [`Session::snapshot`] and skips
//! warmup entirely. Retiring is drain-then-retire — a draining replica
//! takes no new work but finishes its queue before going standby.
//!
//! # Parallel execution
//!
//! Replicas share nothing between cluster-clock barriers, so with
//! [`ClusterSpec::threads`](field@ClusterSpec::threads) > 1 the
//! per-replica `run_until` spans fan
//! out across a persistent scoped worker pool
//! (`scenario::set::with_round_pool`) while every decision
//! that couples replicas — balancing, autoscaling, completion
//! attribution feeding the autoscaler — stays on the coordinating
//! thread in slot-index order. Two modes:
//!
//! * **narrow barriers** (any balancer, governor, autoscaler): workers
//!   only advance sessions to the barrier target; draining, admission,
//!   and sampling run serially exactly as the `threads = 1` reference.
//! * **wide spans** (round-robin balancer, no autoscaler): a
//!   round-robin front end with guaranteed queue space is *oblivious* —
//!   its choices are a pure modular function of the arrival index. The
//!   whole sample window's arrivals are pre-binned per slot, and each
//!   worker replays its slot's exact serial choreography
//!   (advance → drain → admit per arrival) in one long span. A per-slot
//!   precheck (`backlog + assigned <= capacity x tiles`) guarantees no
//!   slot can fill mid-window; windows that fail it fall back to narrow
//!   barriers.
//!
//! Both modes are bit-identical to the serial engine: per-slot latency
//! sets feed order-insensitive consumers ([`Percentiles`] sorts,
//! governor windows take exact percentiles, SLO counters sum), and
//! everything else merges in slot-index order.
//!
//! Everything iterates in slot-index order and the arrival schedule is
//! derived only from `(spec.seed, spec.duration)`, so the same
//! [`ClusterSpec`] + config reproduces a bit-identical
//! [`ClusterReport`] for every thread count.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{Mutex, MutexGuard};

use crate::config::SocConfig;
use crate::fault::{CompFault, FaultLedger, ResolvedPlan};
use crate::monitor::TimeSeries;
use crate::policy::DfsPolicy;
use crate::scenario::set::{resolve_threads, with_round_pool, RoundPool};
use crate::scenario::{Session, SocSnapshot};
use crate::serve::dispatch::{DispatchPolicy, Dispatcher, Req};
use crate::serve::engine::{prepare_serve_tiles, resolve_tiles, tile_queues};
use crate::serve::governor::QueueGovernor;
use crate::serve::report::LatencyStats;
use crate::serve::ServeSpec;
use crate::telemetry::{HostProfile, Tracer};
use crate::util::{Percentiles, Ps};

use super::autoscale::{Autoscaler, HealthMonitor, ScaleDecision};
use super::report::{ClusterReport, ReplicaReport};
use super::spec::ClusterSpec;

/// A pending admission retry: `(due, original arrival, attempt,
/// readmit)`, all in cluster time. `readmit` marks a request that was
/// already admitted once (its replica crashed or was evicted) so the
/// fleet-level `admitted` counter isn't double-incremented.
type Retry = Reverse<(Ps, Ps, u32, bool)>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    Active,
    /// No new work; retires to standby once its queue and pipeline are
    /// empty.
    Draining,
    /// No live SoC; costs nothing until reactivated from the warm base.
    Standby,
    /// Crashed by an injected fault: session gone, in-flight work
    /// requeued or lost. Invisible to the balancer; becomes standby
    /// when a health check notices (no health checks = dead forever).
    Failed,
}

/// One worker assignment for a barrier round, parked on its replica.
struct Task {
    /// Replica-local advance target.
    local: Ps,
    /// Wide span only: this slot's pre-binned cluster-time arrivals to
    /// replay (advance → drain → admit each). `None` = narrow barrier,
    /// advance only.
    inbox: Option<Vec<Ps>>,
}

/// One replica slot of the fleet.
struct Replica {
    state: SlotState,
    session: Option<Session>,
    disp: Dispatcher,
    governor: Option<QueueGovernor>,
    /// Replica-local SoC time at `cluster_base` (the warm snapshot's
    /// clock for the current activation).
    local_base: Ps,
    /// Cluster time of the current activation.
    cluster_base: Ps,
    activated_at: Ps,
    /// Accumulated active/draining time over finished activations (ps).
    active_ps: Ps,
    activations: u64,
    /// Completed-request latencies (ps) across all activations.
    latencies: Vec<f64>,
    /// Completions within the SLO across all activations (summed
    /// fleet-wide at the end — order-insensitive by construction).
    within_slo: u64,
    /// Replica-local time of the last completion drain: a session that
    /// hasn't advanced past this can't have completed anything new, so
    /// the O(tiles) gate peek is skipped.
    drained_at: Ps,
    /// Cluster time this slot entered [`SlotState::Draining`] (for the
    /// drain deadline).
    draining_since: Ps,
    /// Completions of retried requests (attempt > 0) — summed into the
    /// fleet [`FaultLedger`] at the end.
    rescued: u64,
    /// Work parked for the next pool round (taken by a worker).
    task: Option<Task>,
    // Counters carried over from finished activations (live ones are on
    // `disp`, which is rebuilt per activation).
    done_admitted: u64,
    done_completed: u64,
    done_dropped: u64,
    queue_depth: TimeSeries,
    freq_mhz: TimeSeries,
    active_state: TimeSeries,
}

fn lock(m: &Mutex<Replica>) -> MutexGuard<'_, Replica> {
    // A poisoned mutex means a worker panicked mid-round; the panic
    // itself already unwound through the pool, so recover the guard
    // rather than turning the report path into a second panic.
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl Replica {
    fn has_space(&self) -> bool {
        self.disp.has_space()
    }

    fn to_local(&self, tc: Ps) -> Ps {
        self.local_base + (tc - self.cluster_base)
    }

    /// Cheapest estimated drain time among this replica's tiles for one
    /// more request: the tile-level [`DispatchPolicy::LeastLoadedTile`]
    /// estimate lifted to cluster scope — gate backlog
    /// ([`serve_backlog`](crate::tiles::MraTile::serve_backlog)) weighted
    /// by invocation cycles at the island's live DFS frequency.
    fn estimated_drain(&self, tc: Ps) -> f64 {
        let Some(session) = self.session.as_ref() else {
            return f64::INFINITY;
        };
        let local = self.to_local(tc);
        let soc = session.soc();
        self.disp
            .tiles
            .iter()
            .map(|q| {
                let mhz = soc.islands[q.island].freq(local).as_mhz().max(1) as f64;
                let backlog = (soc.mra(q.tile).serve_backlog() + 1) as f64;
                backlog * q.compute_cycles as f64 / (mhz * q.replicas as f64)
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Attribute this replica's pending tile completions (exact
    /// tile-log timestamps mapped onto the cluster clock). Same
    /// peek-then-drain dance as the single-SoC engine: a mutable tile
    /// poke resets the idle wake point, so only touch tiles that
    /// actually completed something. `scratch` is the reused
    /// completion-log buffer; `scaler` is fed per completion on the
    /// serial/narrow path (wide spans never run with an autoscaler).
    /// `tracer` (with this slot's base track index) records exec-start /
    /// complete span events; tracing disables wide spans, so every drain
    /// that can see a tracer runs coordinator-side in slot order.
    fn drain_completions(
        &mut self,
        slo: Option<Ps>,
        mut scaler: Option<&mut Autoscaler>,
        scratch: &mut Vec<Ps>,
        mut tracer: Option<(&mut Tracer, u16)>,
    ) -> crate::Result<()> {
        // O(1) skips: no outstanding request means no undrained
        // completion (every granted credit holds a queue entry until
        // attributed), and a session that hasn't advanced since the
        // last drain can't have completed anything new (an invocation
        // takes at least one island cycle past its grant).
        if self.disp.backlog == 0 || self.session.is_none() {
            return Ok(());
        }
        if self.session.as_ref().expect("checked").soc().now == self.drained_at {
            return Ok(());
        }
        for ti in 0..self.disp.tiles.len() {
            let tile = self.disp.tiles[ti].tile;
            let session = self.session.as_mut().expect("checked");
            let has_completions = session
                .soc()
                .mra(tile)
                .serve
                .as_ref()
                .is_some_and(|g| !g.completions.is_empty());
            if !has_completions {
                continue;
            }
            scratch.clear();
            let mut starts: Vec<(Ps, u8)> = Vec::new();
            {
                let m = session.soc_mut().try_mra_mut(tile)?;
                if let Some(g) = &mut m.serve {
                    if tracer.is_some() {
                        starts.extend(g.starts.drain(..));
                    }
                    scratch.extend(g.completions.drain(..).map(|(t, _replica)| t));
                }
            }
            // Exec starts strictly precede their completions in the gate
            // logs, so recording all pending starts first keeps each
            // span's event order arrival -> start -> complete.
            if let Some((tr, base)) = tracer.as_mut() {
                for &(t_s, r) in &starts {
                    let t_c = self.cluster_base + (t_s - self.local_base);
                    tr.exec_start(*base + ti as u16, t_c, r);
                }
            }
            for &t_local in scratch.iter() {
                let Some(req) = self.disp.complete_req(ti) else {
                    debug_assert!(false, "completion without an outstanding request");
                    continue;
                };
                let t_c = self.cluster_base + (t_local - self.local_base);
                // `extra` folds earlier attempts' wait back in, so the
                // latency spans the original arrival (zero fault-free).
                let lat = t_c - req.t_arr + req.extra;
                if req.attempt > 0 {
                    self.rescued += 1;
                }
                self.latencies.push(lat as f64);
                if let Some((tr, base)) = tracer.as_mut() {
                    tr.complete(*base + ti as u16, t_c, lat);
                }
                if let Some(slo) = slo {
                    if lat <= slo {
                        self.within_slo += 1;
                    }
                }
                if let Some(g) = &mut self.governor {
                    g.observe_latency(lat);
                }
                if let Some(a) = scaler.as_deref_mut() {
                    a.observe_latency(lat);
                }
            }
        }
        self.drained_at = self.session.as_ref().expect("checked").soc().now;
        Ok(())
    }
}

/// Execute one parked [`Task`] against its replica — the only work
/// worker threads do. Narrow tasks advance the session to the barrier;
/// wide tasks replay the slot's serial choreography for a whole sample
/// window: per binned arrival, advance to it, drain completions, pick a
/// tile, bind, and grant, then advance to the window end and drain.
fn run_task(
    rep: &mut Replica,
    task: Task,
    slo: Option<Ps>,
    scratch: &mut Vec<Ps>,
) -> crate::Result<()> {
    let Some(inbox) = task.inbox else {
        if let Some(session) = rep.session.as_mut() {
            session.run_until(task.local);
        }
        return Ok(());
    };
    for t_arr in inbox {
        let local_arr = rep.to_local(t_arr);
        rep.session
            .as_mut()
            .expect("wide-span replicas are live")
            .run_until(local_arr);
        rep.drain_completions(slo, None, scratch, None)?;
        let session = rep.session.as_mut().expect("wide-span replicas are live");
        let ti = rep.disp.pick(session.soc(), local_arr).ok_or_else(|| {
            anyhow::anyhow!("cluster: wide-span precheck failed to guarantee queue space")
        })?;
        rep.disp.bind(ti, t_arr);
        let tile = rep.disp.tiles[ti].tile;
        session.soc_mut().try_mra_mut(tile)?.serve_grant(1);
    }
    rep.session
        .as_mut()
        .expect("wide-span replicas are live")
        .run_until(task.local);
    rep.drain_completions(slo, None, scratch, None)?;
    Ok(())
}

/// Fork the warm base into `slot` and mark it active at cluster time
/// `tc`. The snapshot is already staged + gated + settled, so the
/// replica serves its first request without any warmup.
fn activate(
    slot: &mut Replica,
    snap: &SocSnapshot,
    spec: &ServeSpec,
    tiles: &[usize],
    tc: Ps,
) -> crate::Result<()> {
    let session = Session::resume(snap)?;
    slot.disp = Dispatcher::new(
        spec.policy,
        spec.queue_capacity,
        tile_queues(&session, tiles)?,
    );
    slot.governor = spec
        .governor
        .as_ref()
        .map(|g| QueueGovernor::new(g, tiles.to_vec()));
    slot.local_base = snap.now();
    slot.cluster_base = tc;
    slot.activated_at = tc;
    slot.activations += 1;
    slot.state = SlotState::Active;
    slot.session = Some(session);
    slot.drained_at = 0;
    slot.draining_since = 0;
    slot.task = None;
    Ok(())
}

/// Install the fault plan's still-relevant windows for fleet slot
/// `slot` on a freshly activated replica, translated from cluster time
/// to this activation's local clock. Windows already fully past are
/// skipped; one straddling the activation instant is clipped to its
/// remainder — the replica rejoins the same wall-clock fault schedule
/// every other replica sees, regardless of when it was (re)activated.
fn install_slot_faults(rep: &mut Replica, plan: &ResolvedPlan, slot: usize) -> crate::Result<()> {
    if plan.comps.is_empty() {
        return Ok(());
    }
    let tc = rep.cluster_base;
    let local_base = rep.local_base;
    let session = rep.session.as_mut().expect("just activated");
    for f in plan.for_replica(slot) {
        let windows: Vec<(Ps, Ps)> = f
            .windows
            .iter()
            .filter(|&&(_, e)| e > tc)
            .map(|&(s, e)| (s.max(tc) - tc, e - tc))
            .collect();
        if windows.is_empty() {
            continue;
        }
        let clipped = CompFault {
            replica: f.replica,
            target: f.target,
            windows,
        };
        session.soc_mut().install_fault(&clipped, local_base)?;
    }
    Ok(())
}

/// Kill a live replica at cluster time `tc`: roll its activation
/// counters exactly like a retirement, then requeue (with retry) or
/// lose its in-flight requests and drop the session. Shared by
/// injected crashes, health evictions, and drain-deadline
/// force-retires; the caller sets the final [`SlotState`]. Returns the
/// number of requests lost for good (not requeued). `tracer` (with this
/// slot's base track index) annotates every in-flight span as crashed,
/// then parks requeued spans so the rescue attempt rejoins them.
fn kill_replica(
    rep: &mut Replica,
    spec: &ServeSpec,
    tc: Ps,
    retries: &mut BinaryHeap<Retry>,
    ledger: &mut FaultLedger,
    mut tracer: Option<(&mut Tracer, u16)>,
) -> u64 {
    rep.active_ps += tc - rep.activated_at;
    rep.done_admitted += rep.disp.tiles.iter().map(|q| q.admitted).sum::<u64>();
    rep.done_completed += rep.disp.tiles.iter().map(|q| q.completed).sum::<u64>();
    rep.done_dropped += rep.disp.dropped;
    let mut lost = 0u64;
    let mut reqs: Vec<Req> = Vec::new();
    let mut spans: Vec<Option<u64>> = Vec::new();
    for (ti, q) in rep.disp.tiles.iter_mut().enumerate() {
        let n = q.in_flight.len();
        reqs.extend(q.in_flight.drain(..));
        if let Some((tr, base)) = tracer.as_mut() {
            let ids = tr.crash_track(*base + ti as u16, tc);
            debug_assert_eq!(ids.len(), n, "tracer FIFO diverged from in_flight");
            spans.extend(ids);
        }
    }
    for (i, req) in reqs.into_iter().enumerate() {
        // `None` both without a tracer and for unsampled requests.
        let span = spans.get(i).copied().flatten();
        let orig = req.t_arr - req.extra;
        match spec
            .retry
            .as_ref()
            .and_then(|rs| rs.next_retry(tc, orig, req.attempt))
        {
            Some(at) => {
                ledger.retried += 1;
                retries.push(Reverse((at, orig, req.attempt + 1, true)));
                if let Some((tr, _)) = tracer.as_mut() {
                    // Park even unsampled spans: the parked FIFO must
                    // mirror the retry heap entry-for-entry.
                    tr.retry(span, tc, orig, at, req.attempt + 1, true);
                }
            }
            None => {
                ledger.lost += 1;
                lost += 1;
                if let Some((tr, _)) = tracer.as_mut() {
                    tr.expired(span, tc);
                }
            }
        }
    }
    rep.disp = Dispatcher::new(spec.policy, spec.queue_capacity, Vec::new());
    rep.governor = None;
    rep.session = None;
    rep.task = None;
    lost
}

/// The front-end balancer: pick an active replica with queue space, or
/// `None` (spill) when the whole fleet is saturated. Reuses
/// [`DispatchPolicy`] semantics one level up.
fn pick_slot(
    balancer: DispatchPolicy,
    slots: &[Mutex<Replica>],
    rr_cursor: &mut usize,
    tc: Ps,
) -> Option<usize> {
    let eligible = |s: &Replica| s.state == SlotState::Active && s.has_space();
    let n = slots.len();
    match balancer {
        DispatchPolicy::RoundRobin => {
            for off in 0..n {
                let i = (*rr_cursor + off) % n;
                if eligible(&lock(&slots[i])) {
                    *rr_cursor = (i + 1) % n;
                    return Some(i);
                }
            }
            None
        }
        DispatchPolicy::JoinShortestQueue => slots
            .iter()
            .enumerate()
            .filter_map(|(i, m)| {
                let s = lock(m);
                eligible(&s).then_some((s.disp.backlog, i))
            })
            .min()
            .map(|(_, i)| i),
        DispatchPolicy::LeastLoadedTile => slots
            .iter()
            .enumerate()
            .filter_map(|(i, m)| {
                let s = lock(m);
                eligible(&s).then(|| (i, s.estimated_drain(tc)))
            })
            .min_by(|(ia, a), (ib, b)| a.total_cmp(b).then(ia.cmp(ib)))
            .map(|(i, _)| i),
    }
}

impl ClusterSpec {
    /// Run this cluster on `cfg`. Convenience for [`serve_cluster`].
    pub fn run(&self, cfg: SocConfig) -> crate::Result<ClusterReport> {
        serve_cluster(cfg, self)
    }
}

/// The barrier loop's coordinating state: everything the main thread
/// owns exclusively (workers only ever touch `slots` entries, behind
/// their mutexes, during a round).
struct ClusterEngine<'a> {
    cspec: &'a ClusterSpec,
    spec: &'a ServeSpec,
    tiles: &'a [usize],
    snap: &'a SocSnapshot,
    slots: &'a [Mutex<Replica>],
    /// First worker error of a round (workers can't return `Result`s
    /// through the pool).
    err: &'a Mutex<Option<anyhow::Error>>,
    scaler: Option<Autoscaler>,
    /// Resolved fault plan: component windows install at activation,
    /// crashes apply coordinator-side at their barrier instants.
    plan: &'a ResolvedPlan,
    /// Next unapplied entry of `plan.crashes`.
    next_crash: usize,
    health: Option<HealthMonitor>,
    /// Fleet size the resilience layer restores toward after a
    /// crash/eviction: tracks the autoscaler's realized actions, or
    /// stays at the initial active count without one.
    desired_active: usize,
    /// Pending admission retries (min-heap on due time).
    retries: BinaryHeap<Retry>,
    ledger: FaultLedger,
    arrivals: Vec<Ps>,
    next_arr: usize,
    admitted: u64,
    spilled: u64,
    rr_cursor: usize,
    tc: Ps,
    next_sample: Ps,
    sample_interval: Ps,
    duration: Ps,
    deadline: Ps,
    active_series: TimeSeries,
    /// Serial-path completion-log buffer (workers carry their own).
    scratch: Vec<Ps>,
    /// Deterministic request tracer — all mutation happens
    /// coordinator-side in slot order (tracing disables wide spans), so
    /// the trace is bit-identical for every thread count.
    tracer: Option<Tracer>,
    /// Host-side self-profiling sink (wall-clock, non-deterministic;
    /// never feeds back into the simulation or the report).
    profile: Option<&'a HostProfile>,
}

impl ClusterEngine<'_> {
    /// Drive the cluster clock to completion. `pool` is `Some` when a
    /// worker pool is live; `None` runs every task inline (the
    /// `threads = 1` reference path).
    fn run(&mut self, pool: Option<&RoundPool>) -> crate::Result<()> {
        // A round-robin front end that never sees a full replica is a
        // pure modular function of the arrival index — wide spans
        // replay it per slot. Autoscaling changes slot eligibility at
        // arbitrary barriers, so it forces narrow mode — as does the
        // whole fault/resilience layer (crashes, retries, and health
        // checks all touch slot eligibility at coordinator barriers).
        // Tracing also forces narrow mode: span events must be recorded
        // coordinator-side in slot order to stay thread-invariant.
        let wide_ok = pool.is_some()
            && self.cspec.balancer == DispatchPolicy::RoundRobin
            && self.cspec.autoscale.is_none()
            && self.cspec.health.is_none()
            && self.spec.retry.is_none()
            && self.plan.comps.is_empty()
            && self.plan.crashes.is_empty()
            && self.spec.trace.is_none();
        loop {
            let slots = self.slots;
            let mut pending = 0usize;
            let mut draining = false;
            for m in slots {
                let s = lock(m);
                pending += s.disp.backlog;
                draining |= s.state == SlotState::Draining;
            }
            let next_arrival = self.arrivals.get(self.next_arr).copied();
            if self.tc >= self.deadline
                || (self.tc >= self.duration
                    && next_arrival.is_none()
                    && pending == 0
                    && !draining
                    && self.retries.is_empty()
                    && self.next_crash >= self.plan.crashes.len())
            {
                break;
            }

            if wide_ok {
                let target = self.next_sample.min(self.deadline).max(self.tc);
                if self.wide_window(pool, target)? {
                    self.sample()?;
                    continue;
                }
            }

            // Narrow barrier: the serial reference choreography, with
            // step 1 (advance) optionally fanned across the pool.
            // Injected crash instants and retry due times bound the
            // barrier target so both apply at their exact cluster time
            // on every thread count.
            let mut target = self.next_sample.min(self.deadline);
            if let Some(a) = next_arrival {
                target = target.min(a);
            }
            if let Some(&(t, _)) = self.plan.crashes.get(self.next_crash) {
                target = target.min(t);
            }
            if let Some(Reverse((t, _, _, _))) = self.retries.peek() {
                target = target.min(*t);
            }
            let target = target.max(self.tc);
            self.narrow_barrier(pool, target)?;
            self.apply_crashes();
            self.retire_drained()?;
            self.admit_retries()?;
            self.admit_due()?;
            self.sample()?;
        }
        Ok(())
    }

    /// Apply every injected replica crash due at the current cluster
    /// time: the slot's SoC dies with its in-flight work (requeued
    /// through the retry path when one is configured, lost otherwise).
    /// Detection is the health check's job — without one the slot is
    /// simply dead for the rest of the run.
    fn apply_crashes(&mut self) {
        let slots = self.slots;
        while let Some(&(at, si)) = self.plan.crashes.get(self.next_crash) {
            if at > self.tc {
                break;
            }
            self.next_crash += 1;
            let mut s = lock(&slots[si]);
            if s.session.is_none() {
                continue; // already standby/failed: nothing to kill
            }
            let ntiles = self.tiles.len();
            kill_replica(
                &mut s,
                self.spec,
                self.tc,
                &mut self.retries,
                &mut self.ledger,
                self.tracer.as_mut().map(|t| (t, (si * ntiles) as u16)),
            );
            s.state = SlotState::Failed;
        }
    }

    /// Admit due retries through the balancer (older requests go before
    /// this barrier's fresh arrivals). A retry that finds the fleet
    /// full backs off again; one past its deadline or out of attempts
    /// is lost.
    fn admit_retries(&mut self) -> crate::Result<()> {
        if self.retries.is_empty() {
            return Ok(());
        }
        let spec = self.spec;
        let rs = spec.retry.as_ref().expect("retries exist only with a retry policy");
        let slots = self.slots;
        while self.retries.peek().is_some_and(|Reverse((t, _, _, _))| *t <= self.tc) {
            let Reverse((t_due, orig, attempt, readmit)) = self.retries.pop().expect("peeked");
            // Re-pair this heap entry with its parked span (FIFO per
            // `(orig, attempt, readmit)` — identical keys mean
            // interchangeable requests, so pairing stays deterministic).
            let span = match self.tracer.as_mut() {
                Some(tr) => tr.retry_pop(orig, attempt, readmit),
                None => None,
            };
            if rs.expired(self.tc, orig) {
                self.ledger.detected += 1;
                self.ledger.lost += 1;
                if !readmit {
                    self.spilled += 1;
                }
                if let Some(tr) = self.tracer.as_mut() {
                    tr.expired(span, self.tc);
                }
                continue;
            }
            match pick_slot(self.cspec.balancer, slots, &mut self.rr_cursor, self.tc) {
                Some(si) => {
                    let mut s = lock(&slots[si]);
                    let local_now = s.to_local(self.tc);
                    let rep = &mut *s;
                    let session =
                        rep.session.as_mut().expect("active slot has a live session");
                    let ti = rep
                        .disp
                        .pick(session.soc(), local_now)
                        .expect("picked replica has queue space");
                    rep.disp.bind_attempt(ti, t_due, t_due - orig, attempt);
                    let tile = rep.disp.tiles[ti].tile;
                    session.soc_mut().try_mra_mut(tile)?.serve_grant(1);
                    if !readmit {
                        self.admitted += 1;
                    }
                    let ntiles = self.tiles.len();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.admit(span, self.tc, (si * ntiles + ti) as u16, attempt);
                    }
                }
                None => match rs.next_retry(self.tc, orig, attempt) {
                    Some(at) => {
                        self.ledger.retried += 1;
                        self.retries.push(Reverse((at, orig, attempt + 1, readmit)));
                        if let Some(tr) = self.tracer.as_mut() {
                            tr.retry(span, self.tc, orig, at, attempt + 1, readmit);
                        }
                    }
                    None => {
                        self.ledger.lost += 1;
                        if !readmit {
                            self.spilled += 1;
                        }
                        if let Some(tr) = self.tracer.as_mut() {
                            tr.dropped(span, self.tc);
                        }
                    }
                },
            }
        }
        Ok(())
    }

    /// Run one pool round over every parked task (inline when no pool
    /// is live), then surface the first worker error. With a profile
    /// attached, the whole round is timed on the host clock (and inline
    /// tasks individually) — observation only, nothing feeds back.
    fn exec_round(&mut self, pool: Option<&RoundPool>) -> crate::Result<()> {
        let round_t0 = self.profile.map(|_| std::time::Instant::now());
        match pool {
            Some(p) => p.round(self.slots.len()),
            None => {
                for m in self.slots {
                    let mut rep = lock(m);
                    let Some(task) = rep.task.take() else { continue };
                    let task_t0 = self.profile.map(|_| std::time::Instant::now());
                    run_task(&mut rep, task, self.spec.slo, &mut self.scratch)?;
                    if let (Some(p), Some(t0)) = (self.profile, task_t0) {
                        p.add_task(t0.elapsed().as_nanos() as u64);
                    }
                }
            }
        }
        if let (Some(p), Some(t0)) = (self.profile, round_t0) {
            p.add_round(t0.elapsed().as_nanos() as u64);
        }
        if let Some(e) = self
            .err
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .take()
        {
            return Err(e);
        }
        Ok(())
    }

    /// Try to run one whole sample window `(tc, target]` as a wide
    /// span: pre-bin its arrivals per slot by pure modular round-robin
    /// and let each worker replay its slot independently. Returns
    /// `false` (fall back to narrow barriers) when some slot could run
    /// out of queue space mid-window, which would make serial
    /// round-robin skip it.
    fn wide_window(&mut self, pool: Option<&RoundPool>, target: Ps) -> crate::Result<bool> {
        let n = self.slots.len();
        let start = self.next_arr;
        let mut end = start;
        while end < self.arrivals.len() && self.arrivals[end] <= target {
            end += 1;
        }
        let mut inboxes: Vec<Vec<Ps>> = (0..n).map(|_| Vec::new()).collect();
        for (off, &t) in self.arrivals[start..end].iter().enumerate() {
            inboxes[(self.rr_cursor + off) % n].push(t);
        }
        for (i, inbox) in inboxes.iter().enumerate() {
            let s = lock(&self.slots[i]);
            debug_assert_eq!(s.state, SlotState::Active, "wide spans need a fixed fleet");
            // Worst case (no completions) this slot peaks at
            // backlog + |inbox| outstanding requests; past the
            // replica's total queue space the modular-RR replay would
            // diverge from the skipping serial balancer.
            if s.disp.backlog + inbox.len() > s.disp.capacity * s.disp.tiles.len() {
                return Ok(false);
            }
        }
        for (i, inbox) in inboxes.into_iter().enumerate() {
            let mut s = lock(&self.slots[i]);
            let local = s.to_local(target);
            s.task = Some(Task {
                local,
                inbox: Some(inbox),
            });
        }
        self.rr_cursor = (self.rr_cursor + (end - start)) % n;
        self.admitted += (end - start) as u64;
        self.next_arr = end;
        self.exec_round(pool)?;
        self.tc = target;
        Ok(true)
    }

    /// Steps 1–2 of the reference barrier: advance every live replica
    /// to the cluster target (in parallel when a pool is live — order
    /// only matters for determinism, and replicas are independent),
    /// then attribute completions serially in slot order so the
    /// autoscaler's latency window matches the serial engine exactly.
    fn narrow_barrier(&mut self, pool: Option<&RoundPool>, target: Ps) -> crate::Result<()> {
        let slots = self.slots;
        for m in slots {
            let mut s = lock(m);
            if s.session.is_some() {
                let local = s.to_local(target);
                s.task = Some(Task { local, inbox: None });
            }
        }
        self.exec_round(pool)?;
        self.tc = target;
        let ntiles = self.tiles.len();
        for (i, m) in slots.iter().enumerate() {
            let mut s = lock(m);
            let tr = self.tracer.as_mut().map(|t| (t, (i * ntiles) as u16));
            s.drain_completions(self.spec.slo, self.scaler.as_mut(), &mut self.scratch, tr)?;
        }
        Ok(())
    }

    /// Step 3: drained replicas retire to standby — queue empty and
    /// every pipeline idle. Their session is dropped; a standby replica
    /// costs nothing until the warm base revives it. With a
    /// [`ClusterSpec::drain_deadline`], a replica that still holds a
    /// backlog past the deadline is *force-retired* — its queue drops
    /// (counted on the replica, requeued when a retry policy exists) —
    /// so a wedged replica can never block scale-down forever.
    fn retire_drained(&mut self) -> crate::Result<()> {
        for (i, m) in self.slots.iter().enumerate() {
            let mut s = lock(m);
            if s.state != SlotState::Draining {
                continue;
            }
            if s.disp.backlog > 0 {
                let overdue = self
                    .cspec
                    .drain_deadline
                    .is_some_and(|d| self.tc >= s.draining_since.saturating_add(d));
                if overdue {
                    let ntiles = self.tiles.len();
                    let lost = kill_replica(
                        &mut s,
                        self.spec,
                        self.tc,
                        &mut self.retries,
                        &mut self.ledger,
                        self.tracer.as_mut().map(|t| (t, (i * ntiles) as u16)),
                    );
                    // Force-dropped requests are an explicit decision,
                    // so they count as replica drops, unlike crash
                    // losses (which surface as `unfinished`).
                    s.done_dropped += lost;
                    self.ledger.evicted += 1;
                    s.state = SlotState::Standby;
                    if let Some(h) = &mut self.health {
                        h.reset(i);
                    }
                }
                continue;
            }
            let idle = s
                .session
                .as_ref()
                .is_some_and(|sess| self.tiles.iter().all(|&t| sess.soc().mra(t).pipeline_idle()));
            if !idle {
                continue;
            }
            s.active_ps += self.tc - s.activated_at;
            s.done_admitted += s.disp.tiles.iter().map(|q| q.admitted).sum::<u64>();
            s.done_completed += s.disp.tiles.iter().map(|q| q.completed).sum::<u64>();
            s.done_dropped += s.disp.dropped;
            s.disp = Dispatcher::new(self.spec.policy, self.spec.queue_capacity, Vec::new());
            s.governor = None;
            s.session = None;
            s.state = SlotState::Standby;
        }
        Ok(())
    }

    /// Step 4: admit due arrivals through the balancer. No active
    /// replica with space means a front-end spill — final, like any
    /// open-loop drop.
    fn admit_due(&mut self) -> crate::Result<()> {
        let slots = self.slots;
        while self.next_arr < self.arrivals.len() && self.arrivals[self.next_arr] <= self.tc {
            let t_arr = self.arrivals[self.next_arr];
            self.next_arr += 1;
            // Arrival ordinals drive trace sampling; arrivals pop in
            // schedule order, so span ids are engine/thread-invariant.
            let span = match self.tracer.as_mut() {
                Some(tr) => tr.arrive(t_arr),
                None => None,
            };
            match pick_slot(self.cspec.balancer, slots, &mut self.rr_cursor, self.tc) {
                Some(si) => {
                    let mut s = lock(&slots[si]);
                    let local_now = s.to_local(self.tc);
                    let rep = &mut *s;
                    let session =
                        rep.session.as_mut().expect("active slot has a live session");
                    let ti = rep
                        .disp
                        .pick(session.soc(), local_now)
                        .expect("picked replica has queue space");
                    rep.disp.bind(ti, t_arr);
                    let tile = rep.disp.tiles[ti].tile;
                    session.soc_mut().try_mra_mut(tile)?.serve_grant(1);
                    self.admitted += 1;
                    let ntiles = self.tiles.len();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.admit(span, self.tc, (si * ntiles + ti) as u16, 0);
                    }
                }
                None => {
                    // With a retry policy a front-end spill backs off
                    // instead of being final; it only counts as spilled
                    // once attempts or the deadline run out.
                    let retry =
                        self.spec.retry.as_ref().and_then(|rs| rs.next_retry(self.tc, t_arr, 0));
                    match retry {
                        Some(at) => {
                            self.ledger.retried += 1;
                            self.retries.push(Reverse((at, t_arr, 1, false)));
                            if let Some(tr) = self.tracer.as_mut() {
                                tr.retry(span, self.tc, t_arr, at, 1, false);
                            }
                        }
                        None => {
                            self.spilled += 1;
                            if self.spec.retry.is_some() {
                                self.ledger.lost += 1;
                            }
                            if let Some(tr) = self.tracer.as_mut() {
                                tr.dropped(span, self.tc);
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Step 5: sample timelines, run per-replica governors, and let the
    /// autoscaler resize the fleet. No-op between sample deadlines.
    fn sample(&mut self) -> crate::Result<()> {
        if self.tc < self.next_sample {
            return Ok(());
        }
        let slots = self.slots;
        let tc = self.tc;
        for m in slots {
            let mut s = lock(m);
            let depth = s.disp.backlog as f64;
            s.queue_depth.push(tc, depth);
            let state = match s.state {
                SlotState::Active => 1.0,
                SlotState::Draining => 0.5,
                SlotState::Standby | SlotState::Failed => 0.0,
            };
            s.active_state.push(tc, state);
            let isl = s.disp.tiles.first().map(|q| q.island);
            let rep = &mut *s;
            match (&mut rep.session, isl) {
                (Some(session), Some(isl)) => {
                    let local = rep.local_base + (tc - rep.cluster_base);
                    rep.freq_mhz
                        .push(tc, session.soc().islands[isl].freq(local).as_mhz() as f64);
                    if let Some(g) = &mut rep.governor {
                        g.on_sample(session.soc_mut(), local);
                    }
                }
                _ => rep.freq_mhz.push(tc, 0.0),
            }
        }
        // Health checks ride the sample cadence: notice crashed slots,
        // evict wedged ones (backlog held with zero completions for
        // `evict_after` consecutive windows), then restore the fleet to
        // its desired size from warm standby.
        if self.health.is_some() {
            for (i, m) in slots.iter().enumerate() {
                let mut s = lock(m);
                match s.state {
                    SlotState::Failed => {
                        // The probe notices the dead replica; its slot
                        // becomes schedulable standby capacity again.
                        self.ledger.detected += 1;
                        s.state = SlotState::Standby;
                        self.health.as_mut().expect("checked").reset(i);
                    }
                    SlotState::Active => {
                        let completed: u64 =
                            s.disp.tiles.iter().map(|q| q.completed).sum();
                        let backlog = s.disp.backlog;
                        let h = self.health.as_mut().expect("checked");
                        if h.observe(i, backlog, completed) {
                            self.ledger.detected += 1;
                            self.ledger.evicted += 1;
                            let ntiles = self.tiles.len();
                            kill_replica(
                                &mut s,
                                self.spec,
                                tc,
                                &mut self.retries,
                                &mut self.ledger,
                                self.tracer.as_mut().map(|t| (t, (i * ntiles) as u16)),
                            );
                            s.state = SlotState::Standby;
                            h.reset(i);
                        }
                    }
                    _ => {}
                }
            }
            if self.health.as_ref().expect("checked").replace() && tc < self.duration {
                loop {
                    let active = slots
                        .iter()
                        .filter(|m| lock(m).state == SlotState::Active)
                        .count();
                    if active >= self.desired_active {
                        break;
                    }
                    let Some(i) = slots
                        .iter()
                        .position(|m| lock(m).state == SlotState::Standby)
                    else {
                        break;
                    };
                    let mut s = lock(&slots[i]);
                    activate(&mut s, self.snap, self.spec, self.tiles, tc)?;
                    install_slot_faults(&mut s, self.plan, i)?;
                    self.health.as_mut().expect("checked").reset(i);
                    self.ledger.failed_over += 1;
                }
            }
        }
        let active = slots
            .iter()
            .filter(|m| lock(m).state == SlotState::Active)
            .count();
        self.active_series.push(tc, active as f64);
        if let Some(a) = &mut self.scaler {
            let backlog: usize = slots
                .iter()
                .map(|m| {
                    let s = lock(m);
                    if s.state == SlotState::Active {
                        s.disp.backlog
                    } else {
                        0
                    }
                })
                .sum();
            let mean_backlog = backlog as f64 / active.max(1) as f64;
            match a.decide(active, mean_backlog) {
                // Don't add capacity for traffic that can no longer
                // arrive — past the horizon only drain-downs apply.
                ScaleDecision::Up if tc < self.duration => {
                    // A draining slot is still warm and live: promote it
                    // before waking a standby one.
                    let pick = slots
                        .iter()
                        .position(|m| lock(m).state == SlotState::Draining)
                        .or_else(|| {
                            slots
                                .iter()
                                .position(|m| lock(m).state == SlotState::Standby)
                        });
                    if let Some(i) = pick {
                        let mut s = lock(&slots[i]);
                        if s.state == SlotState::Draining {
                            s.state = SlotState::Active;
                        } else {
                            activate(&mut s, self.snap, self.spec, self.tiles, tc)?;
                            install_slot_faults(&mut s, self.plan, i)?;
                            if let Some(h) = &mut self.health {
                                h.reset(i);
                            }
                        }
                        self.desired_active = active + 1;
                        a.record(tc, active + 1);
                    }
                }
                ScaleDecision::Down => {
                    // Retire the least-backlogged active slot; ties pick
                    // the highest index so slot 0 stays pinned.
                    let victim = slots
                        .iter()
                        .enumerate()
                        .filter_map(|(i, m)| {
                            let s = lock(m);
                            (s.state == SlotState::Active)
                                .then_some((s.disp.backlog, std::cmp::Reverse(i), i))
                        })
                        .min()
                        .map(|(_, _, i)| i);
                    if let Some(i) = victim {
                        let mut s = lock(&slots[i]);
                        s.state = SlotState::Draining;
                        s.draining_since = tc;
                        self.desired_active = active - 1;
                        a.record(tc, active - 1);
                    }
                }
                _ => {}
            }
        }
        while self.next_sample <= tc {
            self.next_sample += self.sample_interval;
        }
        Ok(())
    }
}

/// Serve `cspec.spec`'s traffic across the fleet and return the merged
/// [`ClusterReport`]. See the [module docs](self) for the model and the
/// parallel-execution contract.
pub fn serve_cluster(cfg: SocConfig, cspec: &ClusterSpec) -> crate::Result<ClusterReport> {
    serve_cluster_with_profile(cfg, cspec, None)
}

/// [`serve_cluster`] with optional host-side self-profiling: wall-clock
/// barrier-round and per-task timings accumulate into `profile`
/// (see [`HostProfile`]). Host-clock readings never touch the
/// simulation, so the report stays bit-identical with or without a
/// profile attached.
pub fn serve_cluster_with_profile(
    cfg: SocConfig,
    cspec: &ClusterSpec,
    profile: Option<&HostProfile>,
) -> crate::Result<ClusterReport> {
    cspec.validate()?;
    let spec = &cspec.spec;
    // Resolve the fault plan once against fleet size: component windows
    // install at each activation, crashes apply at their barrier
    // instants. An empty plan resolves to nothing and costs nothing.
    let plan = spec.faults.compile(spec.duration, cspec.replicas)?;

    // Warm base: build, stage, gate, and settle one session, then
    // snapshot it. Every activation forks this (the engine mode and any
    // scheduled DFS retunes ride along in the snapshot).
    let mut base = Session::new(cfg)?;
    base.engine(cspec.engine);
    let tiles = resolve_tiles(&base, spec)?;
    prepare_serve_tiles(&mut base, spec, &tiles)?;
    for &(at, island, mhz) in &cspec.freq_schedule {
        anyhow::ensure!(
            island < base.soc().islands.len(),
            "cluster: freq_schedule island {island} out of range (SoC has {})",
            base.soc().islands.len()
        );
        base.schedule_freq(at, island, mhz);
    }
    let snap = base.snapshot()?;
    drop(base);

    let scaler = cspec.autoscale.as_ref().map(|a| {
        Autoscaler::new(
            a,
            cspec.replicas,
            spec.slo.expect("validated: autoscale needs an SLO"),
        )
    });
    let initial_active = match &cspec.autoscale {
        Some(a) => a.min_replicas,
        None => cspec.replicas,
    };

    let slots: Vec<Mutex<Replica>> = (0..cspec.replicas)
        .map(|i| {
            Mutex::new(Replica {
                state: SlotState::Standby,
                session: None,
                disp: Dispatcher::new(spec.policy, spec.queue_capacity, Vec::new()),
                governor: None,
                local_base: 0,
                cluster_base: 0,
                activated_at: 0,
                active_ps: 0,
                activations: 0,
                latencies: Vec::new(),
                within_slo: 0,
                drained_at: 0,
                draining_since: 0,
                rescued: 0,
                task: None,
                done_admitted: 0,
                done_completed: 0,
                done_dropped: 0,
                queue_depth: TimeSeries::new(format!("r{i}_queue")),
                freq_mhz: TimeSeries::new(format!("r{i}_freq")),
                active_state: TimeSeries::new(format!("r{i}_active")),
            })
        })
        .collect();
    for (i, m) in slots.iter().enumerate().take(initial_active) {
        let mut s = lock(m);
        activate(&mut s, &snap, spec, &tiles, 0)?;
        install_slot_faults(&mut s, &plan, i)?;
    }

    // The cluster-level arrival schedule: exactly what a lone SoC would
    // see from the same spec — the balancer splits it, the seed doesn't.
    let mut arrivals = spec.arrival.times(spec.seed, spec.duration);
    arrivals.sort_unstable();
    let offered = arrivals.len() as u64;

    let duration = spec.duration;
    let deadline = duration + spec.drain;
    let sample_interval = if spec.sample_interval > 0 {
        spec.sample_interval
    } else {
        (duration / 100).max(1_000_000)
    };

    // One trace track per (slot, tile) pair, laid out slot-major so a
    // slot's base track index is `slot * tiles.len()`.
    let tracer = spec.trace.map(|ts| {
        let mut tr = Tracer::new(ts);
        for slot in 0..cspec.replicas {
            for &t in &tiles {
                tr.add_track(format!("r{slot}/tile {t}"), slot, t);
            }
        }
        tr
    });

    let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let mut eng = ClusterEngine {
        cspec,
        spec,
        tiles: &tiles,
        snap: &snap,
        slots: &slots,
        err: &err,
        scaler,
        plan: &plan,
        next_crash: 0,
        health: cspec
            .health
            .clone()
            .map(|h| HealthMonitor::new(h, cspec.replicas)),
        desired_active: initial_active,
        retries: BinaryHeap::new(),
        ledger: FaultLedger {
            injected: plan.injected,
            ..FaultLedger::default()
        },
        arrivals,
        next_arr: 0,
        admitted: 0,
        spilled: 0,
        rr_cursor: 0,
        tc: 0,
        next_sample: 0,
        sample_interval,
        duration,
        deadline,
        active_series: TimeSeries::new("active_replicas"),
        scratch: Vec::new(),
        tracer,
        profile,
    };

    let workers = resolve_threads(cspec.threads, cspec.replicas);
    if workers <= 1 {
        eng.run(None)?;
    } else {
        let scratches: Vec<Mutex<Vec<Ps>>> =
            (0..workers).map(|_| Mutex::new(Vec::new())).collect();
        let slo = spec.slo;
        let slots_ref = &slots;
        let err_ref = &err;
        let work = move |wid: usize, k: usize| {
            let mut rep = lock(&slots_ref[k]);
            let Some(task) = rep.task.take() else { return };
            let mut scratch = scratches[wid]
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            let task_t0 = profile.map(|_| std::time::Instant::now());
            if let Err(e) = run_task(&mut rep, task, slo, &mut scratch) {
                let mut first = err_ref
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                if first.is_none() {
                    *first = Some(e);
                }
            }
            if let (Some(p), Some(t0)) = (profile, task_t0) {
                p.add_task(t0.elapsed().as_nanos() as u64);
            }
        };
        with_round_pool(workers, work, |pool| eng.run(Some(pool)))?;
    }

    // Requests still parked on the retry heap at the hard deadline never
    // completed: they count as lost (and as fleet spills unless they
    // were admitted once before their replica died).
    while let Some(Reverse((_, orig, attempt, readmit))) = eng.retries.pop() {
        if let Some(tr) = eng.tracer.as_mut() {
            let span = tr.retry_pop(orig, attempt, readmit);
            tr.expired(span, eng.tc);
        }
        eng.ledger.lost += 1;
        if !readmit {
            eng.spilled += 1;
        }
    }

    let ClusterEngine {
        scaler,
        admitted,
        spilled,
        tc,
        active_series,
        mut ledger,
        mut tracer,
        ..
    } = eng;

    // Close out live replicas: drain any exec starts whose invocations
    // never finished (the waterfall shows them cut off at run end), then
    // ungate the tiles and count the final activation span into the
    // cost proxy.
    for (si, m) in slots.iter().enumerate() {
        let mut s = lock(m);
        let rep = &mut *s;
        if let Some(session) = rep.session.as_mut() {
            let (cb, lb) = (rep.cluster_base, rep.local_base);
            for (ti, &t) in tiles.iter().enumerate() {
                let mra = session.soc_mut().try_mra_mut(t)?;
                if let Some(tr) = tracer.as_mut() {
                    if let Some(g) = &mut mra.serve {
                        while let Some((t_s, r)) = g.starts.pop_front() {
                            tr.exec_start((si * tiles.len() + ti) as u16, cb + (t_s - lb), r);
                        }
                    }
                }
                mra.serve_end();
            }
        }
        // A killed slot already rolled its span in `kill_replica`.
        if !matches!(rep.state, SlotState::Standby | SlotState::Failed) {
            rep.active_ps += tc - rep.activated_at;
        }
    }

    // Merge per-replica latency distributions exactly.
    let dur_s = duration as f64 / 1e12;
    let mut merged = Percentiles::default();
    let mut completed: u64 = 0;
    let mut within_slo: u64 = 0;
    let mut replica_dropped: u64 = 0;
    let mut per_replica = Vec::with_capacity(slots.len());
    let final_active = slots
        .iter()
        .filter(|m| lock(m).state == SlotState::Active)
        .count();
    let replica_seconds =
        slots.iter().map(|m| lock(m).active_ps).sum::<Ps>() as f64 / 1e12;
    for (i, m) in slots.into_iter().enumerate() {
        let slot = m.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        let p = Percentiles::from_samples(&slot.latencies)?;
        merged = merged.merge(&p);
        completed += slot.latencies.len() as u64;
        within_slo += slot.within_slo;
        ledger.rescued += slot.rescued;
        let live_admitted: u64 = slot.disp.tiles.iter().map(|q| q.admitted).sum();
        let live_completed: u64 = slot.disp.tiles.iter().map(|q| q.completed).sum();
        let unfinished: u64 = slot.disp.tiles.iter().map(|q| q.in_flight.len() as u64).sum();
        let dropped = slot.done_dropped + slot.disp.dropped;
        replica_dropped += dropped;
        per_replica.push(ReplicaReport {
            slot: i,
            activations: slot.activations,
            admitted: slot.done_admitted + live_admitted,
            completed: slot.done_completed + live_completed,
            dropped,
            unfinished,
            latency: LatencyStats::from_percentiles(&p),
            active_ps: slot.active_ps,
            queue_depth: slot.queue_depth,
            freq_mhz: slot.freq_mhz,
            active_state: slot.active_state,
        });
    }
    let latency = LatencyStats::from_percentiles(&merged);
    let slo_met = match (spec.slo, completed) {
        (Some(slo), c) if c > 0 => Some(latency.p95_ps <= slo as f64),
        _ => None,
    };
    let slo_attainment = match (spec.slo, completed) {
        (Some(_), c) if c > 0 => within_slo as f64 / c as f64,
        // An SLO with zero completions is total failure, not perfection.
        (Some(_), _) => 0.0,
        (None, _) => 1.0,
    };

    let report = ClusterReport {
        fleet: cspec.replicas,
        balancer: cspec.balancer,
        offered,
        admitted,
        dropped: spilled + replica_dropped,
        spilled,
        completed,
        unfinished: admitted - completed,
        duration,
        elapsed: tc,
        offered_rps: offered as f64 / dur_s,
        achieved_rps: completed as f64 / dur_s,
        latency,
        slo: spec.slo,
        slo_met,
        slo_attainment,
        per_replica,
        active_replicas: active_series,
        replica_seconds,
        autoscale_actions: scaler.map(|a| a.actions).unwrap_or_default(),
        final_active,
        faults: ledger,
        trace: tracer.map(Tracer::finish),
    };
    debug_assert!(
        report.verify_accounting().is_ok(),
        "cluster accounting diverged: {:?}",
        report.verify_accounting()
    );
    Ok(report)
}
