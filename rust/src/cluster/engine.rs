//! The cluster engine: one open-loop arrival stream fanned across N
//! independent SoC replicas by a front-end balancer, with an optional
//! SLO-driven autoscaler resizing the active fleet.
//!
//! Each replica is a full [`Session`] — its own SoC, NoC, DFS islands,
//! and per-replica [`QueueGovernor`] — advanced in lockstep on a shared
//! *cluster clock*. The clock starts at 0; replica-local SoC time is an
//! affine map fixed at activation (`local = local_base + (t - base)`),
//! so completions keep their exact tile-log timestamps when attributed
//! back to cluster-time arrivals.
//!
//! Elasticity uses the warm-base trick from the sweep engine: the spec's
//! tiles are staged, gated, and settled **once**, then snapshotted;
//! every (re)activation forks that [`Session::snapshot`] and skips
//! warmup entirely. Retiring is drain-then-retire — a draining replica
//! takes no new work but finishes its queue before going standby.
//!
//! Everything iterates in slot-index order and the arrival schedule is
//! derived only from `(spec.seed, spec.duration)`, so the same
//! [`ClusterSpec`] + config reproduces a bit-identical
//! [`ClusterReport`].

use crate::config::SocConfig;
use crate::monitor::TimeSeries;
use crate::policy::DfsPolicy;
use crate::scenario::{Session, SocSnapshot};
use crate::serve::dispatch::{DispatchPolicy, Dispatcher};
use crate::serve::engine::{prepare_serve_tiles, resolve_tiles, tile_queues};
use crate::serve::governor::QueueGovernor;
use crate::serve::report::LatencyStats;
use crate::serve::ServeSpec;
use crate::util::{Percentiles, Ps};

use super::autoscale::{Autoscaler, ScaleDecision};
use super::report::{ClusterReport, ReplicaReport};
use super::spec::ClusterSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    Active,
    /// No new work; retires to standby once its queue and pipeline are
    /// empty.
    Draining,
    /// No live SoC; costs nothing until reactivated from the warm base.
    Standby,
}

/// One replica slot of the fleet.
struct Replica {
    state: SlotState,
    session: Option<Session>,
    disp: Dispatcher,
    governor: Option<QueueGovernor>,
    /// Replica-local SoC time at `cluster_base` (the warm snapshot's
    /// clock for the current activation).
    local_base: Ps,
    /// Cluster time of the current activation.
    cluster_base: Ps,
    activated_at: Ps,
    /// Accumulated active/draining time over finished activations (ps).
    active_ps: Ps,
    activations: u64,
    /// Completed-request latencies (ps) across all activations.
    latencies: Vec<f64>,
    // Counters carried over from finished activations (live ones are on
    // `disp`, which is rebuilt per activation).
    done_admitted: u64,
    done_completed: u64,
    done_dropped: u64,
    queue_depth: TimeSeries,
    freq_mhz: TimeSeries,
    active_state: TimeSeries,
}

impl Replica {
    fn backlog(&self) -> usize {
        self.disp.tiles.iter().map(|q| q.in_flight.len()).sum()
    }

    fn has_space(&self) -> bool {
        self.disp
            .tiles
            .iter()
            .any(|q| q.in_flight.len() < self.disp.capacity)
    }

    fn to_local(&self, tc: Ps) -> Ps {
        self.local_base + (tc - self.cluster_base)
    }

    /// Cheapest estimated drain time among this replica's tiles for one
    /// more request: the tile-level [`DispatchPolicy::LeastLoadedTile`]
    /// estimate lifted to cluster scope — gate backlog
    /// ([`serve_backlog`](crate::tiles::MraTile::serve_backlog)) weighted
    /// by invocation cycles at the island's live DFS frequency.
    fn estimated_drain(&self, tc: Ps) -> f64 {
        let Some(session) = self.session.as_ref() else {
            return f64::INFINITY;
        };
        let local = self.to_local(tc);
        let soc = session.soc();
        self.disp
            .tiles
            .iter()
            .map(|q| {
                let mhz = soc.islands[q.island].freq(local).as_mhz().max(1) as f64;
                let backlog = (soc.mra(q.tile).serve_backlog() + 1) as f64;
                backlog * q.compute_cycles as f64 / (mhz * q.replicas as f64)
            })
            .fold(f64::INFINITY, f64::min)
    }
}

/// Fork the warm base into `slot` and mark it active at cluster time
/// `tc`. The snapshot is already staged + gated + settled, so the
/// replica serves its first request without any warmup.
fn activate(
    slot: &mut Replica,
    snap: &SocSnapshot,
    spec: &ServeSpec,
    tiles: &[usize],
    tc: Ps,
) -> crate::Result<()> {
    let session = Session::resume(snap)?;
    slot.disp = Dispatcher::new(
        spec.policy,
        spec.queue_capacity,
        tile_queues(&session, tiles),
    );
    slot.governor = spec
        .governor
        .as_ref()
        .map(|g| QueueGovernor::new(g, tiles.to_vec()));
    slot.local_base = snap.now();
    slot.cluster_base = tc;
    slot.activated_at = tc;
    slot.activations += 1;
    slot.state = SlotState::Active;
    slot.session = Some(session);
    Ok(())
}

/// The front-end balancer: pick an active replica with queue space, or
/// `None` (spill) when the whole fleet is saturated. Reuses
/// [`DispatchPolicy`] semantics one level up.
fn pick_slot(
    balancer: DispatchPolicy,
    slots: &[Replica],
    rr_cursor: &mut usize,
    tc: Ps,
) -> Option<usize> {
    let eligible = |s: &Replica| s.state == SlotState::Active && s.has_space();
    let n = slots.len();
    match balancer {
        DispatchPolicy::RoundRobin => {
            for off in 0..n {
                let i = (*rr_cursor + off) % n;
                if eligible(&slots[i]) {
                    *rr_cursor = (i + 1) % n;
                    return Some(i);
                }
            }
            None
        }
        DispatchPolicy::JoinShortestQueue => slots
            .iter()
            .enumerate()
            .filter(|(_, s)| eligible(s))
            .min_by_key(|(i, s)| (s.backlog(), *i))
            .map(|(i, _)| i),
        DispatchPolicy::LeastLoadedTile => slots
            .iter()
            .enumerate()
            .filter(|(_, s)| eligible(s))
            .map(|(i, s)| (i, s.estimated_drain(tc)))
            .min_by(|(ia, a), (ib, b)| a.total_cmp(b).then(ia.cmp(ib)))
            .map(|(i, _)| i),
    }
}

impl ClusterSpec {
    /// Run this cluster on `cfg`. Convenience for [`serve_cluster`].
    pub fn run(&self, cfg: SocConfig) -> crate::Result<ClusterReport> {
        serve_cluster(cfg, self)
    }
}

/// Serve `cspec.spec`'s traffic across the fleet and return the merged
/// [`ClusterReport`]. See the [module docs](self) for the model.
pub fn serve_cluster(cfg: SocConfig, cspec: &ClusterSpec) -> crate::Result<ClusterReport> {
    cspec.validate()?;
    let spec = &cspec.spec;

    // Warm base: build, stage, gate, and settle one session, then
    // snapshot it. Every activation forks this (the engine mode rides
    // along in the snapshot).
    let mut base = Session::new(cfg)?;
    base.engine(cspec.engine);
    let tiles = resolve_tiles(&base, spec)?;
    prepare_serve_tiles(&mut base, spec, &tiles)?;
    let snap = base.snapshot()?;
    drop(base);

    let mut scaler = cspec
        .autoscale
        .as_ref()
        .map(|a| Autoscaler::new(a, cspec.replicas, spec.slo.expect("validated: autoscale needs an SLO")));
    let initial_active = match &cspec.autoscale {
        Some(a) => a.min_replicas,
        None => cspec.replicas,
    };

    let mut slots: Vec<Replica> = (0..cspec.replicas)
        .map(|i| Replica {
            state: SlotState::Standby,
            session: None,
            disp: Dispatcher::new(spec.policy, spec.queue_capacity, Vec::new()),
            governor: None,
            local_base: 0,
            cluster_base: 0,
            activated_at: 0,
            active_ps: 0,
            activations: 0,
            latencies: Vec::new(),
            done_admitted: 0,
            done_completed: 0,
            done_dropped: 0,
            queue_depth: TimeSeries::new(format!("r{i}_queue")),
            freq_mhz: TimeSeries::new(format!("r{i}_freq")),
            active_state: TimeSeries::new(format!("r{i}_active")),
        })
        .collect();
    for slot in slots.iter_mut().take(initial_active) {
        activate(slot, &snap, spec, &tiles, 0)?;
    }

    // The cluster-level arrival schedule: exactly what a lone SoC would
    // see from the same spec — the balancer splits it, the seed doesn't.
    let mut arrivals = spec.arrival.times(spec.seed, spec.duration);
    arrivals.sort_unstable();
    let offered = arrivals.len() as u64;
    let mut next_arr = 0usize;

    let duration = spec.duration;
    let deadline = duration + spec.drain;
    let sample_interval = if spec.sample_interval > 0 {
        spec.sample_interval
    } else {
        (duration / 100).max(1_000_000)
    };
    let mut next_sample: Ps = 0;
    let mut active_series = TimeSeries::new("active_replicas");

    // Arrival time of each admitted request, indexed by request id
    // (ids are globally unique across the fleet).
    let mut reqs: Vec<Ps> = Vec::new();
    let mut completed: u64 = 0;
    let mut within_slo: u64 = 0;
    let mut spilled: u64 = 0;
    let mut rr_cursor = 0usize;
    let mut tc: Ps = 0;

    loop {
        let pending: usize = slots.iter().map(|s| s.backlog()).sum();
        let draining = slots.iter().any(|s| s.state == SlotState::Draining);
        let next_arrival = arrivals.get(next_arr).copied();
        if tc >= deadline
            || (tc >= duration && next_arrival.is_none() && pending == 0 && !draining)
        {
            break;
        }
        let mut target = next_sample.min(deadline);
        if let Some(a) = next_arrival {
            target = target.min(a);
        }
        let target = target.max(tc);

        // 1) Advance every live replica to the cluster target, in slot
        // order (replicas are independent, so order only matters for
        // determinism).
        for slot in slots.iter_mut() {
            if slot.session.is_some() {
                let local = slot.to_local(target);
                slot.session.as_mut().expect("checked").run_until(local);
            }
        }
        tc = target;

        // 2) Attribute completions (exact tile-log timestamps mapped
        // onto the cluster clock). Same peek-then-drain dance as the
        // single-SoC engine: a mutable tile poke resets the idle wake
        // point, so only touch tiles that actually completed something.
        for slot in slots.iter_mut() {
            let Some(session) = slot.session.as_mut() else {
                continue;
            };
            for ti in 0..slot.disp.tiles.len() {
                let tile = slot.disp.tiles[ti].tile;
                let has_completions = session
                    .soc()
                    .mra(tile)
                    .serve
                    .as_ref()
                    .is_some_and(|g| !g.completions.is_empty());
                if !has_completions {
                    continue;
                }
                let log: Vec<Ps> = {
                    let m = session.soc_mut().try_mra_mut(tile)?;
                    match &mut m.serve {
                        Some(g) => g.completions.drain(..).map(|(t, _replica)| t).collect(),
                        None => Vec::new(),
                    }
                };
                for t_local in log {
                    let Some(req) = slot.disp.complete(ti) else {
                        debug_assert!(false, "completion without an outstanding request");
                        continue;
                    };
                    let t_c = slot.cluster_base + (t_local - slot.local_base);
                    let lat = t_c - reqs[req];
                    slot.latencies.push(lat as f64);
                    completed += 1;
                    if let Some(slo) = spec.slo {
                        if lat <= slo {
                            within_slo += 1;
                        }
                    }
                    if let Some(g) = &mut slot.governor {
                        g.observe_latency(lat);
                    }
                    if let Some(a) = &mut scaler {
                        a.observe_latency(lat);
                    }
                }
            }
        }

        // 3) Drained replicas retire to standby: queue empty and every
        // pipeline idle. Their session is dropped — a standby replica
        // costs nothing until the warm base revives it.
        for slot in slots.iter_mut() {
            if slot.state != SlotState::Draining || slot.backlog() > 0 {
                continue;
            }
            let idle = slot
                .session
                .as_ref()
                .is_some_and(|s| tiles.iter().all(|&t| s.soc().mra(t).pipeline_idle()));
            if !idle {
                continue;
            }
            slot.active_ps += tc - slot.activated_at;
            slot.done_admitted += slot.disp.tiles.iter().map(|q| q.admitted).sum::<u64>();
            slot.done_completed += slot.disp.tiles.iter().map(|q| q.completed).sum::<u64>();
            slot.done_dropped += slot.disp.dropped;
            slot.disp = Dispatcher::new(spec.policy, spec.queue_capacity, Vec::new());
            slot.governor = None;
            slot.session = None;
            slot.state = SlotState::Standby;
        }

        // 4) Admit due arrivals through the balancer. No active replica
        // with space means a front-end spill — final, like any
        // open-loop drop.
        while next_arr < arrivals.len() && arrivals[next_arr] <= tc {
            let t_arr = arrivals[next_arr];
            next_arr += 1;
            match pick_slot(cspec.balancer, &slots, &mut rr_cursor, tc) {
                Some(si) => {
                    let slot = &mut slots[si];
                    let local_now = slot.to_local(tc);
                    let session = slot.session.as_mut().expect("active slot has a live session");
                    let ti = slot
                        .disp
                        .pick(session.soc(), local_now)
                        .expect("picked replica has queue space");
                    let req = reqs.len();
                    reqs.push(t_arr);
                    slot.disp.bind(ti, req);
                    let tile = slot.disp.tiles[ti].tile;
                    session.soc_mut().try_mra_mut(tile)?.serve_grant(1);
                }
                None => spilled += 1,
            }
        }

        // 5) Sample timelines, run per-replica governors, and let the
        // autoscaler resize the fleet.
        if tc >= next_sample {
            for slot in slots.iter_mut() {
                slot.queue_depth.push(tc, slot.backlog() as f64);
                slot.active_state.push(
                    tc,
                    match slot.state {
                        SlotState::Active => 1.0,
                        SlotState::Draining => 0.5,
                        SlotState::Standby => 0.0,
                    },
                );
                let isl = slot.disp.tiles.first().map(|q| q.island);
                match (&mut slot.session, isl) {
                    (Some(session), Some(isl)) => {
                        let local = slot.to_local(tc);
                        slot.freq_mhz
                            .push(tc, session.soc().islands[isl].freq(local).as_mhz() as f64);
                        if let Some(g) = &mut slot.governor {
                            g.on_sample(session.soc_mut(), local);
                        }
                    }
                    _ => slot.freq_mhz.push(tc, 0.0),
                }
            }
            let active = slots.iter().filter(|s| s.state == SlotState::Active).count();
            active_series.push(tc, active as f64);
            if let Some(a) = &mut scaler {
                let backlog: usize = slots
                    .iter()
                    .filter(|s| s.state == SlotState::Active)
                    .map(|s| s.backlog())
                    .sum();
                let mean_backlog = backlog as f64 / active.max(1) as f64;
                match a.decide(active, mean_backlog) {
                    // Don't add capacity for traffic that can no longer
                    // arrive — past the horizon only drain-downs apply.
                    ScaleDecision::Up if tc < duration => {
                        // A draining slot is still warm and live:
                        // promote it before waking a standby one.
                        let pick = slots
                            .iter()
                            .position(|s| s.state == SlotState::Draining)
                            .or_else(|| {
                                slots.iter().position(|s| s.state == SlotState::Standby)
                            });
                        if let Some(i) = pick {
                            if slots[i].state == SlotState::Draining {
                                slots[i].state = SlotState::Active;
                            } else {
                                activate(&mut slots[i], &snap, spec, &tiles, tc)?;
                            }
                            a.record(tc, active + 1);
                        }
                    }
                    ScaleDecision::Down => {
                        // Retire the least-backlogged active slot; ties
                        // pick the highest index so slot 0 stays pinned.
                        let victim = slots
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| s.state == SlotState::Active)
                            .min_by_key(|(i, s)| (s.backlog(), std::cmp::Reverse(*i)))
                            .map(|(i, _)| i);
                        if let Some(i) = victim {
                            slots[i].state = SlotState::Draining;
                            a.record(tc, active - 1);
                        }
                    }
                    _ => {}
                }
            }
            while next_sample <= tc {
                next_sample += sample_interval;
            }
        }
    }

    // Close out live replicas: ungate their tiles and count their final
    // activation span into the cost proxy.
    for slot in slots.iter_mut() {
        if let Some(session) = slot.session.as_mut() {
            for &t in &tiles {
                session.soc_mut().try_mra_mut(t)?.serve_end();
            }
        }
        if slot.state != SlotState::Standby {
            slot.active_ps += tc - slot.activated_at;
        }
    }

    // Merge per-replica latency distributions exactly.
    let admitted = reqs.len() as u64;
    let dur_s = duration as f64 / 1e12;
    let mut merged = Percentiles::default();
    let mut replica_dropped: u64 = 0;
    let mut per_replica = Vec::with_capacity(slots.len());
    let final_active = slots.iter().filter(|s| s.state == SlotState::Active).count();
    let replica_seconds = slots.iter().map(|s| s.active_ps).sum::<Ps>() as f64 / 1e12;
    for (i, slot) in slots.into_iter().enumerate() {
        let p = Percentiles::from_samples(&slot.latencies)?;
        merged = merged.merge(&p);
        let live_admitted: u64 = slot.disp.tiles.iter().map(|q| q.admitted).sum();
        let live_completed: u64 = slot.disp.tiles.iter().map(|q| q.completed).sum();
        let unfinished: u64 = slot.disp.tiles.iter().map(|q| q.in_flight.len() as u64).sum();
        let dropped = slot.done_dropped + slot.disp.dropped;
        replica_dropped += dropped;
        per_replica.push(ReplicaReport {
            slot: i,
            activations: slot.activations,
            admitted: slot.done_admitted + live_admitted,
            completed: slot.done_completed + live_completed,
            dropped,
            unfinished,
            latency: LatencyStats::from_percentiles(&p),
            active_ps: slot.active_ps,
            queue_depth: slot.queue_depth,
            freq_mhz: slot.freq_mhz,
            active_state: slot.active_state,
        });
    }
    let latency = LatencyStats::from_percentiles(&merged);
    let slo_met = match (spec.slo, completed) {
        (Some(slo), c) if c > 0 => Some(latency.p95_ps <= slo as f64),
        _ => None,
    };
    let slo_attainment = match (spec.slo, completed) {
        (Some(_), c) if c > 0 => within_slo as f64 / c as f64,
        // An SLO with zero completions is total failure, not perfection.
        (Some(_), _) => 0.0,
        (None, _) => 1.0,
    };

    Ok(ClusterReport {
        fleet: cspec.replicas,
        balancer: cspec.balancer,
        offered,
        admitted,
        dropped: spilled + replica_dropped,
        spilled,
        completed,
        unfinished: admitted - completed,
        duration,
        elapsed: tc,
        offered_rps: offered as f64 / dur_s,
        achieved_rps: completed as f64 / dur_s,
        latency,
        slo: spec.slo,
        slo_met,
        slo_attainment,
        per_replica,
        active_replicas: active_series,
        replica_seconds,
        autoscale_actions: scaler.map(|a| a.actions).unwrap_or_default(),
        final_active,
    })
}
