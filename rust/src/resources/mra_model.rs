//! MRA tile area composition: shared infrastructure + K cores + bridge
//! buffering.

use super::accel_db::{AccelArea, SHARED_TILE};
use super::fpga::Utilization;

/// Per-replica AXI-bridge buffering overhead: the four per-replica
/// AXI4-Stream FIFOs plus mux/demux logic. Small LUT/FF, no BRAM/DSP
/// (the skid buffers are LUTRAM at the paper's depths).
pub const BRIDGE_PER_REPLICA: Utilization = Utilization::new(0, 0, 0, 0);

/// Predicted utilization of a K-replica MRA tile for `accel`.
///
/// `MRA(K) = shared + K * (core + bridge_per_replica)`. With Table I's
/// data the bridge term is absorbed into the core figures (the fit's
/// residual is under 1.5%), so `BRIDGE_PER_REPLICA` defaults to zero and
/// exists as the hook for deeper-buffer design points in the DSE.
pub fn mra_area(accel: &AccelArea, k: usize) -> Utilization {
    SHARED_TILE.add(accel.core().add(BRIDGE_PER_REPLICA).scale(k as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I's 2x and 4x rows, for the accuracy check.
    const TABLE1_2X: [(&str, [u64; 4]); 5] = [
        ("adpcm", [16_455, 15_158, 48, 162]),
        ("dfadd", [16_988, 14_090, 2, 18]),
        ("dfmul", [11_352, 12_136, 2, 50]),
        ("dfsin", [27_770, 21_686, 2, 104]),
        ("gsm", [14_304, 14_520, 34, 124]),
    ];
    const TABLE1_4X: [(&str, [u64; 4]); 5] = [
        ("adpcm", [27_313, 21_780, 94, 324]),
        ("dfadd", [28_599, 19_614, 2, 36]),
        ("dfmul", [17_382, 15_706, 2, 100]),
        ("dfsin", [50_043, 34_804, 2, 208]),
        ("gsm", [22_927, 20_473, 66, 248]),
    ];

    #[test]
    fn k1_reproduces_baseline_exactly() {
        for a in AccelArea::db() {
            assert_eq!(mra_area(&a, 1), a.baseline_tile, "{}", a.name);
        }
    }

    #[test]
    fn dsp_scales_exactly_linearly() {
        // Table I: DSP increments are exactly 2x and 4x.
        for a in AccelArea::db() {
            assert_eq!(mra_area(&a, 2).dsp, 2 * a.baseline_tile.dsp);
            assert_eq!(mra_area(&a, 4).dsp, 4 * a.baseline_tile.dsp);
        }
    }

    fn assert_close(name: &str, what: &str, got: u64, want: u64, tol: f64) {
        let err = (got as f64 - want as f64).abs() / want as f64;
        assert!(
            err <= tol,
            "{name} {what}: predicted {got}, Table I {want} ({:.1}% off)",
            err * 100.0
        );
    }

    #[test]
    fn predicts_table1_2x_within_tolerance() {
        for (name, [lut, ff, bram, dsp]) in TABLE1_2X {
            let a = AccelArea::lookup(name).unwrap();
            let u = mra_area(&a, 2);
            assert_close(name, "LUT", u.lut, lut, 0.05);
            assert_close(name, "FF", u.ff, ff, 0.05);
            assert_eq!(u.dsp, dsp, "{name} DSP");
            if bram > 2 {
                assert_close(name, "BRAM", u.bram, bram, 0.05);
            }
        }
    }

    #[test]
    fn predicts_table1_4x_within_tolerance() {
        for (name, [lut, ff, bram, dsp]) in TABLE1_4X {
            let a = AccelArea::lookup(name).unwrap();
            let u = mra_area(&a, 4);
            assert_close(name, "LUT", u.lut, lut, 0.06);
            assert_close(name, "FF", u.ff, ff, 0.06);
            assert_eq!(u.dsp, dsp, "{name} DSP");
            if bram > 2 {
                assert_close(name, "BRAM", u.bram, bram, 0.10);
            }
        }
    }

    #[test]
    fn sublinear_lut_growth_as_in_paper() {
        // Average 2x LUT ratio ~1.50, 4x ~2.49 (Table I "Incr." row).
        let mut r2 = 0.0;
        let mut r4 = 0.0;
        for a in AccelArea::db() {
            r2 += mra_area(&a, 2).lut as f64 / a.baseline_tile.lut as f64;
            r4 += mra_area(&a, 4).lut as f64 / a.baseline_tile.lut as f64;
        }
        r2 /= 5.0;
        r4 /= 5.0;
        assert!((r2 - 1.50).abs() < 0.05, "2x LUT ratio {r2:.3}");
        assert!((r4 - 2.49).abs() < 0.10, "4x LUT ratio {r4:.3}");
    }
}
