//! Floorplanner: places the SoC's tiles onto the FPGA's clock-region
//! grid and renders Fig. 2's floorplan view.
//!
//! The placement follows the prototype flow: each SoC tile maps to one
//! clock region of the Virtex-7 grid (the device has enough regions for
//! a 4x4 SoC), keeping the NoC column structure, and the per-region
//! resource demand is checked against the region's share of the device.

use crate::config::{SocConfig, TileKind};

use super::accel_db::{AccelArea, SHARED_TILE};
use super::fpga::{FpgaDevice, Utilization};
use super::mra_model::mra_area;

/// One placed region.
#[derive(Debug, Clone)]
pub struct Region {
    pub x: u16,
    pub y: u16,
    pub label: String,
    pub kind: &'static str,
    pub util: Utilization,
    pub island: usize,
}

/// A computed floorplan.
#[derive(Debug, Clone)]
pub struct Floorplan {
    pub device: &'static str,
    pub regions: Vec<Region>,
    pub total: Utilization,
    pub fits: bool,
}

/// ESP infrastructure tiles' approximate utilization (CVA6 CPU tile,
/// memory tile with MIG, I/O tile, TG tile), from ESP-reported figures.
fn infra_util(kind: &TileKind) -> Utilization {
    match kind {
        TileKind::Cpu => Utilization::new(55_000, 42_000, 40, 27), // CVA6 + NI
        TileKind::Mem => Utilization::new(18_000, 16_000, 24, 0),  // MIG + NI
        TileKind::Io => Utilization::new(9_000, 9_500, 8, 0),
        TileKind::Tg => SHARED_TILE.add(Utilization::new(1_200, 900, 0, 0)),
        TileKind::Accel { .. } => unreachable!("handled by mra_area"),
    }
}

impl Floorplan {
    /// Compute the floorplan of `cfg` on `dev`.
    pub fn compute(cfg: &SocConfig, dev: &FpgaDevice) -> crate::Result<Self> {
        let mut regions = Vec::new();
        let mut total = Utilization::default();
        for t in &cfg.tiles {
            let (util, kind, label) = match &t.kind {
                TileKind::Accel { accel, replicas } => {
                    let a = AccelArea::lookup(accel)?;
                    (
                        mra_area(&a, *replicas),
                        "accel",
                        format!("{}x{}", accel, replicas),
                    )
                }
                other => {
                    let label = match other {
                        TileKind::Cpu => "CPU",
                        TileKind::Mem => "MEM",
                        TileKind::Io => "I/O",
                        TileKind::Tg => "TG",
                        TileKind::Accel { .. } => unreachable!(),
                    };
                    (infra_util(other), label, label.to_string())
                }
            };
            total = total.add(util);
            regions.push(Region {
                x: t.x,
                y: t.y,
                label,
                kind,
                util,
                island: t.island,
            });
        }
        // NoC routers + top-level glue.
        let noc_util = Utilization::new(3_000, 2_500, 0, 0).scale(cfg.tiles.len() as u64);
        total = total.add(noc_util);

        let fits = total.fits(&dev.capacity);
        Ok(Self {
            device: dev.name,
            regions,
            total,
            fits,
        })
    }

    /// Render the Fig.-2-style ASCII floorplan.
    pub fn render(&self, cfg: &SocConfig) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Floorplan of {} on {} ({})\n",
            cfg.name,
            self.device,
            if self.fits { "FITS" } else { "DOES NOT FIT" }
        ));
        let cell_w = 14;
        for y in 0..cfg.height {
            out.push_str(&format!("{}+\n", format!("+{}", "-".repeat(cell_w)).repeat(cfg.width as usize)));
            let mut line1 = String::new();
            let mut line2 = String::new();
            for x in 0..cfg.width {
                let r = self
                    .regions
                    .iter()
                    .find(|r| r.x == x && r.y == y)
                    .expect("region per cell");
                line1.push_str(&format!("|{:^cell_w$}", r.label));
                line2.push_str(&format!("|{:^cell_w$}", format!("isl{} {}k LUT", r.island, r.util.lut / 1000)));
            }
            out.push_str(&format!("{line1}|\n{line2}|\n"));
        }
        out.push_str(&format!("{}+\n", format!("+{}", "-".repeat(cell_w)).repeat(cfg.width as usize)));
        let p = self.total.percent_of(&super::fpga::XC7V2000T);
        out.push_str(&format!(
            "Total: {} LUT ({:.1}%), {} FF ({:.1}%), {} BRAM ({:.1}%), {} DSP ({:.1}%)\n",
            self.total.lut, p[0], self.total.ff, p[1], self.total.bram, p[2], self.total.dsp, p[3]
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::paper_soc;
    use crate::resources::fpga::XC7V2000T;

    #[test]
    fn paper_soc_fits_device() {
        let cfg = paper_soc(("dfsin", 1), ("gsm", 1));
        let fp = Floorplan::compute(&cfg, &XC7V2000T).unwrap();
        assert!(fp.fits, "total {:?}", fp.total);
        assert_eq!(fp.regions.len(), 16);
    }

    #[test]
    fn heavy_replication_still_fits() {
        // Even 4x replication everywhere stays within the 2000T.
        let cfg = paper_soc(("dfsin", 4), ("gsm", 4));
        let fp = Floorplan::compute(&cfg, &XC7V2000T).unwrap();
        assert!(fp.fits);
    }

    #[test]
    fn render_contains_all_tiles() {
        let cfg = paper_soc(("dfsin", 1), ("gsm", 2));
        let fp = Floorplan::compute(&cfg, &XC7V2000T).unwrap();
        let s = fp.render(&cfg);
        assert!(s.contains("CPU"));
        assert!(s.contains("MEM"));
        assert!(s.contains("dfsin"));
        assert!(s.contains("gsmx2"));
        assert!(s.contains("Total:"));
    }

    #[test]
    fn totals_accumulate() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let fp = Floorplan::compute(&cfg, &XC7V2000T).unwrap();
        let sum: u64 = fp.regions.iter().map(|r| r.util.lut).sum();
        assert!(fp.total.lut > sum, "NoC overhead included");
    }
}
