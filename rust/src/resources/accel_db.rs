//! Per-accelerator HLS characterization and the ESP tile shared-logic
//! constant.
//!
//! `BASELINE_TILE` figures are Table I's 1x columns: the full accelerator
//! *tile* (shared ESP infrastructure + one accelerator core) as reported
//! post-implementation by Vivado. `SHARED_TILE` is the ESP tile
//! infrastructure (NI, DMA, monitors, bridge base) — the intercept the
//! model uses to separate core from tile (DESIGN.md documents the
//! fitting: identical across all five accelerators to within ~1%).

use super::fpga::Utilization;

/// ESP accelerator-tile shared infrastructure.
pub const SHARED_TILE: Utilization = Utilization::new(5_484, 8_392, 2, 0);

/// One accelerator's characterization.
#[derive(Debug, Clone)]
pub struct AccelArea {
    pub name: &'static str,
    /// Full 1x tile utilization (Table I baseline columns).
    pub baseline_tile: Utilization,
    /// Table I baseline throughput in MB/s (for reporting only).
    pub baseline_thr_mbs: f64,
}

impl AccelArea {
    /// The five CHStone accelerators of the paper.
    pub fn db() -> Vec<AccelArea> {
        vec![
            AccelArea {
                name: "adpcm",
                baseline_tile: Utilization::new(10_899, 11_720, 25, 81),
                baseline_thr_mbs: 1.40,
            },
            AccelArea {
                name: "dfadd",
                baseline_tile: Utilization::new(11_268, 11_199, 2, 9),
                baseline_thr_mbs: 9.22,
            },
            AccelArea {
                name: "dfmul",
                baseline_tile: Utilization::new(8_435, 10_222, 2, 25),
                baseline_thr_mbs: 8.70,
            },
            AccelArea {
                name: "dfsin",
                baseline_tile: Utilization::new(16_627, 14_997, 2, 52),
                baseline_thr_mbs: 0.33,
            },
            AccelArea {
                name: "gsm",
                baseline_tile: Utilization::new(9_900, 11_418, 18, 62),
                baseline_thr_mbs: 4.61,
            },
        ]
    }

    pub fn lookup(name: &str) -> crate::Result<AccelArea> {
        Self::db()
            .into_iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("no area characterization for {name:?}"))
    }

    /// The accelerator *core* (baseline tile minus shared infrastructure).
    pub fn core(&self) -> Utilization {
        Utilization {
            lut: self.baseline_tile.lut - SHARED_TILE.lut,
            ff: self.baseline_tile.ff - SHARED_TILE.ff,
            bram: self.baseline_tile.bram - SHARED_TILE.bram,
            dsp: self.baseline_tile.dsp - SHARED_TILE.dsp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_complete() {
        assert_eq!(AccelArea::db().len(), 5);
        assert!(AccelArea::lookup("gsm").is_ok());
        assert!(AccelArea::lookup("x").is_err());
    }

    #[test]
    fn cores_are_positive() {
        for a in AccelArea::db() {
            let c = a.core();
            assert!(c.lut > 0, "{}", a.name);
            assert!(c.ff > 0, "{}", a.name);
            assert_eq!(c.dsp, a.baseline_tile.dsp, "DSPs all in the core");
        }
    }

    #[test]
    fn baseline_under_paper_utilization_caps() {
        // §III-A: each baseline accelerator tile occupies up to 1.4% LUT,
        // 0.6% FF, 1.0% BRAM, 3.8% DSP of the Virtex-7 2000T.
        use super::super::fpga::XC7V2000T;
        for a in AccelArea::db() {
            let p = a.baseline_tile.percent_of(&XC7V2000T);
            assert!(p[0] <= 1.4 + 0.01, "{} LUT {:.2}%", a.name, p[0]);
            assert!(p[1] <= 0.6 + 0.02, "{} FF {:.2}%", a.name, p[1]);
            assert!(p[2] <= 1.0 + 0.01, "{} BRAM {:.2}%", a.name, p[2]);
            assert!(p[3] <= 3.8 + 0.01, "{} DSP {:.2}%", a.name, p[3]);
        }
    }
}
