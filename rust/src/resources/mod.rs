//! FPGA resource model and floorplanner.
//!
//! Reproduces Table I's area columns and Fig. 2's floorplan. The model
//! is compositional: a tile's utilization is the ESP tile *shared*
//! infrastructure (NI, DMA engines, monitors — constant across
//! accelerators) plus `K` times the accelerator *core* (from the
//! per-accelerator HLS characterization DB). See DESIGN.md for the
//! derivation: Table I's own 1x/2x/4x rows are affine in K with a
//! shared-logic intercept that is the same (±1%) for all five
//! accelerators — LUT ~5.5k, FF ~8.4k, BRAM 2 — which is exactly the
//! ESP tile overhead this model encodes.

pub mod accel_db;
pub mod floorplan;
pub mod fpga;
pub mod mra_model;

pub use accel_db::{AccelArea, SHARED_TILE};
pub use floorplan::{Floorplan, Region};
pub use fpga::{FpgaDevice, Utilization, XC7V2000T};
pub use mra_model::mra_area;
