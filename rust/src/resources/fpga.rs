//! FPGA device capacities and utilization accounting.

/// Resource vector (LUT, FF, BRAM18, DSP).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Utilization {
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub dsp: u64,
}

impl Utilization {
    pub const fn new(lut: u64, ff: u64, bram: u64, dsp: u64) -> Self {
        Self { lut, ff, bram, dsp }
    }

    pub fn add(self, o: Self) -> Self {
        Self {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            dsp: self.dsp + o.dsp,
        }
    }

    pub fn scale(self, k: u64) -> Self {
        Self {
            lut: self.lut * k,
            ff: self.ff * k,
            bram: self.bram * k,
            dsp: self.dsp * k,
        }
    }

    /// Fraction of the device per resource, as percentages.
    pub fn percent_of(&self, dev: &FpgaDevice) -> [f64; 4] {
        [
            100.0 * self.lut as f64 / dev.capacity.lut as f64,
            100.0 * self.ff as f64 / dev.capacity.ff as f64,
            100.0 * self.bram as f64 / dev.capacity.bram as f64,
            100.0 * self.dsp as f64 / dev.capacity.dsp as f64,
        ]
    }

    /// Whether this fits within `cap`.
    pub fn fits(&self, cap: &Utilization) -> bool {
        self.lut <= cap.lut && self.ff <= cap.ff && self.bram <= cap.bram && self.dsp <= cap.dsp
    }
}

/// An FPGA device.
#[derive(Debug, Clone)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub capacity: Utilization,
    pub mmcms: u32,
    /// Clock regions (rows x cols) for floorplanning.
    pub regions: (u16, u16),
}

/// The paper's target: AMD Virtex-7 2000T (§III).
pub const XC7V2000T: FpgaDevice = FpgaDevice {
    name: "xc7v2000t",
    capacity: Utilization::new(1_221_600, 2_443_200, 2_584, 2_160),
    mmcms: 24,
    regions: (4, 4),
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_matches_paper() {
        assert_eq!(XC7V2000T.capacity.lut, 1_221_600);
        assert_eq!(XC7V2000T.capacity.ff, 2_443_200);
        assert_eq!(XC7V2000T.capacity.bram, 2_584);
        assert_eq!(XC7V2000T.capacity.dsp, 2_160);
        assert_eq!(XC7V2000T.mmcms, 24);
    }

    #[test]
    fn arithmetic() {
        let a = Utilization::new(1, 2, 3, 4);
        let b = a.scale(2).add(a);
        assert_eq!(b, Utilization::new(3, 6, 9, 12));
        assert!(a.fits(&b));
        assert!(!b.fits(&a));
    }

    #[test]
    fn percentages() {
        let u = Utilization::new(12_216, 0, 0, 0);
        let p = u.percent_of(&XC7V2000T);
        assert!((p[0] - 1.0).abs() < 1e-9);
    }
}
