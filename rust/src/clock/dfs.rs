//! DFS actuators.
//!
//! [`DualMmcmActuator`] is the paper's glitch-free design: a master and a
//! slave MMCM plus an output mux. A frequency request reprograms the
//! *slave* while the master keeps driving the island; when the slave
//! locks, the mux swaps roles. The island's clock therefore never stops —
//! it merely changes period at the swap instant.
//!
//! [`SingleMmcmActuator`] is the naive baseline §II-B warns about: one
//! MMCM whose output is held low for the entire reconfiguration, gating
//! the island's clock. It exists for the `dfs_ablation` bench, which
//! measures exactly how many island cycles the naive design loses.

use crate::util::time::{Freq, Ps};

use super::mmcm::Mmcm;

/// Common interface of the two actuator designs.
pub trait DfsActuator {
    /// Request a new output frequency at time `now`.
    ///
    /// Returns the time at which the new frequency takes effect. Requests
    /// made while a previous one is still in flight supersede it.
    fn request(&mut self, target: Freq, now: Ps) -> Ps;

    /// Advance internal FSM state to `now`.
    fn tick(&mut self, now: Ps);

    /// Output frequency at `now`; `None` means the clock is gated
    /// (dead output — only the naive actuator ever returns this).
    fn output(&self, now: Ps) -> Option<Freq>;

    /// True while a frequency change is still in flight.
    fn busy(&self, now: Ps) -> bool;

    /// Total dead-clock time accumulated so far (ablation metric).
    fn dead_time(&self) -> Ps;
}

/// FSM states of the dual-MMCM actuator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DualState {
    /// Master drives the output; slave idle.
    Idle,
    /// Slave reprogramming; master still drives. Swap at `swap_at`.
    Reprogramming { swap_at: Ps },
}

/// The paper's glitch-free dual-MMCM DFS actuator.
#[derive(Debug, Clone)]
pub struct DualMmcmActuator {
    master: Mmcm,
    slave: Mmcm,
    state: DualState,
    /// Number of completed frequency switches.
    switches: u64,
}

impl DualMmcmActuator {
    pub fn new(initial: Freq) -> Self {
        Self {
            master: Mmcm::new(initial),
            slave: Mmcm::new(initial),
            state: DualState::Idle,
            switches: 0,
        }
    }

    /// Override MMCM timings (tests / sensitivity studies).
    pub fn with_timings(initial: Freq, reconfig: Ps, lock: Ps) -> Self {
        Self {
            master: Mmcm::with_timings(initial, reconfig, lock),
            slave: Mmcm::with_timings(initial, reconfig, lock),
            state: DualState::Idle,
            switches: 0,
        }
    }

    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Time of the pending master/slave swap, if a reconfiguration is in
    /// flight. The idle-aware engine must not coalesce a span across
    /// this instant: the island's period changes there.
    pub fn pending_swap(&self) -> Option<Ps> {
        match self.state {
            DualState::Idle => None,
            DualState::Reprogramming { swap_at } => Some(swap_at),
        }
    }

    /// The latency of one frequency change (request -> effect).
    pub fn switch_latency(&self) -> Ps {
        self.slave.reconfig_latency()
    }
}

impl DfsActuator for DualMmcmActuator {
    fn request(&mut self, target: Freq, now: Ps) -> Ps {
        // Fold any pending swap first so a rapid re-request chains
        // correctly off the *current* master.
        self.tick(now);
        let swap_at = self.slave.start_reconfig(target, now);
        self.state = DualState::Reprogramming { swap_at };
        swap_at
    }

    fn tick(&mut self, now: Ps) {
        self.master.tick(now);
        self.slave.tick(now);
        if let DualState::Reprogramming { swap_at } = self.state {
            if now >= swap_at {
                // Slave locked: swap roles. Output glitch-free retimes to
                // the new period from `swap_at`.
                core::mem::swap(&mut self.master, &mut self.slave);
                self.state = DualState::Idle;
                self.switches += 1;
            }
        }
    }

    fn output(&self, now: Ps) -> Option<Freq> {
        match self.state {
            DualState::Idle => self.master.output(now),
            DualState::Reprogramming { swap_at } => {
                if now >= swap_at {
                    // Swap is due but tick() hasn't run yet: the slave's
                    // (locked) frequency is already driving the mux.
                    self.slave.output(now)
                } else {
                    self.master.output(now)
                }
            }
        }
    }

    fn busy(&self, now: Ps) -> bool {
        matches!(self.state, DualState::Reprogramming { swap_at } if now < swap_at)
    }

    fn dead_time(&self) -> Ps {
        // The mux always selects a locked MMCM: never dead.
        0
    }
}

/// Naive single-MMCM actuator: reconfiguration gates the island clock.
#[derive(Debug, Clone)]
pub struct SingleMmcmActuator {
    mmcm: Mmcm,
}

impl SingleMmcmActuator {
    pub fn new(initial: Freq) -> Self {
        Self {
            mmcm: Mmcm::new(initial),
        }
    }

    pub fn with_timings(initial: Freq, reconfig: Ps, lock: Ps) -> Self {
        Self {
            mmcm: Mmcm::with_timings(initial, reconfig, lock),
        }
    }
}

impl DfsActuator for SingleMmcmActuator {
    fn request(&mut self, target: Freq, now: Ps) -> Ps {
        self.mmcm.start_reconfig(target, now)
    }

    fn tick(&mut self, now: Ps) {
        self.mmcm.tick(now);
    }

    fn output(&self, now: Ps) -> Option<Freq> {
        self.mmcm.output(now)
    }

    fn busy(&self, now: Ps) -> bool {
        self.mmcm.output(now).is_none()
    }

    fn dead_time(&self) -> Ps {
        self.mmcm.dead_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_keeps_clock_alive_during_reconfig() {
        let mut a = DualMmcmActuator::with_timings(Freq::mhz(50), 1_000, 9_000);
        let eff = a.request(Freq::mhz(100), 0);
        assert_eq!(eff, 10_000);
        // Mid-reconfig the OLD frequency still drives the island.
        assert_eq!(a.output(5_000), Some(Freq::mhz(50)));
        assert!(a.busy(5_000));
        a.tick(10_000);
        assert_eq!(a.output(10_000), Some(Freq::mhz(100)));
        assert!(!a.busy(10_000));
        assert_eq!(a.dead_time(), 0);
        assert_eq!(a.switches(), 1);
    }

    #[test]
    fn single_gates_clock_during_reconfig() {
        let mut a = SingleMmcmActuator::with_timings(Freq::mhz(50), 1_000, 9_000);
        a.request(Freq::mhz(100), 0);
        assert_eq!(a.output(5_000), None); // dead clock!
        a.tick(10_000);
        assert_eq!(a.output(10_000), Some(Freq::mhz(100)));
        assert_eq!(a.dead_time(), 10_000);
    }

    #[test]
    fn dual_back_to_back_requests() {
        let mut a = DualMmcmActuator::with_timings(Freq::mhz(10), 1_000, 1_000);
        a.request(Freq::mhz(20), 0);
        a.tick(2_000); // swap to 20 MHz
        assert_eq!(a.output(2_000), Some(Freq::mhz(20)));
        let eff = a.request(Freq::mhz(30), 2_000);
        assert_eq!(eff, 4_000);
        assert_eq!(a.output(3_000), Some(Freq::mhz(20)));
        a.tick(4_000);
        assert_eq!(a.output(4_000), Some(Freq::mhz(30)));
        assert_eq!(a.switches(), 2);
    }

    #[test]
    fn dual_supersede_mid_flight() {
        let mut a = DualMmcmActuator::with_timings(Freq::mhz(10), 1_000, 1_000);
        a.request(Freq::mhz(20), 0);
        // Supersede before the swap: final frequency must be 40.
        a.request(Freq::mhz(40), 1_000);
        a.tick(3_000);
        assert_eq!(a.output(3_000), Some(Freq::mhz(40)));
        // Clock was alive the whole time.
        assert_eq!(a.dead_time(), 0);
    }

    #[test]
    fn output_at_exact_swap_instant_without_tick() {
        let mut a = DualMmcmActuator::with_timings(Freq::mhz(10), 500, 500);
        a.request(Freq::mhz(80), 0);
        // No tick() at 1_000, but output must already be the new freq.
        assert_eq!(a.output(1_000), Some(Freq::mhz(80)));
    }
}
