//! Frequency islands as clock domains driven by fixed clocks or DFS
//! actuators, plus the edge arithmetic the simulation engine uses.

use crate::util::time::{Freq, Ps};

use super::dfs::{DfsActuator, DualMmcmActuator};

/// Index of a frequency island in the SoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct IslandId(pub usize);

/// Clock source of an island.
#[derive(Debug, Clone)]
pub enum ClockSource {
    /// Fixed frequency wired at design time.
    Fixed(Freq),
    /// Run-time reprogrammable dual-MMCM DFS actuator.
    Dfs(DualMmcmActuator),
}

/// One frequency island's clock domain state.
///
/// The engine advances each island edge-by-edge: [`next_edge`] returns
/// the time of the next rising edge strictly after `now`, honouring any
/// in-flight DFS retiming (a frequency change re-phases the clock at the
/// actuator's swap instant).
#[derive(Debug, Clone)]
pub struct ClockDomain {
    pub id: IslandId,
    pub name: String,
    source: ClockSource,
    /// Time of the most recent rising edge (phase reference).
    last_edge: Ps,
    /// Cycle counter (edges delivered).
    pub cycles: u64,
    /// Frequency bounds for run-time requests (from config).
    pub min: Freq,
    pub max: Freq,
    pub step_mhz: u64,
    /// Injected stuck-actuator fault windows (sorted, disjoint):
    /// `request_freq` fails inside a window. Empty outside chaos runs
    /// ([`crate::fault`]).
    stuck_windows: Vec<(Ps, Ps)>,
}

impl ClockDomain {
    pub fn fixed(id: IslandId, name: impl Into<String>, freq: Freq) -> Self {
        Self {
            id,
            name: name.into(),
            source: ClockSource::Fixed(freq),
            last_edge: 0,
            cycles: 0,
            min: freq,
            max: freq,
            step_mhz: 5,
            stuck_windows: Vec::new(),
        }
    }

    pub fn dfs(
        id: IslandId,
        name: impl Into<String>,
        initial: Freq,
        min: Freq,
        max: Freq,
        step_mhz: u64,
    ) -> Self {
        Self {
            id,
            name: name.into(),
            source: ClockSource::Dfs(DualMmcmActuator::new(initial)),
            last_edge: 0,
            cycles: 0,
            min,
            max,
            step_mhz,
            stuck_windows: Vec::new(),
        }
    }

    /// Install stuck-actuator fault windows ([`crate::fault`]);
    /// merged with any already present.
    pub fn add_stuck_windows(&mut self, windows: &[(Ps, Ps)]) {
        self.stuck_windows.extend_from_slice(windows);
        crate::fault::normalize_windows(&mut self.stuck_windows);
    }

    /// DFS-capable islands accept run-time frequency requests.
    pub fn has_dfs(&self) -> bool {
        matches!(self.source, ClockSource::Dfs(_))
    }

    /// Current output frequency at `now`.
    pub fn freq(&self, now: Ps) -> Freq {
        match &self.source {
            ClockSource::Fixed(f) => *f,
            ClockSource::Dfs(a) => a
                .output(now)
                .expect("dual-MMCM actuator output is never dead"),
        }
    }

    /// Current period at `now`.
    pub fn period(&self, now: Ps) -> Ps {
        self.freq(now).period_ps()
    }

    /// Request a frequency change. Returns `Err` if the island is fixed
    /// or the frequency violates the island's configured range/step.
    /// On success returns the time the change takes effect.
    pub fn request_freq(&mut self, target: Freq, now: Ps) -> Result<Ps, FreqError> {
        if let Some(until) = crate::fault::window_until(&self.stuck_windows, now) {
            return Err(FreqError::ActuatorStuck { until });
        }
        if target < self.min || target > self.max {
            return Err(FreqError::OutOfRange {
                target,
                min: self.min,
                max: self.max,
            });
        }
        if self.step_mhz > 0 && (target.as_mhz() - self.min.as_mhz()) % self.step_mhz != 0 {
            return Err(FreqError::OffGrid {
                target,
                step_mhz: self.step_mhz,
            });
        }
        match &mut self.source {
            ClockSource::Fixed(_) => Err(FreqError::NoDfs),
            ClockSource::Dfs(a) => Ok(a.request(target, now)),
        }
    }

    /// Advance actuator FSM state to `now`.
    pub fn tick_actuator(&mut self, now: Ps) {
        if let ClockSource::Dfs(a) = &mut self.source {
            a.tick(now);
        }
    }

    /// Time of the next rising edge strictly after `now`.
    ///
    /// The phase reference is the last delivered edge; if the period
    /// changed since (DFS swap), the next edge lands one *new* period
    /// after the later of (last edge, swap time) — matching the BUFGMUX
    /// behaviour of re-phasing on the first post-swap edge.
    pub fn next_edge(&self, now: Ps) -> Ps {
        let p = self.period(now);
        if now < self.last_edge {
            return self.last_edge;
        }
        // Smallest last_edge + k*p strictly after `now`. After a DFS swap
        // the new period re-anchors at the last delivered edge (first
        // post-swap edge re-phases, as a BUFGMUX output would).
        let k = (now - self.last_edge) / p + 1;
        self.last_edge + k * p
    }

    /// Record that the engine delivered the edge at `t`.
    pub fn edge_delivered(&mut self, t: Ps) {
        debug_assert!(t >= self.last_edge);
        self.last_edge = t;
        self.cycles += 1;
        self.tick_actuator(t);
    }

    /// Time of the most recent delivered edge (phase anchor).
    pub fn last_edge(&self) -> Ps {
        self.last_edge
    }

    /// Time of a pending DFS retiming (actuator swap), if any.
    pub fn pending_retime(&self) -> Option<Ps> {
        match &self.source {
            ClockSource::Fixed(_) => None,
            ClockSource::Dfs(a) => a.pending_swap(),
        }
    }

    /// Bulk-deliver every edge at or before `until`, in one step.
    ///
    /// Equivalent to repeated `next_edge` + `edge_delivered` under the
    /// engine-guaranteed precondition that no DFS retiming lands inside
    /// `(last_edge, until]` — the period is then constant over the span,
    /// and delivering the actuator tick once at the final edge matches
    /// delivering it at every edge (the actuator FSM is time-based and
    /// transition-free across the span). Returns the edges delivered.
    pub fn advance_span(&mut self, until: Ps) -> u64 {
        debug_assert!(self.pending_retime().is_none_or(|swap| swap > until));
        if until <= self.last_edge {
            return 0;
        }
        let p = self.period(self.last_edge);
        let k = (until - self.last_edge) / p;
        if k > 0 {
            self.last_edge += k * p;
            self.cycles += k;
            self.tick_actuator(self.last_edge);
        }
        k
    }

    /// Dead-clock time (0 for fixed and dual-MMCM islands).
    pub fn dead_time(&self) -> Ps {
        match &self.source {
            ClockSource::Fixed(_) => 0,
            ClockSource::Dfs(a) => a.dead_time(),
        }
    }
}

/// Errors from run-time frequency requests.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum FreqError {
    #[error("island has no DFS actuator (fixed clock)")]
    NoDfs,
    #[error("target {target} outside island range [{min}, {max}]")]
    OutOfRange { target: Freq, min: Freq, max: Freq },
    #[error("target {target} not on the {step_mhz}MHz step grid")]
    OffGrid { target: Freq, step_mhz: u64 },
    #[error("DFS actuator stuck (injected fault) until {until} ps")]
    ActuatorStuck { until: Ps },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_domain_edges() {
        let mut d = ClockDomain::fixed(IslandId(0), "noc", Freq::mhz(100));
        assert_eq!(d.next_edge(0), 10_000);
        d.edge_delivered(10_000);
        assert_eq!(d.next_edge(10_000), 20_000);
        assert_eq!(d.cycles, 1);
    }

    #[test]
    fn fixed_domain_rejects_dfs_request() {
        let mut d = ClockDomain::fixed(IslandId(0), "noc", Freq::mhz(100));
        assert_eq!(
            d.request_freq(Freq::mhz(100), 0).unwrap_err(),
            FreqError::NoDfs
        );
    }

    #[test]
    fn dfs_domain_range_checks() {
        let mut d = ClockDomain::dfs(
            IslandId(1),
            "a1",
            Freq::mhz(50),
            Freq::mhz(10),
            Freq::mhz(50),
            5,
        );
        assert!(matches!(
            d.request_freq(Freq::mhz(60), 0),
            Err(FreqError::OutOfRange { .. })
        ));
        assert!(matches!(
            d.request_freq(Freq::mhz(12), 0),
            Err(FreqError::OffGrid { .. })
        ));
        assert!(d.request_freq(Freq::mhz(30), 0).is_ok());
    }

    #[test]
    fn dfs_retimes_edges_after_switch() {
        let mut d = ClockDomain::dfs(
            IslandId(1),
            "a1",
            Freq::mhz(10), // 100 000 ps period
            Freq::mhz(10),
            Freq::mhz(100),
            5,
        );
        let eff = d.request_freq(Freq::mhz(100), 0).unwrap();
        // Until the actuator swaps, edges run at 10 MHz.
        let mut t = 0;
        while t < eff {
            let e = d.next_edge(t);
            assert_eq!(e - t, 100_000, "old period before swap");
            d.edge_delivered(e);
            t = e;
        }
        // After the swap the period is 10 000 ps.
        let e = d.next_edge(t);
        assert_eq!(e - t, 10_000, "new period after swap at {t}");
    }

    #[test]
    fn advance_span_matches_edge_by_edge() {
        let mk = || ClockDomain::fixed(IslandId(0), "x", Freq::mhz(37));
        let mut a = mk();
        let mut t = 0;
        for _ in 0..123 {
            t = a.next_edge(t);
            a.edge_delivered(t);
        }
        let mut b = mk();
        assert_eq!(b.advance_span(t), 123);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.last_edge(), b.last_edge());
        assert_eq!(a.next_edge(t), b.next_edge(t));
        // A target strictly between edges delivers the same count.
        let mut c = mk();
        c.advance_span(t + 1);
        assert_eq!(c.cycles, 123);
        // A target before the next edge delivers nothing.
        assert_eq!(b.advance_span(t), 0);
    }

    #[test]
    fn pending_retime_visible_until_swap() {
        let mut d = ClockDomain::dfs(
            IslandId(1),
            "a1",
            Freq::mhz(50),
            Freq::mhz(10),
            Freq::mhz(50),
            5,
        );
        assert_eq!(d.pending_retime(), None);
        let eff = d.request_freq(Freq::mhz(10), 0).unwrap();
        assert_eq!(d.pending_retime(), Some(eff));
        // Spans may bulk-advance right up to (not across) the swap.
        d.advance_span(eff - 1);
        assert_eq!(d.pending_retime(), Some(eff));
        d.edge_delivered(eff);
        assert_eq!(d.pending_retime(), None);
    }

    #[test]
    fn stuck_actuator_rejects_requests_inside_window() {
        let mut d = ClockDomain::dfs(
            IslandId(1),
            "a1",
            Freq::mhz(50),
            Freq::mhz(10),
            Freq::mhz(50),
            5,
        );
        d.add_stuck_windows(&[(1_000, 2_000)]);
        assert!(d.request_freq(Freq::mhz(30), 500).is_ok());
        assert!(matches!(
            d.request_freq(Freq::mhz(20), 1_500),
            Err(FreqError::ActuatorStuck { until: 2_000 })
        ));
        assert!(d.request_freq(Freq::mhz(20), 2_000).is_ok(), "window is half-open");
    }

    #[test]
    fn cycle_count_monotonic() {
        let mut d = ClockDomain::fixed(IslandId(0), "x", Freq::mhz(50));
        let mut t = 0;
        for i in 1..=100 {
            t = d.next_edge(t);
            d.edge_delivered(t);
            assert_eq!(d.cycles, i);
        }
        assert_eq!(t, 100 * 20_000);
    }
}
