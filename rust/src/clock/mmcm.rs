//! Behavioural model of an AMD 7-series MMCM (mixed-mode clock manager).
//!
//! Two properties matter to the paper:
//!
//! 1. Reprogramming the M/D dividers goes through the dynamic
//!    reconfiguration port (DRP) and takes a fixed programming time, after
//!    which the PLL re-locks (`MMCM_LOCK_TIME_PS`).
//! 2. **While reconfiguring, the output clock stays low** — the
//!    clock-gating effect §II-B describes. A naive single-MMCM DFS
//!    actuator therefore freezes its whole island for the reconfiguration
//!    window; Vespa's dual-MMCM actuator hides it.

use crate::util::time::{Freq, Ps};

/// DRP programming sequence duration. ~23 DRP writes at the 50 MHz DRP
/// clock plus FSM overhead; 1 us is representative for 7-series.
pub const MMCM_RECONFIG_TIME_PS: Ps = 1_000_000;

/// Post-programming lock time. 7-series datasheet worst case is ~100 us;
/// typical observed lock for small M/D changes is tens of us. We use
/// 10 us so benches run quickly; the value is configurable per actuator.
pub const MMCM_LOCK_TIME_PS: Ps = 10_000_000;

/// MMCM operating state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmcmState {
    /// Output clock running at the contained frequency.
    Locked(Freq),
    /// DRP programming + lock in progress; output is held LOW until
    /// `done_at`. The target frequency takes effect at `done_at`.
    Reconfiguring { target: Freq, done_at: Ps },
}

/// One MMCM instance.
#[derive(Debug, Clone)]
pub struct Mmcm {
    state: MmcmState,
    reconfig_time: Ps,
    lock_time: Ps,
    /// Total picoseconds spent with the output dead (for the ablation).
    dead_time: Ps,
}

impl Mmcm {
    /// A locked MMCM outputting `freq`, with default 7-series timings.
    pub fn new(freq: Freq) -> Self {
        Self::with_timings(freq, MMCM_RECONFIG_TIME_PS, MMCM_LOCK_TIME_PS)
    }

    /// Override reconfiguration/lock durations (tests, sensitivity benches).
    pub fn with_timings(freq: Freq, reconfig_time: Ps, lock_time: Ps) -> Self {
        Self {
            state: MmcmState::Locked(freq),
            reconfig_time,
            lock_time,
            dead_time: 0,
        }
    }

    pub fn state(&self) -> MmcmState {
        self.state
    }

    /// Begin DRP reprogramming to `target` at time `now`. Returns the
    /// completion (re-lock) time. Reprogramming an already-reconfiguring
    /// MMCM restarts the sequence (as the hardware FSM would).
    pub fn start_reconfig(&mut self, target: Freq, now: Ps) -> Ps {
        // Account any residual dead time from an aborted reconfiguration.
        if let MmcmState::Reconfiguring { done_at, .. } = self.state {
            let started = done_at - self.reconfig_time - self.lock_time;
            self.dead_time += now.saturating_sub(started);
        }
        let done_at = now + self.reconfig_time + self.lock_time;
        self.state = MmcmState::Reconfiguring { target, done_at };
        done_at
    }

    /// Advance internal state to `now` (completes a pending reconfig).
    pub fn tick(&mut self, now: Ps) {
        if let MmcmState::Reconfiguring { target, done_at } = self.state {
            if now >= done_at {
                self.dead_time += self.reconfig_time + self.lock_time;
                self.state = MmcmState::Locked(target);
            }
        }
    }

    /// Output frequency at `now`, or `None` while the output is dead.
    pub fn output(&self, now: Ps) -> Option<Freq> {
        match self.state {
            MmcmState::Locked(f) => Some(f),
            MmcmState::Reconfiguring { target, done_at } => {
                if now >= done_at {
                    Some(target)
                } else {
                    None
                }
            }
        }
    }

    /// Whether the MMCM is locked (output valid) at `now`.
    pub fn locked(&self, now: Ps) -> bool {
        self.output(now).is_some()
    }

    /// Total dead-output time accumulated by completed reconfigurations.
    pub fn dead_time(&self) -> Ps {
        self.dead_time
    }

    pub fn reconfig_latency(&self) -> Ps {
        self.reconfig_time + self.lock_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locked_output() {
        let m = Mmcm::new(Freq::mhz(50));
        assert_eq!(m.output(0), Some(Freq::mhz(50)));
        assert!(m.locked(123));
    }

    #[test]
    fn output_dead_during_reconfig() {
        let mut m = Mmcm::with_timings(Freq::mhz(50), 1_000, 9_000);
        let done = m.start_reconfig(Freq::mhz(100), 100);
        assert_eq!(done, 100 + 10_000);
        assert_eq!(m.output(100), None);
        assert_eq!(m.output(done - 1), None);
        assert_eq!(m.output(done), Some(Freq::mhz(100)));
    }

    #[test]
    fn tick_completes_and_counts_dead_time() {
        let mut m = Mmcm::with_timings(Freq::mhz(20), 2_000, 8_000);
        m.start_reconfig(Freq::mhz(40), 0);
        m.tick(5_000);
        assert_eq!(m.output(5_000), None);
        m.tick(10_000);
        assert_eq!(m.state(), MmcmState::Locked(Freq::mhz(40)));
        assert_eq!(m.dead_time(), 10_000);
    }

    #[test]
    fn restart_reconfig_accumulates_dead_time() {
        let mut m = Mmcm::with_timings(Freq::mhz(20), 1_000, 1_000);
        m.start_reconfig(Freq::mhz(40), 0);
        // Abort at t=1500 by reprogramming to a third frequency.
        m.start_reconfig(Freq::mhz(60), 1_500);
        m.tick(3_500);
        assert_eq!(m.output(3_500), Some(Freq::mhz(60)));
        // 1500 aborted + 2000 completed.
        assert_eq!(m.dead_time(), 3_500);
    }
}
