//! Clocking subsystem: frequency islands, MMCM models, DFS actuators,
//! and clock-domain-crossing resynchronizers.
//!
//! This is the paper's contribution 2. Every tile and NoC router belongs
//! to a *frequency island*; each island's clock is either fixed or driven
//! by a [`dfs::DualMmcmActuator`] that reprograms one of two MMCMs while
//! the other keeps the output clock alive, then swaps — so the island
//! never sees a dead clock (unlike the naive single-MMCM approach, whose
//! clock-gating effect [`mmcm::Mmcm`] also models for the ablation bench).

pub mod dfs;
pub mod domain;
pub mod mmcm;
pub mod resync;

pub use dfs::{DfsActuator, DualMmcmActuator, SingleMmcmActuator};
pub use domain::{ClockDomain, IslandId};
pub use mmcm::{Mmcm, MmcmState, MMCM_LOCK_TIME_PS, MMCM_RECONFIG_TIME_PS};
pub use resync::cdc_delay;
