//! Clock-domain-crossing resynchronizers (the *Resync* blocks of Fig. 1).
//!
//! Vespa places dual-clock FIFOs with 2-flop synchronizers at every
//! frequency-island boundary. The timing model: a word written in the
//! source domain at time `t` becomes visible to the destination domain at
//! the second destination rising edge at or after `t` (gray-code pointer
//! + 2-flop metastability chain), i.e. between 1 and 2+ destination
//! periods of added latency depending on phase.

use crate::util::time::Ps;

/// Earliest time a value crossing into a destination domain with period
/// `dst_period` (whose edges are anchored at `dst_last_edge`) can be
/// consumed, given it was produced at `t_src`.
///
/// `sync_stages` is the synchronizer depth (2 for the standard 2-flop).
pub fn cdc_delay(t_src: Ps, dst_last_edge: Ps, dst_period: Ps, sync_stages: u64) -> Ps {
    debug_assert!(dst_period > 0);
    // First destination edge strictly after t_src.
    let first = if t_src < dst_last_edge {
        dst_last_edge
    } else {
        let elapsed = t_src - dst_last_edge;
        let k = elapsed / dst_period + 1;
        dst_last_edge + k * dst_period
    };
    first + sync_stages.saturating_sub(1) * dst_period
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn same_phase_crossing() {
        // dst edges at 0, 100, 200...; produced at t=50 -> first edge 100,
        // 2-flop -> visible at 200.
        assert_eq!(cdc_delay(50, 0, 100, 2), 200);
    }

    #[test]
    fn production_on_edge_waits_full_cycle() {
        // Produced exactly on an edge: captured on the *next* edge.
        assert_eq!(cdc_delay(100, 0, 100, 2), 300);
    }

    #[test]
    fn one_stage_sync() {
        assert_eq!(cdc_delay(50, 0, 100, 1), 100);
    }

    #[test]
    fn src_before_dst_history() {
        // Destination edge anchor in the future (domain just retimed).
        assert_eq!(cdc_delay(10, 500, 100, 2), 600);
    }

    #[test]
    fn prop_delay_bounds() {
        // Latency is always in (sync_stages-1, sync_stages+1] dst periods.
        forall(
            0xCDC,
            500,
            |r| {
                let period = (r.next_below(99) + 1) * 1000;
                let anchor = r.next_below(10) * period;
                let t = anchor + r.next_below(20 * period);
                (t, anchor, period)
            },
            |&(t, anchor, period)| {
                let out = cdc_delay(t, anchor, period, 2);
                assert!(out > t, "visible strictly after production");
                assert!(out - t <= 2 * period, "at most 2 dst periods");
                assert!(out - t >= 1, "non-zero latency");
                // Result lands on a destination edge.
                assert_eq!((out - anchor) % period, 0);
            },
        );
    }
}
