//! Deterministic fault injection and serving-layer resilience.
//!
//! A [`FaultPlan`] is a list of typed fault events — accelerator
//! hang/slowdown ([`Fault::TileHang`]/[`Fault::TileSlow`]), link
//! flap/degrade ([`Fault::LinkFlap`]/[`Fault::LinkDegrade`]),
//! stuck DFS actuator ([`Fault::ActuatorStuck`]), and whole-replica
//! crash ([`Fault::ReplicaCrash`], cluster only) — at scheduled or
//! seed-drawn instants ([`Fault::RandomCrashes`], via
//! [`util::rng`](crate::util::rng)). Plans compile to per-component
//! *stall windows* that are installed into the simulated hardware
//! **before** the run starts (tiles, link FIFOs, clock domains), so a
//! fault fires at an exact simulated instant regardless of the host
//! loop's engine mode or worker-thread count: same seed + spec + plan
//! ⇒ bit-identical reports, and an empty plan is bit-identical to a
//! build without faults at all.
//!
//! The resilience half lives next to the machinery it protects:
//!
//! * [`RetrySpec`] — per-request deadlines with bounded retry +
//!   exponential backoff at the serve admission gate
//!   ([`ServeSpec::retry`](crate::serve::ServeSpec));
//! * [`HealthSpec`] — health-check-driven eviction of wedged replicas
//!   and warm-standby replacement of crashed ones in the cluster
//!   engine ([`ClusterSpec::health`](crate::cluster::ClusterSpec)),
//!   reusing the shared snapshot warm base;
//! * [`FaultLedger`] — injected/detected/retried/failed-over/evicted
//!   and requests lost vs. rescued, threaded into
//!   [`ServeReport`](crate::serve::ServeReport) and
//!   [`ClusterReport`](crate::cluster::ClusterReport).
//!
//! See `docs/API.md` ("Fault injection & resilience") for the textual
//! `--faults` grammar and the retry/backoff semantics, and
//! `docs/PERF.md` for the chaos-bench notes.

use crate::util::rng::SplitMix64;
use crate::util::Ps;

/// Seed salt for randomly drawn fault instants, so a plan's draws are
/// decorrelated from the arrival stream built from the same user seed.
const FAULT_SEED_SALT: u64 = 0x9A3C_F0D6_5EBA_11ED;

/// Most pulses a slowdown/degrade window compiles to — bounds the
/// per-component window lists (and the per-tick binary search).
const MAX_PULSES: u64 = 200;

/// Shortest pulse slice a slowdown compiles to.
const MIN_SLICE: Ps = 100_000; // 100 ns

/// One typed fault event. Times are picoseconds **relative to serve
/// start** (after warmup/settle); `replica: None` applies the fault to
/// every fleet slot (and to the single SoC under `vespa serve`).
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Accelerator hang: the tile does no work inside the window.
    TileHang {
        tile: usize,
        replica: Option<usize>,
        at: Ps,
        dur: Ps,
    },
    /// Accelerator slowdown: the tile runs at `1/factor` duty inside
    /// the window (compiled to periodic stall pulses).
    TileSlow {
        tile: usize,
        replica: Option<usize>,
        at: Ps,
        dur: Ps,
        factor: u64,
    },
    /// Link flap: flits crossing the tile's inject/eject links become
    /// visible only after the window ends.
    LinkFlap {
        tile: usize,
        replica: Option<usize>,
        at: Ps,
        dur: Ps,
    },
    /// Link degrade: the tile's links deliver at `1/factor` duty
    /// inside the window (periodic short flaps).
    LinkDegrade {
        tile: usize,
        replica: Option<usize>,
        at: Ps,
        dur: Ps,
        factor: u64,
    },
    /// Stuck DFS actuator: frequency requests on the island fail
    /// inside the window (governor/schedule writes do not actuate).
    ActuatorStuck {
        island: usize,
        replica: Option<usize>,
        at: Ps,
        dur: Ps,
    },
    /// Whole-replica crash at `at` (cluster only): the slot's session
    /// dies, in-flight requests are lost (or retried, see
    /// [`RetrySpec`]).
    ReplicaCrash { slot: usize, at: Ps },
    /// `n` replica crashes at seed-drawn instants and slots.
    RandomCrashes { n: usize, seed: u64 },
}

/// A deterministic, seed-driven fault schedule.
///
/// Build programmatically with [`FaultPlan::with`] or parse the
/// textual CLI grammar with [`FaultPlan::parse`]; [`compile`]
/// resolves it (drawing any random instants) against a run horizon
/// and fleet size.
///
/// [`compile`]: FaultPlan::compile
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub events: Vec<Fault>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append an event (builder style).
    pub fn with(mut self, f: Fault) -> Self {
        self.events.push(f);
        self
    }

    /// Parse the textual plan grammar used by `--faults`:
    ///
    /// ```text
    /// spec    := event (';' event)*
    /// event   := kind ('@' target)* [':' kv (',' kv)*]
    /// kind    := hang | slow | flap | degrade | stuck | crash | rand-crash
    /// target  := t<N> (tile node) | i<N> (island) | r<N> (replica slot)
    /// kv      := at=<time> | dur=<time> | factor=<int> | n=<int> | seed=<int>
    /// time    := float with optional ns|us|ms|s suffix (default ms)
    /// ```
    ///
    /// Examples: `hang@t5:at=10ms,dur=5ms`, `crash@r1:at=20ms`,
    /// `slow@t5@r0:at=10ms,dur=30ms,factor=4`, `rand-crash:n=2,seed=7`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        let mut plan = FaultPlan::new();
        for raw in s.split(';') {
            let ev = raw.trim();
            if ev.is_empty() {
                continue;
            }
            plan.events.push(parse_event(ev)?);
        }
        anyhow::ensure!(!plan.is_empty(), "fault spec {s:?} contains no events");
        Ok(plan)
    }

    /// Resolve the plan against a run: draw random instants, expand
    /// slowdowns into pulse windows, and validate targets. `horizon`
    /// is the serve duration + drain; `slots` the fleet size (1 for
    /// single-SoC serving).
    pub fn compile(&self, horizon: Ps, slots: usize) -> crate::Result<ResolvedPlan> {
        let mut r = ResolvedPlan::default();
        for ev in &self.events {
            match *ev {
                Fault::TileHang {
                    tile,
                    replica,
                    at,
                    dur,
                } => {
                    anyhow::ensure!(dur > 0, "hang@t{tile}: dur must be > 0");
                    r.push_comp(replica, CompTarget::Tile(tile), vec![(at, at + dur)]);
                }
                Fault::TileSlow {
                    tile,
                    replica,
                    at,
                    dur,
                    factor,
                } => {
                    anyhow::ensure!(dur > 0, "slow@t{tile}: dur must be > 0");
                    anyhow::ensure!(factor >= 2, "slow@t{tile}: factor must be >= 2");
                    r.push_comp(replica, CompTarget::Tile(tile), pulse_windows(at, dur, factor));
                }
                Fault::LinkFlap {
                    tile,
                    replica,
                    at,
                    dur,
                } => {
                    anyhow::ensure!(dur > 0, "flap@t{tile}: dur must be > 0");
                    r.push_comp(replica, CompTarget::Link(tile), vec![(at, at + dur)]);
                }
                Fault::LinkDegrade {
                    tile,
                    replica,
                    at,
                    dur,
                    factor,
                } => {
                    anyhow::ensure!(dur > 0, "degrade@t{tile}: dur must be > 0");
                    anyhow::ensure!(factor >= 2, "degrade@t{tile}: factor must be >= 2");
                    r.push_comp(replica, CompTarget::Link(tile), pulse_windows(at, dur, factor));
                }
                Fault::ActuatorStuck {
                    island,
                    replica,
                    at,
                    dur,
                } => {
                    anyhow::ensure!(dur > 0, "stuck@i{island}: dur must be > 0");
                    r.push_comp(replica, CompTarget::Island(island), vec![(at, at + dur)]);
                }
                Fault::ReplicaCrash { slot, at } => {
                    anyhow::ensure!(
                        slot < slots,
                        "crash@r{slot}: slot out of range (fleet of {slots})"
                    );
                    r.crashes.push((at, slot));
                    r.injected += 1;
                }
                Fault::RandomCrashes { n, seed } => {
                    anyhow::ensure!(n > 0, "rand-crash: n must be > 0");
                    anyhow::ensure!(horizon > 0, "rand-crash: empty horizon");
                    let mut rng = SplitMix64::new(seed ^ FAULT_SEED_SALT);
                    for _ in 0..n {
                        // Land inside the middle 80% of the run so a
                        // drawn crash neither pre-empts warm start nor
                        // vanishes into the drain tail.
                        let at = horizon / 10 + rng.next_below(horizon / 10 * 8);
                        let slot = rng.index(slots);
                        r.crashes.push((at, slot));
                        r.injected += 1;
                    }
                }
            }
        }
        r.crashes.sort_unstable();
        Ok(r)
    }
}

/// Component a resolved fault targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompTarget {
    /// An accelerator tile (stall windows in `MraTile`).
    Tile(usize),
    /// The inject/eject link FIFOs at a tile's NoC node.
    Link(usize),
    /// A frequency island's DFS actuator.
    Island(usize),
}

/// One resolved component fault: windows (relative to serve start,
/// half-open `[start, end)`, sorted and disjoint) on one target.
#[derive(Debug, Clone, PartialEq)]
pub struct CompFault {
    pub replica: Option<usize>,
    pub target: CompTarget,
    pub windows: Vec<(Ps, Ps)>,
}

/// A [`FaultPlan`] resolved against a concrete run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ResolvedPlan {
    pub comps: Vec<CompFault>,
    /// Replica crashes as `(at, slot)`, sorted by time.
    pub crashes: Vec<(Ps, usize)>,
    /// Fault events resolved from the plan (one per event or draw).
    pub injected: u64,
}

impl ResolvedPlan {
    fn push_comp(&mut self, replica: Option<usize>, target: CompTarget, mut windows: Vec<(Ps, Ps)>) {
        normalize_windows(&mut windows);
        if !windows.is_empty() {
            self.comps.push(CompFault {
                replica,
                target,
                windows,
            });
            self.injected += 1;
        }
    }

    /// Component faults that apply to fleet slot `slot`.
    pub fn for_replica(&self, slot: usize) -> impl Iterator<Item = &CompFault> {
        self.comps
            .iter()
            .filter(move |c| c.replica.is_none_or(|r| r == slot))
    }
}

/// Sort windows by start and merge overlapping/adjacent ones so
/// lookups can binary-search a disjoint list.
pub fn normalize_windows(windows: &mut Vec<(Ps, Ps)>) {
    windows.retain(|&(s, e)| e > s);
    windows.sort_unstable();
    let mut merged: Vec<(Ps, Ps)> = Vec::with_capacity(windows.len());
    for &(s, e) in windows.iter() {
        match merged.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged.push((s, e)),
        }
    }
    *windows = merged;
}

/// If `now` falls inside a window of the sorted disjoint list, return
/// the window's end (the instant the component resumes).
#[inline]
pub fn window_until(windows: &[(Ps, Ps)], now: Ps) -> Option<Ps> {
    if windows.is_empty() {
        return None;
    }
    // Last window with start <= now.
    let i = windows.partition_point(|&(s, _)| s <= now);
    if i == 0 {
        return None;
    }
    let (_, e) = windows[i - 1];
    (now < e).then_some(e)
}

/// Defer a link-FIFO ready time out of any fault window: a flit that
/// would become visible inside `[s, e)` becomes visible at `e`. The
/// mapping is monotone non-decreasing, so FIFO ready-time ordering is
/// preserved.
#[inline]
pub fn deferred_ready(windows: &[(Ps, Ps)], ready_at: Ps) -> Ps {
    match window_until(windows, ready_at) {
        Some(e) => e,
        None => ready_at,
    }
}

/// Compile a `1/factor`-duty slowdown into periodic stall pulses:
/// one active slice followed by `factor - 1` stalled slices, repeated
/// across `[at, at + dur)`.
fn pulse_windows(at: Ps, dur: Ps, factor: u64) -> Vec<(Ps, Ps)> {
    let slice = (dur / (factor * MAX_PULSES)).max(MIN_SLICE);
    let end = at + dur;
    let mut v = Vec::new();
    let mut t = at + slice;
    while t < end {
        let stop = (t + (factor - 1) * slice).min(end);
        v.push((t, stop));
        t = stop + slice;
    }
    v
}

// ---------------------------------------------------------------------
// Resilience specs.
// ---------------------------------------------------------------------

/// Per-request deadline + bounded retry with exponential backoff at
/// the serve admission gate.
///
/// A request that cannot be admitted (every queue full, or its
/// replica crashed while it was in flight) is re-enqueued
/// `backoff << attempt` after the failure instead of being dropped,
/// up to `max_attempts` total admission attempts and never past its
/// deadline. Latency is always measured from the *original* arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct RetrySpec {
    /// Total admission attempts, including the first (`1` = no retry).
    pub max_attempts: u32,
    /// Base backoff; attempt `k` waits `backoff << (k - 1)`.
    pub backoff: Ps,
    /// Optional per-request deadline from the original arrival; no
    /// retry is scheduled past it.
    pub deadline: Option<Ps>,
}

impl RetrySpec {
    pub fn new(max_attempts: u32, backoff: Ps) -> Self {
        Self {
            max_attempts,
            backoff,
            deadline: None,
        }
    }

    pub fn deadline(mut self, d: Ps) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Schedule the next attempt after a failure at `now`, or `None`
    /// when attempts are exhausted or the deadline would pass.
    /// `attempt` is the 0-based attempt that just failed.
    pub fn next_retry(&self, now: Ps, t_orig: Ps, attempt: u32) -> Option<Ps> {
        if attempt + 1 >= self.max_attempts {
            return None;
        }
        let at = now + (self.backoff << attempt.min(20));
        if let Some(d) = self.deadline {
            if at > t_orig.saturating_add(d) {
                return None;
            }
        }
        Some(at)
    }

    /// Whether a request that originally arrived at `t_orig` is past
    /// its deadline at `now`.
    pub fn expired(&self, now: Ps, t_orig: Ps) -> bool {
        self.deadline.is_some_and(|d| now > t_orig.saturating_add(d))
    }
}

/// Health-check policy for the cluster engine: evict wedged replicas,
/// replace dead ones from the warm-standby pool.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthSpec {
    /// Evict an active replica after this many consecutive sample
    /// windows with a non-empty backlog and zero completions
    /// (`0` = never evict).
    pub evict_after: u32,
    /// Replace crashed/evicted replicas by activating a warm standby
    /// (from the shared snapshot base) at the next health check.
    pub replace: bool,
}

impl Default for HealthSpec {
    fn default() -> Self {
        Self {
            evict_after: 3,
            replace: true,
        }
    }
}

impl HealthSpec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn evict_after(mut self, windows: u32) -> Self {
        self.evict_after = windows;
        self
    }

    pub fn replace(mut self, yes: bool) -> Self {
        self.replace = yes;
        self
    }
}

// ---------------------------------------------------------------------
// Accounting.
// ---------------------------------------------------------------------

/// Fault/retry/eviction accounting, threaded into
/// [`ServeReport`](crate::serve::ServeReport) and
/// [`ClusterReport`](crate::cluster::ClusterReport). All-zero (and
/// omitted from `render()`) for fault-free, retry-free runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultLedger {
    /// Fault events resolved from the plan for this run.
    pub injected: u64,
    /// Faults the resilience layer observed: crashed/wedged replicas
    /// seen by a health check, requests expired at the admission gate.
    pub detected: u64,
    /// Retry attempts scheduled at the admission gate.
    pub retried: u64,
    /// Warm-standby activations replacing crashed/evicted replicas.
    pub failed_over: u64,
    /// Replicas force-retired by a health check or drain deadline.
    pub evicted: u64,
    /// Requests lost for good (crash/eviction victims past retry,
    /// expired deadlines, retries still pending at run end).
    pub lost: u64,
    /// Requests that survived a failed attempt and still completed.
    pub rescued: u64,
}

impl FaultLedger {
    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }

    /// Fraction of at-risk requests (lost or rescued) that completed;
    /// `1.0` when nothing was ever at risk.
    pub fn rescued_fraction(&self) -> f64 {
        let at_risk = self.lost + self.rescued;
        if at_risk == 0 {
            1.0
        } else {
            self.rescued as f64 / at_risk as f64
        }
    }

    pub(crate) fn to_json(&self) -> String {
        format!(
            "{{\"injected\":{},\"detected\":{},\"retried\":{},\"failed_over\":{},\"evicted\":{},\"lost\":{},\"rescued\":{}}}",
            self.injected,
            self.detected,
            self.retried,
            self.failed_over,
            self.evicted,
            self.lost,
            self.rescued
        )
    }

    pub(crate) fn render_line(&self) -> String {
        format!(
            "faults     : {} injected, {} detected, {} retried, {} failed-over, {} evicted, {} lost / {} rescued",
            self.injected,
            self.detected,
            self.retried,
            self.failed_over,
            self.evicted,
            self.lost,
            self.rescued
        )
    }
}

// ---------------------------------------------------------------------
// Textual grammar.
// ---------------------------------------------------------------------

struct EventTargets {
    tile: Option<usize>,
    island: Option<usize>,
    replica: Option<usize>,
}

fn parse_event(ev: &str) -> crate::Result<Fault> {
    let (head, kvs) = match ev.split_once(':') {
        Some((h, k)) => (h, k),
        None => (ev, ""),
    };
    let mut parts = head.split('@');
    let kind = parts.next().unwrap_or_default().trim();
    let mut tg = EventTargets {
        tile: None,
        island: None,
        replica: None,
    };
    for t in parts {
        let t = t.trim();
        let (tag, num) = t.split_at(1.min(t.len()));
        let idx: usize = num
            .parse()
            .map_err(|_| anyhow::anyhow!("fault target {t:?}: expected t<N>, i<N> or r<N>"))?;
        match tag {
            "t" => tg.tile = Some(idx),
            "i" => tg.island = Some(idx),
            "r" => tg.replica = Some(idx),
            _ => anyhow::bail!("fault target {t:?}: expected t<N>, i<N> or r<N>"),
        }
    }

    let mut at = None;
    let mut dur = None;
    let mut factor = None;
    let mut n = None;
    let mut seed = None;
    for kv in kvs.split(',') {
        let kv = kv.trim();
        if kv.is_empty() {
            continue;
        }
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("fault param {kv:?}: expected key=value"))?;
        match k.trim() {
            "at" => at = Some(parse_time(v)?),
            "dur" => dur = Some(parse_time(v)?),
            "factor" => factor = Some(parse_int(v, "factor")?),
            "n" => n = Some(parse_int(v, "n")? as usize),
            "seed" => seed = Some(parse_int(v, "seed")?),
            other => anyhow::bail!("fault param {other:?}: unknown key (at/dur/factor/n/seed)"),
        }
    }

    let need_at = || at.ok_or_else(|| anyhow::anyhow!("fault {kind:?}: missing at=<time>"));
    let need_dur = || dur.ok_or_else(|| anyhow::anyhow!("fault {kind:?}: missing dur=<time>"));
    let need_tile =
        || tg.tile.ok_or_else(|| anyhow::anyhow!("fault {kind:?}: missing @t<tile> target"));
    match kind {
        "hang" => Ok(Fault::TileHang {
            tile: need_tile()?,
            replica: tg.replica,
            at: need_at()?,
            dur: need_dur()?,
        }),
        "slow" => Ok(Fault::TileSlow {
            tile: need_tile()?,
            replica: tg.replica,
            at: need_at()?,
            dur: need_dur()?,
            factor: factor.unwrap_or(2),
        }),
        "flap" => Ok(Fault::LinkFlap {
            tile: need_tile()?,
            replica: tg.replica,
            at: need_at()?,
            dur: need_dur()?,
        }),
        "degrade" => Ok(Fault::LinkDegrade {
            tile: need_tile()?,
            replica: tg.replica,
            at: need_at()?,
            dur: need_dur()?,
            factor: factor.unwrap_or(2),
        }),
        "stuck" => Ok(Fault::ActuatorStuck {
            island: tg
                .island
                .ok_or_else(|| anyhow::anyhow!("fault \"stuck\": missing @i<island> target"))?,
            replica: tg.replica,
            at: need_at()?,
            dur: need_dur()?,
        }),
        "crash" => Ok(Fault::ReplicaCrash {
            slot: tg
                .replica
                .ok_or_else(|| anyhow::anyhow!("fault \"crash\": missing @r<slot> target"))?,
            at: need_at()?,
        }),
        "rand-crash" => Ok(Fault::RandomCrashes {
            n: n.ok_or_else(|| anyhow::anyhow!("fault \"rand-crash\": missing n=<count>"))?,
            seed: seed.unwrap_or(0xC4A5),
        }),
        other => anyhow::bail!(
            "unknown fault kind {other:?} (hang/slow/flap/degrade/stuck/crash/rand-crash)"
        ),
    }
}

fn parse_int(v: &str, key: &str) -> crate::Result<u64> {
    v.trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("fault param {key}={v:?}: expected an integer"))
}

/// Parse a time value: float with optional `ns`/`us`/`ms`/`s` suffix,
/// defaulting to milliseconds.
fn parse_time(v: &str) -> crate::Result<Ps> {
    let v = v.trim();
    let (num, scale) = if let Some(n) = v.strip_suffix("ns") {
        (n, 1e3)
    } else if let Some(n) = v.strip_suffix("us") {
        (n, 1e6)
    } else if let Some(n) = v.strip_suffix("ms") {
        (n, 1e9)
    } else if let Some(n) = v.strip_suffix('s') {
        (n, 1e12)
    } else {
        (v, 1e9)
    };
    let x: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("fault time {v:?}: expected a number (ns/us/ms/s)"))?;
    anyhow::ensure!(x >= 0.0 && x.is_finite(), "fault time {v:?}: must be >= 0");
    Ok((x * scale) as Ps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse(
            "hang@t5:at=10ms,dur=5ms; slow@t5@r0:at=1ms,dur=2ms,factor=4; \
             flap@t2:at=3ms,dur=500us; degrade@t2:at=0ms,dur=1ms; \
             stuck@i1:at=0ms,dur=20ms; crash@r1:at=20ms; rand-crash:n=2,seed=7",
        )
        .unwrap();
        assert_eq!(plan.events.len(), 7);
        assert_eq!(
            plan.events[0],
            Fault::TileHang {
                tile: 5,
                replica: None,
                at: 10_000_000_000,
                dur: 5_000_000_000
            }
        );
        assert_eq!(
            plan.events[1],
            Fault::TileSlow {
                tile: 5,
                replica: Some(0),
                at: 1_000_000_000,
                dur: 2_000_000_000,
                factor: 4
            }
        );
        assert_eq!(
            plan.events[3],
            Fault::LinkDegrade {
                tile: 2,
                replica: None,
                at: 0,
                dur: 1_000_000_000,
                factor: 2
            }
        );
        assert_eq!(plan.events[5], Fault::ReplicaCrash { slot: 1, at: 20_000_000_000 });
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "explode@t1:at=1ms",
            "hang@t1",               // missing at/dur
            "hang:at=1ms,dur=1ms",   // missing tile target
            "crash:at=1ms",          // missing replica target
            "stuck@t1:at=1ms,dur=1", // stuck needs an island
            "hang@t1:at=x,dur=1ms",
            "hang@q1:at=1ms,dur=1ms",
            "hang@t1:at=1ms,dur=1ms,bogus=3",
            "rand-crash:seed=7", // missing n
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn compile_resolves_random_crashes_deterministically() {
        let plan = FaultPlan::new().with(Fault::RandomCrashes { n: 3, seed: 9 });
        let a = plan.compile(1_000_000, 4).unwrap();
        let b = plan.compile(1_000_000, 4).unwrap();
        assert_eq!(a, b, "same seed => same draws");
        assert_eq!(a.crashes.len(), 3);
        assert_eq!(a.injected, 3);
        for &(at, slot) in &a.crashes {
            assert!((100_000..900_000).contains(&at));
            assert!(slot < 4);
        }
        let c = plan.compile(1_000_000, 2).unwrap();
        assert!(c.crashes.iter().all(|&(_, s)| s < 2));
    }

    #[test]
    fn compile_validates_targets() {
        let plan = FaultPlan::new().with(Fault::ReplicaCrash { slot: 5, at: 10 });
        assert!(plan.compile(100, 4).is_err());
        let plan = FaultPlan::new().with(Fault::TileSlow {
            tile: 1,
            replica: None,
            at: 0,
            dur: 100,
            factor: 1,
        });
        assert!(plan.compile(100, 1).is_err(), "factor < 2 rejected");
    }

    #[test]
    fn window_lookup_and_merge() {
        let mut w = vec![(50, 60), (10, 20), (18, 30), (30, 40)];
        normalize_windows(&mut w);
        assert_eq!(w, vec![(10, 40), (50, 60)]);
        assert_eq!(window_until(&w, 5), None);
        assert_eq!(window_until(&w, 10), Some(40));
        assert_eq!(window_until(&w, 39), Some(40));
        assert_eq!(window_until(&w, 40), None);
        assert_eq!(window_until(&w, 55), Some(60));
        assert_eq!(window_until(&w, 60), None);
        assert_eq!(window_until(&[], 55), None);
    }

    #[test]
    fn deferred_ready_is_monotone() {
        let w = vec![(100u64, 200u64), (300, 350)];
        let mut prev = 0;
        for t in 0..400 {
            let d = deferred_ready(&w, t);
            assert!(d >= prev, "monotone at {t}");
            assert!(d >= t);
            prev = d;
        }
        assert_eq!(deferred_ready(&w, 99), 99);
        assert_eq!(deferred_ready(&w, 100), 200);
        assert_eq!(deferred_ready(&w, 199), 200);
        assert_eq!(deferred_ready(&w, 200), 200);
    }

    #[test]
    fn pulse_windows_cover_requested_duty() {
        let at = 1_000_000;
        let dur = 80_000_000;
        let w = pulse_windows(at, dur, 4);
        assert!(!w.is_empty() && w.len() <= 2 * MAX_PULSES as usize);
        let stalled: Ps = w.iter().map(|&(s, e)| e - s).sum();
        let duty = stalled as f64 / dur as f64;
        assert!(
            (duty - 0.75).abs() < 0.05,
            "factor 4 => ~75% stalled, got {duty}"
        );
        for win in w.windows(2) {
            assert!(win[0].1 < win[1].0, "windows disjoint and sorted");
        }
        assert!(w.last().unwrap().1 <= at + dur);
    }

    #[test]
    fn retry_backoff_and_deadline() {
        let rs = RetrySpec::new(3, 1000).deadline(10_000);
        assert_eq!(rs.next_retry(5_000, 5_000, 0), Some(6_000));
        assert_eq!(rs.next_retry(6_000, 5_000, 1), Some(8_000), "backoff doubles");
        assert_eq!(rs.next_retry(8_000, 5_000, 2), None, "attempts exhausted");
        assert_eq!(
            rs.next_retry(14_500, 5_000, 0),
            None,
            "retry would land past the deadline"
        );
        assert!(!rs.expired(15_000, 5_000));
        assert!(rs.expired(15_001, 5_000));
        let no_retry = RetrySpec::new(1, 1000);
        assert_eq!(no_retry.next_retry(0, 0, 0), None);
    }

    #[test]
    fn ledger_accounting_helpers() {
        let mut l = FaultLedger::default();
        assert!(l.is_empty());
        assert_eq!(l.rescued_fraction(), 1.0);
        l.rescued = 9;
        l.lost = 1;
        assert!(!l.is_empty());
        assert!((l.rescued_fraction() - 0.9).abs() < 1e-12);
        let json = l.to_json();
        assert!(json.contains("\"rescued\":9") && json.contains("\"lost\":1"));
    }
}
