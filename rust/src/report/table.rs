//! Aligned text tables.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * cols));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_export() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
