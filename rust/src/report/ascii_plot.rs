//! Minimal ASCII line plots for the figure benches.

use crate::monitor::TimeSeries;

/// Render one or more series into an ASCII plot of `width x height`
/// characters. Each series gets a distinct glyph.
pub fn plot(series: &[&TimeSeries], width: usize, height: usize) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    let (mut t_min, mut t_max) = (u64::MAX, 0u64);
    let (mut v_min, mut v_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for s in series {
        for p in &s.samples {
            t_min = t_min.min(p.t);
            t_max = t_max.max(p.t);
            v_min = v_min.min(p.value);
            v_max = v_max.max(p.value);
        }
    }
    if t_min >= t_max || !v_min.is_finite() {
        return "(empty plot)\n".to_string();
    }
    if (v_max - v_min).abs() < 1e-12 {
        v_max = v_min + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for p in &s.samples {
            let x = ((p.t - t_min) as f64 / (t_max - t_min) as f64 * (width - 1) as f64) as usize;
            let yf = (p.value - v_min) / (v_max - v_min);
            let y = height - 1 - (yf * (height - 1) as f64) as usize;
            grid[y][x] = g;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{v_max:>12.3} ┐\n"));
    for row in grid {
        out.push_str("             │");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!(
        "{v_min:>12.3} └{}\n",
        "─".repeat(width)
    ));
    out.push_str(&format!(
        "             {:.1}us .. {:.1}us\n",
        t_min as f64 / 1e6,
        t_max as f64 / 1e6
    ));
    let mut legend = String::new();
    for (si, s) in series.iter().enumerate() {
        legend.push_str(&format!("  {} {}", GLYPHS[si % GLYPHS.len()], s.name));
    }
    out.push_str(&format!("legend:{legend}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plots_a_ramp() {
        let mut ts = TimeSeries::new("ramp");
        for i in 0..50 {
            ts.push(i * 1000, i as f64);
        }
        let s = plot(&[&ts], 40, 10);
        assert!(s.contains('*'));
        assert!(s.contains("ramp"));
    }

    #[test]
    fn empty_series_safe() {
        let ts = TimeSeries::new("e");
        assert_eq!(plot(&[&ts], 10, 5), "(empty plot)\n");
    }

    #[test]
    fn constant_series_safe() {
        let mut ts = TimeSeries::new("c");
        ts.push(0, 5.0);
        ts.push(100, 5.0);
        let s = plot(&[&ts], 10, 5);
        assert!(s.contains('*'));
    }
}
