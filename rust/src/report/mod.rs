//! Table and time-series rendering for the reproduction benches: aligned
//! text tables (the paper's tables) and ASCII line plots (the figures),
//! plus CSV export for external plotting.

pub mod ascii_plot;
pub mod table;
pub mod waterfall;

pub use ascii_plot::plot;
pub use table::Table;
pub use waterfall::waterfall;
