//! ASCII span waterfall: the slowest traced requests rendered as one
//! timeline row each, so a terminal shows *where* tail latency went
//! (queueing, retry backoff, or execution) without opening Perfetto.
//!
//! Glyph legend (also printed under the chart):
//!
//! | glyph | phase |
//! |-------|-------|
//! | `.`   | arrived, not yet admitted |
//! | `=`   | queued on a tile |
//! | `#`   | executing on an accelerator replica |
//! | `~`   | retry backoff |
//! | `X`   | in flight on a crashed replica |
//! | `!`   | terminal drop/expiry |
//!
//! Deterministic: rendering only reads the [`Trace`], which is itself
//! bit-identical across engines and thread counts.

use crate::telemetry::{SpanEvent, Trace};
use crate::util::Ps;

/// Render the `k` slowest finished spans of `trace` (`k = 0` = the
/// spec's `slowest`; unfinished spans fill in when too few finished) as
/// an ASCII waterfall `width` columns wide. Returns a note instead of a
/// chart when the trace holds no spans.
pub fn waterfall(trace: &Trace, width: usize, k: usize) -> String {
    let width = width.clamp(20, 400);
    let mut picked: Vec<&crate::telemetry::RequestSpan> = trace.slowest(k);
    let k = if k == 0 { trace.spec.slowest.max(1) } else { k };
    if picked.len() < k {
        // Not enough finished spans: pad with unfinished ones in id
        // order (crashed/expired/still-queued requests are often
        // exactly what the reader is hunting).
        for s in trace.spans.iter().filter(|s| s.latency.is_none()) {
            if picked.len() >= k {
                break;
            }
            picked.push(s);
        }
    }
    if picked.is_empty() {
        return "trace: no spans retained (nothing sampled?)\n".to_string();
    }

    let t0 = picked.iter().map(|s| s.t_arr).min().unwrap_or(0);
    let t1 = picked
        .iter()
        .map(|s| s.t_last())
        .max()
        .unwrap_or(t0)
        .max(t0 + 1);
    let range = (t1 - t0) as f64;
    let cell = |t: Ps| -> usize {
        let c = ((t.saturating_sub(t0)) as f64 / range * width as f64) as usize;
        c.min(width - 1)
    };

    let mut out = format!(
        "span waterfall — {} span(s), {:.3} ms window ({} of {} requests recorded)\n",
        picked.len(),
        range / 1e9,
        trace.recorded,
        trace.total_requests,
    );
    for span in &picked {
        let mut row = vec![' '; width];
        // Walk the event list as a phase machine: each interval up to
        // the next event is filled with the current phase's glyph.
        let mut phase = '.';
        let mut t_prev = span.t_arr;
        let fill = |row: &mut Vec<char>, a: Ps, b: Ps, g: char| {
            for c in row.iter_mut().take(cell(b) + 1).skip(cell(a)) {
                if *c == ' ' {
                    *c = g;
                }
            }
        };
        for &(t, ev) in &span.events {
            fill(&mut row, t_prev, t, phase);
            t_prev = t;
            match ev {
                SpanEvent::Admit { .. } => phase = '=',
                SpanEvent::ExecStart { .. } => phase = '#',
                SpanEvent::Retry { .. } => phase = '~',
                SpanEvent::Crashed { .. } => {
                    row[cell(t)] = 'X';
                    phase = '~';
                }
                SpanEvent::Complete { .. } => {
                    fill(&mut row, t, t, phase);
                }
                SpanEvent::Dropped | SpanEvent::Expired => {
                    row[cell(t)] = '!';
                }
            }
        }
        if span.latency.is_none() && !matches!(
            span.events.last(),
            Some((_, SpanEvent::Dropped | SpanEvent::Expired))
        ) {
            // Still live at drain: extend its last phase to the edge.
            fill(&mut row, t_prev, t1, phase);
        }
        let tail = match span.latency {
            Some(l) => format!("{:9.3} ms", l as f64 / 1e9),
            None => "  unfinished".to_string(),
        };
        out.push_str(&format!(
            "{:>8} |{}| {tail}\n",
            format!("#{}", span.id),
            row.iter().collect::<String>(),
        ));
    }
    out.push_str(&format!(
        "{:>8} |{:<w$}| t0 = {:.3} ms\n",
        "",
        "legend: .=wait ==queued #=exec ~=backoff X=crash !=lost",
        t0 as f64 / 1e9,
        w = width,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{TraceSpec, Tracer};

    fn traced_happy_path() -> Trace {
        let mut tr = Tracer::new(TraceSpec::new());
        tr.add_track("tile 4 (acc)".into(), 0, 4);
        let id = tr.arrive(0);
        tr.admit(id, 0, 0, 0);
        tr.exec_start(0, 500_000_000, 0);
        tr.complete(0, 2_000_000_000, 2_000_000_000);
        tr.finish()
    }

    #[test]
    fn renders_phases_in_order() {
        let t = traced_happy_path();
        let s = waterfall(&t, 40, 4);
        assert!(s.contains("#0"), "row labelled by span id:\n{s}");
        assert!(s.contains("2.000 ms"), "latency annotated:\n{s}");
        let row = s.lines().nth(1).unwrap();
        let chart = &row[row.find('|').unwrap()..]; // skip the "#0" label
        let queued = chart.find('=').expect("queued glyph");
        let exec = chart.find('#').expect("exec glyph");
        assert!(queued < exec, "queueing precedes exec: {row}");
    }

    #[test]
    fn crashed_span_shows_crash_and_rescue() {
        let mut tr = Tracer::new(TraceSpec::new());
        tr.add_track("t0".into(), 0, 0);
        let id = tr.arrive(0);
        tr.admit(id, 0, 0, 0);
        for got in tr.crash_track(0, 1_000_000_000) {
            tr.retry(got, 1_000_000_000, 0, 2_000_000_000, 1, true);
        }
        let back = tr.retry_pop(0, 1, true);
        assert_eq!(back, id);
        tr.admit(back, 2_000_000_000, 0, 1);
        tr.exec_start(0, 2_100_000_000, 0);
        tr.complete(0, 3_000_000_000, 3_000_000_000);
        let t = tr.finish();
        let s = waterfall(&t, 60, 1);
        assert!(s.contains('X'), "crash glyph rendered:\n{s}");
        assert!(s.contains('~'), "backoff rendered:\n{s}");
        assert!(s.contains("3.000 ms"), "rescued latency spans arrival:\n{s}");
    }

    #[test]
    fn empty_trace_degrades_gracefully() {
        let t = Tracer::new(TraceSpec::new()).finish();
        let s = waterfall(&t, 80, 0);
        assert!(s.contains("no spans"));
    }

    #[test]
    fn unfinished_span_marked() {
        let mut tr = Tracer::new(TraceSpec::new());
        tr.add_track("t0".into(), 0, 0);
        let id = tr.arrive(0);
        tr.admit(id, 0, 0, 0);
        tr.exec_start(0, 1_000_000_000, 0);
        let t = tr.finish();
        let s = waterfall(&t, 40, 2);
        assert!(s.contains("unfinished"), "{s}");
    }
}
