//! Native-Rust reference implementations of the five CHStone
//! accelerators — an independent oracle for the PJRT datapath and the
//! default functional backend for artifact-less unit tests.
//!
//! These are transcriptions of the same specifications the Python
//! kernels implement (IMA ADPCM from CHStone's `adpcm.c`, GSM LPC from
//! GSM 06.10, Taylor sine), *not* ports of the Pallas code: agreement
//! between the two is a meaningful end-to-end check of the whole
//! JAX -> HLO -> PJRT pipeline.

use anyhow::bail;

use super::AccelCompute;
use crate::mem::Block;

/// Invocation geometry (must match `python/compile/model.py`).
pub const DF_ROWS: usize = 8;
pub const ADPCM_ROWS: usize = 64;
pub const GSM_ROWS: usize = 160;
pub const LANES: usize = 128;
pub const GSM_ACF_ROWS: usize = 16;
pub const GSM_ORDER: usize = 8;

/// IMA ADPCM step-size table (89 entries), as in CHStone.
pub const IMA_STEP_TABLE: [i32; 89] = [
    7, 8, 9, 10, 11, 12, 13, 14, 16, 17, 19, 21, 23, 25, 28, 31, 34, 37, 41, 45, 50, 55, 60, 66,
    73, 80, 88, 97, 107, 118, 130, 143, 157, 173, 190, 209, 230, 253, 279, 307, 337, 371, 408,
    449, 494, 544, 598, 658, 724, 796, 876, 963, 1060, 1166, 1282, 1411, 1552, 1707, 1878, 2066,
    2272, 2499, 2749, 3024, 3327, 3660, 4026, 4428, 4871, 5358, 5894, 6484, 7132, 7845, 8630,
    9493, 10442, 11487, 12635, 13899, 15289, 16818, 18500, 20350, 22385, 24623, 27086, 29794,
    32767,
];

/// IMA index-adjustment table for the 3 magnitude bits.
pub const IMA_INDEX_TABLE: [i32; 8] = [-1, -1, -1, -1, 2, 4, 6, 8];

/// The reference backend.
#[derive(Debug, Default, Clone)]
pub struct RefCompute;

impl RefCompute {
    pub fn new() -> Self {
        Self
    }
}

fn want_f32<'b>(b: &'b Block, what: &str, len: usize) -> crate::Result<&'b [f32]> {
    match b.as_f32() {
        Some(v) if v.len() == len => Ok(v),
        Some(v) => bail!("{what}: expected {len} f32 words, got {}", v.len()),
        None => bail!("{what}: expected f32 block"),
    }
}

fn want_i32<'b>(b: &'b Block, what: &str, len: usize) -> crate::Result<&'b [i32]> {
    match b.as_i32() {
        Some(v) if v.len() == len => Ok(v),
        Some(v) => bail!("{what}: expected {len} i32 words, got {}", v.len()),
        None => bail!("{what}: expected i32 block"),
    }
}

/// `sin(x)` elementwise (f64 libm sine, cast to f32 — the oracle side).
pub fn dfsin(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| (v as f64).sin() as f32).collect()
}

/// IMA ADPCM encode: `x` is (rows, LANES) row-major i32 PCM; returns the
/// 4-bit codes. Direct transcription of CHStone `adpcm_coder`.
pub fn adpcm_encode(x: &[i32], rows: usize, lanes: usize) -> Vec<i32> {
    let mut out = vec![0i32; rows * lanes];
    for c in 0..lanes {
        let mut valpred: i64 = 0;
        let mut index: i32 = 0;
        for t in 0..rows {
            let sample = x[t * lanes + c] as i64;
            let mut step = IMA_STEP_TABLE[index as usize] as i64;
            let mut diff = sample - valpred;
            let sign = if diff < 0 { 8 } else { 0 };
            if diff < 0 {
                diff = -diff;
            }
            let mut code: i32 = 0;
            let mut vpdiff = step >> 3;
            if diff >= step {
                code |= 4;
                diff -= step;
                vpdiff += step;
            }
            step >>= 1;
            if diff >= step {
                code |= 2;
                diff -= step;
                vpdiff += step;
            }
            step >>= 1;
            if diff >= step {
                code |= 1;
                vpdiff += step;
            }
            if sign != 0 {
                valpred -= vpdiff;
            } else {
                valpred += vpdiff;
            }
            valpred = valpred.clamp(-32768, 32767);
            index = (index + IMA_INDEX_TABLE[code as usize]).clamp(0, 88);
            out[t * lanes + c] = code | sign;
        }
    }
    out
}

/// GSM autocorrelation lags r[0..8], zero-padded to `GSM_ACF_ROWS` rows.
pub fn gsm_acf(x: &[f32], rows: usize, lanes: usize) -> Vec<f32> {
    let mut out = vec![0f32; GSM_ACF_ROWS * lanes];
    for k in 0..9 {
        for c in 0..lanes {
            let mut acc = 0f64;
            for t in 0..rows - k {
                acc += x[t * lanes + c] as f64 * x[(t + k) * lanes + c] as f64;
            }
            out[k * lanes + c] = acc as f32;
        }
    }
    out
}

/// Reflection coefficients from the ACF via Levinson-Durbin (matches the
/// Layer-2 graph and `ref.py`). `acf` is (GSM_ACF_ROWS, lanes) row-major.
pub fn gsm_reflection(acf: &[f32], lanes: usize) -> Vec<f32> {
    let order = GSM_ORDER;
    let mut out = vec![0f32; order * lanes];
    for c in 0..lanes {
        let r: Vec<f64> = (0..9).map(|k| acf[k * lanes + c] as f64).collect();
        if r[0] <= 0.0 {
            continue; // silent frame: zeros
        }
        let mut a = vec![0f64; order + 1];
        a[0] = 1.0;
        let mut err = r[0];
        for i in 1..=order {
            let mut acc = r[i];
            for j in 1..i {
                acc += a[j] * r[i - j];
            }
            let k = if err > 0.0 {
                (-acc / err).clamp(-1.0, 1.0)
            } else {
                0.0
            };
            out[(i - 1) * lanes + c] = k as f32;
            let mut a_new = a.clone();
            for j in 1..i {
                a_new[j] = a[j] + k * a[i - j];
            }
            a_new[i] = k;
            a = a_new;
            err *= 1.0 - k * k;
        }
    }
    out
}

impl AccelCompute for RefCompute {
    fn invoke(&mut self, name: &str, inputs: &[&Block]) -> crate::Result<Vec<Block>> {
        let df = DF_ROWS * LANES;
        match name {
            "dfadd" | "dfmul" => {
                if inputs.len() != 2 {
                    bail!("{name}: want 2 inputs, got {}", inputs.len());
                }
                let a = want_f32(inputs[0], name, df)?;
                let b = want_f32(inputs[1], name, df)?;
                let out: Vec<f32> = if name == "dfadd" {
                    a.iter().zip(b).map(|(x, y)| x + y).collect()
                } else {
                    a.iter().zip(b).map(|(x, y)| x * y).collect()
                };
                Ok(vec![Block::F32(out)])
            }
            "dfsin" => {
                if inputs.len() != 1 {
                    bail!("dfsin: want 1 input");
                }
                let x = want_f32(inputs[0], name, df)?;
                Ok(vec![Block::F32(dfsin(x))])
            }
            "adpcm" => {
                if inputs.len() != 1 {
                    bail!("adpcm: want 1 input");
                }
                let x = want_i32(inputs[0], name, ADPCM_ROWS * LANES)?;
                Ok(vec![Block::I32(adpcm_encode(x, ADPCM_ROWS, LANES))])
            }
            "gsm" => {
                if inputs.len() != 1 {
                    bail!("gsm: want 1 input");
                }
                let x = want_f32(inputs[0], name, GSM_ROWS * LANES)?;
                let acf = gsm_acf(x, GSM_ROWS, LANES);
                let refl = gsm_reflection(&acf, LANES);
                Ok(vec![Block::F32(acf), Block::F32(refl)])
            }
            other => bail!("unknown accelerator {other:?}"),
        }
    }

    fn backend(&self) -> &'static str {
        "ref"
    }

    fn fork(&self) -> crate::Result<Box<dyn AccelCompute>> {
        Ok(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn f32_block(rng: &mut SplitMix64, n: usize, lo: f32, hi: f32) -> Block {
        Block::F32((0..n).map(|_| rng.range_f32(lo, hi)).collect())
    }

    #[test]
    fn dfadd_adds() {
        let mut rc = RefCompute::new();
        let a = Block::F32(vec![1.0; DF_ROWS * LANES]);
        let b = Block::F32(vec![2.5; DF_ROWS * LANES]);
        let out = rc.invoke("dfadd", &[&a, &b]).unwrap();
        assert_eq!(out[0].as_f32().unwrap()[17], 3.5);
    }

    #[test]
    fn dfmul_multiplies() {
        let mut rc = RefCompute::new();
        let a = Block::F32(vec![3.0; DF_ROWS * LANES]);
        let b = Block::F32(vec![-2.0; DF_ROWS * LANES]);
        let out = rc.invoke("dfmul", &[&a, &b]).unwrap();
        assert_eq!(out[0].as_f32().unwrap()[100], -6.0);
    }

    #[test]
    fn dfsin_known_values() {
        let mut rc = RefCompute::new();
        let mut v = vec![0f32; DF_ROWS * LANES];
        v[0] = std::f32::consts::FRAC_PI_2;
        let out = rc.invoke("dfsin", &[&Block::F32(v)]).unwrap();
        let o = out[0].as_f32().unwrap();
        assert!((o[0] - 1.0).abs() < 1e-6);
        assert_eq!(o[1], 0.0);
    }

    #[test]
    fn adpcm_codes_in_range_and_deterministic() {
        let mut rng = SplitMix64::new(5);
        let x: Vec<i32> = (0..ADPCM_ROWS * LANES)
            .map(|_| rng.range_i64(-32768, 32767) as i32)
            .collect();
        let a = adpcm_encode(&x, ADPCM_ROWS, LANES);
        let b = adpcm_encode(&x, ADPCM_ROWS, LANES);
        assert_eq!(a, b);
        assert!(a.iter().all(|&c| (0..=15).contains(&c)));
    }

    #[test]
    fn adpcm_silence_is_zero_codes() {
        let x = vec![0i32; ADPCM_ROWS * LANES];
        let out = adpcm_encode(&x, ADPCM_ROWS, LANES);
        assert!(out.iter().all(|&c| c == 0));
    }

    #[test]
    fn gsm_acf_lag0_is_energy() {
        let mut rng = SplitMix64::new(9);
        let x = f32_block(&mut rng, GSM_ROWS * LANES, -1.0, 1.0);
        let v = x.as_f32().unwrap();
        let acf = gsm_acf(v, GSM_ROWS, LANES);
        let energy: f64 = (0..GSM_ROWS).map(|t| (v[t * LANES] as f64).powi(2)).sum();
        assert!((acf[0] as f64 - energy).abs() / energy < 1e-5);
    }

    #[test]
    fn gsm_reflection_bounded() {
        let mut rng = SplitMix64::new(11);
        let x = f32_block(&mut rng, GSM_ROWS * LANES, -1.0, 1.0);
        let acf = gsm_acf(x.as_f32().unwrap(), GSM_ROWS, LANES);
        let refl = gsm_reflection(&acf, LANES);
        assert!(refl.iter().all(|k| k.abs() <= 1.0 + 1e-6));
    }

    #[test]
    fn gsm_silent_frame_zero_reflection() {
        let acf = vec![0f32; GSM_ACF_ROWS * LANES];
        let refl = gsm_reflection(&acf, LANES);
        assert!(refl.iter().all(|&k| k == 0.0));
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut rc = RefCompute::new();
        let bad = Block::F32(vec![0.0; 7]);
        assert!(rc.invoke("dfsin", &[&bad]).is_err());
        let int = Block::I32(vec![0; DF_ROWS * LANES]);
        assert!(rc.invoke("dfsin", &[&int]).is_err());
        assert!(rc.invoke("nosuch", &[&bad]).is_err());
    }
}
