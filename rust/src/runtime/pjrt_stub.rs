//! Stub PJRT backend for builds without the `pjrt` feature.
//!
//! The real [`super::pjrt`] module needs the `xla` crate and its C++
//! runtime — a heavyweight optional dependency. This stub mirrors the
//! module's public API exactly so every call site compiles unchanged;
//! constructors return a descriptive error at run time, steering users to
//! the native [`super::RefCompute`] oracle or a `--features pjrt` build.

use anyhow::bail;

use super::manifest::Manifest;
use super::AccelCompute;
use crate::mem::Block;

/// Place-holder for the PJRT CPU backend (`--features pjrt` enables the
/// real implementation).
pub struct PjrtCompute {
    /// Invocation counter (perf reporting); always 0 in the stub.
    pub invocations: u64,
}

impl PjrtCompute {
    /// Always fails: the crate was built without PJRT support.
    pub fn load(_artifacts_dir: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        bail!(
            "vespa was built without the `pjrt` feature; rebuild with \
             `cargo build --features pjrt` (requires the xla crate) or use \
             the native RefCompute backend"
        )
    }

    /// Always fails: the crate was built without PJRT support.
    pub fn from_manifest(_manifest: Manifest) -> crate::Result<Self> {
        Self::load("")
    }
}

impl AccelCompute for PjrtCompute {
    fn invoke(&mut self, name: &str, _inputs: &[&Block]) -> crate::Result<Vec<Block>> {
        bail!("PJRT backend unavailable (built without the `pjrt` feature); cannot invoke {name}")
    }

    fn backend(&self) -> &'static str {
        "pjrt-stub"
    }

    fn fork(&self) -> crate::Result<Box<dyn AccelCompute>> {
        bail!("PJRT backend unavailable (built without the `pjrt` feature); cannot fork")
    }
}
