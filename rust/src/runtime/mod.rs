//! PJRT runtime: loads the AOT-lowered HLO artifacts once at startup and
//! executes accelerator invocations from the simulator's hot path.
//!
//! Python never runs here — `make artifacts` (build time) lowered the
//! Layer-2 JAX functions to HLO *text* (see `python/compile/aot.py` for
//! why text, not serialized protos), and [`pjrt::PjrtCompute`] compiles
//! them on the PJRT CPU client via the `xla` crate.
//!
//! [`AccelCompute`] abstracts the functional datapath so unit tests and
//! artifact-less builds can use [`refcompute::RefCompute`] — an
//! independent native-Rust implementation of the five accelerators that
//! doubles as a second oracle: the integration tests assert PJRT and
//! RefCompute agree.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod refcompute;

pub use manifest::{DType, Manifest, ModuleSpec, TensorSpec};
pub use pjrt::PjrtCompute;
pub use refcompute::RefCompute;

use crate::mem::Block;

/// The functional datapath of an accelerator invocation.
///
/// `Send + Sync` so simulations (and frozen
/// [`crate::scenario::SocSnapshot`]s) can move to and be shared across
/// sweep worker threads; mutation still happens behind `&mut` from one
/// thread at a time.
pub trait AccelCompute: Send + Sync {
    /// Execute one invocation of accelerator `name` on `inputs`,
    /// returning the output blocks in manifest order.
    fn invoke(&mut self, name: &str, inputs: &[&Block]) -> crate::Result<Vec<Block>>;

    /// Implementation label (for logs/reports).
    fn backend(&self) -> &'static str;

    /// Duplicate this backend for a forked simulation
    /// ([`crate::sim::Soc::fork`]). Backends whose state cannot be
    /// duplicated (compiled PJRT executables hold runtime handles)
    /// return an error; the native [`RefCompute`] always succeeds.
    fn fork(&self) -> crate::Result<Box<dyn AccelCompute>>;
}
