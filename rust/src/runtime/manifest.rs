//! Parser for `artifacts/manifest.txt`.
//!
//! The AOT step (`python/compile/aot.py`) writes a line-oriented manifest
//! describing each lowered module's I/O geometry:
//!
//! ```text
//! module dfadd file=dfadd.hlo.txt
//! input dfadd 0 dtype=f32 shape=8x128
//! output dfadd 0 dtype=f32 shape=8x128
//! ```
//!
//! (A deliberate non-JSON format: the build is offline and a JSON dep is
//! not available; this parser is ~100 lines and fully tested.)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

/// Tensor element type (only the two the accelerators use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// Shape + dtype of one input or output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }
}

/// One lowered module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModuleSpec {
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ModuleSpec {
    /// Total input payload bytes of one invocation.
    pub fn bytes_in(&self) -> usize {
        self.inputs.iter().map(TensorSpec::bytes).sum()
    }

    /// Total output payload bytes of one invocation.
    pub fn bytes_out(&self) -> usize {
        self.outputs.iter().map(TensorSpec::bytes).sum()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub modules: BTreeMap<String, ModuleSpec>,
}

impl Manifest {
    /// Parse `dir/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> crate::Result<Self> {
        let mut modules: BTreeMap<String, ModuleSpec> = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            let err = || format!("manifest line {}: {line:?}", ln + 1);
            match fields[0] {
                "module" => {
                    let [_, name, filekv] = fields[..] else {
                        bail!("{}: want `module <name> file=<f>`", err());
                    };
                    let file = filekv
                        .strip_prefix("file=")
                        .with_context(err)?
                        .to_string();
                    modules.insert(
                        name.to_string(),
                        ModuleSpec {
                            name: name.to_string(),
                            file,
                            inputs: vec![],
                            outputs: vec![],
                        },
                    );
                }
                dir_kw @ ("input" | "output") => {
                    let [_, name, idx, dtypekv, shapekv] = fields[..] else {
                        bail!("{}: want `{dir_kw} <name> <i> dtype= shape=`", err());
                    };
                    let idx: usize = idx.parse().with_context(err)?;
                    let dtype = DType::parse(dtypekv.strip_prefix("dtype=").with_context(err)?)?;
                    let shape: Vec<usize> = shapekv
                        .strip_prefix("shape=")
                        .with_context(err)?
                        .split('x')
                        .map(|d| d.parse::<usize>())
                        .collect::<Result<_, _>>()
                        .with_context(err)?;
                    let m = modules
                        .get_mut(name)
                        .with_context(|| format!("{}: unknown module {name}", err()))?;
                    let list = if dir_kw == "input" {
                        &mut m.inputs
                    } else {
                        &mut m.outputs
                    };
                    if list.len() != idx {
                        bail!("{}: index {idx} out of order (have {})", err(), list.len());
                    }
                    list.push(TensorSpec { dtype, shape });
                }
                other => bail!("{}: unknown keyword {other:?}", err()),
            }
        }
        for m in modules.values() {
            if m.inputs.is_empty() || m.outputs.is_empty() {
                bail!("module {} missing inputs or outputs", m.name);
            }
        }
        Ok(Self { dir, modules })
    }

    pub fn get(&self, name: &str) -> crate::Result<&ModuleSpec> {
        self.modules
            .get(name)
            .with_context(|| format!("no module {name:?} in manifest"))
    }

    /// Absolute path to a module's HLO text file.
    pub fn hlo_path(&self, name: &str) -> crate::Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
module dfadd file=dfadd.hlo.txt
input dfadd 0 dtype=f32 shape=8x128
input dfadd 1 dtype=f32 shape=8x128
output dfadd 0 dtype=f32 shape=8x128
module gsm file=gsm.hlo.txt
input gsm 0 dtype=f32 shape=160x128
output gsm 0 dtype=f32 shape=16x128
output gsm 1 dtype=f32 shape=8x128
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.modules.len(), 2);
        let dfadd = m.get("dfadd").unwrap();
        assert_eq!(dfadd.inputs.len(), 2);
        assert_eq!(dfadd.bytes_in(), 2 * 8 * 128 * 4);
        assert_eq!(dfadd.bytes_out(), 8 * 128 * 4);
        let gsm = m.get("gsm").unwrap();
        assert_eq!(gsm.outputs.len(), 2);
        assert_eq!(gsm.outputs[1].shape, vec![8, 128]);
        assert_eq!(m.hlo_path("gsm").unwrap(), PathBuf::from("/a/gsm.hlo.txt"));
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = "module x file=x\ninput x 0 dtype=f64 shape=2\noutput x 0 dtype=f32 shape=2\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_out_of_order_index() {
        let bad = "module x file=x\ninput x 1 dtype=f32 shape=2\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_io_for_unknown_module() {
        let bad = "input y 0 dtype=f32 shape=2\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_module_without_outputs() {
        let bad = "module x file=x\ninput x 0 dtype=f32 shape=2\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn skips_comments_and_blank_lines(){
        let ok = "# c\n\nmodule x file=x\ninput x 0 dtype=f32 shape=2x3\noutput x 0 dtype=s32 shape=4\n";
        let m = Manifest::parse(ok, PathBuf::new()).unwrap();
        assert_eq!(m.get("x").unwrap().inputs[0].elems(), 6);
        assert_eq!(m.get("x").unwrap().outputs[0].dtype, DType::S32);
    }
}
