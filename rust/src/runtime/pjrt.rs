//! PJRT-backed functional datapath.
//!
//! Loads each `artifacts/<name>.hlo.txt` once, compiles it on the PJRT
//! CPU client (`xla` crate), and executes invocations with [`Block`]
//! inputs/outputs. Adapted from /opt/xla-example/src/bin/load_hlo.rs:
//! HLO *text* interchange + `return_tuple=True` unwrapping.

use std::collections::HashMap;

use anyhow::{bail, Context};

use super::manifest::{DType, Manifest, ModuleSpec};
use super::AccelCompute;
use crate::mem::Block;

/// One compiled module.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    spec: ModuleSpec,
}

/// PJRT CPU backend holding all compiled accelerator executables.
pub struct PjrtCompute {
    _client: xla::PjRtClient,
    modules: HashMap<String, Loaded>,
    /// Invocation counter (perf reporting).
    pub invocations: u64,
}

impl PjrtCompute {
    /// Load and compile every module in the manifest at `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        Self::from_manifest(manifest)
    }

    /// Load and compile from a parsed manifest.
    pub fn from_manifest(manifest: Manifest) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut modules = HashMap::new();
        for (name, spec) in &manifest.modules {
            let path = manifest.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling module {name}"))?;
            modules.insert(
                name.clone(),
                Loaded {
                    exe,
                    spec: spec.clone(),
                },
            );
        }
        Ok(Self {
            _client: client,
            modules,
            invocations: 0,
        })
    }

    pub fn module_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.modules.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> crate::Result<&ModuleSpec> {
        Ok(&self
            .modules
            .get(name)
            .with_context(|| format!("module {name:?} not loaded"))?
            .spec)
    }

    fn block_to_literal(block: &Block, spec: &super::TensorSpec) -> crate::Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match (block, spec.dtype) {
            (Block::F32(v), DType::F32) => {
                if v.len() != spec.elems() {
                    bail!("input has {} words, spec wants {}", v.len(), spec.elems());
                }
                xla::Literal::vec1(v).reshape(&dims)?
            }
            (Block::I32(v), DType::S32) => {
                if v.len() != spec.elems() {
                    bail!("input has {} words, spec wants {}", v.len(), spec.elems());
                }
                xla::Literal::vec1(v).reshape(&dims)?
            }
            _ => bail!("block dtype does not match spec dtype"),
        };
        Ok(lit)
    }

    fn literal_to_block(lit: &xla::Literal, dtype: DType) -> crate::Result<Block> {
        Ok(match dtype {
            DType::F32 => Block::F32(lit.to_vec::<f32>()?),
            DType::S32 => Block::I32(lit.to_vec::<i32>()?),
        })
    }
}

impl AccelCompute for PjrtCompute {
    fn invoke(&mut self, name: &str, inputs: &[&Block]) -> crate::Result<Vec<Block>> {
        let loaded = self
            .modules
            .get(name)
            .with_context(|| format!("module {name:?} not loaded"))?;
        let spec = &loaded.spec;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(b, ts)| Self::block_to_literal(b, ts))
            .collect::<crate::Result<_>>()?;

        let result = loaded.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        let parts = result.to_tuple().context("untupling result")?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: {} outputs returned, manifest wants {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        self.invocations += 1;
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, ts)| Self::literal_to_block(lit, ts.dtype))
            .collect()
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn fork(&self) -> crate::Result<Box<dyn AccelCompute>> {
        bail!(
            "the PJRT backend holds compiled executables and cannot be \
             forked; snapshot/fork sweeps need the native RefCompute \
             backend"
        )
    }
}

// PjRtClient/LoadedExecutable wrap thread-safe XLA objects; the xla crate
// just doesn't mark them Send/Sync. The simulator only ever mutates the
// backend from one thread at a time (it is behind &mut), so this is
// sound.
unsafe impl Send for PjrtCompute {}
unsafe impl Sync for PjrtCompute {}
