//! Network-on-chip substrate: a 2D-mesh, input-buffered, wormhole-routed
//! interconnect modelled after ESP's multi-plane NoC.
//!
//! The paper's SoCs attach one tile per NoC node of a 4-by-4 mesh; the
//! NoC (plus memory controller) forms its own frequency island, so flits
//! crossing from a tile into the NoC pass a resynchronizer (handled by the
//! link FIFOs' ready-time stamps, see [`link`]).
//!
//! Planes: like ESP, the NoC is physically replicated into independent
//! planes to keep message classes from deadlocking each other — plane 0
//! carries DMA requests, plane 1 DMA responses, plane 2 MMIO/config.

pub mod link;
pub mod packet;
pub mod router;
pub mod topology;

pub use link::{LinkFifo, LinkId};
pub use packet::{Flit, FlitKind, Msg, Packet, PacketArena, PacketId, Plane, NUM_PLANES};
pub use router::{ClockView, OutputRef, Router, RouterCtx, RouterStats};
pub use topology::{Mesh, NodeId, Port, NUM_PORTS};
