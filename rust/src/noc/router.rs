//! Input-buffered wormhole router.
//!
//! One [`Router`] instance routes one NoC plane at one mesh node. Per
//! cycle and per output port it either continues a wormhole-allocated
//! packet or arbitrates (round-robin) among input ports whose head flit
//! routes to that output; at most one flit advances per output per cycle.
//! Flow control is credit-shaped: a flit only moves if the downstream
//! FIFO has space.
//!
//! Input FIFOs live in the fabric's central link arena (see
//! [`super::link`]); the router holds only indices, so a tick borrows the
//! arena once and never aliases another router's state.

use super::link::{LinkFifo, LinkId};
use super::topology::{Mesh, NodeId, Port, NUM_PORTS};
use crate::sim::event::{Deadline, EventSource, Outcome};
use crate::util::Ps;

/// Where an output port sends flits, and how the push is timestamped.
#[derive(Debug, Clone, Copy)]
pub struct OutputRef {
    pub link: LinkId,
    /// Island of the consumer (for CDC stamping). Same island as the
    /// router -> plain pipeline delay.
    pub dst_island: usize,
}

/// Per-router statistics (exposed through the monitoring infrastructure).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    /// Flits forwarded (all ports).
    pub flits: u64,
    /// Cycles in which at least one output wanted to move a flit but
    /// could not (back-pressure or head-of-line block).
    pub stall_cycles: u64,
    /// Packets whose head was routed (wormhole allocations).
    pub packets: u64,
}

/// Timing view the engine passes to ticking components so producers can
/// stamp `ready_at` for consumers in other islands.
#[derive(Debug, Clone)]
pub struct ClockView {
    /// Per-island current period (ps).
    pub periods: Vec<Ps>,
    /// Per-island last delivered edge (phase anchor).
    pub last_edges: Vec<Ps>,
    /// Router pipeline depth in cycles (ESP NoC: lookahead + output reg).
    pub pipeline: u64,
    /// Synchronizer stages at island boundaries.
    pub sync_stages: u64,
}

impl ClockView {
    /// `ready_at` stamp for a word produced at `now` in `src` island,
    /// consumed in `dst` island.
    pub fn ready_at(&self, now: Ps, src: usize, dst: usize) -> Ps {
        let extra = (self.pipeline - 1) * self.periods[src];
        if src == dst {
            now + extra + 1
        } else {
            crate::clock::cdc_delay(
                now + extra,
                self.last_edges[dst],
                self.periods[dst],
                self.sync_stages,
            )
        }
    }
}

/// Wormhole allocation state of one output port.
#[derive(Debug, Clone, Copy, Default)]
struct OutAlloc {
    /// Input port currently holding this output, if any.
    holder: Option<usize>,
}

/// One router (single plane, single node).
#[derive(Debug, Clone)]
pub struct Router {
    pub node: NodeId,
    pub island: usize,
    /// Input FIFO per port (indices into the fabric link arena).
    pub inputs: [LinkId; NUM_PORTS],
    /// Downstream reference per output port; `None` at mesh edges.
    pub outputs: [Option<OutputRef>; NUM_PORTS],
    alloc: [OutAlloc; NUM_PORTS],
    /// Round-robin pointer per output port.
    rr: [usize; NUM_PORTS],
    pub stats: RouterStats,
}

impl Router {
    pub fn new(
        node: NodeId,
        island: usize,
        inputs: [LinkId; NUM_PORTS],
        outputs: [Option<OutputRef>; NUM_PORTS],
    ) -> Self {
        Self {
            node,
            island,
            inputs,
            outputs,
            alloc: [OutAlloc::default(); NUM_PORTS],
            rr: [0; NUM_PORTS],
            stats: RouterStats::default(),
        }
    }

    /// Whether any output port currently holds a wormhole allocation.
    /// A held grant accrues `stall_cycles` whenever it cannot advance,
    /// so the idle-aware engine must tick such a router every cycle.
    pub fn holds_grant(&self) -> bool {
        self.alloc.iter().any(|a| a.holder.is_some())
    }

    /// One cycle at time `now`. `links` is the fabric's FIFO arena.
    /// Returns `true` when the router had (potential) work this cycle —
    /// a held grant or any buffered input flit — and `false` when the
    /// tick was the provable no-op fast path.
    pub fn tick(&mut self, now: Ps, mesh: &Mesh, links: &mut [LinkFifo], view: &ClockView) -> bool {
        // Fast path (the §Perf hot-loop optimization): with no wormhole
        // allocated and every input FIFO empty there is nothing to do —
        // 5 length checks instead of a full 5x5 arbitration scan. An
        // idle mesh costs ~0 this way.
        if !self.holds_grant()
            && self
                .inputs
                .iter()
                .all(|l| links[l.0 as usize].is_empty())
        {
            return false;
        }

        let mut stalled = false;

        // Pass 1: route each input's visible head flit once (5 peeks +
        // at most 5 route computations per cycle, instead of rescanning
        // every input for every output port).
        let mut head_route: [Option<usize>; NUM_PORTS] = [None; NUM_PORTS];
        for p in 0..NUM_PORTS {
            if let Some(f) = links[self.inputs[p].0 as usize].peek(now) {
                if f.is_head() {
                    head_route[p] = Some(mesh.route_xy(self.node, f.dst).index());
                }
            }
        }

        // Pass 2: per output, continue the allocated wormhole or grant a
        // requesting input round-robin.
        for out in 0..NUM_PORTS {
            let Some(out_ref) = self.outputs[out] else {
                continue;
            };

            let in_port = match self.alloc[out].holder {
                Some(p) => Some(p),
                None => {
                    let mut found = None;
                    for i in 0..NUM_PORTS {
                        let p = (self.rr[out] + i) % NUM_PORTS;
                        // A port never routes back on itself (no U-turns
                        // in XY).
                        if p == out && Port::from_index(out) != Port::Local {
                            continue;
                        }
                        if head_route[p] == Some(out) {
                            self.rr[out] = (p + 1) % NUM_PORTS;
                            self.alloc[out].holder = Some(p);
                            found = Some(p);
                            break;
                        }
                    }
                    found
                }
            };
            let Some(in_port) = in_port else {
                continue;
            };

            // Move one flit if the head is visible and downstream has
            // space.
            let ready = links[self.inputs[in_port].0 as usize].peek(now).is_some();
            let space = links[out_ref.link.0 as usize].can_push();
            if ready && space {
                let flit = links[self.inputs[in_port].0 as usize].pop(now).unwrap();
                head_route[in_port] = None; // consumed this cycle
                let t = view.ready_at(now, self.island, out_ref.dst_island);
                links[out_ref.link.0 as usize].push(flit, t);
                self.stats.flits += 1;
                if flit.is_head() {
                    self.stats.packets += 1;
                }
                self.alloc[out].holder = if flit.is_tail() { None } else { Some(in_port) };
            } else if self.alloc[out].holder.is_some() {
                // Allocated but could not advance: a genuine stall.
                stalled = true;
            }
        }

        if stalled {
            self.stats.stall_cycles += 1;
        }
        true
    }

    /// Earliest instant any buffered input head flit becomes visible.
    fn next_input_ready(&self, links: &[LinkFifo]) -> Option<Ps> {
        let mut next: Option<Ps> = None;
        for l in &self.inputs {
            if let Some(rt) = links[l.0 as usize].head_ready_at() {
                next = Some(next.map_or(rt, |n| n.min(rt)));
            }
        }
        next
    }
}

/// Shared engine state a router touches during one cycle, packaged for
/// the [`EventSource`] contract.
pub struct RouterCtx<'a> {
    /// NoC-island cycle count at this edge.
    pub cycle: u64,
    pub mesh: &'a Mesh,
    /// The fabric's link-FIFO arena.
    pub links: &'a mut [LinkFifo],
    pub view: &'a ClockView,
}

impl EventSource for Router {
    type Ctx<'a> = RouterCtx<'a>;

    fn next_deadline(&self, ctx: &RouterCtx<'_>) -> Deadline {
        if self.holds_grant() {
            // A held wormhole grant accrues stall statistics every
            // cycle; the router must run each edge until it releases.
            return Deadline::Cycle(0);
        }
        match self.next_input_ready(&*ctx.links) {
            Some(rt) => Deadline::At(rt),
            None => Deadline::OnInput,
        }
    }

    fn fire(&mut self, now: Ps, ctx: &mut RouterCtx<'_>) -> Outcome {
        let did_work = self.tick(now, ctx.mesh, ctx.links, ctx.view);
        let next = if self.holds_grant() {
            Deadline::Cycle(ctx.cycle + 1)
        } else {
            // A remaining buffered head (possibly already visible, if
            // two were queued) re-arms the router at its `ready_at`.
            match self.next_input_ready(ctx.links) {
                Some(rt) => Deadline::At(rt),
                None => Deadline::OnInput,
            }
        };
        Outcome { did_work, next }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::{Flit, PacketId};

    fn view() -> ClockView {
        ClockView {
            periods: vec![10_000],
            last_edges: vec![0],
            pipeline: 1,
            sync_stages: 2,
        }
    }

    fn flit(pkt: u32, seq: u16, len: u16, dst: NodeId) -> Flit {
        Flit {
            packet: PacketId(pkt),
            seq,
            len,
            dst,
        }
    }

    /// Build a 2x1 mesh with a router at node 0; east output feeds
    /// link[5]; all inputs are links[0..5].
    fn setup() -> (Mesh, Router, Vec<LinkFifo>) {
        let mesh = Mesh::new(2, 1);
        let mut links: Vec<LinkFifo> = (0..6).map(|_| LinkFifo::new(4)).collect();
        links[5] = LinkFifo::new(2); // small downstream for backpressure
        let inputs = [LinkId(0), LinkId(1), LinkId(2), LinkId(3), LinkId(4)];
        let mut outputs: [Option<OutputRef>; NUM_PORTS] = [None; NUM_PORTS];
        outputs[Port::East.index()] = Some(OutputRef {
            link: LinkId(5),
            dst_island: 0,
        });
        let r = Router::new(NodeId(0), 0, inputs, outputs);
        (mesh, r, links)
    }

    #[test]
    fn idle_tick_reports_no_work() {
        let (mesh, mut r, mut links) = setup();
        assert!(!r.tick(10_000, &mesh, &mut links, &view()));
        assert!(!r.holds_grant());
        links[Port::Local.index()].push(flit(1, 0, 2, NodeId(1)), 0);
        assert!(r.tick(20_000, &mesh, &mut links, &view()));
        assert!(r.holds_grant(), "wormhole held until the tail moves");
    }

    #[test]
    fn routes_single_flit_packet_east() {
        let (mesh, mut r, mut links) = setup();
        links[Port::Local.index()].push(flit(1, 0, 1, NodeId(1)), 0);
        r.tick(10_000, &mesh, &mut links, &view());
        assert_eq!(links[5].len(), 1);
        assert_eq!(r.stats.flits, 1);
        assert_eq!(r.stats.packets, 1);
    }

    #[test]
    fn wormhole_holds_output_until_tail() {
        let (mesh, mut r, mut links) = setup();
        // 3-flit packet from Local, competing head from West.
        for s in 0..3 {
            links[Port::Local.index()].push(flit(1, s, 3, NodeId(1)), 0);
        }
        links[Port::West.index()].push(flit(2, 0, 1, NodeId(1)), 0);
        // Drain downstream each cycle (its capacity is only 2).
        let mut moved = Vec::new();
        let mut t = 10_000;
        for _ in 0..4 {
            r.tick(t, &mesh, &mut links, &view());
            while let Some(f) = links[5].pop(u64::MAX) {
                moved.push(f.packet.0);
            }
            t += 10_000;
        }
        // RR grants West's single-flit pkt 2 first, then pkt 1's three
        // flits move back-to-back — never interleaved.
        assert_eq!(moved, vec![2, 1, 1, 1]);
    }

    #[test]
    fn backpressure_stalls() {
        let (mesh, mut r, mut links) = setup();
        for s in 0..4 {
            links[Port::Local.index()].push(flit(1, s, 4, NodeId(1)), 0);
        }
        // Downstream cap is 2: after two ticks it is full.
        let mut t = 10_000;
        for _ in 0..4 {
            r.tick(t, &mesh, &mut links, &view());
            t += 10_000;
        }
        assert_eq!(links[5].len(), 2);
        assert!(r.stats.stall_cycles >= 2, "stalls {}", r.stats.stall_cycles);
        assert_eq!(r.stats.flits, 2);
    }

    #[test]
    fn flits_not_visible_same_cycle() {
        let (mesh, mut r, mut links) = setup();
        links[Port::Local.index()].push(flit(1, 0, 1, NodeId(1)), 500);
        // Visible only at ready_at=500; tick at 400 moves nothing.
        r.tick(400, &mesh, &mut links, &view());
        assert_eq!(r.stats.flits, 0);
        r.tick(500, &mesh, &mut links, &view());
        assert_eq!(r.stats.flits, 1);
    }

    #[test]
    fn event_source_deadlines_track_state() {
        let (mesh, mut r, mut links) = setup();
        let v = view();
        {
            let ctx = RouterCtx {
                cycle: 0,
                mesh: &mesh,
                links: &mut links,
                view: &v,
            };
            assert_eq!(r.next_deadline(&ctx), Deadline::OnInput, "idle router");
        }
        // A buffered future flit arms an At deadline; firing early is a
        // no-op that keeps it armed.
        links[Port::Local.index()].push(flit(1, 0, 2, NodeId(1)), 500);
        let mut ctx = RouterCtx {
            cycle: 3,
            mesh: &mesh,
            links: &mut links,
            view: &v,
        };
        assert_eq!(r.next_deadline(&ctx), Deadline::At(500));
        let out = r.fire(400, &mut ctx);
        assert_eq!(out.next, Deadline::At(500));
        assert_eq!(r.stats.flits, 0, "head not visible yet: nothing moved");
        // Once visible, firing routes the head and the held wormhole
        // demands a next-cycle deadline.
        let out = r.fire(500, &mut ctx);
        assert!(out.did_work);
        assert_eq!(out.next, Deadline::Cycle(4), "grant held until tail");
    }

    #[test]
    fn rr_arbitration_alternates_inputs() {
        let (mesh, mut r, mut links) = setup();
        // Two single-flit streams from Local and West, same output.
        for i in 0..3 {
            links[Port::Local.index()].push(flit(10 + i, 0, 1, NodeId(1)), 0);
            links[Port::West.index()].push(flit(20 + i, 0, 1, NodeId(1)), 0);
        }
        let mut order = Vec::new();
        let mut t = 10_000;
        for _ in 0..6 {
            r.tick(t, &mesh, &mut links, &view());
            while let Some(f) = links[5].pop(u64::MAX) {
                order.push(f.packet.0 / 10);
            }
            t += 10_000;
        }
        // Both sources served, interleaved (no starvation).
        assert_eq!(order.len(), 6);
        assert!(order.windows(2).any(|w| w[0] != w[1]), "{order:?}");
        assert_eq!(order.iter().filter(|&&s| s == 1).count(), 3);
    }
}
