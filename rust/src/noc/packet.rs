//! Packets, flits, and message payloads.
//!
//! The hot loop moves [`Flit`]s — small `Copy` values carrying a packet
//! index — while full [`Packet`] descriptors live in a free-listed
//! [`PacketArena`]. DMA payload *data* never rides in flits: blocks of
//! real numbers live in [`crate::mem::BlockStore`] and messages reference
//! them by id, so the functional datapath (PJRT kernels) and the timing
//! datapath (flits) stay coherent without per-flit allocation.

use super::topology::NodeId;
use crate::mem::BlockId;

/// Physical NoC plane (independent sub-network, as in ESP's 6-plane NoC).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Plane {
    /// DMA read/write requests (tile -> MEM).
    Request = 0,
    /// DMA responses (MEM -> tile).
    Response = 1,
    /// MMIO / configuration traffic.
    Config = 2,
}

pub const NUM_PLANES: usize = 3;

impl Plane {
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Plane {
        [Plane::Request, Plane::Response, Plane::Config][i]
    }
}

/// Message payloads. One message = one packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Msg {
    /// DMA read burst request: `beats` data words starting at `addr`.
    /// `tag` routes the response back to the issuing DMA engine/replica.
    MemRead { addr: u64, beats: u16, tag: u32 },
    /// DMA write burst: data carried as `beats` payload flits; the
    /// functional content is `block[offset..offset+beats]`.
    MemWrite {
        addr: u64,
        beats: u16,
        tag: u32,
        block: BlockId,
        offset: u32,
    },
    /// Read response carrying `beats` data words.
    MemReadResp {
        beats: u16,
        tag: u32,
        block: BlockId,
        offset: u32,
    },
    /// Write acknowledgement.
    MemWriteAck { tag: u32 },
    /// MMIO register write (CPU/host -> any tile or frequency register).
    MmioWrite { addr: u64, value: u64 },
    /// MMIO register read request.
    MmioRead { addr: u64, tag: u32 },
    /// MMIO read response.
    MmioResp { value: u64, tag: u32 },
}

impl Msg {
    /// Payload beats carried by the packet body (on top of the header).
    pub fn payload_beats(&self) -> u16 {
        match self {
            Msg::MemWrite { beats, .. } | Msg::MemReadResp { beats, .. } => *beats,
            _ => 0,
        }
    }

    /// The plane this message class travels on.
    pub fn plane(&self) -> Plane {
        match self {
            Msg::MemRead { .. } | Msg::MemWrite { .. } => Plane::Request,
            Msg::MemReadResp { .. } | Msg::MemWriteAck { .. } => Plane::Response,
            Msg::MmioWrite { .. } | Msg::MmioRead { .. } | Msg::MmioResp { .. } => Plane::Config,
        }
    }
}

/// Index of a live packet in the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PacketId(pub u32);

/// A packet in flight: header metadata + payload length.
#[derive(Debug, Clone)]
pub struct Packet {
    pub src: NodeId,
    pub dst: NodeId,
    pub msg: Msg,
    /// Total flits: 1 header + payload beats (ESP-style single-flit
    /// header carrying route info; tail is the last payload flit, or the
    /// header itself for header-only packets).
    pub len_flits: u16,
    /// Injection timestamp (for NoC latency stats).
    pub injected_at: crate::util::Ps,
    /// Generation counter to catch stale ids in debug builds.
    pub gen: u32,
}

/// One flow-control unit. `Copy`, 16 bytes, moved by value in the hot loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flit {
    pub packet: PacketId,
    /// 0-based position within the packet.
    pub seq: u16,
    /// Total packet length (replicated so routers need no arena lookup
    /// for wormhole bookkeeping).
    pub len: u16,
    pub dst: NodeId,
}

/// Flit position classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlitKind {
    Head,
    Body,
    Tail,
    /// Single-flit packet (header only).
    HeadTail,
}

impl Flit {
    pub fn kind(&self) -> FlitKind {
        let last = self.seq + 1 == self.len;
        match (self.seq == 0, last) {
            (true, true) => FlitKind::HeadTail,
            (true, false) => FlitKind::Head,
            (false, true) => FlitKind::Tail,
            (false, false) => FlitKind::Body,
        }
    }

    pub fn is_head(&self) -> bool {
        self.seq == 0
    }

    pub fn is_tail(&self) -> bool {
        self.seq + 1 == self.len
    }
}

/// Free-listed arena of live packets (no allocation in the hot loop once
/// warmed up). `Clone` deep-copies every slot and the free list, so a
/// forked simulation ([`crate::sim::Soc::fork`]) keeps identical packet
/// ids and generation counters.
#[derive(Debug, Default, Clone)]
pub struct PacketArena {
    slots: Vec<Packet>,
    free: Vec<u32>,
    live: usize,
    /// Monotonic allocation counter (stats; also feeds `gen`).
    allocated: u64,
}

impl PacketArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a packet; returns its id. `len_flits` is derived from the
    /// message payload (1 header + payload beats).
    pub fn alloc(
        &mut self,
        src: NodeId,
        dst: NodeId,
        msg: Msg,
        injected_at: crate::util::Ps,
    ) -> PacketId {
        let len_flits = 1 + msg.payload_beats();
        self.allocated += 1;
        self.live += 1;
        let gen = self.allocated as u32;
        let pkt = Packet {
            src,
            dst,
            msg,
            len_flits,
            injected_at,
            gen,
        };
        if let Some(idx) = self.free.pop() {
            self.slots[idx as usize] = pkt;
            PacketId(idx)
        } else {
            self.slots.push(pkt);
            PacketId((self.slots.len() - 1) as u32)
        }
    }

    pub fn get(&self, id: PacketId) -> &Packet {
        &self.slots[id.0 as usize]
    }

    /// Release a packet (after ejection at its destination).
    pub fn release(&mut self, id: PacketId) {
        self.live -= 1;
        self.free.push(id.0);
    }

    pub fn live(&self) -> usize {
        self.live
    }

    pub fn allocated(&self) -> u64 {
        self.allocated
    }

    /// Total slots ever created (live + free-listed). Bounded by the
    /// peak number of simultaneously live packets, not by `allocated`.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Build the `seq`-th flit of packet `id`.
    pub fn flit(&self, id: PacketId, seq: u16) -> Flit {
        let p = self.get(id);
        debug_assert!(seq < p.len_flits);
        Flit {
            packet: id,
            seq,
            len: p.len_flits,
            dst: p.dst,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_arena() -> (PacketArena, PacketId) {
        let mut a = PacketArena::new();
        let id = a.alloc(
            NodeId(0),
            NodeId(5),
            Msg::MemReadResp {
                beats: 16,
                tag: 7,
                block: BlockId(1),
                offset: 0,
            },
            100,
        );
        (a, id)
    }

    #[test]
    fn packet_length_includes_header() {
        let (a, id) = mk_arena();
        assert_eq!(a.get(id).len_flits, 17);
    }

    #[test]
    fn flit_kinds() {
        let (a, id) = mk_arena();
        assert_eq!(a.flit(id, 0).kind(), FlitKind::Head);
        assert_eq!(a.flit(id, 8).kind(), FlitKind::Body);
        assert_eq!(a.flit(id, 16).kind(), FlitKind::Tail);

        let mut a2 = PacketArena::new();
        let single = a2.alloc(
            NodeId(0),
            NodeId(1),
            Msg::MemRead {
                addr: 0,
                beats: 16,
                tag: 0,
            },
            0,
        );
        assert_eq!(a2.flit(single, 0).kind(), FlitKind::HeadTail);
    }

    #[test]
    fn arena_reuses_slots() {
        let (mut a, id) = mk_arena();
        let first_idx = id.0;
        a.release(id);
        assert_eq!(a.live(), 0);
        let id2 = a.alloc(
            NodeId(1),
            NodeId(2),
            Msg::MemWriteAck { tag: 1 },
            5,
        );
        assert_eq!(id2.0, first_idx, "slot reused");
        assert_eq!(a.live(), 1);
        assert_eq!(a.allocated(), 2);
    }

    fn header_only(a: &mut PacketArena, tag: u32) -> PacketId {
        a.alloc(NodeId(0), NodeId(1), Msg::MemWriteAck { tag }, 0)
    }

    /// Alloc/free/realloc cycles must keep the slot vector bounded by
    /// the peak live count while the free list recycles indices.
    #[test]
    fn free_list_bounds_slot_growth() {
        let mut a = PacketArena::new();
        // Peak occupancy: 4 live packets.
        let ids: Vec<PacketId> = (0..4).map(|i| header_only(&mut a, i)).collect();
        assert_eq!(a.capacity(), 4);
        // 100 full churn rounds at the same peak: no new slots.
        let mut ids = ids;
        for round in 0..100 {
            for id in ids.drain(..) {
                a.release(id);
            }
            assert_eq!(a.live(), 0);
            ids = (0..4).map(|i| header_only(&mut a, round * 4 + i)).collect();
            assert_eq!(a.live(), 4);
            assert_eq!(a.capacity(), 4, "free list must recycle, not grow");
        }
        assert_eq!(a.allocated(), 4 * 101);
    }

    /// The `gen` counter must stay fresh across recycles: a slot reused
    /// by a new packet carries a generation distinct from every earlier
    /// occupant of the same slot.
    #[test]
    fn recycled_slots_get_fresh_generations() {
        let mut a = PacketArena::new();
        let mut seen = std::collections::HashSet::new();
        let mut last_gen = 0;
        for i in 0..50 {
            let id = header_only(&mut a, i);
            assert_eq!(id.0, 0, "single-packet churn reuses slot 0");
            let gen = a.get(id).gen;
            assert!(seen.insert(gen), "generation {gen} reused");
            assert!(gen > last_gen, "generations must be monotonic");
            last_gen = gen;
            a.release(id);
        }
        assert_eq!(a.allocated(), 50);
        assert_eq!(a.capacity(), 1);
    }

    /// Interleaved alloc/release (the NoC's steady state) keeps ids
    /// valid: every live id resolves to its own packet, never a stale
    /// neighbour's.
    #[test]
    fn interleaved_churn_keeps_ids_coherent() {
        let mut a = PacketArena::new();
        let mut live: Vec<(PacketId, u32)> = Vec::new();
        for i in 0u32..200 {
            if i % 3 == 2 {
                let (id, tag) = live.remove((i as usize * 7) % live.len());
                match a.get(id).msg {
                    Msg::MemWriteAck { tag: t } => assert_eq!(t, tag),
                    other => panic!("id {id:?} resolved to {other:?}"),
                }
                a.release(id);
            } else {
                let id = header_only(&mut a, i);
                live.push((id, i));
            }
        }
        for (id, tag) in live {
            match a.get(id).msg {
                Msg::MemWriteAck { tag: t } => assert_eq!(t, tag),
                other => panic!("id {id:?} resolved to {other:?}"),
            }
            a.release(id);
        }
        assert_eq!(a.live(), 0);
    }

    #[test]
    fn planes_by_message_class() {
        assert_eq!(
            Msg::MemRead {
                addr: 0,
                beats: 1,
                tag: 0
            }
            .plane(),
            Plane::Request
        );
        assert_eq!(Msg::MemWriteAck { tag: 0 }.plane(), Plane::Response);
        assert_eq!(Msg::MmioRead { addr: 0, tag: 0 }.plane(), Plane::Config);
    }
}
