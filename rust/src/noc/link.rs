//! Link FIFOs: the buffered channels between routers, and between routers
//! and tile network interfaces.
//!
//! Every entry carries a `ready_at` timestamp. Producers stamp flits with
//! the time the downstream consumer may first observe them:
//!
//! * same frequency island — one router pipeline delay;
//! * across islands — the resynchronizer latency ([`crate::clock::cdc_delay`]).
//!
//! Consumers only pop entries whose `ready_at` has passed, which yields
//! registered (edge-to-edge) semantics without a two-phase tick.

use std::collections::VecDeque;

use super::packet::Flit;
use crate::util::Ps;

/// Index of a link FIFO in the fabric's arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId(pub u32);

/// A bounded FIFO of timed flits.
#[derive(Debug, Clone)]
pub struct LinkFifo {
    cap: usize,
    q: VecDeque<(Ps, Flit)>,
    /// Total flits ever pushed (stats).
    pub pushed: u64,
    /// Injected fault windows (sorted, disjoint): a flit whose
    /// `ready_at` lands inside a window is deferred to the window's
    /// end — the link "flaps" without reordering the FIFO (the
    /// deferral map is monotone). Empty outside chaos runs.
    fault_windows: Vec<(Ps, Ps)>,
}

impl LinkFifo {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            q: VecDeque::with_capacity(cap),
            pushed: 0,
            fault_windows: Vec::new(),
        }
    }

    /// Install fault windows ([`crate::fault`]); merged with any
    /// already present.
    pub fn add_fault_windows(&mut self, windows: &[(Ps, Ps)]) {
        self.fault_windows.extend_from_slice(windows);
        crate::fault::normalize_windows(&mut self.fault_windows);
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Space check — models the upstream credit counter.
    pub fn can_push(&self) -> bool {
        self.q.len() < self.cap
    }

    /// Push a flit that becomes visible at `ready_at`. Panics if full
    /// (callers must check `can_push`, as hardware checks credits).
    pub fn push(&mut self, flit: Flit, ready_at: Ps) {
        assert!(self.can_push(), "link overflow: credit protocol violated");
        let ready_at = if self.fault_windows.is_empty() {
            ready_at
        } else {
            crate::fault::deferred_ready(&self.fault_windows, ready_at)
        };
        debug_assert!(
            self.q.back().map_or(true, |(t, _)| *t <= ready_at),
            "FIFO ordering violated"
        );
        self.q.push_back((ready_at, flit));
        self.pushed += 1;
    }

    /// `ready_at` stamp of the head flit, if any — the earliest instant
    /// this FIFO can produce work. The idle-aware engine uses this as a
    /// wakeup: a non-empty FIFO whose head is still in flight (CDC or
    /// pipeline delay) provably yields no-op ticks until this time.
    pub fn head_ready_at(&self) -> Option<Ps> {
        self.q.front().map(|(t, _)| *t)
    }

    /// Head flit if it is visible at `now`.
    pub fn peek(&self, now: Ps) -> Option<&Flit> {
        match self.q.front() {
            Some((t, f)) if *t <= now => Some(f),
            _ => None,
        }
    }

    /// Pop the head flit if visible at `now`.
    pub fn pop(&mut self, now: Ps) -> Option<Flit> {
        if self.peek(now).is_some() {
            self.q.pop_front().map(|(_, f)| f)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::PacketId;
    use crate::noc::topology::NodeId;

    fn flit(seq: u16) -> Flit {
        Flit {
            packet: PacketId(0),
            seq,
            len: 4,
            dst: NodeId(3),
        }
    }

    #[test]
    fn respects_ready_time() {
        let mut l = LinkFifo::new(4);
        l.push(flit(0), 100);
        assert!(l.peek(99).is_none());
        assert!(l.peek(100).is_some());
        assert_eq!(l.pop(100).unwrap().seq, 0);
    }

    #[test]
    fn capacity_enforced() {
        let mut l = LinkFifo::new(2);
        l.push(flit(0), 0);
        l.push(flit(1), 0);
        assert!(!l.can_push());
    }

    #[test]
    #[should_panic(expected = "credit protocol violated")]
    fn overflow_panics() {
        let mut l = LinkFifo::new(1);
        l.push(flit(0), 0);
        l.push(flit(1), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut l = LinkFifo::new(4);
        for i in 0..4 {
            l.push(flit(i), (i as u64) * 10);
        }
        for i in 0..4 {
            assert_eq!(l.pop(1000).unwrap().seq, i);
        }
        assert!(l.is_empty());
    }

    #[test]
    fn head_ready_at_reports_earliest_work() {
        let mut l = LinkFifo::new(4);
        assert_eq!(l.head_ready_at(), None);
        l.push(flit(0), 70);
        l.push(flit(1), 90);
        assert_eq!(l.head_ready_at(), Some(70));
        l.pop(100);
        assert_eq!(l.head_ready_at(), Some(90));
    }

    #[test]
    fn fault_window_defers_but_never_reorders() {
        let mut l = LinkFifo::new(8);
        l.add_fault_windows(&[(100, 200)]);
        l.push(flit(0), 90); // before the flap: untouched
        l.push(flit(1), 120); // inside: deferred to window end
        l.push(flit(2), 250); // after: untouched
        assert_eq!(l.pop(90).unwrap().seq, 0);
        assert!(l.pop(199).is_none(), "flapped flit hidden until 200");
        assert_eq!(l.head_ready_at(), Some(200));
        assert_eq!(l.pop(200).unwrap().seq, 1);
        assert_eq!(l.pop(250).unwrap().seq, 2);
    }

    #[test]
    fn head_blocks_until_ready_even_if_later_entries_exist() {
        let mut l = LinkFifo::new(4);
        l.push(flit(0), 50);
        // Later flits cannot overtake the head.
        l.push(flit(1), 60);
        assert!(l.pop(40).is_none());
        assert_eq!(l.pop(55).unwrap().seq, 0);
        assert!(l.pop(55).is_none());
    }
}
