//! Mesh topology: node coordinates, ports, and XY dimension-order routing.

/// A NoC node (one per tile). `id = y * width + x`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u16);

impl NodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Router ports. `Local` attaches the tile's network interface.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Port {
    North = 0,
    South = 1,
    East = 2,
    West = 3,
    Local = 4,
}

pub const NUM_PORTS: usize = 5;

pub const ALL_PORTS: [Port; NUM_PORTS] =
    [Port::North, Port::South, Port::East, Port::West, Port::Local];

impl Port {
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Port {
        ALL_PORTS[i]
    }

    /// The port on the neighbouring router that faces back at us.
    pub fn opposite(self) -> Port {
        match self {
            Port::North => Port::South,
            Port::South => Port::North,
            Port::East => Port::West,
            Port::West => Port::East,
            Port::Local => Port::Local,
        }
    }
}

/// A `width x height` 2D mesh.
#[derive(Debug, Clone)]
pub struct Mesh {
    pub width: u16,
    pub height: u16,
}

impl Mesh {
    pub fn new(width: u16, height: u16) -> Self {
        assert!(width > 0 && height > 0);
        Self { width, height }
    }

    pub fn nodes(&self) -> usize {
        self.width as usize * self.height as usize
    }

    pub fn node(&self, x: u16, y: u16) -> NodeId {
        debug_assert!(x < self.width && y < self.height);
        NodeId(y * self.width + x)
    }

    pub fn coords(&self, n: NodeId) -> (u16, u16) {
        (n.0 % self.width, n.0 / self.width)
    }

    /// Neighbour of `n` through `port`, if it exists.
    pub fn neighbor(&self, n: NodeId, port: Port) -> Option<NodeId> {
        let (x, y) = self.coords(n);
        match port {
            Port::North => (y > 0).then(|| self.node(x, y - 1)),
            Port::South => (y + 1 < self.height).then(|| self.node(x, y + 1)),
            Port::East => (x + 1 < self.width).then(|| self.node(x + 1, y)),
            Port::West => (x > 0).then(|| self.node(x - 1, y)),
            Port::Local => None,
        }
    }

    /// XY dimension-order routing: the output port at `here` for a packet
    /// headed to `dst`. X first, then Y; `Local` when arrived.
    pub fn route_xy(&self, here: NodeId, dst: NodeId) -> Port {
        let (hx, hy) = self.coords(here);
        let (dx, dy) = self.coords(dst);
        if dx > hx {
            Port::East
        } else if dx < hx {
            Port::West
        } else if dy > hy {
            Port::South
        } else if dy < hy {
            Port::North
        } else {
            Port::Local
        }
    }

    /// Manhattan hop distance.
    pub fn hops(&self, a: NodeId, b: NodeId) -> u16 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn coords_roundtrip() {
        let m = Mesh::new(4, 4);
        for y in 0..4 {
            for x in 0..4 {
                let n = m.node(x, y);
                assert_eq!(m.coords(n), (x, y));
            }
        }
    }

    #[test]
    fn neighbors_edge_cases() {
        let m = Mesh::new(4, 4);
        let nw = m.node(0, 0);
        assert_eq!(m.neighbor(nw, Port::North), None);
        assert_eq!(m.neighbor(nw, Port::West), None);
        assert_eq!(m.neighbor(nw, Port::East), Some(m.node(1, 0)));
        assert_eq!(m.neighbor(nw, Port::South), Some(m.node(0, 1)));
        let se = m.node(3, 3);
        assert_eq!(m.neighbor(se, Port::South), None);
        assert_eq!(m.neighbor(se, Port::East), None);
    }

    #[test]
    fn neighbor_port_symmetry() {
        let m = Mesh::new(5, 3);
        for n in 0..m.nodes() {
            let n = NodeId(n as u16);
            for p in [Port::North, Port::South, Port::East, Port::West] {
                if let Some(nb) = m.neighbor(n, p) {
                    assert_eq!(m.neighbor(nb, p.opposite()), Some(n));
                }
            }
        }
    }

    #[test]
    fn xy_routes_x_first() {
        let m = Mesh::new(4, 4);
        assert_eq!(m.route_xy(m.node(0, 0), m.node(3, 2)), Port::East);
        assert_eq!(m.route_xy(m.node(3, 0), m.node(3, 2)), Port::South);
        assert_eq!(m.route_xy(m.node(2, 2), m.node(0, 2)), Port::West);
        assert_eq!(m.route_xy(m.node(2, 2), m.node(2, 0)), Port::North);
        assert_eq!(m.route_xy(m.node(1, 1), m.node(1, 1)), Port::Local);
    }

    #[test]
    fn prop_xy_terminates_and_matches_hops() {
        // Following route_xy from any src reaches dst in exactly
        // hops(src,dst) steps (XY is minimal and deadlock-free).
        forall(
            0x10C,
            300,
            |r| {
                let w = (r.next_below(6) + 1) as u16;
                let h = (r.next_below(6) + 1) as u16;
                let m = Mesh::new(w, h);
                let a = NodeId(r.next_below(m.nodes() as u64) as u16);
                let b = NodeId(r.next_below(m.nodes() as u64) as u16);
                (m, a, b)
            },
            |(m, a, b)| {
                let mut here = *a;
                let mut steps = 0;
                loop {
                    let p = m.route_xy(here, *b);
                    if p == Port::Local {
                        break;
                    }
                    here = m.neighbor(here, p).expect("route into the void");
                    steps += 1;
                    assert!(steps <= m.nodes() as u16, "routing loop");
                }
                assert_eq!(here, *b);
                assert_eq!(steps, m.hops(*a, *b));
            },
        );
    }
}
