//! The SoC description types, validation, and TOML loading.

use anyhow::{bail, Context};

use crate::mem::MemParams;
use crate::tiles::DmaParams;
use crate::util::time::Freq;

use super::toml::{self, View};

/// What a tile is.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TileKind {
    Cpu,
    Mem,
    Io,
    /// Traffic generator (memory-bound requester; dfadd-like).
    Tg,
    /// Multi-replica accelerator tile.
    Accel { accel: String, replicas: usize },
}

/// One tile of the grid.
#[derive(Debug, Clone)]
pub struct TileSpec {
    pub x: u16,
    pub y: u16,
    pub kind: TileKind,
    /// Frequency island index (into `SocConfig::islands`).
    pub island: usize,
}

/// One frequency island.
#[derive(Debug, Clone)]
pub struct IslandSpec {
    pub name: String,
    /// Initial (or fixed) frequency.
    pub freq_mhz: u64,
    /// Whether a DFS actuator drives this island.
    pub dfs: bool,
    pub min_mhz: u64,
    pub max_mhz: u64,
    pub step_mhz: u64,
}

/// NoC microarchitecture parameters.
#[derive(Debug, Clone)]
pub struct NocParams {
    /// Input/link FIFO depth in flits.
    pub fifo_depth: usize,
    /// Router pipeline depth in cycles.
    pub pipeline: u64,
    /// Synchronizer stages at island boundaries.
    pub sync_stages: u64,
    /// Island the routers (and MEM controller) belong to.
    pub island: usize,
}

impl Default for NocParams {
    fn default() -> Self {
        Self {
            fifo_depth: 4,
            pipeline: 2,
            sync_stages: 2,
            island: 0,
        }
    }
}

/// MRA bridge parameters (see [`crate::axi::BridgeParams`]).
#[derive(Debug, Clone)]
pub struct BridgeCfg {
    pub replica_fifo_depth: usize,
    pub tile_fifo_depth: usize,
    pub switch_cycles: u64,
}

impl Default for BridgeCfg {
    fn default() -> Self {
        Self {
            replica_fifo_depth: 8,
            tile_fifo_depth: 16,
            // Per-burst grant/setup serialization of the tile's shared
            // DMA path (descriptor setup + TLB + channel arbitration in
            // ESP's single-engine tile DMA). Calibrated so the shared
            // path binds at K=4 for the memory-bound accelerators, as
            // Table I reports (dfadd/dfmul cap at ~26 MB/s), while K=1
            // and compute-bound tiles are unaffected.
            switch_cycles: 60,
        }
    }
}

/// The complete SoC description.
#[derive(Debug, Clone)]
pub struct SocConfig {
    pub name: String,
    pub width: u16,
    pub height: u16,
    pub seed: u64,
    pub tiles: Vec<TileSpec>,
    pub islands: Vec<IslandSpec>,
    pub noc: NocParams,
    pub mem: MemParams,
    pub dma: DmaParams,
    pub bridge: BridgeCfg,
    /// CPU monitor-poll interval in CPU cycles (0 = off).
    pub cpu_poll_interval: u32,
}

impl SocConfig {
    /// Grid position -> linear node index.
    pub fn node_of(&self, x: u16, y: u16) -> usize {
        (y * self.width + x) as usize
    }

    /// The MEM tile's spec (validated unique).
    pub fn mem_tile(&self) -> &TileSpec {
        self.tiles
            .iter()
            .find(|t| t.kind == TileKind::Mem)
            .expect("validated config has a MEM tile")
    }

    /// Indices of tiles of a given predicate.
    pub fn tiles_where(&self, pred: impl Fn(&TileKind) -> bool) -> Vec<usize> {
        self.tiles
            .iter()
            .enumerate()
            .filter(|(_, t)| pred(&t.kind))
            .map(|(i, _)| i)
            .collect()
    }

    /// Validate structural invariants. Called by the SoC builder.
    pub fn validate(&self) -> crate::Result<()> {
        if self.width == 0 || self.height == 0 {
            bail!("empty grid");
        }
        if self.tiles.len() != (self.width as usize) * (self.height as usize) {
            bail!(
                "{} tiles for a {}x{} grid (need {})",
                self.tiles.len(),
                self.width,
                self.height,
                self.width * self.height
            );
        }
        let mut seen = vec![false; self.tiles.len()];
        for t in &self.tiles {
            if t.x >= self.width || t.y >= self.height {
                bail!("tile at ({}, {}) outside {}x{} grid", t.x, t.y, self.width, self.height);
            }
            let n = self.node_of(t.x, t.y);
            if seen[n] {
                bail!("duplicate tile at ({}, {})", t.x, t.y);
            }
            seen[n] = true;
            if t.island >= self.islands.len() {
                bail!("tile at ({}, {}) references island {} of {}", t.x, t.y, t.island, self.islands.len());
            }
            if let TileKind::Accel { accel, replicas } = &t.kind {
                if *replicas == 0 || *replicas > 16 {
                    bail!("tile at ({}, {}): replication {replicas} out of [1, 16]", t.x, t.y);
                }
                crate::tiles::AccelTiming::lookup(accel)
                    .with_context(|| format!("tile at ({}, {})", t.x, t.y))?;
            }
        }
        let mems = self.tiles.iter().filter(|t| t.kind == TileKind::Mem).count();
        if mems != 1 {
            bail!("need exactly one MEM tile, found {mems}");
        }
        if self.noc.island >= self.islands.len() {
            bail!("NoC island {} out of range", self.noc.island);
        }
        for isl in &self.islands {
            if isl.min_mhz == 0 || isl.max_mhz < isl.min_mhz {
                bail!("island {}: bad range [{}, {}]", isl.name, isl.min_mhz, isl.max_mhz);
            }
            if isl.freq_mhz < isl.min_mhz || isl.freq_mhz > isl.max_mhz {
                bail!("island {}: initial {} outside range", isl.name, isl.freq_mhz);
            }
            if isl.step_mhz == 0 {
                bail!("island {}: zero step", isl.name);
            }
        }
        if self.noc.pipeline == 0 {
            bail!("router pipeline must be >= 1");
        }
        Ok(())
    }

    /// Initial frequency of an island.
    pub fn island_freq(&self, i: usize) -> Freq {
        Freq::mhz(self.islands[i].freq_mhz)
    }

    /// Load from a TOML file.
    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> crate::Result<Self> {
        let doc = toml::parse(text)?;

        let soc_t = doc.table("soc");
        let soc = View::new(&soc_t, "[soc]");
        let name = soc.str_or("name", "vespa-soc")?;
        let width = soc.int_or("width", 4)? as u16;
        let height = soc.int_or("height", 4)? as u16;
        let seed = soc.int_or("seed", 0xC0FFEE)? as u64;
        let cpu_poll_interval = soc.int_or("cpu_poll_interval", 0)? as u32;

        let mut islands = Vec::new();
        for (i, t) in doc.array("island").iter().enumerate() {
            let v = View::new(t, format!("[[island]] #{i}"));
            let freq_mhz = v.int("freq_mhz")? as u64;
            islands.push(IslandSpec {
                name: v.str_or("name", &format!("island{i}"))?,
                freq_mhz,
                dfs: v.bool_or("dfs", false)?,
                min_mhz: v.int_or("min_mhz", freq_mhz as i64)? as u64,
                max_mhz: v.int_or("max_mhz", freq_mhz as i64)? as u64,
                step_mhz: v.int_or("step_mhz", 5)? as u64,
            });
        }

        let mut tiles = Vec::new();
        for (i, t) in doc.array("tile").iter().enumerate() {
            let v = View::new(t, format!("[[tile]] #{i}"));
            let pos = t
                .get("pos")
                .and_then(|p| p.as_array())
                .with_context(|| format!("[[tile]] #{i}: missing pos = [x, y]"))?;
            if pos.len() != 2 {
                bail!("[[tile]] #{i}: pos must be [x, y]");
            }
            let x = pos[0].as_int().context("pos.x")? as u16;
            let y = pos[1].as_int().context("pos.y")? as u16;
            let kind = match v.str("kind")?.as_str() {
                "cpu" => TileKind::Cpu,
                "mem" => TileKind::Mem,
                "io" => TileKind::Io,
                "tg" => TileKind::Tg,
                "accel" => TileKind::Accel {
                    accel: v.str("accel")?,
                    replicas: v.int_or("replicas", 1)? as usize,
                },
                other => bail!("[[tile]] #{i}: unknown kind {other:?}"),
            };
            tiles.push(TileSpec {
                x,
                y,
                kind,
                island: v.int("island")? as usize,
            });
        }

        let noc_t = doc.table("noc");
        let noc_v = View::new(&noc_t, "[noc]");
        let noc = NocParams {
            fifo_depth: noc_v.int_or("fifo_depth", 4)? as usize,
            pipeline: noc_v.int_or("pipeline", 2)? as u64,
            sync_stages: noc_v.int_or("sync_stages", 2)? as u64,
            island: noc_v.int_or("island", 0)? as usize,
        };

        let mem_t = doc.table("mem");
        let mem_v = View::new(&mem_t, "[mem]");
        let mem = MemParams {
            access_cycles: mem_v.int_or("access_cycles", 12)? as u64,
            queue_depth: mem_v.int_or("queue_depth", 64)? as usize,
        };

        let dma_t = doc.table("dma");
        let dma_v = View::new(&dma_t, "[dma]");
        let dma = DmaParams {
            burst_beats: dma_v.int_or("burst_beats", 16)? as u16,
            max_outstanding: dma_v.int_or("max_outstanding", 4)? as usize,
        };

        let br_t = doc.table("bridge");
        let br_v = View::new(&br_t, "[bridge]");
        let bridge = BridgeCfg {
            replica_fifo_depth: br_v.int_or("replica_fifo_depth", 8)? as usize,
            tile_fifo_depth: br_v.int_or("tile_fifo_depth", 16)? as usize,
            switch_cycles: br_v.int_or("switch_cycles", 12)? as u64,
        };

        let cfg = Self {
            name,
            width,
            height,
            seed,
            tiles,
            islands,
            noc,
            mem,
            dma,
            bridge,
            cpu_poll_interval,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"
[soc]
name = "mini"
width = 2
height = 1

[[island]]
name = "noc"
freq_mhz = 100
min_mhz = 10
max_mhz = 100
dfs = true

[[island]]
name = "acc"
freq_mhz = 50
min_mhz = 10
max_mhz = 50

[[tile]]
kind = "mem"
pos = [0, 0]
island = 0

[[tile]]
kind = "accel"
accel = "dfmul"
replicas = 2
pos = [1, 0]
island = 1
"#;

    #[test]
    fn parses_minimal_config() {
        let cfg = SocConfig::from_toml(MINI).unwrap();
        assert_eq!(cfg.name, "mini");
        assert_eq!(cfg.tiles.len(), 2);
        assert_eq!(
            cfg.tiles[1].kind,
            TileKind::Accel {
                accel: "dfmul".into(),
                replicas: 2
            }
        );
        assert!(cfg.islands[0].dfs);
        assert_eq!(cfg.mem_tile().x, 0);
    }

    #[test]
    fn rejects_wrong_tile_count() {
        let bad = MINI.replace("width = 2", "width = 3");
        assert!(SocConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_accel() {
        let bad = MINI.replace("accel = \"dfmul\"", "accel = \"nope\"");
        assert!(SocConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_bad_island_reference() {
        let bad = MINI.replace("island = 1", "island = 7");
        assert!(SocConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_duplicate_position() {
        let bad = MINI.replace("pos = [1, 0]", "pos = [0, 0]");
        assert!(SocConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_two_mem_tiles() {
        let bad = MINI.replace("kind = \"accel\"", "kind = \"mem\"");
        assert!(SocConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_initial_freq_outside_range() {
        let bad = MINI.replace("freq_mhz = 50", "freq_mhz = 80");
        assert!(SocConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_zero_replicas() {
        let bad = MINI.replace("replicas = 2", "replicas = 0");
        assert!(SocConfig::from_toml(&bad).is_err());
    }
}
