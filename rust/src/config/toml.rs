//! A TOML-subset parser (offline stand-in for `toml` + `serde`).
//!
//! Supports the constructs the SoC configuration files use:
//!
//! * `[section]` tables and `[[section]]` arrays-of-tables,
//! * `key = value` with strings (`"..."`), integers, floats, booleans,
//!   and homogeneous arrays of those,
//! * `#` comments and blank lines.
//!
//! Unsupported TOML (dotted keys, inline tables, multi-line strings,
//! datetimes) is rejected with a line-numbered error.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// One table of key/value pairs.
pub type Table = BTreeMap<String, Value>;

/// Parse result: singleton tables and arrays-of-tables.
#[derive(Debug, Clone, Default)]
pub struct Document {
    pub tables: BTreeMap<String, Table>,
    pub arrays: BTreeMap<String, Vec<Table>>,
}

impl Document {
    /// Singleton table by name (empty table if absent).
    pub fn table(&self, name: &str) -> Table {
        self.tables.get(name).cloned().unwrap_or_default()
    }

    /// Array-of-tables by name (empty if absent).
    pub fn array(&self, name: &str) -> Vec<Table> {
        self.arrays.get(name).cloned().unwrap_or_default()
    }
}

/// Typed getters over a [`Table`] with good error messages.
pub struct View<'a> {
    pub table: &'a Table,
    pub ctx: String,
}

impl<'a> View<'a> {
    pub fn new(table: &'a Table, ctx: impl Into<String>) -> Self {
        Self {
            table,
            ctx: ctx.into(),
        }
    }

    fn want(&self, key: &str) -> crate::Result<&Value> {
        self.table
            .get(key)
            .with_context(|| format!("{}: missing key {key:?}", self.ctx))
    }

    pub fn str(&self, key: &str) -> crate::Result<String> {
        Ok(self
            .want(key)?
            .as_str()
            .with_context(|| format!("{}: {key:?} must be a string", self.ctx))?
            .to_string())
    }

    pub fn str_or(&self, key: &str, default: &str) -> crate::Result<String> {
        match self.table.get(key) {
            None => Ok(default.to_string()),
            Some(v) => Ok(v
                .as_str()
                .with_context(|| format!("{}: {key:?} must be a string", self.ctx))?
                .to_string()),
        }
    }

    pub fn int(&self, key: &str) -> crate::Result<i64> {
        self.want(key)?
            .as_int()
            .with_context(|| format!("{}: {key:?} must be an integer", self.ctx))
    }

    pub fn int_or(&self, key: &str, default: i64) -> crate::Result<i64> {
        match self.table.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_int()
                .with_context(|| format!("{}: {key:?} must be an integer", self.ctx)),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> crate::Result<bool> {
        match self.table.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .with_context(|| format!("{}: {key:?} must be a boolean", self.ctx)),
        }
    }

    pub fn float_or(&self, key: &str, default: f64) -> crate::Result<f64> {
        match self.table.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_float()
                .with_context(|| format!("{}: {key:?} must be a number", self.ctx)),
        }
    }
}

/// Parse a document.
pub fn parse(text: &str) -> crate::Result<Document> {
    let mut doc = Document::default();
    // Current insertion target.
    enum Target {
        None,
        Table(String),
        Array(String),
    }
    let mut target = Target::None;

    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        let err = || format!("line {}: {raw:?}", ln + 1);
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = inner.trim().to_string();
            if name.is_empty() {
                bail!("{}: empty table name", err());
            }
            doc.arrays.entry(name.clone()).or_default().push(Table::new());
            target = Target::Array(name);
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = inner.trim().to_string();
            if name.is_empty() {
                bail!("{}: empty table name", err());
            }
            if doc.tables.contains_key(&name) {
                bail!("{}: duplicate table [{name}]", err());
            }
            doc.tables.insert(name.clone(), Table::new());
            target = Target::Table(name);
        } else {
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("{}: expected key = value", err()))?;
            let key = key.trim().to_string();
            if key.is_empty() {
                bail!("{}: empty key", err());
            }
            let value = parse_value(val.trim()).with_context(err)?;
            let table = match &target {
                Target::None => bail!("{}: key outside any [section]", err()),
                Target::Table(name) => doc.tables.get_mut(name).unwrap(),
                Target::Array(name) => doc.arrays.get_mut(name).unwrap().last_mut().unwrap(),
            };
            if table.insert(key.clone(), value).is_some() {
                bail!("{}: duplicate key {key:?}", err());
            }
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> crate::Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .context("unterminated string")?;
        if inner.contains('"') {
            bail!("embedded quotes not supported");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = split_array(inner)?
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<crate::Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

/// Split a flat array body on commas (no nested arrays in our subset).
fn split_array(s: &str) -> crate::Result<Vec<&str>> {
    if s.contains('[') {
        bail!("nested arrays not supported");
    }
    Ok(s.split(',').collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# SoC description
[soc]
name = "vespa4x4"   # inline comment
width = 4
seed = 0xxx_invalid_is_not_here
"#
            .replace("seed = 0xxx_invalid_is_not_here", "seed = 1_000")
            .as_str(),
        )
        .unwrap();
        let t = doc.table("soc");
        assert_eq!(t["name"], Value::Str("vespa4x4".into()));
        assert_eq!(t["width"], Value::Int(4));
        assert_eq!(t["seed"], Value::Int(1000));
    }

    #[test]
    fn arrays_of_tables() {
        let doc = parse(
            r#"
[[tile]]
kind = "cpu"
pos = [0, 0]
[[tile]]
kind = "mem"
pos = [3, 0]
"#,
        )
        .unwrap();
        let tiles = doc.array("tile");
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[1]["kind"], Value::Str("mem".into()));
        let pos = tiles[1]["pos"].as_array().unwrap();
        assert_eq!(pos[0].as_int(), Some(3));
    }

    #[test]
    fn value_types() {
        let doc = parse("[x]\na = true\nb = 1.5\nc = [\"p\", \"q\"]\n").unwrap();
        let t = doc.table("x");
        assert_eq!(t["a"].as_bool(), Some(true));
        assert_eq!(t["b"].as_float(), Some(1.5));
        assert_eq!(t["c"].as_array().unwrap()[1].as_str(), Some("q"));
    }

    #[test]
    fn rejects_key_outside_section() {
        assert!(parse("a = 1\n").is_err());
    }

    #[test]
    fn rejects_duplicate_key() {
        assert!(parse("[x]\na = 1\na = 2\n").is_err());
    }

    #[test]
    fn rejects_duplicate_table() {
        assert!(parse("[x]\n[x]\n").is_err());
    }

    #[test]
    fn rejects_garbage_value() {
        assert!(parse("[x]\na = @@\n").is_err());
        assert!(parse("[x]\na = \"unterminated\n").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse("[x]\na = \"b#c\"\n").unwrap();
        assert_eq!(doc.table("x")["a"].as_str(), Some("b#c"));
    }

    #[test]
    fn view_typed_getters() {
        let doc = parse("[x]\nn = 3\ns = \"hi\"\n").unwrap();
        let t = doc.table("x");
        let v = View::new(&t, "[x]");
        assert_eq!(v.int("n").unwrap(), 3);
        assert_eq!(v.str("s").unwrap(), "hi");
        assert_eq!(v.int_or("missing", 7).unwrap(), 7);
        assert!(v.int("s").is_err());
        assert!(v.str("missing").is_err());
    }
}
