//! SoC configuration: the design-time description a Vespa user writes —
//! grid size, tile placement, accelerator replication factors (the MRA
//! design parameter), frequency islands and their DFS ranges — plus the
//! loader for the on-disk TOML format and the paper's preset instance.

pub mod presets;
pub mod soc;
pub mod toml;

pub use presets::paper_soc;
pub use soc::{BridgeCfg, IslandSpec, NocParams, SocConfig, TileKind, TileSpec};
