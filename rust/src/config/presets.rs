//! The paper's experimental SoC instance (§III), built programmatically.
//!
//! A 4-by-4 grid with a CVA6 CPU tile, a DDR MEM tile, an auxiliary I/O
//! tile, eleven TG tiles (dfadd-like memory-bound requesters) and two
//! accelerator tiles: A1 close to MEM, A2 far from it. Five frequency
//! islands: NoC+MEM (DFS 10-100 MHz), A1, A2, TG, CPU+I/O (each DFS
//! 10-50 MHz), all on a 5 MHz step grid.

use super::soc::{BridgeCfg, IslandSpec, NocParams, SocConfig, TileKind, TileSpec};
use crate::mem::MemParams;
use crate::tiles::DmaParams;

/// Island indices of the paper preset.
pub const ISL_NOC: usize = 0;
pub const ISL_A1: usize = 1;
pub const ISL_A2: usize = 2;
pub const ISL_TG: usize = 3;
pub const ISL_CPU: usize = 4;

/// Grid positions of the named tiles.
pub const MEM_POS: (u16, u16) = (0, 0);
pub const CPU_POS: (u16, u16) = (1, 0);
pub const IO_POS: (u16, u16) = (2, 0);
/// A1 is adjacent to MEM (1 hop).
pub const A1_POS: (u16, u16) = (0, 1);
/// A2 is the far corner (6 hops).
pub const A2_POS: (u16, u16) = (3, 3);

/// Build the paper's 4x4 SoC with the given accelerators in A1 and A2.
///
/// `a1`/`a2` are (accelerator name, replication factor). The eleven
/// remaining tiles become TGs.
pub fn paper_soc(a1: (&str, usize), a2: (&str, usize)) -> SocConfig {
    let islands = vec![
        IslandSpec {
            name: "noc-mem".into(),
            freq_mhz: 100,
            dfs: true,
            min_mhz: 10,
            max_mhz: 100,
            step_mhz: 5,
        },
        IslandSpec {
            name: "a1".into(),
            freq_mhz: 50,
            dfs: true,
            min_mhz: 10,
            max_mhz: 50,
            step_mhz: 5,
        },
        IslandSpec {
            name: "a2".into(),
            freq_mhz: 50,
            dfs: true,
            min_mhz: 10,
            max_mhz: 50,
            step_mhz: 5,
        },
        IslandSpec {
            name: "tg".into(),
            freq_mhz: 50,
            dfs: true,
            min_mhz: 10,
            max_mhz: 50,
            step_mhz: 5,
        },
        IslandSpec {
            name: "cpu-io".into(),
            freq_mhz: 50,
            dfs: true,
            min_mhz: 10,
            max_mhz: 50,
            step_mhz: 5,
        },
    ];

    let mut tiles = Vec::new();
    for y in 0..4u16 {
        for x in 0..4u16 {
            let (kind, island) = if (x, y) == MEM_POS {
                (TileKind::Mem, ISL_NOC)
            } else if (x, y) == CPU_POS {
                (TileKind::Cpu, ISL_CPU)
            } else if (x, y) == IO_POS {
                (TileKind::Io, ISL_CPU)
            } else if (x, y) == A1_POS {
                (
                    TileKind::Accel {
                        accel: a1.0.into(),
                        replicas: a1.1,
                    },
                    ISL_A1,
                )
            } else if (x, y) == A2_POS {
                (
                    TileKind::Accel {
                        accel: a2.0.into(),
                        replicas: a2.1,
                    },
                    ISL_A2,
                )
            } else {
                (TileKind::Tg, ISL_TG)
            };
            tiles.push(TileSpec { x, y, kind, island });
        }
    }

    SocConfig {
        name: format!("paper-4x4-{}x{}-{}x{}", a1.0, a1.1, a2.0, a2.1),
        width: 4,
        height: 4,
        seed: 0xE5B,
        tiles,
        islands,
        noc: NocParams::default(),
        mem: MemParams::default(),
        dma: DmaParams::default(),
        bridge: BridgeCfg::default(),
        cpu_poll_interval: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_soc_validates() {
        let cfg = paper_soc(("dfsin", 1), ("gsm", 1));
        cfg.validate().unwrap();
        assert_eq!(cfg.tiles.len(), 16);
        assert_eq!(cfg.islands.len(), 5);
    }

    #[test]
    fn eleven_tgs() {
        let cfg = paper_soc(("adpcm", 4), ("dfmul", 4));
        let tgs = cfg.tiles_where(|k| *k == TileKind::Tg);
        assert_eq!(tgs.len(), 11);
    }

    #[test]
    fn a1_near_a2_far() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mesh = crate::noc::Mesh::new(4, 4);
        let mem = mesh.node(MEM_POS.0, MEM_POS.1);
        let a1 = mesh.node(A1_POS.0, A1_POS.1);
        let a2 = mesh.node(A2_POS.0, A2_POS.1);
        assert_eq!(mesh.hops(mem, a1), 1);
        assert!(mesh.hops(mem, a2) >= 5);
        drop(cfg);
    }
}
