//! The paper's experimental SoC instance (§III), as a thin preset over
//! the [`crate::scenario::Scenario`] builder.
//!
//! A 4-by-4 grid with a CVA6 CPU tile, a DDR MEM tile, an auxiliary I/O
//! tile, eleven TG tiles (dfadd-like memory-bound requesters) and two
//! accelerator tiles: A1 close to MEM, A2 far from it. Five frequency
//! islands: NoC+MEM (DFS 10-100 MHz), A1, A2, TG, CPU+I/O (each DFS
//! 10-50 MHz), all on a 5 MHz step grid.
//!
//! New code exploring *other* floorplans should use the builder
//! directly — `Scenario::grid(w, h)…` composes any grid, island set, and
//! placement; this module only pins down the paper's instance.

use super::soc::SocConfig;
use crate::scenario::Scenario;

/// Island indices of the paper preset.
pub const ISL_NOC: usize = 0;
pub const ISL_A1: usize = 1;
pub const ISL_A2: usize = 2;
pub const ISL_TG: usize = 3;
pub const ISL_CPU: usize = 4;

/// Grid positions of the named tiles.
pub const MEM_POS: (u16, u16) = (0, 0);
pub const CPU_POS: (u16, u16) = (1, 0);
pub const IO_POS: (u16, u16) = (2, 0);
/// A1 is adjacent to MEM (1 hop).
pub const A1_POS: (u16, u16) = (0, 1);
/// A2 is the far corner (6 hops).
pub const A2_POS: (u16, u16) = (3, 3);

/// Build the paper's 4x4 SoC with the given accelerators in A1 and A2.
///
/// `a1`/`a2` are (accelerator name, replication factor). The eleven
/// remaining tiles become TGs.
///
/// Panics on structurally impossible inputs (unknown accelerator name,
/// zero/overlarge replication): the preset's geometry itself is always
/// valid, so failures can only come from these two arguments. Callers
/// taking user-supplied names should pre-validate with
/// [`crate::tiles::AccelTiming::lookup`].
pub fn paper_soc(a1: (&str, usize), a2: (&str, usize)) -> SocConfig {
    Scenario::grid(4, 4)
        .name(format!(
            "paper-4x4-{}x{}-{}x{}",
            a1.0, a1.1, a2.0, a2.1
        ))
        .seed(0xE5B)
        .island_dfs("noc-mem", 100, 10..=100, 5)
        .island_dfs("a1", 50, 10..=50, 5)
        .island_dfs("a2", 50, 10..=50, 5)
        .island_dfs("tg", 50, 10..=50, 5)
        .island_dfs("cpu-io", 50, 10..=50, 5)
        .noc_island("noc-mem")
        .mem_at(MEM_POS.0, MEM_POS.1)
        .cpu_at_on(CPU_POS.0, CPU_POS.1, "cpu-io")
        .io_at_on(IO_POS.0, IO_POS.1, "cpu-io")
        .accel_at(A1_POS.0, A1_POS.1, a1.0, a1.1, "a1")
        .accel_at(A2_POS.0, A2_POS.1, a2.0, a2.1, "a2")
        .fill_tg("tg")
        .build()
        .expect("paper preset with valid accelerators")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TileKind;

    #[test]
    fn paper_soc_validates() {
        let cfg = paper_soc(("dfsin", 1), ("gsm", 1));
        cfg.validate().unwrap();
        assert_eq!(cfg.tiles.len(), 16);
        assert_eq!(cfg.islands.len(), 5);
    }

    #[test]
    fn eleven_tgs() {
        let cfg = paper_soc(("adpcm", 4), ("dfmul", 4));
        let tgs = cfg.tiles_where(|k| *k == TileKind::Tg);
        assert_eq!(tgs.len(), 11);
    }

    #[test]
    fn island_indices_match_the_named_constants() {
        // The builder assigns island indices in declaration order; the
        // ISL_* constants (used by experiments to reprogram frequencies)
        // must agree with it.
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        assert_eq!(cfg.islands[ISL_NOC].name, "noc-mem");
        assert_eq!(cfg.islands[ISL_A1].name, "a1");
        assert_eq!(cfg.islands[ISL_A2].name, "a2");
        assert_eq!(cfg.islands[ISL_TG].name, "tg");
        assert_eq!(cfg.islands[ISL_CPU].name, "cpu-io");
        assert_eq!(cfg.noc.island, ISL_NOC);
        let a1 = &cfg.tiles[cfg.node_of(A1_POS.0, A1_POS.1)];
        assert_eq!(a1.island, ISL_A1);
        let cpu = &cfg.tiles[cfg.node_of(CPU_POS.0, CPU_POS.1)];
        assert_eq!(cpu.kind, TileKind::Cpu);
        assert_eq!(cpu.island, ISL_CPU);
        let mem = cfg.mem_tile();
        assert_eq!((mem.x, mem.y), MEM_POS);
        assert_eq!(mem.island, ISL_NOC);
    }

    #[test]
    fn a1_near_a2_far() {
        let cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        let mesh = crate::noc::Mesh::new(4, 4);
        let mem = mesh.node(MEM_POS.0, MEM_POS.1);
        let a1 = mesh.node(A1_POS.0, A1_POS.1);
        let a2 = mesh.node(A2_POS.0, A2_POS.1);
        assert_eq!(mesh.hops(mem, a1), 1);
        assert!(mesh.hops(mem, a2) >= 5);
        drop(cfg);
    }
}
